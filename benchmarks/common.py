"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 3, **kw):
    """Returns (result, us_per_call)."""
    fn(*args, **kw)                    # warmup / trace
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
