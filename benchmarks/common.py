"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import os
import time


def timed(fn, *args, repeat: int = 3, **kw):
    """Returns (result, us_per_call)."""
    fn(*args, **kw)                    # warmup / trace
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def subproc_env(sentinel: str | None = None) -> dict:
    """Environment for a benchmark subprocess: PYTHONPATH includes
    `src` (the drivers import `repro.*` from the source tree), and
    `sentinel`, when given, marks the child as already re-executed so
    the device-count re-exec guards cannot loop."""
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH")) if p))
    if sentinel:
        env[sentinel] = "1"
    return env


#: `benchmarks.run --regress` fails a driver whose re-measured
#: throughput drops below this fraction of its committed baseline.
REGRESS_THRESHOLD = 0.7


def regress_gate(name: str, measured: float, baseline: float,
                 threshold: float = REGRESS_THRESHOLD) -> list:
    """One benchmark-regression check: `measured` (higher is better)
    must reach `threshold` x the committed `baseline`. Prints the
    comparison; returns [] on pass or a one-line failure message."""
    ok = measured >= threshold * baseline
    print(f"regress,{name},measured={measured:.1f},"
          f"baseline={baseline:.1f},floor={threshold * baseline:.1f},"
          f"{'ok' if ok else 'FAIL'}", flush=True)
    if ok:
        return []
    return [f"{name}: measured {measured:.1f} < "
            f"{threshold:.0%} of baseline {baseline:.1f}"]
