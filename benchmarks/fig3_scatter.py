"""Fig 3: Compare8 x Compare12 scatter — the separation that justifies
the 0.72 threshold. Emits per-class score statistics (the figure's
content as numbers)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.criticality import COMPARE8_THRESHOLD, score
from repro.sim.telemetry import generate_population


def run(n_vms: int = 840, seed: int = 0):
    pop = generate_population(n_vms, seed=seed)
    sc, us = timed(lambda: score(jnp.asarray(pop.series)))
    c8 = np.asarray(sc.compare8)
    c12 = np.asarray(sc.compare12)
    klass = pop.classes()
    groups = {"clearly_user_facing": klass == "uf_diurnal",
              "possibly_user_facing": klass == "uf_noisy",
              "machine_generated": klass == "machine_periodic",
              "clearly_non_user_facing": np.isin(
                  klass, ["batch_flat", "batch_random", "dev_burst"])}
    for name, m in groups.items():
        left = (c8[m] < COMPARE8_THRESHOLD).mean()
        emit(f"fig3/{name}", us,
             f"n={m.sum()} c8_median={np.median(c8[m]):.3f} "
             f"c12_median={np.median(c12[m]):.3f} "
             f"left_of_bar={left:.2f}")
    uf = pop.labels
    emit("fig3/separation", us,
         f"bar@{COMPARE8_THRESHOLD}: UF left of bar "
         f"{(c8[uf] < COMPARE8_THRESHOLD).mean():.3f} (paper: all "
         f"important workloads left of the bar), non-UF right "
         f"{(c8[~uf] >= COMPARE8_THRESHOLD).mean():.3f}")
    return c8, c12


if __name__ == "__main__":
    run()
