"""Figs 4 & 5: single-server capping dynamics + performance impact of
full-server (RAPL) vs per-VM capping at caps 250/240/230/220/210 W.

All caps of a mode run as ONE vmapped fleet-engine call (the cap grid
is the batch axis); each figure is a slice of the fleet run."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.sim.chassis_sim import paper_single_server_spec
from repro.sim.fleet import run_fleet

CAPS = (250, 240, 230, 220, 210)
PAPER_NOTE = {230: "paper: rapl +18% lat; per-VM ~0 lat, +28% runtime",
              210: "paper: per-VM can no longer protect (RAPL engages)"}


def run(duration_s: float = 600.0, seed: int = 3,
        backend: str = "jax"):
    spec = [paper_single_server_spec()]
    caps = np.asarray(CAPS, np.float64)
    fleet_nc, us = timed(lambda: run_fleet(
        spec, None, "none", duration_s, seed, backend=backend), repeat=1)
    nocap = fleet_nc.chassis(0)
    emit("fig4/no_cap", us,
         f"power_max={nocap.power_w.max():.0f}W "
         f"power_min={nocap.power_w.min():.0f}W")
    fleet_rr, us_r = timed(lambda: run_fleet(
        spec, caps, "rapl", duration_s, seed, backend=backend), repeat=1)
    fleet_rv, us_v = timed(lambda: run_fleet(
        spec, caps, "per_vm", duration_s, seed, backend=backend),
        repeat=1)
    rows = {}
    for i, cap in enumerate(CAPS):
        rr, rv = fleet_rr.chassis(i), fleet_rv.chassis(i)
        rows[cap] = (rr, rv)
        note = PAPER_NOTE.get(cap, "")
        emit(f"fig5/cap{cap}W", (us_r + us_v) / len(CAPS),
             f"rapl_lat=x{rr.uf_p95_latency / nocap.uf_p95_latency:.2f} "
             f"rapl_runtime=x{rr.nuf_slowdown:.2f} "
             f"pervm_lat=x{rv.uf_p95_latency / nocap.uf_p95_latency:.2f} "
             f"pervm_runtime=x{rv.nuf_slowdown:.2f} "
             f"pervm_rapl_backup={rv.rapl_engaged_frac:.2f} {note}")
    # Fig 4 dynamics summary: caps respected, controller sits below cap
    rr, rv = rows[230]
    emit("fig4/cap230W", us_r + us_v,
         f"rapl_power_max={rr.power_w[25:].max():.0f}W "
         f"pervm_power_max={rv.power_w[25:].max():.0f}W "
         f"pervm_min_nuf_freq={rv.min_nuf_freq.min():.2f}")
    return rows


if __name__ == "__main__":
    run()
