"""Fig 6: chassis-level dynamics — capping granularity x VM placement
(balanced vs imbalanced), 12 servers, 36 UF + 36 NUF VMs, 2450 W.

Each (placement, mode) cell is one compiled fleet-engine run; the
balanced and imbalanced chassis reuse the same compilation (identical
shapes, different layout values)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.sim.chassis_sim import paper_chassis_specs
from repro.sim.fleet import run_fleet

BUDGET = 2450.0


def run(duration_s: float = 600.0, seed: int = 4,
        backend: str = "jax"):
    out = {}
    for balanced in (True, False):
        specs = paper_chassis_specs(balanced)
        label = "balanced" if balanced else "imbalanced"
        fnc, us = timed(lambda s=specs: run_fleet(
            s, None, "none", duration_s, seed, backend=backend), repeat=1)
        nc = fnc.chassis(0)
        rv = run_fleet(specs, BUDGET, "per_vm", duration_s, seed,
                       backend=backend).chassis(0)
        rr = run_fleet(specs, BUDGET, "rapl", duration_s, seed,
                       backend=backend).chassis(0)
        out[label] = (nc, rv, rr)
        emit(f"fig6/{label}", us,
             f"pervm_lat=x{rv.uf_p95_latency / nc.uf_p95_latency:.2f} "
             f"pervm_runtime=x{rv.nuf_slowdown:.2f} "
             f"rapl_lat=x{rr.uf_p95_latency / nc.uf_p95_latency:.2f} "
             f"rapl_runtime=x{rr.nuf_slowdown:.2f} "
             f"nocap_max={nc.power_w.max():.0f}W")
    emit("fig6/summary", 0.0,
         "paper: balanced per-VM keeps UF at no-cap level; imbalanced "
         "per-VM degrades like full-server")
    return out


if __name__ == "__main__":
    run()
