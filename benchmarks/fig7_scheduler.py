"""Fig 7: cluster scheduler simulation — four metrics as a function of
alpha, for NoRule / ML predictions / oracle / criticality-only."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.sim.scheduler_sim import fig7_sweep


def run(days: float = 30.0, seed: int = 0,
        alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0)):
    out, us = timed(lambda: fig7_sweep(alphas=alphas, days=days,
                                       seed=seed), repeat=1)
    for key, m in out.items():
        emit(f"fig7/{key}", us / len(out),
             f"fail={m.failure_rate:.4f} empty={m.empty_server_ratio:.3f}"
             f" chassis_std={m.chassis_score_std:.4f}"
             f" server_std={m.server_score_std:.4f}")
    best = min((k for k in out if k.startswith("ml")),
               key=lambda k: out[k].chassis_score_std
               + out[k].server_score_std)
    emit("fig7/best_alpha", 0.0,
         f"{best} (paper: alpha=0.8 best compromise)")
    return out


if __name__ == "__main__":
    run()
