"""Fleet-engine throughput: numpy Python-loop oracle vs the
scan/vmap-compiled jax engine at 1 / 64 / 1024 chassis.

Metric: chassis-steps/second (one chassis-step = one 200 ms control
poll of a 12-blade chassis, 480 cores). The numpy baseline loops
chassis one at a time — the seed's execution model — so its rate is
per-chassis-constant; at large fleet sizes it is measured on a subset
and extrapolated (recorded in the JSON). Writes BENCH_fleet_engine.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit
from repro.sim.chassis_sim import paper_chassis_specs, simulate_chassis
from repro.sim.fleet import build_layout, run_fleet

OUT_PATH = "BENCH_fleet_engine.json"
CHASSIS_COUNTS = (1, 64, 1024)
NUMPY_MEASURE_CAP = 8          # loop at most this many chassis
BUDGET = 2450.0


def _time(fn, repeat: int = 3) -> float:
    """Best-of-`repeat` wall time (first call = warmup / jit compile)."""
    fn()
    best = np.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(duration_s: float = 30.0, seed: int = 0,
        out_path: str = OUT_PATH) -> dict:
    specs = paper_chassis_specs(balanced=True)
    layout = build_layout(specs)
    n_steps = int(duration_s / 0.2)
    results = []

    def numpy_loop(m):
        # the seed's execution model, literally: loop the one-chassis
        # numpy simulator (per-chassis setup + stepping + aggregation)
        for c in range(m):
            simulate_chassis(specs, BUDGET, "per_vm", duration_s,
                             seed + c, backend="numpy")

    for n in CHASSIS_COUNTS:
        budgets = np.full(n, BUDGET)
        seeds = seed + np.arange(n)
        m = min(n, NUMPY_MEASURE_CAP)
        t_np = _time(lambda: numpy_loop(m))
        np_sps = m * n_steps / t_np
        t_jax = _time(lambda: run_fleet(
            specs, budgets, "per_vm", duration_s, seeds,
            backend="jax", layout=layout))
        jax_sps = n * n_steps / t_jax
        row = {"n_chassis": n, "n_steps": n_steps,
               "numpy_steps_per_s": np_sps,
               "numpy_measured_chassis": m,
               "numpy_extrapolated": m < n,
               "jax_steps_per_s": jax_sps,
               "jax_wall_s": t_jax,
               "speedup": jax_sps / np_sps}
        results.append(row)
        emit(f"fleet_engine/{n}chassis", t_jax * 1e6,
             f"numpy={np_sps:.0f}sps jax={jax_sps:.0f}sps "
             f"speedup=x{row['speedup']:.1f}")
    out = {"duration_s": duration_s, "budget_w": BUDGET,
           "chassis": "12 blades x 40 cores, balanced 36UF+36NUF",
           "results": results}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-time the 64-chassis jax engine (the quick mid-size row) and
    fail on a >30% steps/s drop vs BENCH_fleet_engine.json."""
    from benchmarks.common import regress_gate
    want = next(r for r in baseline["results"] if r["n_chassis"] == 64)
    specs = paper_chassis_specs(balanced=True)
    layout = build_layout(specs)
    duration_s = baseline["duration_s"]
    n = 64
    t_jax = _time(lambda: run_fleet(
        specs, np.full(n, BUDGET), "per_vm", duration_s,
        np.arange(n), backend="jax", layout=layout))
    measured = n * int(duration_s / 0.2) / t_jax
    return regress_gate("fleet_engine/64chassis/jax_steps_per_s",
                        measured, want["jax_steps_per_s"])


if __name__ == "__main__":
    import sys

    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            sys.exit(1 if regress(json.load(f)) else 0)
    run()
