"""Tiled oblivious-forest kernel timings (DESIGN.md §13).

Times the 2-D `(batch, trees)` Pallas grid across tile shapes against
the plain-jnp reference formulation on the same packed operands, and
records the fallback ratio `repro.serve.inference.resolve_kernel`
acts on. Off TPU the kernel runs in interpret mode (the grid is
emulated program by program) — slower than XLA's fused dense math,
though a well-tiled grid stays within a small factor at batch scale,
which is exactly why the routing is measured rather than assumed. The
artifact commits (a) parity at every tile shape asserted under a
clock, (b) the measured interpret/ref ratio behind the serving path's
fallback, and (c) the tiled kernel's throughput at the committed best
tile shape behind the regression gate.

Writes BENCH_forest_kernel.json. ``--smoke`` runs one small forest
(CI); ``--regress`` re-measures the committed best tile shape against
the baseline (the plain-jnp reference is re-measured and printed but
not gated — its sub-millisecond wall is bimodal across fresh
interpreters on small CI boxes, and the serving pipeline it powers is
already gated end-to-end by ``benchmarks.serve_online``).
"""
from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, regress_gate
from repro.core.forest import train_random_forest
from repro.kernels.forest.forest import (forest_predict_pallas,
                                         resolve_block_t)
from repro.kernels.forest.ops import pack_forest
from repro.kernels.forest.ref import forest_predict_ref

OUT_PATH = "BENCH_forest_kernel.json"

N_TREES, DEPTH, N_CLASSES, N_FEATURES = 24, 4, 4, 16
#: large enough that the ref wall is work-dominated, not dispatch-
#: dominated — per-process dispatch overhead varies ~2x on small CI
#: boxes and would otherwise flap the regression gate
BATCH = 4096
#: (block_b, block_t) sweep — grid shapes from (1, 1) to (32, 12)
TILES = ((4096, None), (512, None), (512, 8), (128, 8), (128, 2))
SMOKE_TILES = ((128, None), (128, 4))


def _best_of(fn, repeat: int = 7):
    """(result, us_per_call) by best-of — interpret-mode walls are
    one-sided noisy (GC + per-program dispatch), so the min is the
    stable statistic, same as the serving drivers' regress probes."""
    import time

    out = fn()                          # warmup / trace
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def _operands(smoke: bool, seed: int = 0):
    t, b = (12, 128) if smoke else (N_TREES, BATCH)
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (600, N_FEATURES)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, 600)
    y[x[:, 0] > 0.3] = 0
    f = train_random_forest(x, y, N_CLASSES, n_trees=t, depth=DEPTH,
                            seed=seed)
    xq = jnp.asarray(rng.normal(0, 1, (b, N_FEATURES)).astype(np.float32))
    return f, xq


def _ref_fn(f):
    fi = jnp.asarray(f.feat_idx)
    th = jnp.asarray(f.thresholds)
    lv = jnp.asarray(f.leaf_values)
    return jax.jit(lambda x: forest_predict_ref(x, fi, th, lv, f.kind))


def _tiled_fn(packed, n_trees, block_b, block_t):
    gather, thr, leaf, _t, d, _kind = packed
    return jax.jit(lambda x: forest_predict_pallas(
        x, gather, thr, leaf, n_trees, d, block_b=block_b,
        block_t=block_t, interpret=jax.default_backend() != "tpu"))


def _time_tiles(f, xq, tiles) -> list:
    """One row per tile shape; parity vs the reference is asserted
    under the same clock that times the kernel."""
    packed = pack_forest(f)
    t = packed[3]
    b = xq.shape[0]
    p_ref = np.asarray(_ref_fn(f)(xq))
    rows = []
    for block_b, block_t in tiles:
        bb = min(block_b, b)
        pad = (-b) % bb
        xp = jnp.concatenate(
            [xq, jnp.zeros((pad, xq.shape[1]), xq.dtype)], 0) \
            if pad else xq
        fn = _tiled_fn(packed, t, bb, block_t)
        summed, us = _best_of(
            lambda fn=fn, xp=xp: np.asarray(
                jax.block_until_ready(fn(xp))))
        np.testing.assert_allclose(summed[:b] / t, p_ref, atol=1e-5)
        bt = resolve_block_t(t, block_t)
        row = {"block_b": bb, "block_t": bt,
               "grid": [xp.shape[0] // bb, t // bt],
               "us_per_call": us, "rows_per_s": b / (us * 1e-6)}
        rows.append(row)
        emit(f"forest_kernel/tiled/b{bb}xt{bt}", us,
             f"grid={row['grid']} rows_per_s={row['rows_per_s']:.0f}")
    return rows


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    from repro.serve.inference import resolve_kernel
    f, xq = _operands(smoke)
    b = xq.shape[0]
    _, us_ref = _best_of(
        lambda fn=_ref_fn(f): np.asarray(jax.block_until_ready(fn(xq))))
    emit("forest_kernel/ref", us_ref,
         f"rows_per_s={b / (us_ref * 1e-6):.0f}")
    rows = _time_tiles(f, xq, SMOKE_TILES if smoke else TILES)
    best = min(rows, key=lambda r: r["us_per_call"])
    out = {"n_trees": f.n_trees, "depth": DEPTH, "batch": b,
           "backend": jax.default_backend(),
           "ref": {"us_per_call": us_ref,
                   "rows_per_s": b / (us_ref * 1e-6)},
           "tiled": rows,
           "best_tile": [best["block_b"], best["block_t"]],
           "interpret_over_ref": best["us_per_call"] / us_ref,
           "resolve_kernel_auto": resolve_kernel("auto")}
    emit("forest_kernel/fallback", 0.0,
         f"auto={out['resolve_kernel_auto']} "
         f"interpret_over_ref={out['interpret_over_ref']:.1f}x")
    if not smoke:
        with open(out_path, "w") as fh:
            json.dump(out, fh, indent=2)
    return out


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the committed best tile shape and fail on a >30%
    rows/s drop vs BENCH_forest_kernel.json. The reference path is
    printed for context but not gated (see module docstring)."""
    f, xq = _operands(smoke=False)
    b = xq.shape[0]
    _, us_ref = _best_of(
        lambda fn=_ref_fn(f): np.asarray(jax.block_until_ready(fn(xq))))
    emit("forest_kernel/ref", us_ref,
         f"rows_per_s={b / (us_ref * 1e-6):.0f} (not gated)")
    bb, bt = baseline["best_tile"]
    failures = []
    want = next(r for r in baseline["tiled"]
                if [r["block_b"], r["block_t"]] == [bb, bt])
    rows = _time_tiles(f, xq, ((bb, bt),))
    failures += regress_gate(f"forest_kernel/tiled/b{bb}xt{bt}/rows_per_s",
                             rows[0]["rows_per_s"], want["rows_per_s"])
    return failures


if __name__ == "__main__":
    if "--regress" in sys.argv:
        with open(OUT_PATH) as fh:
            sys.exit(1 if regress(json.load(fh)) else 0)
    run(smoke="--smoke" in sys.argv)
