"""Roofline report (deliverable g): per (arch x shape x mesh) terms from
the dry-run artifacts. Single-pod (256 chips) is the roofline table per
the assignment; multi-pod artifacts prove the pod axis shards."""
from __future__ import annotations

import os

from benchmarks.common import emit
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS
from repro.launch.dryrun import ARTIFACT_DIR
from repro.launch.roofline import load_artifacts, roofline_row


def run(artifact_dir: str = None, multi_pod: bool = False):
    art = artifact_dir or ARTIFACT_DIR
    if not os.path.isdir(art):
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun` first")
        return []
    rows = []
    chips = 512 if multi_pod else 256
    want_pod = multi_pod
    for rec in load_artifacts(art):
        if rec.get("multi_pod") != want_pod:
            continue
        if rec["status"] != "ok":
            if rec["status"] == "skipped":
                emit(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                     f"SKIP {rec['reason'][:60]}")
            continue
        cfg = ARCHS[rec["arch"]]
        shape = SHAPES[rec["shape"]]
        # prefer trip-corrected collective bytes recorded by the dry-run
        coll = rec.get("collectives_trip_corrected",
                       {}).get("total") or \
            rec["collectives"]["total_bytes"]
        rec2 = dict(rec)
        rec2["collectives"] = {"total_bytes": coll}
        row = roofline_row(rec2, cfg, shape, chips=chips)
        rows.append(row)
        emit(f"roofline/{rec['arch']}/{rec['shape']}",
             row["t_compute_s"] * 1e6,
             f"t_comp={row['t_compute_s']:.4f}s "
             f"t_mem={row['t_memory_s']:.4f}s "
             f"t_coll={row['t_collective_s']:.4f}s "
             f"dom={row['dominant']} "
             f"roofline={row['roofline_overlapped']:.2f} "
             f"useful={row['useful_ratio']:.2f} "
             f"mem/dev={(rec['memory']['argument_bytes'] or 0 + (rec['memory']['temp_bytes'] or 0)) / 2**30:.1f}GiB")
    return rows


if __name__ == "__main__":
    run()
