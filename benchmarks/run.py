"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # all
  PYTHONPATH=src python -m benchmarks.run table4 fig7 # subset

Each driver row pins the JSON artifact it writes (None = stdout only),
so callers and CI can locate outputs without running anything.
"""
from __future__ import annotations

import sys

#: (name, import path, JSON output path or None) — run order.
DRIVERS = (
    ("table2", "benchmarks.table2_criticality", None),
    ("fig3", "benchmarks.fig3_scatter", None),
    ("table3", "benchmarks.table3_models", None),
    ("fig4_fig5", "benchmarks.fig4_5_server_capping", None),
    ("fig6", "benchmarks.fig6_chassis", None),
    ("fig7", "benchmarks.fig7_scheduler", None),
    ("table4", "benchmarks.table4_oversubscription", None),
    ("fleet", "benchmarks.fleet_engine", "BENCH_fleet_engine.json"),
    ("serve", "benchmarks.serve_online", "BENCH_serve.json"),
    ("roofline", "benchmarks.roofline_report", None),
)


def main() -> None:
    want = set(sys.argv[1:])

    def on(name):
        return not want or any(w in name for w in want)

    print("name,us_per_call,derived")
    for name, module, out in DRIVERS:
        if on(name):
            run = __import__(module, fromlist=["run"]).run
            run(out_path=out) if out else run()


if __name__ == '__main__':
    main()
