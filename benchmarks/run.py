"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # all
  PYTHONPATH=src python -m benchmarks.run table4 fig7 # subset
"""
from __future__ import annotations

import sys


def main() -> None:
    want = set(sys.argv[1:])

    def on(name):
        return not want or any(w in name for w in want)

    print("name,us_per_call,derived")
    if on("table2"):
        from benchmarks.table2_criticality import run
        run()
    if on("fig3"):
        from benchmarks.fig3_scatter import run
        run()
    if on("table3"):
        from benchmarks.table3_models import run
        run()
    if on("fig4") or on("fig5"):
        from benchmarks.fig4_5_server_capping import run
        run()
    if on("fig6"):
        from benchmarks.fig6_chassis import run
        run()
    if on("fig7"):
        from benchmarks.fig7_scheduler import run
        run()
    if on("table4"):
        from benchmarks.table4_oversubscription import run
        run()
    if on("fleet"):
        from benchmarks.fleet_engine import run
        run()
    if on("roofline"):
        from benchmarks.roofline_report import run
        run()


if __name__ == '__main__':
    main()
