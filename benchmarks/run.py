"""Benchmark harness: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run             # all
  PYTHONPATH=src python -m benchmarks.run table4 fig7 # subset
  PYTHONPATH=src python -m benchmarks.run --check     # artifacts only
  PYTHONPATH=src python -m benchmarks.run --regress   # CI perf gate

Each driver row pins the JSON artifact it writes (None = stdout only),
so callers and CI can locate outputs without running anything. A
driver that declares an artifact must actually produce it — asserted
after every run, and checkable without running via ``--check``.

``--regress`` is the benchmark-regression gate: every artifact driver
exposes a ``--regress`` probe that re-measures a quick representative
configuration and fails (exit 1) if its throughput drops more than
30% below the committed BENCH_*.json baseline
(`benchmarks.common.REGRESS_THRESHOLD`). Each probe runs in a fresh
interpreter — the probes are noise-sensitive on small CI boxes, and a
parent process full of jitted executables and training state taxes
them measurably.
"""
from __future__ import annotations

import os
import subprocess
import sys

#: (name, import path, JSON output path or None) — run order.
DRIVERS = (
    ("table2", "benchmarks.table2_criticality", None),
    ("fig3", "benchmarks.fig3_scatter", None),
    ("table3", "benchmarks.table3_models", None),
    ("fig4_fig5", "benchmarks.fig4_5_server_capping", None),
    ("fig6", "benchmarks.fig6_chassis", None),
    ("fig7", "benchmarks.fig7_scheduler", None),
    ("table4", "benchmarks.table4_oversubscription", None),
    ("fleet", "benchmarks.fleet_engine", "BENCH_fleet_engine.json"),
    ("serve", "benchmarks.serve_online", "BENCH_serve.json"),
    ("serve_sharded", "benchmarks.serve_sharded",
     "BENCH_serve_sharded.json"),
    ("serve_ingest", "benchmarks.serve_ingest",
     "BENCH_serve_ingest.json"),
    ("serve_emergency", "benchmarks.serve_emergency",
     "BENCH_serve_emergency.json"),
    ("serve_obs", "benchmarks.serve_obs", "BENCH_serve_obs.json"),
    ("serve_quality", "benchmarks.serve_quality",
     "BENCH_serve_quality.json"),
    ("serve_adaptive", "benchmarks.serve_adaptive",
     "BENCH_serve_adaptive.json"),
    ("serve_resources", "benchmarks.serve_resources",
     "BENCH_serve_resources.json"),
    ("forest_kernel", "benchmarks.forest_kernel",
     "BENCH_forest_kernel.json"),
    ("roofline", "benchmarks.roofline_report", None),
)


def check_artifacts(ran: set | None = None) -> list:
    """Assert every BENCH_*.json the driver table lists exists on disk
    (all of them, or just the drivers in `ran`). Returns the paths."""
    missing = [out for name, _, out in DRIVERS
               if out and (ran is None or name in ran)
               and not os.path.exists(out)]
    assert not missing, f"driver table lists missing artifacts: {missing}"
    return [out for _, _, out in DRIVERS if out]


def regress() -> int:
    """Run every artifact driver's ``--regress`` probe against its
    committed baseline (see module docstring), one fresh interpreter
    each. Returns the number of failed gates."""
    from benchmarks.common import subproc_env
    check_artifacts()
    failed = []
    for name, module, out in DRIVERS:
        if not out:
            continue
        rc = subprocess.run(
            [sys.executable, "-m", module, "--regress"],
            env=subproc_env()).returncode
        print(f"regress,{name},{'ok' if rc == 0 else 'FAIL'}",
              flush=True)
        if rc:
            failed.append(name)
    for name in failed:
        print(f"REGRESS FAIL: {name}", file=sys.stderr)
    return len(failed)


def main() -> None:
    args = set(sys.argv[1:])
    if "--check" in args:
        for p in check_artifacts():
            print(f"artifact,{p},ok")
        return
    if "--regress" in args:
        sys.exit(1 if regress() else 0)
    want = args
    names = {name for name, _, _ in DRIVERS}

    def on(name):
        # exact driver names select only themselves ('serve' must not
        # drag in 'serve_sharded'); non-name tokens keep substring
        # matching ('fig4' -> fig4_fig5)
        if not want:
            return True
        return name in want or any(w in name and w not in names
                                   for w in want)

    print("name,us_per_call,derived")
    ran = set()
    for name, module, out in DRIVERS:
        if on(name):
            run = __import__(module, fromlist=["run"]).run
            run(out_path=out) if out else run()
            ran.add(name)
    check_artifacts(ran)


if __name__ == '__main__':
    main()
