"""Closed-loop adaptive oversubscription benchmark (DESIGN.md §15).

Two axes, one artifact (``BENCH_serve_adaptive.json``):

1. **Table-4-style ratio sweep** — `sim.scheduler_sim.simulate`
   (serve backend, emergency plane live) runs the same diurnal
   arrival trace under each fixed oversubscription ratio in
   ``FIXED_RATIOS`` (the ratio scales the admission watt budget's
   dynamic span, exactly what `serve.adaptive` scales online) and
   once under the adaptive controller. The acceptance claim mirrors
   the paper's Table 4 read: the controller must sit on the
   fixed-ratio trade-off curve's good corner — **critical
   throttled-seconds no worse than the safest fixed ratio, with at
   least the admitted-VM count of every fixed ratio that is equally
   safe** — so no offline ratio choice both admits more and throttles
   critical VMs less. Asserted at measurement time, per arm.

2. **Controller overhead at 4 shards** — the `serve_emergency`
   arrival stream with a full-fleet power sweep every
   ``SWEEP_EVERY`` micro-batches (every sweep drives an adaptive
   scan; the cadence is 2x the production stream's every-4), through
   `ShardedServePipeline` with the controller off vs on. Timing uses
   the alternating best-of discipline from `benchmarks/serve_obs`
   (docs/performance.md), hardened for the short walls here: warm
   both variants once, then alternate off/on keeping the minimum
   wall over ``BEST_OF`` rounds, each wall timing
   ``STREAMS_PER_WALL`` back-to-back streams (pipes built off the
   clock) — process noise is one-sided, so alternation + best-of
   cancels it instead of crediting whichever variant runs last.
   Acceptance: **<5% arrivals/s overhead**
   (``adaptive_overhead_frac``).

``--smoke`` runs a miniature sweep + one small stream per variant
(CI, no asserts, no artifact); ``--regress`` re-measures the 4-shard
controller-on row against the committed baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: 4 shards want 4 devices; set before JAX initializes (see
#: `benchmarks/serve_sharded` for the re-exec rationale).
_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, regress_gate, subproc_env
from benchmarks.serve_emergency import (
    BLADES_PER_CHASSIS, BUDGET_2X, CORES_PER_SERVER, N_CHASSIS,
    _sweep_power, _train, _warm_state)
from repro.core import features as F
from repro.core.placement import SchedulerPolicy
from repro.core.power_model import F_MAX, idle_power
from repro.serve import (
    AdaptiveConfig, EmergencyConfig, PlaneBundle, ResourceVector,
    ShardedServeConfig, ShardedServePipeline, device_state)
from repro.serve.featurizer import table_from_history
from repro.sim.scheduler_sim import (PredictionChannel, ServeBackendSpec,
                                     SimSpec, simulate)
from repro.sim.telemetry import arrival_batch, arrival_stamps

OUT_PATH = "BENCH_serve_adaptive.json"

# --- axis 1: the ratio sweep ----------------------------------------------
#: the offline choices the controller competes against (paper Table 4)
FIXED_RATIOS = (1.0, 1.25, 1.5, 2.0)
#: per-chassis admission watt budget at ratio 1.0 — the same 2x budget
#: the emergency plane alarms on, so ratio r admits r times the
#: budget's dynamic power span
CHASSIS_BUDGET_W = BUDGET_2X
SWEEP_DAYS = 1.25
SWEEP_SEED = 0
SWEEP_DEPLOYMENTS_PER_HOUR = 32.0
SWEEP_PREFILL = 0.4
#: noise floor for the critical-throttle comparison, as a fraction of
#: the adaptive arm's total throttled-seconds (an emergency-plane tick
#: of jitter must not flip the verdict)
UF_SLACK_FRAC = 0.002

# --- axis 2: controller overhead ------------------------------------------
BATCH_SIZE = 256
N_SHARDS = 4
#: full-fleet sweep (= adaptive scan) cadence in micro-batches —
#: every 2nd batch, twice the `serve_emergency` production stream's
#: every-4 cadence, so the overhead row is still a stress reading
SWEEP_EVERY = 2
#: timing rounds per variant (min wins) and streams per timed wall —
#: sub-second single-stream walls swing past the acceptance bar on a
#: small box, so each wall times several streams back to back
BEST_OF = 5
STREAMS_PER_WALL = 2
#: acceptance bar: controller-on costs < 5% arrivals/s at 4 shards
MAX_OVERHEAD_FRAC = 0.05


def _sweep_adaptive_cfg() -> AdaptiveConfig:
    """Controller knobs for the sweep: a short window reacting at the
    32-scans/hour cadence, backing off well before the diurnal peak
    (`sim.telemetry.diurnal_util` tops out at ~0.81) and re-ratcheting
    hard once the fleet cools."""
    return AdaptiveConfig(window=8, min_history=3, hot_util=0.63,
                          step_up=0.15, step_down=0.5, ratio_max=3.0)


def _fixed_budget_w(ratio: float) -> float:
    """Admission budget whose per-chassis rho ceiling is `ratio` times
    the ratio-1.0 ceiling (`admission.rho_cap_from_budget` is affine
    in watts: only the dynamic span above idle scales)."""
    static = BLADES_PER_CHASSIS * float(idle_power(F_MAX))
    return static + ratio * (CHASSIS_BUDGET_W - static)


def _sweep_arm(budget_w: float, adaptive_cfg, smoke: bool) -> dict:
    t0 = time.perf_counter()
    m = simulate(
        SchedulerPolicy(), PredictionChannel("ml"),
        SimSpec(days=0.2 if smoke else SWEEP_DAYS, seed=SWEEP_SEED,
                deployments_per_hour=16.0 if smoke else
                SWEEP_DEPLOYMENTS_PER_HOUR,
                prefill_core_ratio=SWEEP_PREFILL,
                serve=ServeBackendSpec(
                    backend="serve",
                    admission_budget=ResourceVector(watts=budget_w)),
                emergency=EmergencyConfig.from_model(CHASSIS_BUDGET_W),
                adaptive=adaptive_cfg))
    return {"admitted": m.placements - m.failures,
            "failures": m.failures,
            "uf_throttled_s": m.uf_throttled_s,
            "nuf_throttled_s": m.nuf_throttled_s,
            "migrations": m.migrations,
            "final_ratio": m.adaptive_ratio,
            "ratchets": m.adaptive_ratchets,
            "backoffs": m.adaptive_backoffs,
            "wall_s": time.perf_counter() - t0}


def sweep(smoke: bool = False) -> dict:
    """Run every fixed-ratio arm plus the adaptive arm on the same
    trace; outside smoke, assert the Table-4 claim per arm."""
    ratios = (1.0, 2.0) if smoke else FIXED_RATIOS
    acfg = _sweep_adaptive_cfg()
    out = {"days": 0.2 if smoke else SWEEP_DAYS, "seed": SWEEP_SEED,
           "deployments_per_hour": 16.0 if smoke else
           SWEEP_DEPLOYMENTS_PER_HOUR,
           "prefill_core_ratio": SWEEP_PREFILL,
           "chassis_budget_w": CHASSIS_BUDGET_W,
           "adaptive_cfg": {
               "window": acfg.window, "min_history": acfg.min_history,
               "hot_util": acfg.hot_util, "step_up": acfg.step_up,
               "step_down": acfg.step_down,
               "ratio_max": acfg.ratio_max},
           "arms": []}
    for r in ratios:
        row = {"name": f"fixed-{r:.2f}", "ratio": r,
               **_sweep_arm(_fixed_budget_w(r), None, smoke)}
        out["arms"].append(row)
        emit(f"serve_adaptive/sweep/{row['name']}", 0.0,
             f"admitted={row['admitted']} "
             f"uf_throttled_s={row['uf_throttled_s']:.0f}")
    adp = {"name": "adaptive", "ratio": None,
           **_sweep_arm(_fixed_budget_w(1.0), acfg, smoke)}
    out["arms"].append(adp)
    emit("serve_adaptive/sweep/adaptive", 0.0,
         f"admitted={adp['admitted']} "
         f"uf_throttled_s={adp['uf_throttled_s']:.0f} "
         f"ratchets={adp['ratchets']} backoffs={adp['backoffs']}")
    fixed = [a for a in out["arms"] if a["name"] != "adaptive"]
    slack = UF_SLACK_FRAC * (adp["uf_throttled_s"]
                             + adp["nuf_throttled_s"])
    safe = [a for a in fixed
            if a["uf_throttled_s"] <= adp["uf_throttled_s"] + slack]
    best_safe = max(safe, key=lambda a: a["admitted"], default=None)
    out["uf_slack_s"] = slack
    out["best_safe_fixed"] = None if best_safe is None \
        else best_safe["name"]
    out["capacity_gain_vs_best_safe"] = None if best_safe is None \
        else adp["admitted"] / max(best_safe["admitted"], 1)
    if not smoke:
        # the Table-4 claim, per arm: the controller ties the safest
        # offline ratio on critical throttled-seconds and admits at
        # least as much as every fixed ratio that is equally safe —
        # no fixed choice is both safer-or-equal AND higher-capacity
        min_uf = min(a["uf_throttled_s"] for a in fixed)
        assert adp["uf_throttled_s"] <= min_uf + slack, \
            f"adaptive critical throttled-s {adp['uf_throttled_s']:.0f}" \
            f" exceeds the safest fixed ratio's {min_uf:.0f}"
        for a in fixed:
            assert (a["uf_throttled_s"] > adp["uf_throttled_s"] + slack
                    or a["admitted"] <= adp["admitted"]), \
                f"{a['name']} dominates adaptive: " \
                f"admitted {a['admitted']} >= {adp['admitted']} at " \
                f"uf_throttled_s {a['uf_throttled_s']:.0f}"
    return out


# --- axis 2: controller overhead at 4 shards ------------------------------


def _make_pipe(svc, hist, labels, state, batch_size,
               adaptive_on: bool):
    cap = max(v.subscription for v in hist.vms) + 1024
    return ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(state), cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(
            batch_size=batch_size, n_shards=N_SHARDS,
            planes=PlaneBundle(
                emergency=EmergencyConfig.from_model(BUDGET_2X),
                adaptive=AdaptiveConfig(window=8, min_history=1,
                                        hot_util=0.9, step_up=0.25)
                if adaptive_on else None)))


def _stream(pipe, arrivals, batch_size, sweep_power) -> None:
    """The `serve_emergency` stream with a full-fleet power sweep
    every ``SWEEP_EVERY`` micro-batches, so each sweep costs one
    emergency scan — and, controller on, one adaptive scan — per cap
    window."""
    n = len(arrivals.vms)
    stamps = arrival_stamps(n)
    cap_idx = np.arange(N_CHASSIS)
    for bi, lo in enumerate(range(0, n, batch_size)):
        idx = np.arange(lo, min(lo + batch_size, n))
        pipe.submit_to(0, arrival_batch(arrivals, idx), t=stamps[idx])
        if (bi + 1) % SWEEP_EVERY == 0:
            t0 = float(stamps[idx][-1])
            pipe.cap_to(0, cap_idx, sweep_power,
                        t=t0 + (cap_idx + 1) * 1e-7)
    pipe.flush()


def overhead(smoke: bool = False) -> dict:
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:256])
    bs = 64 if smoke else BATCH_SIZE
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    out = {"n_shards": N_SHARDS, "batch_size": bs,
           "n_arrivals": len(arrivals.vms),
           "max_overhead_frac": MAX_OVERHEAD_FRAC, "configs": []}
    # warm the jit caches once per variant, then ALTERNATE off/on
    # keeping the best (minimum) wall, each wall timing several
    # streams back to back — the serve_obs discipline
    # (docs/performance.md), widened because sub-second walls swing
    # past the 5% bar on a loaded box
    for on in (False, True):
        _stream(_make_pipe(svc, hist, labels, warm, bs, on),
                arrivals, bs, sweep_power)
    per = 1 if smoke else STREAMS_PER_WALL
    walls = {False: np.inf, True: np.inf}
    for _ in range(1 if smoke else BEST_OF):
        for on in (False, True):
            pipes = [_make_pipe(svc, hist, labels, warm, bs, on)
                     for _ in range(per)]
            t0 = time.perf_counter()
            for pipe in pipes:
                _stream(pipe, arrivals, bs, sweep_power)
            walls[on] = min(walls[on],
                            (time.perf_counter() - t0) / per)
            for pipe in pipes:
                assert pipe.served == len(arrivals.vms)
                if on:
                    # the controller really consumed the sweeps:
                    # every shard's ratio ratcheted off 1.0 on the
                    # stable constant-power windows
                    assert (np.asarray(pipe.adaptive_ratio)
                            > 1.0).all()
    for on in (False, True):
        wall = walls[on]
        row = {"adaptive": on,
               "arrivals_per_s": len(arrivals.vms) / wall,
               "wall_s": wall}
        out["configs"].append(row)
        emit(f"serve_adaptive/shards{N_SHARDS}"
             f"/{'on' if on else 'off'}",
             wall / max(len(arrivals.vms), 1) * 1e6,
             f"arrivals_per_s={row['arrivals_per_s']:.0f}")
    by = {r["adaptive"]: r["arrivals_per_s"] for r in out["configs"]}
    out["adaptive_overhead_frac"] = 1.0 - by[True] / by[False]
    frac = out["adaptive_overhead_frac"]
    emit("serve_adaptive/overhead_frac", 0.0, f"frac={frac:.4f}")
    if not smoke:
        assert frac < MAX_OVERHEAD_FRAC, \
            f"adaptive-controller overhead {frac:.1%} exceeds the " \
            f"{MAX_OVERHEAD_FRAC:.0%} acceptance bar at " \
            f"{N_SHARDS} shards"
    return out


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    if len(jax.devices()) < N_SHARDS \
            and "REPRO_SERVE_ADAPTIVE_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    out = {"sweep": sweep(smoke), "overhead": overhead(smoke)}
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def _reexec(out_path: str, smoke: bool) -> dict:
    """Re-run in a fresh interpreter where the forced device count can
    still take effect (same trap as `benchmarks/serve_sharded`)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_adaptive"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd,
                   env=subproc_env("REPRO_SERVE_ADAPTIVE_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the 4-shard controller-on row quickly and fail on a
    >30% arrivals/s drop vs the committed BENCH_serve_adaptive.json."""
    import jax
    if len(jax.devices()) < N_SHARDS:
        if "REPRO_SERVE_ADAPTIVE_SUBPROC" in os.environ:
            return [f"serve_adaptive: {len(jax.devices())} devices "
                    f"in subprocess, need {N_SHARDS}"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_adaptive",
             "--regress"],
            env=subproc_env("REPRO_SERVE_ADAPTIVE_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_adaptive: regress subprocess exited {rc}"]
    want = next(r for r in baseline["overhead"]["configs"]
                if r["adaptive"])
    hist, arrivals, labels, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:768])
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    bs = baseline["overhead"]["batch_size"]
    _stream(_make_pipe(svc, hist, labels, warm, bs, True),
            arrivals, bs, sweep_power)
    walls = []
    for _ in range(3):              # best-of: CI noise is one-sided
        pipe = _make_pipe(svc, hist, labels, warm, bs, True)
        t0 = time.perf_counter()
        _stream(pipe, arrivals, bs, sweep_power)
        walls.append(time.perf_counter() - t0)
    measured = len(arrivals.vms) / min(walls)
    return regress_gate("serve_adaptive/shards4/on/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
