"""Power-emergency plane cost + criticality impact (DESIGN.md §12).

Two axes, one artifact (BENCH_serve_emergency.json):

  * **Serving cost** — arrivals/s through `ShardedServePipeline` at
    1 and 4 shards with the emergency plane off vs on. The "on" runs
    interleave a full-fleet chassis power sweep (one `CapBatch` per
    chassis through `cap_to`) every few micro-batches over a
    warm-started 2x-oversubscribed cluster, so the alarm +
    apportionment kernel really fires on the serving path; the
    overhead should stay a small fraction of the serve wall.
  * **Criticality impact** — the paper's Table-4 axis: a scheduler-sim
    run at the 2x-oversubscription chassis budget reports critical vs
    non-critical throttled-seconds under criticality-aware
    apportionment against the criticality-blind baseline on the same
    trace (aware must hold the critical number strictly lower;
    asserted in the tier-1 suite, measured here).

``--smoke`` pushes one small stream per shard count (CI);
``--regress`` re-measures the 4-shard emergency-on row against the
committed baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: 4 shards want 4 devices; set before JAX initializes (see
#: `benchmarks/serve_sharded` for the re-exec rationale).
_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, regress_gate, subproc_env
from repro.core import features as F
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.predictor import train_service
from repro.serve import (
    EmergencyConfig, PlaneBundle, ShardedServeConfig,
    ShardedServePipeline, device_state)
from repro.serve.featurizer import table_from_history
from repro.sim.telemetry import (
    arrival_batch, arrival_stamps, generate_population)

OUT_PATH = "BENCH_serve_emergency.json"

N_HISTORY = 1500
N_ARRIVALS = 2048
BLADES_PER_CHASSIS = 12
N_CHASSIS = 64
N_SERVERS = N_CHASSIS * BLADES_PER_CHASSIS
CORES_PER_SERVER = 40
BATCH_SIZE = 256
SHARD_COUNTS = (1, 4)
#: 2x oversubscription of a 12 x 310 W chassis (the paper's headline).
BUDGET_2X = BLADES_PER_CHASSIS * 310.0 / 2.0
#: chassis power sweep cadence, in micro-batches
SWEEP_EVERY = 4
#: fixed hot-fleet utilization sample for the sweeps (alarm-rich over
#: the warm-started cluster)
SWEEP_UTIL = 0.85
WARM_OCCUPANCY = 0.6


def _train(seed: int = 0, n_trees: int = 48):
    pop = generate_population(N_HISTORY + N_ARRIVALS, seed=seed)
    hist = F.Population(vms=pop.vms[:N_HISTORY])
    arrivals = F.Population(vms=pop.vms[N_HISTORY:])
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=n_trees, seed=seed)
    return hist, arrivals, labels, svc


def _warm_state(seed: int = 0) -> ClusterState:
    """Cluster pre-committed to ~WARM_OCCUPANCY of its cores, so the
    2x-oversubscription alarm threshold is actually reachable."""
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=N_SERVERS,
                      cores_per_server=CORES_PER_SERVER,
                      chassis_of_server=np.arange(N_SERVERS)
                      // BLADES_PER_CHASSIS,
                      n_chassis=N_CHASSIS)
    target = WARM_OCCUPANCY * N_SERVERS * CORES_PER_SERVER
    filled, srv = 0.0, 0
    while filled < target:
        cores = int(rng.choice([2, 4, 8]))
        if st.free_cores[srv % N_SERVERS] >= cores:
            st.place(srv % N_SERVERS, cores,
                     float(rng.uniform(0.3, 0.9)),
                     bool(rng.random() < 0.4))
            filled += cores
        srv += 1
    return st


def _make_pipe(svc, hist, labels, state, n_shards, batch_size,
               emergency: bool):
    cap = max(v.subscription for v in hist.vms) + 1024
    return ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(state), cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(
            batch_size=batch_size, n_shards=n_shards,
            planes=PlaneBundle(
                emergency=EmergencyConfig.from_model(BUDGET_2X)
                if emergency else None)))


def _sweep_power(state: ClusterState) -> np.ndarray:
    """(C,) synthetic PSU readings of the warm snapshot at SWEEP_UTIL —
    power samples are exogenous telemetry in production (BMC pollers),
    so the benchmark synthesizes them once up front; the in-scan
    apportionment still reads the *live* criticality aggregates."""
    from repro.serve import chassis_rho_levels, sampled_power
    cfg = EmergencyConfig.from_model(BUDGET_2X)
    chs = np.argsort(state.chassis_of_server, kind="stable") \
        .reshape(N_CHASSIS, -1).astype(np.int32)
    rho = np.asarray(chassis_rho_levels(
        state.gamma_nuf, state.gamma_uf, chs, np))
    return np.asarray(sampled_power(
        cfg, rho, SWEEP_UTIL, np.zeros((N_CHASSIS, 2), np.int32),
        np.zeros(N_CHASSIS, bool), np))


def _push_stream(pipe, arrivals, batch_size, emergency: bool,
                 sweep_power=None) -> dict:
    """Stream the population through `submit_to` with unit-clock
    stamps; with `emergency`, interleave a full-fleet power sweep
    every SWEEP_EVERY micro-batches (stamps tucked between arrival
    ticks, so the merge stays monotone per host)."""
    n = len(arrivals.vms)
    stamps = arrival_stamps(n)
    cap_idx = np.arange(N_CHASSIS)
    sweeps = 0
    for k, lo in enumerate(range(0, n, batch_size)):
        idx = np.arange(lo, min(lo + batch_size, n))
        pipe.submit_to(0, arrival_batch(arrivals, idx), t=stamps[idx])
        if emergency and (k + 1) % SWEEP_EVERY == 0:
            t0 = float(stamps[idx][-1])
            pipe.cap_to(0, cap_idx, sweep_power,
                        t=t0 + (cap_idx + 1) * 1e-7)
            sweeps += 1
    pipe.flush()
    return {"sweeps": sweeps, "alarms": pipe.alarms}


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    if len(jax.devices()) < max(SHARD_COUNTS) \
            and "REPRO_SERVE_EMERGENCY_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:256])
    bs = 64 if smoke else BATCH_SIZE
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    out = {"n_servers": N_SERVERS, "n_chassis": N_CHASSIS,
           "chassis_budget_w": BUDGET_2X, "batch_size": bs,
           "n_devices": len(jax.devices()),
           "n_arrivals": len(arrivals.vms), "configs": []}
    for n_shards in SHARD_COUNTS:
        # one warm pass per variant shares the jit cache; the timed
        # passes then ALTERNATE off/on (each from a clean cluster) so
        # progressive process warm-up — allocator, XLA autotuning —
        # cancels instead of crediting whichever variant runs last;
        # best-of over the alternations (CI noise is one-sided)
        for emergency in (False, True):
            _push_stream(_make_pipe(svc, hist, labels, warm, n_shards,
                                    bs, emergency), arrivals, bs,
                         emergency, sweep_power)
        walls = {False: np.inf, True: np.inf}
        infos = {}
        for _ in range(1 if smoke else 3):
            for emergency in (False, True):
                pipe = _make_pipe(svc, hist, labels, warm, n_shards,
                                  bs, emergency)
                t0 = time.perf_counter()
                infos[emergency] = _push_stream(pipe, arrivals, bs,
                                                emergency, sweep_power)
                walls[emergency] = min(walls[emergency],
                                       time.perf_counter() - t0)
                assert pipe.served == len(arrivals.vms)
        assert infos[True]["alarms"] > 0, \
            "emergency sweeps never alarmed — dead measurement"
        for emergency in (False, True):
            wall = walls[emergency]
            row = {"n_shards": n_shards, "emergency": emergency,
                   "arrivals_per_s": len(arrivals.vms) / wall,
                   "wall_s": wall, **infos[emergency]}
            out["configs"].append(row)
            emit(f"serve_emergency/shards{n_shards}"
                 f"/{'on' if emergency else 'off'}",
                 wall / max(len(arrivals.vms), 1) * 1e6,
                 f"arrivals_per_s={row['arrivals_per_s']:.0f} "
                 f"alarms={row['alarms']}")
    by = {(r["n_shards"], r["emergency"]): r["arrivals_per_s"]
          for r in out["configs"]}
    out["emergency_overhead_frac"] = {
        f"shards{s}": 1.0 - by[(s, True)] / by[(s, False)]
        for s in SHARD_COUNTS}

    # Table-4 axis: critical vs non-critical throttled-seconds at 2x
    from repro.sim.scheduler_sim import (PredictionChannel, SimSpec,
                                         simulate)
    sim_kw = dict(days=0.1 if smoke else 0.55, seed=0,
                  deployments_per_hour=16.0, prefill_core_ratio=0.75)
    throttled = {}
    for name, blind in (("aware", False), ("blind", True)):
        m = simulate(SchedulerPolicy(alpha=0.8),
                     PredictionChannel("ml"),
                     SimSpec(emergency=EmergencyConfig.from_model(
                         BUDGET_2X, dwell_s=1800.0,
                         criticality_blind=blind), **sim_kw))
        throttled[name] = {"uf_throttled_s": m.uf_throttled_s,
                           "nuf_throttled_s": m.nuf_throttled_s,
                           "alarms": m.alarms,
                           "migrations": m.migrations}
        emit(f"serve_emergency/table4/{name}", 0.0,
             f"uf_s={m.uf_throttled_s:.0f} "
             f"nuf_s={m.nuf_throttled_s:.0f} alarms={m.alarms}")
    out["throttled_2x"] = throttled
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def _reexec(out_path: str, smoke: bool) -> dict:
    """Re-run in a fresh interpreter where the forced device count can
    still take effect (same trap as `benchmarks/serve_sharded`)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_emergency"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd,
                   env=subproc_env("REPRO_SERVE_EMERGENCY_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the 4-shard emergency-on row quickly and fail on a >30%
    arrivals/s drop vs the committed BENCH_serve_emergency.json."""
    import jax
    if len(jax.devices()) < max(SHARD_COUNTS):
        if "REPRO_SERVE_EMERGENCY_SUBPROC" in os.environ:
            return [f"serve_emergency: {len(jax.devices())} devices in "
                    f"subprocess, need {max(SHARD_COUNTS)}"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_emergency",
             "--regress"],
            env=subproc_env("REPRO_SERVE_EMERGENCY_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_emergency: regress subprocess exited {rc}"]
    want = next(r for r in baseline["configs"]
                if r["n_shards"] == 4 and r["emergency"])
    hist, arrivals, labels, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:768])
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    bs = baseline["batch_size"]
    _push_stream(_make_pipe(svc, hist, labels, warm, 4, bs, True),
                 arrivals, bs, True, sweep_power)
    walls = []
    for _ in range(3):              # best-of: CI noise is one-sided
        pipe = _make_pipe(svc, hist, labels, warm, 4, bs, True)
        t0 = time.perf_counter()
        _push_stream(pipe, arrivals, bs, True, sweep_power)
        walls.append(time.perf_counter() - t0)
    measured = len(arrivals.vms) / min(walls)
    return regress_gate("serve_emergency/shards4/on/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
