"""Cross-host ingest throughput: arrivals/s vs ingest host count at a
fixed 4 shards.

Streams the same population as `benchmarks/serve_sharded` through
`ShardedServePipeline.submit_to` with 1/2/4/8 per-host queues
(`repro.serve.ingest`, docs/ingest.md): per-host stamped chunks are
pushed round-robin across hosts, micro-batches are released by the
fleet watermark as the merge allows, and the tail is flushed at end of
stream. The decisions are host-count-invariant (unique stamps); the
measurement is what the per-host queues + k-way merge *cost* on the
serving path — the merge is host-side numpy, so the overhead should
stay a small, flat fraction of the compiled serve time as hosts grow.

A separate merge-only pass (same streams, no serving) isolates the
ingest data plane in events/s. Writes BENCH_serve_ingest.json;
`--smoke` pushes one small stream per host count (CI).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: 4 shards want 4 devices; set before JAX initializes (see
#: `benchmarks/serve_sharded` for the re-exec rationale).
_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, regress_gate, subproc_env
from repro.core import features as F
from repro.core.placement import SchedulerPolicy
from repro.core.predictor import train_service
from repro.serve import (IngestMux, ShardedServeConfig, ShardedServePipeline)
from repro.sim.telemetry import generate_population, split_streams

OUT_PATH = "BENCH_serve_ingest.json"

N_HISTORY = 1500
N_ARRIVALS = 2048
BLADES_PER_CHASSIS = 12
N_CHASSIS = 64
N_SERVERS = N_CHASSIS * BLADES_PER_CHASSIS
CORES_PER_SERVER = 40
BATCH_SIZE = 256
N_SHARDS = 4
HOST_COUNTS = (1, 2, 4, 8)
POLICY = SchedulerPolicy()              # rank_rule — the sharded winner


def _train(seed: int = 0, n_trees: int = 48):
    pop = generate_population(N_HISTORY + N_ARRIVALS, seed=seed)
    hist = F.Population(vms=pop.vms[:N_HISTORY])
    arrivals = F.Population(vms=pop.vms[N_HISTORY:])
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=n_trees, seed=seed)
    return hist, arrivals, labels, svc


def _make_pipe(svc, hist, labels, n_hosts, batch_size):
    return ShardedServePipeline.from_history(
        svc, hist, labels, n_servers=N_SERVERS,
        cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(batch_size=batch_size, policy=POLICY,
                                  n_shards=N_SHARDS,
                                  n_ingest_hosts=n_hosts))


def _push_stream(sink, streams) -> int:
    """Interleave per-host chunk pushes in global time order (the
    chunk schedule a wall clock would produce) and flush; returns the
    number of served results observed."""
    heads = [(chunks[0][0][0], h, 0) for h, chunks in enumerate(streams)
             if chunks]
    served = 0
    heads.sort()
    while heads:
        _, h, j = heads.pop(0)
        stamps, batch = streams[h][j]
        served += len(sink.submit_to(h, batch, t=stamps))
        if j + 1 < len(streams[h]):
            heads.append((streams[h][j + 1][0][0], h, j + 1))
            heads.sort()
    tail = sink.flush()
    return served + (tail is not None)


class _MergeOnly:
    """Serve-free sink: same mux traffic, no placement (isolates the
    ingest data plane)."""

    def __init__(self, n_hosts):
        self.mux = IngestMux(n_hosts)
        self.events = 0

    def submit_to(self, host, batch, t=None):
        self.mux.submit_to(host, batch, t)
        ev = self.mux.poll()
        self.events += len(ev)
        return []

    def flush(self):
        self.events += len(self.mux.drain())
        return None


def _reexec(out_path: str, smoke: bool) -> dict:
    """Re-run in a fresh interpreter where the forced device count can
    still take effect (same trap as `benchmarks/serve_sharded`)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_ingest"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, env=subproc_env("REPRO_SERVE_INGEST_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    if len(jax.devices()) < N_SHARDS \
            and "REPRO_SERVE_INGEST_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    host_counts = (1, 4) if smoke else HOST_COUNTS
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:256])
    bs = 64 if smoke else BATCH_SIZE
    rate = 1e4                      # Poisson stamps; unique -> invariant
    out = {"n_servers": N_SERVERS, "n_shards": N_SHARDS,
           "batch_size": bs, "n_devices": len(jax.devices()),
           "n_arrivals": len(arrivals.vms), "hosts": []}
    for n_hosts in host_counts:
        chunk = max(1, bs // n_hosts)
        streams = split_streams(arrivals, n_hosts, chunk,
                                arrival_rate_per_s=rate)
        # one warm pass on a throwaway pipe (shared jit cache), then
        # the timed pass on a clean cluster
        _push_stream(_make_pipe(svc, hist, labels, n_hosts, bs),
                     streams)
        pipe = _make_pipe(svc, hist, labels, n_hosts, bs)
        t0 = time.perf_counter()
        _push_stream(pipe, streams)
        wall = time.perf_counter() - t0
        assert pipe.served == len(arrivals.vms)
        merge = _MergeOnly(n_hosts)
        t0 = time.perf_counter()
        _push_stream(merge, streams)
        merge_wall = time.perf_counter() - t0
        assert merge.events == len(arrivals.vms)
        row = {"n_hosts": n_hosts,
               "arrivals_per_s": len(arrivals.vms) / wall,
               "wall_s": wall,
               "merge_only_events_per_s":
                   merge.events / max(merge_wall, 1e-9),
               "ingest_overhead_frac": merge_wall / wall}
        out["hosts"].append(row)
        emit(f"serve_ingest/hosts{n_hosts}",
             wall / max(len(arrivals.vms), 1) * 1e6,
             f"arrivals_per_s={row['arrivals_per_s']:.0f} "
             f"merge_events_per_s="
             f"{row['merge_only_events_per_s']:.0f} "
             f"overhead={row['ingest_overhead_frac']:.3f}")
    base = out["hosts"][0]["arrivals_per_s"]
    out["throughput_vs_1host"] = {
        f"hosts{r['n_hosts']}": r["arrivals_per_s"] / base
        for r in out["hosts"]}
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the 4-host row quickly and fail on a >30% arrivals/s
    drop vs the committed BENCH_serve_ingest.json."""
    import jax
    if len(jax.devices()) < N_SHARDS:
        if "REPRO_SERVE_INGEST_SUBPROC" in os.environ:
            return [f"serve_ingest: {len(jax.devices())} devices in "
                    f"subprocess, need {N_SHARDS}"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_ingest",
             "--regress"],
            env=subproc_env("REPRO_SERVE_INGEST_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_ingest: regress subprocess exited {rc}"]
    want = next(r for r in baseline["hosts"] if r["n_hosts"] == 4)
    hist, arrivals, labels, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:768])
    streams = split_streams(arrivals, 4,
                            max(1, baseline["batch_size"] // 4),
                            arrival_rate_per_s=1e4)
    _push_stream(_make_pipe(svc, hist, labels, 4,
                            baseline["batch_size"]), streams)
    walls = []
    for _ in range(3):              # best-of: CI noise is one-sided
        pipe = _make_pipe(svc, hist, labels, 4, baseline["batch_size"])
        t0 = time.perf_counter()
        _push_stream(pipe, streams)
        walls.append(time.perf_counter() - t0)
    measured = len(arrivals.vms) / min(walls)
    return regress_gate("serve_ingest/hosts4/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
