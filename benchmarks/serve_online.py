"""Online serving throughput: per-arrival numpy path vs the compiled
serve pipeline (`repro.serve`).

Baseline = the offline path called one arrival at a time, exactly as
the pre-serve code would answer an online query: build the feature row
from the aggregates dict, run the four numpy forests (Table III
defaults), score candidates with `SchedulerPolicy.choose`, update
`ClusterState`. The serve path runs the same arrivals through
`ServePipeline` micro-batches.

Both placement modes are measured against their own numpy twin:

  * `rank_rule`  — the full Azure-style two-rule rank aggregation
                   (`SchedulerPolicy()`), served by the incremental-
                   rank scan (decision-exact; sort- and scatter-free);
  * `algorithm1` — the paper's literal Algorithm-1 / §IV-E preference
                   (`SchedulerPolicy(packing_weight=0)`), served by
                   the rank-free scan (decision-exact; the fast path
                   the production scheduler's 7 ms budget wants).

Metrics: arrivals/s and p50/p99 per-batch latency. Writes
BENCH_serve.json. `--smoke` serves one 64-arrival batch (CI).
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import features as F
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.predictor import bucket_to_p95, train_service
from repro.serve import ServeConfig, ServePipeline
from repro.sim.telemetry import arrival_batch, generate_population

OUT_PATH = "BENCH_serve.json"

N_HISTORY = 1500
N_ARRIVALS = 2048
N_SERVERS = 720              # the Fig-7 cluster: 20 racks x 3 x 12
BLADES_PER_CHASSIS = 12
CORES_PER_SERVER = 40
BATCH_SIZES = (64, 256)
POLICIES = {"rank_rule": SchedulerPolicy(),
            "algorithm1": SchedulerPolicy(packing_weight=0.0)}


def _train(seed: int = 0, n_trees: int = 48):
    pop = generate_population(N_HISTORY + N_ARRIVALS, seed=seed)
    hist = F.Population(vms=pop.vms[:N_HISTORY])
    arrivals = F.Population(vms=pop.vms[N_HISTORY:])
    labels = hist.labels.astype(np.float64)      # ground truth as labels
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs), labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=n_trees, seed=seed)
    return hist, arrivals, labels, aggs, svc


def _numpy_state():
    return ClusterState(
        n_servers=N_SERVERS, cores_per_server=CORES_PER_SERVER,
        chassis_of_server=np.arange(N_SERVERS) // BLADES_PER_CHASSIS,
        n_chassis=N_SERVERS // BLADES_PER_CHASSIS)


def _numpy_per_arrival(arrivals, aggs, svc, policy) -> float:
    """Serve every arrival one at a time on the host path; returns
    wall seconds."""
    state = _numpy_state()
    t0 = time.perf_counter()
    for vm in arrivals.vms:
        x = F.build_features(F.Population(vms=[vm]), aggs)
        q = svc.query(x)
        is_uf = bool(q["workload_type_used"][0])
        p95 = float(bucket_to_p95(q["p95_bucket_used"][0]))
        srv = policy.choose(state, vm.cores, is_uf)
        if srv is not None:
            state.place(srv, vm.cores, policy.effective_p95(p95), is_uf)
    return time.perf_counter() - t0


def _make_pipe(svc, hist, labels, bs, policy):
    return ServePipeline.from_history(
        svc, hist, labels, n_servers=N_SERVERS,
        cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ServeConfig(batch_size=bs, policy=policy))


def _serve_batches(pipe: ServePipeline, batches) -> list:
    """Serve pre-packed batches; returns per-batch seconds."""
    times = []
    for b in batches:
        t0 = time.perf_counter()
        pipe.serve(b)                 # ServeResult is host-materialized
        times.append(time.perf_counter() - t0)
    return times


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    hist, arrivals, labels, aggs, svc = _train(
        n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:64])
    out = {"n_servers": N_SERVERS, "n_arrivals": len(arrivals.vms),
           "modes": {}}
    for mode, policy in POLICIES.items():
        rows = []
        for bs in (64,) if smoke else BATCH_SIZES:
            batches = [arrival_batch(arrivals,
                                     np.arange(i, min(i + bs,
                                                      len(arrivals.vms))))
                       for i in range(0, len(arrivals.vms), bs)]
            pipe = _make_pipe(svc, hist, labels, bs, policy)
            if len(batches) > 1:
                # first batch = jit trace + steady-state entry, untimed
                _serve_batches(pipe, batches[:1])
                batches = batches[1:]
            else:                                  # smoke: warm apart
                warm = _make_pipe(svc, hist, labels, bs, policy)
                _serve_batches(warm, batches[:1])
            times = np.array(_serve_batches(pipe, batches))
            served = sum(len(b) for b in batches)
            p50 = float(np.percentile(times, 50))
            # steady-state throughput from the median batch (the mean
            # is os-jitter-bound on a small box); p99 is still reported
            row = {"batch_size": bs, "arrivals": served,
                   "arrivals_per_s": bs / p50,
                   "arrivals_per_s_mean": served / times.sum(),
                   "batch_p50_ms": p50 * 1e3,
                   "batch_p99_ms": float(np.percentile(times, 99) * 1e3)}
            rows.append(row)
            emit(f"serve_online/{mode}/batch{bs}", times.mean() * 1e6,
                 f"arrivals_per_s={row['arrivals_per_s']:.0f} "
                 f"p50={row['batch_p50_ms']:.2f}ms "
                 f"p99={row['batch_p99_ms']:.2f}ms")
        if smoke:
            out["modes"][mode] = {"serve": rows}
            continue
        t_np = _numpy_per_arrival(arrivals, aggs, svc, policy)
        np_rate = len(arrivals.vms) / t_np
        emit(f"serve_online/{mode}/numpy_per_arrival",
             t_np / len(arrivals.vms) * 1e6,
             f"arrivals_per_s={np_rate:.0f}")
        out["modes"][mode] = {
            "numpy_per_arrival": {"arrivals_per_s": np_rate,
                                  "us_per_arrival":
                                      t_np / len(arrivals.vms) * 1e6},
            "serve": rows,
            "speedup": {f"batch{r['batch_size']}":
                        r["arrivals_per_s"] / np_rate for r in rows}}
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-serve a short stream at the committed batch-256 configs (same
    forest size and cluster, fewer batches) and fail on a >30%
    arrivals/s drop vs BENCH_serve.json."""
    from benchmarks.common import regress_gate
    hist, arrivals, labels, _aggs, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:1024])
    failures = []
    for mode, policy in POLICIES.items():
        want = next(r for r in baseline["modes"][mode]["serve"]
                    if r["batch_size"] == 256)
        batches = [arrival_batch(arrivals, np.arange(i, i + 256))
                   for i in range(0, 1024, 256)]
        pipe = _make_pipe(svc, hist, labels, 256, policy)
        _serve_batches(pipe, batches[:1])          # jit trace, untimed
        times = np.array(_serve_batches(pipe, batches[1:]))
        # best-of: regression noise on a small CI box is one-sided
        measured = 256 / float(times.min())
        failures += regress_gate(
            f"serve_online/{mode}/batch256/arrivals_per_s", measured,
            want["arrivals_per_s"])
    return failures


if __name__ == "__main__":
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            sys.exit(1 if regress(json.load(f)) else 0)
    run(smoke="--smoke" in sys.argv)
