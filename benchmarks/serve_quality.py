"""Prediction-quality-pillar overhead on the serving path
(DESIGN.md §17).

One axis, one artifact (BENCH_serve_quality.json): arrivals/s through
`ShardedServePipeline` at 1 and 4 shards with the §14 base bundle
(registry + audit + tracer — the cost `benchmarks/serve_obs` already
gates) vs the full §17 bundle (`Observability.full()`: + windowed
aggregation + prediction scorecard + SLO monitor + flight recorder),
over the same emergency-sweep-interleaved stream
`benchmarks/serve_emergency` drives. The new pillars fold outputs the
commit `device_get` already fetches, so the acceptance bar matches
serve_obs: **<5% arrivals/s overhead at 4 shards** (recorded as
``quality_overhead_frac`` and asserted at measurement time).

``--smoke`` pushes one small stream per shard count (CI);
``--regress`` re-measures the 4-shard full-bundle row against the
committed baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: 4 shards want 4 devices; set before JAX initializes (see
#: `benchmarks/serve_sharded` for the re-exec rationale).
_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, regress_gate, subproc_env
from benchmarks.serve_emergency import (
    BLADES_PER_CHASSIS, BUDGET_2X, CORES_PER_SERVER, _push_stream,
    _sweep_power, _train, _warm_state)
from repro.core import features as F
from repro.obs import (AdaptiveTrail, AuditTrail, MetricsRegistry,
                       Observability, SpanTracer)
from repro.serve import (
    EmergencyConfig, PlaneBundle, ShardedServeConfig,
    ShardedServePipeline, device_state)
from repro.serve.featurizer import table_from_history

OUT_PATH = "BENCH_serve_quality.json"

BATCH_SIZE = 256
SHARD_COUNTS = (1, 4)
#: acceptance bar: the four §17 pillars cost < 5% arrivals/s at 4
#: shards on top of the (already-gated) §14 base bundle
MAX_OVERHEAD_FRAC = 0.05


def _bundle(full: bool) -> Observability:
    if full:
        return Observability.full()
    reg = MetricsRegistry()
    return Observability(registry=reg, audit=AuditTrail(capacity=4096),
                         tracer=SpanTracer(reg, capacity=4096),
                         adaptive=AdaptiveTrail())


def _make_pipe(svc, hist, labels, state, n_shards, batch_size,
               full: bool):
    cap = max(v.subscription for v in hist.vms) + 1024
    return ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(state), cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(
            batch_size=batch_size, n_shards=n_shards,
            planes=PlaneBundle(
                emergency=EmergencyConfig.from_model(BUDGET_2X),
                obs=_bundle(full))))


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    if len(jax.devices()) < max(SHARD_COUNTS) \
            and "REPRO_SERVE_QUALITY_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:256])
    bs = 64 if smoke else BATCH_SIZE
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    out = {"batch_size": bs, "n_devices": len(jax.devices()),
           "n_arrivals": len(arrivals.vms),
           "max_overhead_frac": MAX_OVERHEAD_FRAC, "configs": []}
    for n_shards in SHARD_COUNTS:
        # warm the jit caches once per variant, then ALTERNATE base/
        # full (best-of-3) so process warm-up cancels instead of
        # crediting whichever variant runs last
        for full in (False, True):
            _push_stream(_make_pipe(svc, hist, labels, warm, n_shards,
                                    bs, full), arrivals, bs, True,
                         sweep_power)
        walls = {False: np.inf, True: np.inf}
        last_obs: Observability | None = None
        for _ in range(1 if smoke else 3):
            for full in (False, True):
                pipe = _make_pipe(svc, hist, labels, warm, n_shards,
                                  bs, full)
                t0 = time.perf_counter()
                _push_stream(pipe, arrivals, bs, True, sweep_power)
                walls[full] = min(walls[full],
                                  time.perf_counter() - t0)
                assert pipe.served == len(arrivals.vms)
                if full:
                    last_obs = pipe.obs
        # the full run really exercised the new pillars
        assert last_obs.quality.n_scored == len(arrivals.vms)
        assert last_obs.recorder.summary()["by_kind"]["decision"] > 0
        assert last_obs.registry.value("quality_scored") == \
            len(arrivals.vms)
        for full in (False, True):
            wall = walls[full]
            row = {"n_shards": n_shards, "full": full,
                   "arrivals_per_s": len(arrivals.vms) / wall,
                   "wall_s": wall}
            if full:
                row["n_scored"] = int(last_obs.quality.n_scored)
                row["recorder_rows"] = int(last_obs.recorder.rows)
                row["model_stale"] = bool(last_obs.quality.model_stale)
            out["configs"].append(row)
            emit(f"serve_quality/shards{n_shards}"
                 f"/{'full' if full else 'base'}",
                 wall / max(len(arrivals.vms), 1) * 1e6,
                 f"arrivals_per_s={row['arrivals_per_s']:.0f}")
    by = {(r["n_shards"], r["full"]): r["arrivals_per_s"]
          for r in out["configs"]}
    out["quality_overhead_frac"] = {
        f"shards{s}": 1.0 - by[(s, True)] / by[(s, False)]
        for s in SHARD_COUNTS}
    frac4 = out["quality_overhead_frac"]["shards4"]
    emit("serve_quality/overhead_frac_shards4", 0.0,
         f"frac={frac4:.4f}")
    if not smoke:
        assert frac4 < MAX_OVERHEAD_FRAC, \
            f"quality-pillar overhead {frac4:.1%} exceeds the " \
            f"{MAX_OVERHEAD_FRAC:.0%} acceptance bar at 4 shards"
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def _reexec(out_path: str, smoke: bool) -> dict:
    """Re-run in a fresh interpreter where the forced device count can
    still take effect (same trap as `benchmarks/serve_sharded`)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_quality"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd,
                   env=subproc_env("REPRO_SERVE_QUALITY_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the 4-shard full-bundle row quickly and fail on a >30%
    arrivals/s drop vs the committed BENCH_serve_quality.json."""
    import jax
    if len(jax.devices()) < max(SHARD_COUNTS):
        if "REPRO_SERVE_QUALITY_SUBPROC" in os.environ:
            return [f"serve_quality: {len(jax.devices())} devices in "
                    f"subprocess, need {max(SHARD_COUNTS)}"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_quality",
             "--regress"],
            env=subproc_env("REPRO_SERVE_QUALITY_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_quality: regress subprocess exited {rc}"]
    want = next(r for r in baseline["configs"]
                if r["n_shards"] == 4 and r["full"])
    hist, arrivals, labels, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:768])
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    bs = baseline["batch_size"]
    _push_stream(_make_pipe(svc, hist, labels, warm, 4, bs, True),
                 arrivals, bs, True, sweep_power)
    walls = []
    for _ in range(3):              # best-of: CI noise is one-sided
        pipe = _make_pipe(svc, hist, labels, warm, 4, bs, True)
        t0 = time.perf_counter()
        _push_stream(pipe, arrivals, bs, True, sweep_power)
        walls.append(time.perf_counter() - t0)
    measured = len(arrivals.vms) / min(walls)
    return regress_gate("serve_quality/shards4/full/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
