"""All-resource oversubscription benchmark (DESIGN.md §16,
docs/resources.md).

Three axes, one artifact (``BENCH_serve_resources.json``):

1. **Joint vs power-only sweep** (Table-4-style) — the same diurnal
   arrival trace through `sim.scheduler_sim.simulate` (serve backend,
   emergency plane live) under (a) a power-only admission budget and
   (b) the joint (watts+cores+GB) budget: a wider watt ceiling whose
   risk is bounded by Coach-style cores/GB ceilings ratcheting on the
   diurnal trough (``diurnal_ratchet``) with the ballooning rung
   absorbing the residual alarms. Acceptance, asserted at measurement
   time: **joint admits strictly more VMs at equal-or-lower critical
   (UF) throttled-seconds**.

2. **Mitigation-ladder comparison** — cap -> migrate vs
   cap -> balloon -> migrate at the *same* admission budget on the
   same trace. Acceptance: the ballooned ladder performs **fewer
   migrations** and no more critical throttled-seconds (the balloon
   serves the watt deficit the NUF frequency floor cannot, so the
   migration trigger `emergency.mitigation_due` never dwells hot).

3. **Resource-plane overhead at 4 shards** — the `serve_emergency`
   arrival stream with a full-fleet power sweep every ``SWEEP_EVERY``
   micro-batches (the production every-4 cadence) through
   `ShardedServePipeline`, power-only ledger (watt-axis cluster
   budget + emergency) vs the full joint plane (3-axis budget +
   emergency + ballooning). Timing uses the alternating best-of
   discipline from `benchmarks/serve_adaptive` (docs/performance.md).
   Acceptance: **<5% arrivals/s overhead**
   (``resource_plane_overhead_frac``).

``--smoke`` runs miniature arms (CI, no asserts, no artifact);
``--regress`` re-measures the 4-shard joint-plane row against the
committed baseline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: 4 shards want 4 devices; set before JAX initializes (see
#: `benchmarks/serve_sharded` for the re-exec rationale).
_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, regress_gate, subproc_env
from benchmarks.serve_emergency import (
    BLADES_PER_CHASSIS, BUDGET_2X, CORES_PER_SERVER, N_CHASSIS,
    N_SERVERS, _sweep_power, _train, _warm_state)
from repro.core import features as F
from repro.core.placement import SchedulerPolicy
from repro.core.power_model import F_MAX, idle_power
from repro.serve import (
    BallooningConfig, EmergencyConfig, PlaneBundle, ResourceVector,
    ShardedServeConfig, ShardedServePipeline, device_state)
from repro.serve.featurizer import table_from_history
from repro.sim.scheduler_sim import (GB_PER_CORE, PredictionChannel,
                                     ServeBackendSpec, SimSpec,
                                     simulate)
from repro.sim.telemetry import arrival_batch, arrival_stamps

OUT_PATH = "BENCH_serve_resources.json"

# --- axis 1: joint vs power-only sweep ------------------------------------
#: sim fleet geometry (scheduler_sim constants): 60 chassis of
#: 12 x 40-core blades, GB_PER_CORE GB of DRAM per core
SIM_CHASSIS_CORES = 12 * 40
SIM_CHASSIS_GB = SIM_CHASSIS_CORES * GB_PER_CORE
SWEEP_DAYS = 0.5
SWEEP_SEED = 0
SWEEP_DEPLOYMENTS_PER_HOUR = 32.0
SWEEP_PREFILL = 0.45
#: per-chassis budgets: the emergency plane alarms at EMER_BUDGET_W;
#: the power-only arm admits up to the same watts, the joint arm
#: widens the *dynamic* watt span by JOINT_WATT_SPAN while capping
#: cores/GB at a fraction of physical capacity (the Coach restraint:
#: binding at the peak, ratcheted vacuous on the trough)
EMER_BUDGET_W = 2000.0
POWER_ONLY_W = 2000.0
JOINT_WATT_SPAN = 1.2
JOINT_CORES_FRAC = 0.85
JOINT_GB_FRAC = 0.9
#: noise floor for the critical-throttle comparison, seconds — one
#: emergency-plane tick of jitter must not flip a deterministic tie
UF_SLACK_S = 60.0

# --- axis 2: the mitigation ladder ----------------------------------------
LADDER_DAYS = 0.5
LADDER_PREFILL = 0.5
LADDER_EMER_W = BUDGET_2X            # 1860 — the paper's 2x headline
#: both ladder arms admit to the same widened watt ceiling, hot enough
#: that the migration rung actually fires without ballooning
LADDER_ADMIT_SPAN = 1.3

# --- axis 3: plane overhead at 4 shards -----------------------------------
BATCH_SIZE = 256
N_SHARDS = 4
#: full-fleet sweep cadence in micro-batches — the production
#: stream's every-4 (`benchmarks/serve_emergency`). Unlike the fused
#: power-only path, every ballooned sweep costs one *standalone*
#: sharded dispatch (the rung applies eagerly so its kernel reads the
#: live memory ledger — `pipeline._apply_caps`), so the overhead
#: scales with sweep cadence: ~10% at a 2x-stress every-2 cadence,
#: <1% here
SWEEP_EVERY = 4
BEST_OF = 5
STREAMS_PER_WALL = 2
#: acceptance bar: the joint ledger + ballooning rung cost < 5%
#: arrivals/s over the power-only ledger at 4 shards
MAX_OVERHEAD_FRAC = 0.05


def _static_w() -> float:
    return BLADES_PER_CHASSIS * float(idle_power(F_MAX))


def _widened_w(base_w: float, span: float) -> float:
    """Watt budget whose *dynamic* span above chassis idle is `span`
    times the base's (idle power is not oversubscribable)."""
    static = _static_w()
    return static + span * (base_w - static)


def _arm_metrics(m, wall_s: float) -> dict:
    return {"admitted": m.placements - m.failures,
            "failures": m.failures,
            "uf_throttled_s": m.uf_throttled_s,
            "nuf_throttled_s": m.nuf_throttled_s,
            "alarms": m.alarms, "migrations": m.migrations,
            "balloon_events": m.balloon_events,
            "balloon_reclaimed_gb": m.balloon_reclaimed_gb,
            "wall_s": wall_s}


def _sim_arm(name: str, spec: SimSpec) -> dict:
    t0 = time.perf_counter()
    m = simulate(SchedulerPolicy(), PredictionChannel("ml"), spec)
    row = {"name": name,
           **_arm_metrics(m, time.perf_counter() - t0)}
    emit(f"serve_resources/{name}", 0.0,
         f"admitted={row['admitted']} "
         f"uf_throttled_s={row['uf_throttled_s']:.0f} "
         f"migrations={row['migrations']}")
    return row


def sweep(smoke: bool = False) -> dict:
    """Power-only vs joint admission on the same diurnal trace;
    outside smoke, assert the capacity-at-equal-safety claim."""
    days = 0.1 if smoke else SWEEP_DAYS
    kw = dict(days=days, seed=SWEEP_SEED,
              deployments_per_hour=SWEEP_DEPLOYMENTS_PER_HOUR,
              prefill_core_ratio=SWEEP_PREFILL)
    ecfg = EmergencyConfig.from_model(EMER_BUDGET_W)
    joint_w = _widened_w(POWER_ONLY_W, JOINT_WATT_SPAN)
    joint_vec = ResourceVector(
        watts=joint_w, cores=JOINT_CORES_FRAC * SIM_CHASSIS_CORES,
        gb=JOINT_GB_FRAC * SIM_CHASSIS_GB)
    out = {**kw, "emergency_budget_w": EMER_BUDGET_W,
           "power_only_w": POWER_ONLY_W,
           "joint_budget": {"watts": joint_w,
                            "cores": joint_vec.cores,
                            "gb": joint_vec.gb},
           "uf_slack_s": UF_SLACK_S, "arms": []}
    power = _sim_arm("sweep/power-only", SimSpec(
        serve=ServeBackendSpec(
            backend="serve",
            admission_budget=ResourceVector(watts=POWER_ONLY_W)),
        emergency=ecfg, **kw))
    joint = _sim_arm("sweep/joint", SimSpec(
        serve=ServeBackendSpec(backend="serve",
                               admission_budget=joint_vec,
                               diurnal_ratchet=True),
        emergency=ecfg, ballooning=BallooningConfig(), **kw))
    out["arms"] = [power, joint]
    out["capacity_gain"] = joint["admitted"] / max(power["admitted"], 1)
    if not smoke:
        assert joint["admitted"] > power["admitted"], \
            f"joint admitted {joint['admitted']} <= power-only's " \
            f"{power['admitted']}"
        assert joint["uf_throttled_s"] \
            <= power["uf_throttled_s"] + UF_SLACK_S, \
            f"joint critical throttled-s {joint['uf_throttled_s']:.0f}" \
            f" exceeds power-only's {power['uf_throttled_s']:.0f}"
    return out


def ladder(smoke: bool = False) -> dict:
    """cap -> migrate vs cap -> balloon -> migrate at the same
    admission budget; outside smoke, assert the fewer-migrations
    claim."""
    days = 0.1 if smoke else LADDER_DAYS
    kw = dict(days=days, seed=SWEEP_SEED,
              deployments_per_hour=SWEEP_DEPLOYMENTS_PER_HOUR,
              prefill_core_ratio=LADDER_PREFILL)
    ecfg = EmergencyConfig.from_model(LADDER_EMER_W)
    admit = ResourceVector(
        watts=_widened_w(LADDER_EMER_W, LADDER_ADMIT_SPAN))
    out = {**kw, "emergency_budget_w": LADDER_EMER_W,
           "admission_w": admit.watts, "arms": []}
    base = _sim_arm("ladder/cap-migrate", SimSpec(
        serve=ServeBackendSpec(backend="serve",
                               admission_budget=admit),
        emergency=ecfg, **kw))
    rung = _sim_arm("ladder/cap-balloon-migrate", SimSpec(
        serve=ServeBackendSpec(backend="serve",
                               admission_budget=admit),
        emergency=ecfg, ballooning=BallooningConfig(), **kw))
    out["arms"] = [base, rung]
    if not smoke:
        assert base["migrations"] > 0, \
            "cap->migrate never migrated: the ladder comparison is vacuous"
        assert rung["migrations"] < base["migrations"], \
            f"ballooned ladder migrated {rung['migrations']}x, " \
            f"cap->migrate {base['migrations']}x"
        assert rung["uf_throttled_s"] \
            <= base["uf_throttled_s"] + UF_SLACK_S
        assert rung["balloon_events"] > 0
    return out


# --- axis 3: plane overhead at 4 shards -----------------------------------


def _make_pipe(svc, hist, labels, state, batch_size, joint_on: bool):
    cap = max(v.subscription for v in hist.vms) + 1024
    watts = N_CHASSIS * BUDGET_2X
    if joint_on:
        budget = ResourceVector(
            watts=watts,
            cores=0.9 * N_SERVERS * CORES_PER_SERVER,
            gb=0.9 * N_SERVERS * CORES_PER_SERVER * GB_PER_CORE)
    else:
        budget = ResourceVector(watts=watts)
    return ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(state), cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(
            batch_size=batch_size, n_shards=N_SHARDS,
            planes=PlaneBundle(
                cluster_budget=budget,
                emergency=EmergencyConfig.from_model(BUDGET_2X),
                ballooning=BallooningConfig() if joint_on else None)))


def _stream(pipe, arrivals, batch_size, sweep_power) -> None:
    """The `serve_emergency` stream with a full-fleet power sweep
    every ``SWEEP_EVERY`` micro-batches, so each sweep costs one
    emergency scan — and, joint plane on, one ballooning scan — per
    cap window."""
    n = len(arrivals.vms)
    stamps = arrival_stamps(n)
    cap_idx = np.arange(N_CHASSIS)
    for bi, lo in enumerate(range(0, n, batch_size)):
        idx = np.arange(lo, min(lo + batch_size, n))
        pipe.submit_to(0, arrival_batch(arrivals, idx), t=stamps[idx])
        if (bi + 1) % SWEEP_EVERY == 0:
            t0 = float(stamps[idx][-1])
            pipe.cap_to(0, cap_idx, sweep_power,
                        t=t0 + (cap_idx + 1) * 1e-7)
    pipe.flush()


def overhead(smoke: bool = False) -> dict:
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:256])
    bs = 64 if smoke else BATCH_SIZE
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    out = {"n_shards": N_SHARDS, "batch_size": bs,
           "n_arrivals": len(arrivals.vms),
           "max_overhead_frac": MAX_OVERHEAD_FRAC, "configs": []}
    # warm the jit caches once per variant, then ALTERNATE off/on
    # keeping the best (minimum) wall, each wall timing several
    # streams back to back — the serve_adaptive discipline
    # (docs/performance.md): process noise is one-sided, so
    # alternation + best-of cancels it
    for on in (False, True):
        _stream(_make_pipe(svc, hist, labels, warm, bs, on),
                arrivals, bs, sweep_power)
    per = 1 if smoke else STREAMS_PER_WALL
    walls = {False: np.inf, True: np.inf}
    for _ in range(1 if smoke else BEST_OF):
        for on in (False, True):
            pipes = [_make_pipe(svc, hist, labels, warm, bs, on)
                     for _ in range(per)]
            t0 = time.perf_counter()
            for pipe in pipes:
                _stream(pipe, arrivals, bs, sweep_power)
            walls[on] = min(walls[on],
                            (time.perf_counter() - t0) / per)
            for pipe in pipes:
                assert pipe.served == len(arrivals.vms)
    for on in (False, True):
        wall = walls[on]
        row = {"joint": on,
               "arrivals_per_s": len(arrivals.vms) / wall,
               "wall_s": wall}
        out["configs"].append(row)
        emit(f"serve_resources/shards{N_SHARDS}"
             f"/{'joint' if on else 'power-only'}",
             wall / max(len(arrivals.vms), 1) * 1e6,
             f"arrivals_per_s={row['arrivals_per_s']:.0f}")
    by = {r["joint"]: r["arrivals_per_s"] for r in out["configs"]}
    out["resource_plane_overhead_frac"] = 1.0 - by[True] / by[False]
    frac = out["resource_plane_overhead_frac"]
    emit("serve_resources/overhead_frac", 0.0, f"frac={frac:.4f}")
    if not smoke:
        assert frac < MAX_OVERHEAD_FRAC, \
            f"resource-plane overhead {frac:.1%} exceeds the " \
            f"{MAX_OVERHEAD_FRAC:.0%} acceptance bar at " \
            f"{N_SHARDS} shards"
    return out


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    if len(jax.devices()) < N_SHARDS \
            and "REPRO_SERVE_RESOURCES_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    out = {"sweep": sweep(smoke), "ladder": ladder(smoke),
           "overhead": overhead(smoke)}
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def _reexec(out_path: str, smoke: bool) -> dict:
    """Re-run in a fresh interpreter where the forced device count can
    still take effect (same trap as `benchmarks/serve_sharded`)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_resources"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd,
                   env=subproc_env("REPRO_SERVE_RESOURCES_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the 4-shard joint-plane row quickly and fail on a >30%
    arrivals/s drop vs the committed BENCH_serve_resources.json."""
    import jax
    if len(jax.devices()) < N_SHARDS:
        if "REPRO_SERVE_RESOURCES_SUBPROC" in os.environ:
            return [f"serve_resources: {len(jax.devices())} devices "
                    f"in subprocess, need {N_SHARDS}"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_resources",
             "--regress"],
            env=subproc_env("REPRO_SERVE_RESOURCES_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_resources: regress subprocess exited {rc}"]
    want = next(r for r in baseline["overhead"]["configs"]
                if r["joint"])
    hist, arrivals, labels, svc = _train(n_trees=48)
    arrivals = F.Population(vms=arrivals.vms[:768])
    warm = _warm_state()
    sweep_power = _sweep_power(warm)
    bs = baseline["overhead"]["batch_size"]
    _stream(_make_pipe(svc, hist, labels, warm, bs, True),
            arrivals, bs, sweep_power)
    walls = []
    for _ in range(3):              # best-of: CI noise is one-sided
        pipe = _make_pipe(svc, hist, labels, warm, bs, True)
        t0 = time.perf_counter()
        _stream(pipe, arrivals, bs, sweep_power)
        walls.append(time.perf_counter() - t0)
    measured = len(arrivals.vms) / min(walls)
    return regress_gate("serve_resources/shards4/joint/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
