"""Sharded serve-pipeline scaling: arrivals/s at 1/2/4/8 shards.

Runs the same arrival stream as `benchmarks/serve_online` through
`ShardedServePipeline` on a 64-chassis cluster (the fig-7 cluster
padded from 60 to 64 chassis so every shard count divides it), with
the shards mapped onto forced host-platform CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — set below
before JAX initializes). Each shard scans only B/N arrivals against
S/N servers, so the protocol wins twice: shorter scans per shard and
one scan per device in parallel under `shard_map`.

Both placement modes are measured (see `benchmarks/serve_online`):
`rank_rule` (full two-rule rank aggregation) and `algorithm1` (the
paper's literal §IV-E preference). Writes BENCH_serve_sharded.json
with per-shard-count rows and speedups vs the 1-shard run; `--smoke`
serves one small batch per shard count (CI).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

#: Must be set before jax initializes; when it is already too late
#: (another benchmark driver initialized the single-device backend
#: first), `run` re-executes itself in a subprocess — see `_reexec`.
_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

import numpy as np

from benchmarks.common import emit, subproc_env
from repro.core import features as F
from repro.core.placement import SchedulerPolicy
from repro.core.predictor import train_service
from repro.serve import ShardedServeConfig, ShardedServePipeline
from repro.sim.telemetry import arrival_batch, generate_population

OUT_PATH = "BENCH_serve_sharded.json"

N_HISTORY = 1500
N_ARRIVALS = 2048
BLADES_PER_CHASSIS = 12
N_CHASSIS = 64               # fig-7's 60 padded up so 1/2/4/8 divide
N_SERVERS = N_CHASSIS * BLADES_PER_CHASSIS
CORES_PER_SERVER = 40
BATCH_SIZE = 256
SHARD_COUNTS = (1, 2, 4, 8)
POLICIES = {"rank_rule": SchedulerPolicy(),
            "algorithm1": SchedulerPolicy(packing_weight=0.0)}


def _train(seed: int = 0, n_trees: int = 48):
    pop = generate_population(N_HISTORY + N_ARRIVALS, seed=seed)
    hist = F.Population(vms=pop.vms[:N_HISTORY])
    arrivals = F.Population(vms=pop.vms[N_HISTORY:])
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=n_trees, seed=seed)
    return hist, arrivals, labels, svc


def _make_pipe(svc, hist, labels, n_shards, policy, batch_size):
    return ShardedServePipeline.from_history(
        svc, hist, labels, n_servers=N_SERVERS,
        cores_per_server=CORES_PER_SERVER,
        blades_per_chassis=BLADES_PER_CHASSIS,
        config=ShardedServeConfig(batch_size=batch_size, policy=policy,
                                  n_shards=n_shards))


def _reexec(out_path: str, smoke: bool) -> dict:
    """Run the benchmark in a fresh interpreter where the forced
    device count can still take effect (XLA_FLAGS is read exactly once
    at backend init, so a parent that already initialized a
    single-device JAX — e.g. `benchmarks.run` after the serve driver —
    would silently measure the vmap fallback and overwrite the
    artifact with no-scaling rows)."""
    cmd = [sys.executable, "-m", "benchmarks.serve_sharded"]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, env=subproc_env("REPRO_SERVE_SHARDED_SUBPROC"),
                   check=True)
    if smoke:
        return {}
    with open(out_path) as f:
        return json.load(f)


def run(out_path: str = OUT_PATH, smoke: bool = False) -> dict:
    import jax
    shard_counts = (1, 4) if smoke else SHARD_COUNTS
    if len(jax.devices()) < max(shard_counts) \
            and "REPRO_SERVE_SHARDED_SUBPROC" not in os.environ:
        return _reexec(out_path, smoke)
    hist, arrivals, labels, svc = _train(n_trees=12 if smoke else 48)
    if smoke:
        arrivals = F.Population(vms=arrivals.vms[:128])
    bs = 64 if smoke else BATCH_SIZE
    out = {"n_servers": N_SERVERS, "batch_size": bs,
           "n_devices": len(jax.devices()),
           "n_arrivals": len(arrivals.vms), "modes": {}}
    batches = [arrival_batch(arrivals,
                             np.arange(i, min(i + bs,
                                              len(arrivals.vms))))
               for i in range(0, len(arrivals.vms), bs)]
    for mode, policy in POLICIES.items():
        rows = []
        for n_shards in shard_counts:
            pipe = _make_pipe(svc, hist, labels, n_shards, policy, bs)
            if len(batches) > 1:                 # jit trace, untimed
                pipe.serve(batches[0])
                rest = batches[1:]
            else:
                # single batch: warm a throwaway twin (compilation
                # caches are shared) so the timed pipe starts from a
                # clean, un-double-committed cluster
                _make_pipe(svc, hist, labels, n_shards, policy,
                           bs).serve(batches[0])
                rest = batches
            times = []
            for b in rest:
                t0 = time.perf_counter()
                pipe.serve(b)
                times.append(time.perf_counter() - t0)
            times = np.asarray(times)
            p50 = float(np.percentile(times, 50))
            row = {"n_shards": n_shards,
                   "shard_map": pipe.mesh is not None,
                   "arrivals_per_s": bs / p50,
                   "batch_p50_ms": p50 * 1e3,
                   "batch_p99_ms": float(np.percentile(times, 99) * 1e3),
                   "spill": pipe.spill_info}
            rows.append(row)
            emit(f"serve_sharded/{mode}/shards{n_shards}",
                 times.mean() * 1e6,
                 f"arrivals_per_s={row['arrivals_per_s']:.0f} "
                 f"p50={row['batch_p50_ms']:.2f}ms "
                 f"shard_map={row['shard_map']}")
        base = rows[0]["arrivals_per_s"]
        out["modes"][mode] = {
            "shards": rows,
            "speedup_vs_1shard": {f"shards{r['n_shards']}":
                                  r["arrivals_per_s"] / base
                                  for r in rows}}
    if not smoke:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def regress(baseline: dict) -> list:
    """Benchmark-regression gate (``benchmarks.run --regress``):
    re-measure the rank_rule 4-shard row (the headline speedup config,
    same batch size and forest, fewer arrivals) and fail on a >30%
    arrivals/s drop vs BENCH_serve_sharded.json. Re-execs itself when
    the parent already initialized a small-device JAX (same trap as
    `run`)."""
    from benchmarks.common import regress_gate
    import jax
    if len(jax.devices()) < 4:
        if "REPRO_SERVE_SHARDED_SUBPROC" in os.environ:
            return [f"serve_sharded: {len(jax.devices())} devices in "
                    "subprocess, need 4"]
        rc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serve_sharded",
             "--regress"],
            env=subproc_env("REPRO_SERVE_SHARDED_SUBPROC")).returncode
        return [] if rc == 0 else \
            [f"serve_sharded: regress subprocess exited {rc}"]
    want = next(r for r in baseline["modes"]["rank_rule"]["shards"]
                if r["n_shards"] == 4)
    bs = baseline["batch_size"]
    hist, arrivals, labels, svc = _train(n_trees=48)
    # as many timed batches as the baseline run: the 4-shard config
    # under forced host devices schedules noisily on small boxes, and
    # best-of needs samples to shed that one-sided noise
    arrivals = F.Population(vms=arrivals.vms[:8 * bs])
    batches = [arrival_batch(arrivals, np.arange(i, i + bs))
               for i in range(0, len(arrivals.vms), bs)]
    pipe = _make_pipe(svc, hist, labels, 4, POLICIES["rank_rule"], bs)
    pipe.serve(batches[0])                         # jit trace, untimed
    times = []
    for b in batches[1:]:
        t0 = time.perf_counter()
        pipe.serve(b)
        times.append(time.perf_counter() - t0)
    # best-of: regression noise on a small CI box is one-sided
    measured = bs / float(min(times))
    return regress_gate("serve_sharded/rank_rule/shards4/arrivals_per_s",
                        measured, want["arrivals_per_s"])


def _main() -> int:
    if "--regress" in sys.argv:
        with open(OUT_PATH) as f:
            baseline = json.load(f)
        failures = regress(baseline)
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1 if failures else 0
    run(smoke="--smoke" in sys.argv)
    return 0


if __name__ == "__main__":
    sys.exit(_main())
