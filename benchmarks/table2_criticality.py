"""Table II: pattern-matching vs ACF vs FFT — precision at recall
targets 0.99 / 0.98 on a labeled synthetic population (the paper used
840 manually labeled Azure workloads)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core.baselines import acf_score, fft_score, precision_at_recall
from repro.core.criticality import score
from repro.kernels.template.ops import criticality_scores
from repro.sim.telemetry import generate_population

PAPER = {("pattern", 0.99): 0.76, ("acf", 0.99): 0.54,
         ("fft", 0.99): 0.48, ("pattern", 0.98): 0.77,
         ("acf", 0.98): 0.56, ("fft", 0.98): 0.50}


def run(n_vms: int = 840, seed: int = 0):
    pop = generate_population(n_vms, seed=seed)
    s = jnp.asarray(pop.series)
    labels = pop.labels

    sc, us_pattern = timed(lambda: score(s).compare8.block_until_ready())
    scores = {
        "pattern": -np.asarray(score(s).compare8),
        "acf": np.asarray(acf_score(s)),
        "fft": np.asarray(fft_score(s)),
    }
    _, us_kernel = timed(
        lambda: criticality_scores(s).block_until_ready())
    rows = []
    for method in ("pattern", "acf", "fft"):
        for target in (0.99, 0.98):
            p, r, _ = precision_at_recall(scores[method], labels, target)
            rows.append((method, target, p, r,
                         PAPER[(method, target)]))
    for method, target, p, r, paper in rows:
        emit(f"table2/{method}@R{target}", us_pattern,
             f"precision={p:.3f} recall={r:.3f} paper={paper}")
    emit("table2/pallas_kernel_scoring", us_kernel,
         f"n={n_vms} fused-template-kernel")
    return rows


if __name__ == "__main__":
    run()
