"""Table III: RF vs GB criticality + two-stage P95 models — percent
high-confidence, per-bucket recall/precision, accuracy."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import features as F
from repro.core.criticality import classify
from repro.core.predictor import table3_metrics, train_service
from repro.kernels.forest.ops import forest_predict
from repro.sim.telemetry import generate_population

PAPER = {"rf": {"crit_acc": 0.98, "p95_acc": 0.84, "p95_hi": 0.73},
         "gb": {"crit_acc": 0.98, "p95_acc": 0.82, "p95_hi": 0.68}}


def run(n_vms: int = 4000, seed: int = 2):
    pop = generate_population(n_vms, seed=seed)
    hist, arr = F.split_history_arrivals(pop)
    hist_labels = np.asarray(classify(jnp.asarray(hist.series)))
    aggs = F.subscription_aggregates(hist, hist_labels)
    x = F.build_features(arr, aggs)
    y_uf = np.asarray(classify(jnp.asarray(arr.series))).astype(np.int64)
    y_p95 = F.p95_bucket(np.array([v.p95_util for v in arr.vms]))
    n = len(y_uf)
    tr, te = slice(0, int(0.7 * n)), slice(int(0.7 * n), n)

    out = {}
    for model in ("rf", "gb"):
        svc, us_train = timed(
            lambda m=model: train_service(x[tr], y_uf[tr], y_p95[tr],
                                          model=m, n_trees=48), repeat=1)
        m = table3_metrics(svc, x[te], y_uf[te], y_p95[te])
        out[model] = m
        c, p = m["criticality"], m["p95"]
        emit(f"table3/{model}/criticality", us_train,
             f"hi%={c['pct_high_conf']:.2f} acc={c['accuracy_high_conf']:.3f} "
             f"uf_recall={c['buckets'].get(1, {}).get('recall', 0):.2f} "
             f"paper_acc={PAPER[model]['crit_acc']}")
        emit(f"table3/{model}/p95", us_train,
             f"hi%={p['pct_high_conf']:.2f} acc={p['accuracy_high_conf']:.3f} "
             f"paper_acc={PAPER[model]['p95_acc']} "
             f"paper_hi%={PAPER[model]['p95_hi']}")
        # serve a prediction batch through the Pallas forest kernel
        _, us_pred = timed(lambda s=svc: np.asarray(
            forest_predict(s.criticality, x[te])))
        emit(f"table3/{model}/kernel_inference", us_pred,
             f"batch={te.stop - te.start}")
    return out


if __name__ == "__main__":
    run()
