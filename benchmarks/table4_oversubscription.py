"""Table IV: oversubscription % and $-savings for the eight provisioning
approaches (1440 chassis x 3 months of telemetry, 128 MW campus,
$10/W)."""
from __future__ import annotations


from benchmarks.common import emit, timed
from repro.core.oversubscription import FleetProfile, scenario_table
from repro.core.power_model import ServerPowerModel
from repro.sim.telemetry import generate_chassis_telemetry

PAPER = {"traditional": (0.0, 0.0),
         "state_of_the_art": (6.2, 79.4),
         "predictions_all_no_uf_impact": (11.0, 140.8),
         "predictions_all_minimal_uf_impact": (12.1, 154.9),
         "predictions_internal_no_uf_impact": (8.4, 107.5),
         "predictions_internal_minimal_uf_impact": (10.3, 131.8),
         "predictions_internal_non_premium_no_uf_impact": (10.6, 135.7),
         "predictions_internal_non_premium_minimal_uf_impact":
             (12.1, 154.9)}

PROVISIONED_W = 12 * 310.0          # 12 blades at SPECpower-style peak


def run(n_chassis: int = 1440, n_days: int = 90, seed: int = 0):
    draws, us_gen = timed(lambda: generate_chassis_telemetry(
        n_chassis, n_days, PROVISIONED_W, seed), repeat=1)
    fleet = FleetProfile(beta=0.40, util_uf=0.65, util_nuf=0.44,
                         allocated_frac=0.85, servers_per_chassis=12,
                         model=ServerPowerModel())
    rows, us = timed(lambda: scenario_table(
        draws, PROVISIONED_W, fleet, beta_internal_only=0.54,
        beta_non_premium=0.4225), repeat=1)
    for k, r in rows.items():
        paper_delta, paper_m = PAPER.get(k, (None, None))
        emit(f"table4/{k}", us / len(rows),
             f"delta={100 * r.oversubscription:.2f}% "
             f"savings=${r.savings_usd() / 1e6:.1f}M "
             f"paper={paper_delta}%/${paper_m}M "
             f"ufr={r.uf_event_rate:.5f} nufr={r.nuf_event_rate:.5f}")
    sota = rows["state_of_the_art"].oversubscription
    ours = rows["predictions_all_minimal_uf_impact"].oversubscription
    emit("table4/headline", 0.0,
         f"oversubscription_increase=x{ours / max(sota, 1e-9):.2f} "
         f"(paper: ~2x, 6.2% -> 12.1%)")
    return rows


if __name__ == "__main__":
    run()
