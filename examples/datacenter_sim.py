"""Full datacenter scenario: everything in the paper running together.

  telemetry -> criticality algorithm -> ML predictors -> 30-day cluster
  scheduling sim -> chassis capping dynamics -> oversubscription budget

    PYTHONPATH=src python examples/datacenter_sim.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core.criticality import classify
from repro.core.oversubscription import FleetProfile, scenario_table
from repro.core.placement import SchedulerPolicy
from repro.core.power_model import ServerPowerModel
from repro.core.predictor import train_service, table3_metrics
from repro.sim.chassis_sim import paper_chassis_specs, simulate_chassis
from repro.sim.scheduler_sim import (PredictionChannel, SimSpec,
                                     simulate)
from repro.sim.telemetry import (generate_chassis_telemetry,
                                 generate_population)

print("=== 1. criticality + predictors (Tables II/III) ===")
pop = generate_population(2000, seed=1)
hist, arr = F.split_history_arrivals(pop)
labels = np.asarray(classify(jnp.asarray(hist.series)))
aggs = F.subscription_aggregates(hist, labels)
svc = train_service(F.build_features(hist, aggs), labels.astype(np.int64),
                    F.p95_bucket([v.p95_util for v in hist.vms]))
m = table3_metrics(svc, F.build_features(arr, aggs),
                   np.asarray(classify(jnp.asarray(arr.series))).astype(np.int64),
                   F.p95_bucket([v.p95_util for v in arr.vms]))
print(f"criticality acc {m['criticality']['accuracy_high_conf']:.2f}, "
      f"p95 acc {m['p95']['accuracy_high_conf']:.2f} at "
      f"{m['p95']['pct_high_conf']:.0%} high-confidence")

print("=== 2. criticality-aware scheduling (Fig 7) ===")
base = simulate(SchedulerPolicy(use_power_rule=False),
                PredictionChannel("none"), SimSpec(days=6, seed=0))
ours = simulate(SchedulerPolicy(alpha=0.8), PredictionChannel("ml"),
                SimSpec(days=6, seed=0))
print(f"chassis balance std: {base.chassis_score_std:.3f} -> "
      f"{ours.chassis_score_std:.3f}; server balance std: "
      f"{base.server_score_std:.3f} -> {ours.server_score_std:.3f}")

print("=== 3. per-VM capping under a tight chassis budget (Fig 6) ===")
nc = simulate_chassis(paper_chassis_specs(True), None, "none", 180, 4)
rv = simulate_chassis(paper_chassis_specs(True), 2450.0, "per_vm", 180, 4)
print(f"balanced placement: UF p95 latency x"
      f"{rv.uf_p95_latency/nc.uf_p95_latency:.2f} under a 2450 W budget "
      f"(batch slowdown x{rv.nuf_slowdown:.2f})")

print("=== 4. oversubscription strategy (Table IV) ===")
fleet = FleetProfile(beta=0.4, util_uf=0.65, util_nuf=0.44,
                     allocated_frac=0.85, servers_per_chassis=12,
                     model=ServerPowerModel())
draws = generate_chassis_telemetry(256, 45, 3720.0, seed=0)
rows = scenario_table(draws, 3720.0, fleet, beta_internal_only=0.54,
                      beta_non_premium=0.4225)
sota = rows["state_of_the_art"]
ours_row = rows["predictions_all_minimal_uf_impact"]
print(f"state of the art: {sota.oversubscription:.1%}; with predictions: "
      f"{ours_row.oversubscription:.1%} "
      f"(x{ours_row.oversubscription/sota.oversubscription:.1f}, "
      f"paper: ~2x)")
