"""Quickstart: the paper's prediction pipeline in ~60 lines.

Generates a VM population, labels it with the criticality
pattern-matching algorithm (Pallas kernel), trains the Random-Forest
predictors, places arrivals with Algorithm 1, and computes the
oversubscribed chassis budget.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core.criticality import classify
from repro.core.oversubscription import (SCENARIOS, FleetProfile,
                                         compute_budget)
from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.power_model import ServerPowerModel
from repro.core.predictor import bucket_to_p95, train_service
from repro.sim.telemetry import (generate_chassis_telemetry,
                                 generate_population)

# 1 — label history with the criticality algorithm (paper §III-B)
pop = generate_population(1200, seed=0)
hist, arrivals = F.split_history_arrivals(pop)
labels = np.asarray(classify(jnp.asarray(hist.series)))
print(f"history: {len(hist.vms)} VMs, {labels.mean():.0%} user-facing")

# 2 — train the prediction service (paper §III-B, Table III)
aggs = F.subscription_aggregates(hist, labels)
svc = train_service(F.build_features(hist, aggs),
                    labels.astype(np.int64),
                    F.p95_bucket([v.p95_util for v in hist.vms]))

# 3 — place arrivals with criticality-aware Algorithm 1 (paper §III-C)
preds = svc.query(F.build_features(arrivals, aggs))
state = ClusterState(n_servers=36, cores_per_server=40,
                     chassis_of_server=np.arange(36) // 12, n_chassis=3)
policy = SchedulerPolicy(alpha=0.8)
for i, vm in enumerate(arrivals.vms[:150]):
    srv = policy.choose(state, vm.cores, bool(preds["workload_type_used"][i]))
    if srv is not None:
        state.place(srv, vm.cores,
                    float(bucket_to_p95(preds["p95_bucket_used"][i])),
                    bool(preds["workload_type_used"][i]))
print(f"placed 150 VMs; chassis balance std = "
      f"{np.std(state.score_chassis()):.3f}")

# 4 — oversubscribe the chassis budget (paper §III-E, Table IV)
fleet = FleetProfile(beta=0.4, util_uf=0.65, util_nuf=0.44,
                     allocated_frac=0.85, servers_per_chassis=12,
                     model=ServerPowerModel())
draws = generate_chassis_telemetry(64, 30, 3720.0, seed=0)
res = compute_budget(draws.ravel(), 3720.0,
                     SCENARIOS["predictions_minimal_uf_impact"], fleet)
print(f"oversubscription: {res.oversubscription:.1%} "
      f"(${res.savings_usd()/1e6:.0f}M on a 128 MW campus)")

# 5 — serve an arrival stream through the online pipeline (DESIGN §9)
from repro.serve import ServePipeline
from repro.sim.telemetry import arrival_batch, generate_population as gen

pipe = ServePipeline.from_history(svc, hist, labels, n_servers=36,
                                  cores_per_server=40,
                                  blades_per_chassis=12)
served = pipe.serve(arrival_batch(gen(256, seed=7)))
print(f"served 256 arrivals: {served.n_admitted} admitted, "
      f"{served.n_conservative} conservative fallbacks")
