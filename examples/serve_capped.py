"""Serving scenario: a user-facing LM serving job and a batch training
job share a chassis under an oversubscribed power budget. The per-VM
capping controller (paper §III-D) throttles only the batch job; the
serving job's decode latency stays flat.

    PYTHONPATH=src python examples/serve_capped.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import transformer as T
from repro.optim import get_optimizer
from repro.runtime.power_control import (ChassisPowerSim, JobSpec,
                                         ThrottledLoop)


def main():
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    # chassis with a serving job (user-facing) + training job (batch)
    chassis = ChassisPowerSim(budget_w=245.0)
    chassis.register(JobSpec("serve", cores=16, user_facing=True,
                             p95_util=0.7))
    chassis.register(JobSpec("train", cores=24, user_facing=False,
                             p95_util=1.0))
    serve_loop = ThrottledLoop(chassis, "serve", utilization=0.7)
    train_loop = ThrottledLoop(chassis, "train")

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    train = jax.jit(make_train_step(cfg, impl="naive", lr=1e-3),
                    donate_argnums=(0, 1))
    opt_state = get_optimizer(cfg.optimizer).init(params)

    B, S = 4, 48
    cache = T.init_cache(cfg, B, S)
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)),
                         jnp.int32)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (2, 32)), jnp.int32)}

    serve_lat, train_freqs = [], []
    t_params, t_opt = params, opt_state
    for i in range(32):
        # interleave: one decode step (user-facing) + one train step
        t0 = time.time()
        (logits, cache), m_s = serve_loop.run_step(
            serve, params, cache,
            {"tokens": tokens, "cache_index": jnp.asarray(i, jnp.int32)})
        serve_lat.append(time.time() - t0)
        (t_params, t_opt, m), m_t = train_loop.run_step(
            train, t_params, t_opt, batch)
        train_freqs.append(m_t["freq"])
        tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    print(f"[serve_capped] chassis budget 245 W")
    print(f"  serve (user-facing): freq stayed at "
          f"{chassis.job_frequency('serve'):.2f}, p95 decode latency "
          f"{np.percentile(serve_lat, 95)*1e3:.0f} ms")
    print(f"  train (batch): throttled to min freq "
          f"{min(train_freqs):.2f} under the budget")
    assert chassis.job_frequency("serve") == 1.0
    assert min(train_freqs) < 1.0


if __name__ == "__main__":
    main()
