"""End-to-end training driver: train a ~100M-parameter LM for a few
hundred steps with the full production stack — data prefetch, AdamW,
checkpoint/restart — optionally under the paper's power-capping control
plane (the job is tagged non-user-facing and gets throttled when the
chassis is tight).

    PYTHONPATH=src python examples/train_lm.py                  # ~20M demo
    PYTHONPATH=src python examples/train_lm.py --params-100m    # ~100M
    PYTHONPATH=src python examples/train_lm.py --power-capped
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import get_optimizer
from repro.runtime.power_control import (ChassisPowerSim, JobSpec,
                                         ThrottledLoop)


def demo_config(params_100m: bool) -> ModelConfig:
    if params_100m:
        return ModelConfig(name="demo-100m", family="dense", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4,
                           d_ff=3072, vocab_size=32000, head_dim=64)
    return ModelConfig(name="demo-20m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=2, d_ff=1536,
                       vocab_size=16000, head_dim=64)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--params-100m", action="store_true")
    ap.add_argument("--power-capped", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args(argv)

    cfg = demo_config(args.params_100m)
    print(f"[train_lm] {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = get_optimizer(cfg.optimizer)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, impl="naive", lr=args.lr),
                   donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    prefetch = Prefetcher(data)
    ckpt = Checkpointer(args.ckpt_dir, keep_last=2)

    throttle = None
    if args.power_capped:
        chassis = ChassisPowerSim(budget_w=250.0)
        chassis.register(JobSpec("latency-svc", 12, True, 0.65))
        chassis.register(JobSpec("this-job", 28, False, 1.0))
        throttle = ThrottledLoop(chassis, "this-job")

    t0 = time.time()
    losses = []
    for i in range(args.steps):
        _, batch = prefetch.next()
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if throttle is None:
            params, opt_state, m = step(params, opt_state, b)
        else:
            (params, opt_state, m), pw = throttle.run_step(
                step, params, opt_state, b)
        losses.append(float(m["loss"]))
        if (i + 1) % 50 == 0:
            ckpt.save(i + 1, {"params": params})
            msg = f"[train_lm] step {i+1}: loss {np.mean(losses[-20:]):.3f}"
            if throttle is not None:
                msg += f" freq {pw['freq']:.2f}"
            print(msg, flush=True)
    prefetch.close()
    dt = time.time() - t0
    print(f"[train_lm] {args.steps} steps in {dt:.0f}s "
          f"({dt/args.steps*1e3:.0f} ms/step); "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-20:]):.3f}")
    assert np.mean(losses[-20:]) < losses[0], "training must converge"


if __name__ == "__main__":
    main()
