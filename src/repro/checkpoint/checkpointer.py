"""Sharded, atomic checkpointing with elastic restore (no orbax).

Layout: <dir>/step_<N>/
    meta.json            — step, flat key list, shapes/dtypes, mesh info
    shard_<i>.npz        — one file per host-shard group (here: single
                           host; keys are flat 'a/b/c' paths)
    COMMIT               — written last; a checkpoint without COMMIT is
                           ignored (atomic rename + commit marker)

Fault-tolerance contract (paper-style restart):
  * save() is atomic: partial writes never corrupt the latest checkpoint;
  * restore() picks the newest committed step;
  * elastic restore: arrays are saved UNSHARDED per key (gathered), so a
    restart may use a different mesh/topology and re-shard on load —
    the elastic-scaling path (runtime/elastic.py) relies on this;
  * keep_last rotates old checkpoints out.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        flat, _ = _flatten(tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {}
        meta = {"step": step, "keys": {}}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jax.numpy.bfloat16:
                meta["keys"][key] = {"dtype": "bfloat16",
                                     "shape": list(arr.shape)}
                arr = arr.view(np.uint16)
            else:
                meta["keys"][key] = {"dtype": str(arr.dtype),
                                     "shape": list(arr.shape)}
            arrays[key.replace("/", "__")] = arr
        np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._rotate()
        return final

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of `tree_like`. With `shardings`
        (a matching pytree of NamedSharding), arrays are placed sharded —
        the elastic re-shard path for a different mesh than at save."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in "
                                    f"{self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        flat_like, treedef = _flatten(tree_like)
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        out = {}
        for key in flat_like:
            arr = data[key.replace("/", "__")]
            info = meta["keys"][key]
            if info["dtype"] == "bfloat16":
                arr = arr.view(jax.numpy.bfloat16)
            if shard_flat is not None:
                out[key] = jax.device_put(arr, shard_flat[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        leaves = [out[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, leaves), step
