"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf] — 128-expert
top-2 MoE with a dense SwiGLU residual branch.

468B total parameters: Adafactor optimizer (fp32 Adam moments would not
fit the single-pod mesh; see DESIGN.md §5/§6 and EXPERIMENTS.md §Dry-run).
Full attention => skips long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    mlp="swiglu", n_experts=128, experts_per_token=2,
    moe_d_ff=4864, moe_dense_residual=True,
    optimizer="adafactor", grad_accum_dtype="bfloat16",
    rope_theta=1e4,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)
