"""Model/config dataclasses shared by every architecture."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    norm: str = "rmsnorm"
    rope: bool = True
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    qkv_bias: bool = False
    sliding_window: int | None = None
    # MoE
    n_experts: int = 0
    experts_per_token: int = 2
    moe_d_ff: int | None = None
    moe_dense_residual: bool = False
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0              # hybrid: shared attn every k layers
    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    frontend: str | None = None      # 'audio' | 'vision' (stub)
    # training
    optimizer: str = "adamw"         # adamw | adafactor
    #: gradient-accumulation dtype; bf16 halves accumulator memory for
    #: the biggest models (arctic: fp32 accumulators alone are 7.3 GiB
    #: per device at 256 chips)
    grad_accum_dtype: str = "float32"
    remat: bool = True
    # metadata
    source: str = ""
    sub_quadratic: bool = False      # can run long_500k

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    def encoder_cfg(self) -> "ModelConfig":
        """Whisper encoder layers: non-causal dense blocks, no rope."""
        return dataclasses.replace(
            self, family="dense", rope=False, n_experts=0,
            n_kv_heads=self.n_heads)

    def reduced(self) -> "ModelConfig":
        """Smoke-test configuration of the same family: small widths,
        few layers/experts, tiny vocab — same code paths."""
        return dataclasses.replace(
            self,
            n_layers=max(2, self.attn_every or 2),
            d_model=64,
            n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads
            else 4,
            head_dim=16,
            d_ff=128, vocab_size=512,
            moe_d_ff=64 if self.n_experts else None,
            n_experts=min(self.n_experts, 4),
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=32 if self.encoder_layers else 1500,
            sliding_window=64 if self.sliding_window else None,
            mrope_sections=(4, 2, 2) if self.mrope else (16, 24, 24),
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        hd = self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.mlp == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "ssm":
            d_inner = self.ssm_expand * d
            nheads = d_inner // self.ssm_head_dim
            block = d * (2 * d_inner + 2 * self.ssm_state + nheads) \
                + d_inner * d
        elif self.n_experts > 0:
            eff = self.moe_d_ff or self.d_ff
            block = attn + self.n_experts * 3 * d * eff + d * \
                self.n_experts
            if self.moe_dense_residual:
                block += 3 * d * self.d_ff
        elif self.family == "hybrid":
            d_inner = self.ssm_expand * d
            nheads = d_inner // self.ssm_head_dim
            block = d * (2 * d_inner + 2 * self.ssm_state + nheads) \
                + d_inner * d
        else:
            block = attn + mlp
        total = 2 * v * d + self.n_layers * block
        if self.family == "hybrid":
            total += attn          # one shared attention block
        if self.family == "audio":
            total += self.encoder_layers * (attn + mlp) \
                + self.n_layers * attn          # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        eff = self.moe_d_ff or self.d_ff
        hd = self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        block = attn + self.experts_per_token * 3 * d * eff
        if self.moe_dense_residual:
            block += 3 * d * self.d_ff
        return int(2 * self.vocab_size * d + self.n_layers * block)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
