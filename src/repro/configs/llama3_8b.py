"""llama3-8b [arXiv:2407.21783; unverified] — dense GQA, 128k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    mlp="swiglu", rope_theta=5e5,
    source="arXiv:2407.21783; unverified",
)
