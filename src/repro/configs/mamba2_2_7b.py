"""mamba2-2.7b [arXiv:2405.21060; unverified] — SSD, attention-free.

Attention-free => sub-quadratic => runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    rope=False, sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
)
