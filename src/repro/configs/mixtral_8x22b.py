"""mixtral-8x22b [arXiv:2401.04088; hf] — 8-expert top-2 MoE + SWA.

Sliding-window attention (4096) => sub-quadratic => runs long_500k with a
rolling window cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    mlp="swiglu", n_experts=8, experts_per_token=2,
    sliding_window=4096, rope_theta=1e6, sub_quadratic=True,
    source="arXiv:2401.04088; hf",
)
