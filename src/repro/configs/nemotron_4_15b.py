"""nemotron-4-15b [arXiv:2402.16819; unverified] — GQA, squared-ReLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    mlp="relu2", norm="layernorm", rope_theta=1e4,
    source="arXiv:2402.16819; unverified",
)
