"""qwen2-vl-72b [arXiv:2409.12191; hf] — VLM backbone with M-RoPE.

Backbone only: the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings that replace the first positions of the
sequence (dynamic resolution handling is out of scope per assignment).
Full attention => skips long_500k. Adafactor (72B).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, head_dim=128,
    mlp="swiglu", qkv_bias=True, mrope=True,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision", optimizer="adafactor",
    source="arXiv:2409.12191; hf",
)
