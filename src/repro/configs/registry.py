"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.configs.llama3_8b import CONFIG as llama3_8b
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.mixtral_8x22b import CONFIG as mixtral_8x22b
from repro.configs.nemotron_4_15b import CONFIG as nemotron_4_15b
from repro.configs.phi4_mini_3_8b import CONFIG as phi4_mini_3_8b
from repro.configs.qwen2_5_32b import CONFIG as qwen2_5_32b
from repro.configs.qwen2_vl_72b import CONFIG as qwen2_vl_72b
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.zamba2_2_7b import CONFIG as zamba2_2_7b

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    phi4_mini_3_8b, llama3_8b, nemotron_4_15b, qwen2_5_32b,
    mamba2_2_7b, mixtral_8x22b, arctic_480b, zamba2_2_7b,
    qwen2_vl_72b, whisper_tiny,
]}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(runnable, reason). long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped " \
            "(DESIGN.md §5)"
    return True, ""


def all_cells():
    """Every (arch x shape) cell with its skip status — 40 total."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = cell_is_runnable(arch, shape)
            yield arch, shape, ok, reason
