"""whisper-tiny [arXiv:2212.04356; unverified] — enc-dec with a stubbed
conv frontend: input_specs() provides precomputed 1500-frame embeddings
(post-conv mel features). kv=6 == heads (MHA). Full attention => skips
long_500k; enc-dec (not encoder-only) => decode shapes run."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    mlp="gelu", norm="layernorm", rope=False,
    encoder_layers=4, encoder_frames=1500, frontend="audio",
    source="arXiv:2212.04356; unverified",
)
