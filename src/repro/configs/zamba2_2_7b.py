"""zamba2-2.7b [arXiv:2411.15242; hf] — Mamba2 backbone with a weight-
shared attention block applied every 6 layers (GQA kv=32 => MHA).

SSM backbone => sub-quadratic => runs long_500k (shared-attention KV
grows, but only for n_layers/6 = 9 shared applications).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6, rope_theta=1e4, sub_quadratic=True,
    source="arXiv:2411.15242; hf",
)
