"""Periodicity-detection baselines the paper compares against (Table II).

FFT: [Cortez et al., SOSP'17] assume a workload is user-facing if the FFT
indicates a 24-hour period. ACF: autocorrelation at the 24-hour lag.

Per the paper's methodology, both baselines get the *same* preprocessing
(de-trend + normalize) and the same machine-generated disambiguation
(compare the 24h signal against the 8h/12h harmonics). Each returns a
continuous "user-facing-ness" score so the Table II benchmark can sweep a
threshold to a recall target and report the achieved precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import timeseries as ts
from repro.core.criticality import PERIOD_12H, PERIOD_24H, PERIOD_8H

_EPS = 1e-9


@jax.jit
def fft_score(series: jnp.ndarray) -> jnp.ndarray:
    """Higher = more user-facing. (B, T) -> (B,).

    Power at the 24h frequency relative to (24h + its 8h/12h competitors
    + broadband residual). T must be a multiple of 48.
    """
    x = ts.preprocess(series)
    x = x - jnp.mean(x, axis=-1, keepdims=True)
    t = x.shape[-1]
    spec = jnp.abs(jnp.fft.rfft(x, axis=-1)) ** 2            # (B, T//2+1)
    k24 = t // PERIOD_24H        # cycles of the 24h period in the window
    k12 = t // PERIOD_12H
    k8 = t // PERIOD_8H
    p24 = spec[..., k24]
    p12 = spec[..., k12]
    p8 = spec[..., k8]
    total = jnp.sum(spec[..., 1:], axis=-1)
    # 24h share of total energy, discounted by short-period harmonics
    # (machine-generated disambiguation).
    return (p24 - jnp.maximum(p12, p8)) / jnp.maximum(total, _EPS)


def _acf_at(x: jnp.ndarray, lag: int) -> jnp.ndarray:
    a = x[..., :-lag]
    b = x[..., lag:]
    a = a - jnp.mean(a, axis=-1, keepdims=True)
    b = b - jnp.mean(b, axis=-1, keepdims=True)
    num = jnp.sum(a * b, axis=-1)
    den = jnp.sqrt(jnp.sum(a * a, axis=-1) * jnp.sum(b * b, axis=-1))
    return num / jnp.maximum(den, _EPS)


@jax.jit
def acf_score(series: jnp.ndarray) -> jnp.ndarray:
    """Higher = more user-facing. Autocorrelation at the 24h lag minus the
    stronger of the 8h/12h lags (same disambiguation as fft_score)."""
    x = ts.preprocess(series)
    r24 = _acf_at(x, PERIOD_24H)
    r12 = _acf_at(x, PERIOD_12H)
    r8 = _acf_at(x, PERIOD_8H)
    return r24 - jnp.maximum(r12, r8)


def precision_at_recall(scores, labels, recall_target: float):
    """Sweep a threshold on `scores` (higher = predicted UF) to reach
    `recall_target` on the true-UF class; return (precision, recall,
    threshold). numpy-side helper used by Table II."""
    import numpy as np

    scores = np.asarray(scores, dtype=np.float64)
    labels = np.asarray(labels, dtype=bool)
    order = np.argsort(-scores)               # descending score
    sorted_labels = labels[order]
    tp = np.cumsum(sorted_labels)
    fp = np.cumsum(~sorted_labels)
    n_pos = max(int(labels.sum()), 1)
    recall = tp / n_pos
    precision = tp / np.maximum(tp + fp, 1)
    ok = np.nonzero(recall >= recall_target)[0]
    if len(ok) == 0:
        return 0.0, float(recall[-1]), float(scores[order][-1])
    i = ok[0]
    return float(precision[i]), float(recall[i]), float(scores[order][i])
