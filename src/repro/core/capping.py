"""Per-VM power capping controller + full-server RAPL model (paper §III-D).

The hybrid design, faithful to the paper:

  * the chassis manager polls PSUs every 200 ms and alerts the in-band
    per-VM controller when chassis draw crosses a threshold *just below*
    the chassis budget (we use budget - ALERT_MARGIN_W, matching the
    paper's 225 W target for a 230 W cap);
  * on alert, the controller immediately drops every low-priority
    (non-user-facing) core to the minimum p-state (f_max/2);
  * it then runs a feedback loop: each iteration reads the server power
    meter and raises N = 4 low-priority cores to the next higher p-state
    while power stays below the target, or lowers them if above;
  * the cap is lifted LIFT_AFTER_S = 30 s after the alert clears;
  * out-of-band backup: if power still exceeds the *server* budget (PSU
    alert -> BMC), RAPL throttles ALL cores equally (user-facing
    included) until under — "protection from overdraw must take
    precedence over performance loss". RAPL converges within ~2 s.

The classes here are small per-server adapters kept for the original
object API (tests, examples). The actual dynamics live in
`repro.core.fleet_dynamics.fleet_step`, a pure fixed-shape transition
over padded (n_servers, n_cores) arrays with identical numpy and jnp
paths; `repro.sim.fleet` scans/vmaps it over time and chassis, and
`repro.runtime.power_control` runs the jnp twin under the framework.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.fleet_dynamics import (ALERT_FRACTION, ALERT_MARGIN_W,
                                       LIFT_AFTER_S, N_RAISE,
                                       POLL_INTERVAL_S, PSU_TRIP_MARGIN_W,
                                       RAISE_HEADROOM_W, RAPL_STEP_FRAC,
                                       ControlParams, FleetState,
                                       RunParams, inband_step, rapl_step)
from repro.core.power_model import (F_MAX, N_PSTATES, ServerPowerModel,
                                    pstate_frequencies)

__all__ = ["POLL_INTERVAL_S", "ALERT_MARGIN_W", "LIFT_AFTER_S", "N_RAISE",
           "RAPL_STEP_FRAC", "RAISE_HEADROOM_W", "PSU_TRIP_MARGIN_W",
           "ServerCapState", "PerVMController", "RaplController",
           "ChassisManager"]


@dataclass
class ServerCapState:
    """Mutable controller state for one server."""
    n_cores: int
    uf_mask: np.ndarray                       # (n_cores,) True = high-prio
    freq: np.ndarray = field(default=None)    # (n_cores,) current frequency
    pstate: np.ndarray = field(default=None)  # (n_cores,) index into table
    capping: bool = False
    rapl_active: bool = False
    clear_since_s: float = np.inf             # time since alert cleared

    def __post_init__(self):
        if self.freq is None:
            self.freq = np.full(self.n_cores, F_MAX, dtype=np.float32)
        if self.pstate is None:
            self.pstate = np.zeros(self.n_cores, dtype=np.int32)

    def _pack(self) -> FleetState:
        """View as a (1, n_cores) fleet state for the shared transition."""
        return FleetState(
            freq=np.asarray(self.freq, np.float32).reshape(1, -1),
            pstate=np.asarray(self.pstate, np.int32).reshape(1, -1),
            capping=np.array([self.capping]),
            rapl=np.array([self.rapl_active]),
            clear_s=np.array([self.clear_since_s], np.float32))

    def _unpack(self, fs: FleetState) -> None:
        self.freq = np.asarray(fs.freq[0])
        self.pstate = np.asarray(fs.pstate[0])
        self.capping = bool(fs.capping[0])
        self.rapl_active = bool(fs.rapl[0])
        self.clear_since_s = float(fs.clear_s[0])

    def _run_params(self, budget_w: float) -> RunParams:
        return RunParams(
            server_budget_w=np.float32(budget_w),
            target_w=np.float32(budget_w - ALERT_MARGIN_W),
            alert_w=np.float32(np.inf),
            min_pstate=np.int32(N_PSTATES - 1),
            uf_mask=np.asarray(self.uf_mask, bool).reshape(1, -1),
            active=None)


class PerVMController:
    """In-band controller for one server (paper Fig. 2 steps 4-5)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w
        self.target = server_budget_w - ALERT_MARGIN_W
        self.freq_table = pstate_frequencies(N_PSTATES)  # descending
        self.min_pstate = N_PSTATES - 1
        self._cp = ControlParams.from_model(model, mode="per_vm")

    def step(self, st: ServerCapState, util: np.ndarray, alert: bool,
             dt: float = POLL_INTERVAL_S) -> float:
        """One 200 ms control step. `util` = per-core utilization (0-1),
        `alert` = chassis-manager alert. Returns the server power draw
        AFTER the control action (what the next poll would read)."""
        cp = self._cp if dt == self._cp.dt else replace(self._cp, dt=dt)
        fs, p = inband_step(
            cp, st._run_params(self.budget), st._pack(),
            np.asarray(util, np.float32).reshape(1, -1),
            np.array([alert]), np)
        st._unpack(fs)
        return float(p[0])


class RaplController:
    """Out-of-band full-server capping (existing mechanism, and the
    backup when per-VM capping is insufficient). Throttles the whole
    socket — all cores equally (paper §II-B)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w
        self._cp = ControlParams.from_model(model, mode="rapl")

    def step(self, st: ServerCapState, util: np.ndarray,
             dt: float = POLL_INTERVAL_S) -> float:
        fs, p = rapl_step(
            self._cp, st._run_params(self.budget), st._pack(),
            np.asarray(util, np.float32).reshape(1, -1),
            np.ones(1, bool), np)
        st._unpack(fs)
        return float(p[0])


@dataclass(frozen=True)
class ChassisManager:
    """Polls PSUs and raises alerts (paper Fig. 2 step 4). The alert
    threshold sits just below the chassis budget so the in-band
    controller can act before the PSU->BMC hardware path must."""
    chassis_budget_w: float
    alert_fraction: float = ALERT_FRACTION

    @property
    def alert_threshold_w(self) -> float:
        return self.chassis_budget_w * self.alert_fraction

    def poll(self, chassis_power_w: float) -> bool:
        return chassis_power_w >= self.alert_threshold_w
