"""Per-VM power capping controller + full-server RAPL model (paper §III-D).

The hybrid design, faithful to the paper:

  * the chassis manager polls PSUs every 200 ms and alerts the in-band
    per-VM controller when chassis draw crosses a threshold *just below*
    the chassis budget (we use budget - ALERT_MARGIN_W, matching the
    paper's 225 W target for a 230 W cap);
  * on alert, the controller immediately drops every low-priority
    (non-user-facing) core to the minimum p-state (f_max/2);
  * it then runs a feedback loop: each iteration reads the server power
    meter and raises N = 4 low-priority cores to the next higher p-state
    while power stays below the target, or lowers them if above;
  * the cap is lifted LIFT_AFTER_S = 30 s after the alert clears;
  * out-of-band backup: if power still exceeds the *server* budget (PSU
    alert -> BMC), RAPL throttles ALL cores equally (user-facing
    included) until under — "protection from overdraw must take
    precedence over performance loss". RAPL converges within ~2 s.

The controller is a pure state-transition function over fixed-shape
arrays, so the chassis simulator can scan it over time; a jnp twin
(`repro.runtime.power_control`) drives the training-loop integration.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.power_model import (F_MAX, F_MIN, N_PSTATES,
                                    ServerPowerModel, pstate_frequencies)

POLL_INTERVAL_S = 0.2       # 200 ms PSU polling
ALERT_MARGIN_W = 5.0        # controller target sits 5 W under the cap
LIFT_AFTER_S = 30.0         # cap lifted 30 s after alert clears
N_RAISE = 4                 # cores stepped up per feedback iteration
RAPL_STEP_FRAC = 0.05       # RAPL lowers all-core frequency 5 %/poll
                            # (reaches f_min from f_max within 2 s)
RAISE_HEADROOM_W = 2.0      # feedback-raise safety margin below target
PSU_TRIP_MARGIN_W = 2.0     # PSU averaging window: sub-poll transients
                            # this small do not trip the out-of-band path


@dataclass
class ServerCapState:
    """Mutable controller state for one server."""
    n_cores: int
    uf_mask: np.ndarray                       # (n_cores,) True = high-prio
    freq: np.ndarray = field(default=None)    # (n_cores,) current frequency
    pstate: np.ndarray = field(default=None)  # (n_cores,) index into table
    capping: bool = False
    rapl_active: bool = False
    clear_since_s: float = np.inf             # time since alert cleared

    def __post_init__(self):
        if self.freq is None:
            self.freq = np.full(self.n_cores, F_MAX)
        if self.pstate is None:
            self.pstate = np.zeros(self.n_cores, dtype=np.int64)


class PerVMController:
    """In-band controller for one server (paper Fig. 2 steps 4-5)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w
        self.target = server_budget_w - ALERT_MARGIN_W
        self.freq_table = pstate_frequencies(N_PSTATES)  # descending
        self.min_pstate = N_PSTATES - 1

    def step(self, st: ServerCapState, util: np.ndarray, alert: bool,
             dt: float = POLL_INTERVAL_S) -> float:
        """One 200 ms control step. `util` = per-core utilization (0-1),
        `alert` = chassis-manager alert. Returns the server power draw
        AFTER the control action (what the next poll would read)."""
        power = self.model.power(util, st.freq)
        low = ~st.uf_mask
        if alert and power > self.target and not st.capping:
            # Immediate drop of all low-priority cores to min p-state.
            st.capping = True
            st.clear_since_s = 0.0
            st.pstate[low] = self.min_pstate
        elif st.capping:
            if alert or power > self.target:
                st.clear_since_s = 0.0
            else:
                st.clear_since_s += dt
            if st.clear_since_s >= LIFT_AFTER_S:
                # lift the cap: all cores back to maximum performance
                st.capping = False
                st.rapl_active = False
                st.pstate[:] = 0
            elif power > self.target:
                self._lower(st, low)
            else:
                self._raise_if_headroom(st, low, util)
        if st.rapl_active:
            # respect RAPL's out-of-band reductions while they persist
            st.freq = np.minimum(self.freq_table[st.pstate], st.freq)
        else:
            st.freq = self.freq_table[st.pstate]
        return float(self.model.power(util, st.freq))

    def _lower(self, st, low):
        """Lower the N lowest-frequency... highest-frequency low-priority
        cores one p-state (fastest power shed without touching UF)."""
        idx = np.nonzero(low & (st.pstate < self.min_pstate))[0]
        if len(idx) == 0:
            return
        order = np.argsort(st.pstate[idx])       # highest-freq cores first
        sel = idx[order[:N_RAISE]]
        st.pstate[sel] += 1

    def _raise_if_headroom(self, st, low, util):
        """Feedback recovery: raise N low-priority cores to the next
        higher p-state, but only if the predicted power stays below the
        target ('selects the highest frequency that keeps the power below
        this threshold')."""
        idx = np.nonzero(low & (st.pstate > 0))[0]
        if len(idx) == 0:
            return
        order = np.argsort(-st.pstate[idx])      # lowest-freq cores first
        sel = idx[order[:N_RAISE]]
        trial = st.pstate.copy()
        trial[sel] -= 1
        trial_power = self.model.power(util, self.freq_table[trial])
        # small safety margin so inter-poll load spikes rarely push the
        # draw over the hard budget (which would trip the PSU->BMC path)
        if trial_power < self.target - RAISE_HEADROOM_W:
            st.pstate = trial


class RaplController:
    """Out-of-band full-server capping (existing mechanism, and the
    backup when per-VM capping is insufficient). Throttles the whole
    socket — all cores equally (paper §II-B)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w

    def step(self, st: ServerCapState, util: np.ndarray,
             dt: float = POLL_INTERVAL_S) -> float:
        power = self.model.power(util, st.freq)
        table = pstate_frequencies(N_PSTATES)
        intended = table[st.pstate]         # in-band controller's setting
        if power > self.budget:
            st.rapl_active = True
            uniform = max(st.freq.max() - RAPL_STEP_FRAC * F_MAX, F_MIN)
            st.freq = np.minimum(st.freq, uniform)
        elif st.rapl_active:
            if power < self.budget - 2 * ALERT_MARGIN_W:
                # RAPL's feedback loop restores frequency gradually,
                # handing control back to the in-band setting
                st.freq = np.minimum(st.freq + RAPL_STEP_FRAC * F_MAX,
                                     intended)
            if np.all(st.freq >= intended - 1e-9):
                st.rapl_active = False
        return float(self.model.power(util, st.freq))


@dataclass(frozen=True)
class ChassisManager:
    """Polls PSUs and raises alerts (paper Fig. 2 step 4). The alert
    threshold sits just below the chassis budget so the in-band
    controller can act before the PSU->BMC hardware path must."""
    chassis_budget_w: float
    alert_fraction: float = 0.97    # alert at 97 % of the chassis budget

    @property
    def alert_threshold_w(self) -> float:
        return self.chassis_budget_w * self.alert_fraction

    def poll(self, chassis_power_w: float) -> bool:
        return chassis_power_w >= self.alert_threshold_w
