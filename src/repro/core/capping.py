"""Per-VM power capping controller + full-server RAPL model (paper §III-D).

The hybrid design, faithful to the paper:

  * the chassis manager polls PSUs every 200 ms and alerts the in-band
    per-VM controller when chassis draw crosses a threshold *just below*
    the chassis budget (we use budget - ALERT_MARGIN_W, matching the
    paper's 225 W target for a 230 W cap);
  * on alert, the controller immediately drops every low-priority
    (non-user-facing) core to the minimum p-state (f_max/2);
  * it then runs a feedback loop: each iteration reads the server power
    meter and raises N = 4 low-priority cores to the next higher p-state
    while power stays below the target, or lowers them if above;
  * the cap is lifted LIFT_AFTER_S = 30 s after the alert clears;
  * out-of-band backup: if power still exceeds the *server* budget (PSU
    alert -> BMC), RAPL throttles ALL cores equally (user-facing
    included) until under — "protection from overdraw must take
    precedence over performance loss". RAPL converges within ~2 s.

The classes here are small per-server adapters kept for the original
object API (tests, examples). The actual dynamics live in
`repro.core.fleet_dynamics.fleet_step`, a pure fixed-shape transition
over padded (n_servers, n_cores) arrays with identical numpy and jnp
paths; `repro.sim.fleet` scans/vmaps it over time and chassis, and
`repro.runtime.power_control` runs the jnp twin under the framework.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.fleet_dynamics import (ALERT_FRACTION, ALERT_MARGIN_W,
                                       FREQ_TABLE, LIFT_AFTER_S, N_RAISE,
                                       POLL_INTERVAL_S, PSU_TRIP_MARGIN_W,
                                       RAISE_HEADROOM_W, RAPL_STEP_FRAC,
                                       ControlParams, FleetState,
                                       RunParams, inband_step, rapl_step)
from repro.core.power_model import (F_MAX, N_PSTATES, ServerPowerModel,
                                    dyn_scale, pstate_frequencies)

__all__ = ["POLL_INTERVAL_S", "ALERT_MARGIN_W", "LIFT_AFTER_S", "N_RAISE",
           "RAPL_STEP_FRAC", "RAISE_HEADROOM_W", "PSU_TRIP_MARGIN_W",
           "ServerCapState", "PerVMController", "RaplController",
           "ChassisManager", "reducible_fracs", "apportion_watts"]


def reducible_fracs() -> np.ndarray:
    """(P,) fraction of a class's full-frequency *dynamic* power shaved
    by capping its cores uniformly to p-state p: ``1 - g(FREQ_TABLE[p])``,
    ascending from 0 (p-state 0 = f_max) to ``1 - g(f_min)`` (~0.707
    under the calibrated model). The lookup table every watt-cut
    apportionment below inverts."""
    return 1.0 - dyn_scale(FREQ_TABLE)


def apportion_watts(cut_w, dyn_w, floors, xp=np, blind: bool = False):
    """Apportion a required watt cut across criticality levels,
    lowest-criticality-first (paper §III-D: non-user-facing cores are
    capped before user-facing ones).

    cut_w:  (...,) required reduction of dynamic draw, watts.
    dyn_w:  (..., L) full-frequency dynamic draw per criticality level,
            in apportionment priority order (level 0 is cut first).
    floors: (L,) int — deepest p-state each level may be capped to by
            the criticality-aware stage (the per-level frequency floor).
    blind:  apportion the cut proportionally to each level's draw
            instead (the criticality-blind baseline the benchmarks
            compare against).

    Returns ``(pstate, take_w, leftover_w)``: the per-level uniform
    p-state ((..., L) int32, smallest index whose reducible fraction
    covers the level's share), the watt share assigned to each level,
    and the cut that no level could absorb within its floor —
    ``leftover_w > 0`` is the RAPL-backstop trigger.

    Branchless and xp-generic (identical under numpy and jnp), so the
    serve emergency plane vmaps/shard_maps it while the numpy call is
    its own oracle. Two edge cases are handled explicitly:

      * **zero-util levels** — a level with no dynamic draw takes no
        share and stays at p-state 0 instead of dividing the cut by
        its zero draw (NaN-free for idle/empty classes);
      * **all-critical chassis** — when the low-criticality levels
        cannot absorb the cut, the cascade caps the *critical* levels
        down to their own floor before any leftover falls through to
        the RAPL backstop (critical VMs are throttled politely first,
        not handed straight to the blunt all-core throttle).
    """
    dyn_w = xp.asarray(dyn_w)
    dtype = dyn_w.dtype
    fracs = xp.asarray(reducible_fracs(), dtype)
    floors = np.asarray(floors, np.int32)
    cut = xp.maximum(xp.asarray(cut_w, dtype), 0)
    red_max = dyn_w * fracs[floors]                     # (..., L)
    if blind:
        total = xp.sum(dyn_w, axis=-1, keepdims=True)
        share = xp.where(total > 0,
                         dyn_w / xp.where(total > 0, total, 1), 0)
        take = xp.minimum(cut[..., None] * share, red_max)
    else:
        cum = xp.cumsum(red_max, axis=-1) - red_max     # exclusive
        take = xp.clip(cut[..., None] - cum, 0, red_max)
    leftover = xp.maximum(cut - xp.sum(take, axis=-1), 0)
    # invert the reduction table per level: smallest p-state whose
    # reducible fraction covers the level's share (zero-draw guard)
    ratio = xp.where(dyn_w > 0, take / xp.where(dyn_w > 0, dyn_w, 1), 0)
    pstate = xp.sum((fracs < ratio[..., None]).astype(np.int32),
                    axis=-1)
    pstate = xp.minimum(pstate, xp.asarray(floors))
    return pstate, take, leftover


@dataclass
class ServerCapState:
    """Mutable controller state for one server."""
    n_cores: int
    uf_mask: np.ndarray                       # (n_cores,) True = high-prio
    freq: np.ndarray = field(default=None)    # (n_cores,) current frequency
    pstate: np.ndarray = field(default=None)  # (n_cores,) index into table
    capping: bool = False
    rapl_active: bool = False
    clear_since_s: float = np.inf             # time since alert cleared

    def __post_init__(self):
        if self.freq is None:
            self.freq = np.full(self.n_cores, F_MAX, dtype=np.float32)
        if self.pstate is None:
            self.pstate = np.zeros(self.n_cores, dtype=np.int32)

    def _pack(self) -> FleetState:
        """View as a (1, n_cores) fleet state for the shared transition."""
        return FleetState(
            freq=np.asarray(self.freq, np.float32).reshape(1, -1),
            pstate=np.asarray(self.pstate, np.int32).reshape(1, -1),
            capping=np.array([self.capping]),
            rapl=np.array([self.rapl_active]),
            clear_s=np.array([self.clear_since_s], np.float32))

    def _unpack(self, fs: FleetState) -> None:
        self.freq = np.asarray(fs.freq[0])
        self.pstate = np.asarray(fs.pstate[0])
        self.capping = bool(fs.capping[0])
        self.rapl_active = bool(fs.rapl[0])
        self.clear_since_s = float(fs.clear_s[0])

    def _run_params(self, budget_w: float) -> RunParams:
        return RunParams(
            server_budget_w=np.float32(budget_w),
            target_w=np.float32(budget_w - ALERT_MARGIN_W),
            alert_w=np.float32(np.inf),
            min_pstate=np.int32(N_PSTATES - 1),
            uf_mask=np.asarray(self.uf_mask, bool).reshape(1, -1),
            active=None)


class PerVMController:
    """In-band controller for one server (paper Fig. 2 steps 4-5)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w
        self.target = server_budget_w - ALERT_MARGIN_W
        self.freq_table = pstate_frequencies(N_PSTATES)  # descending
        self.min_pstate = N_PSTATES - 1
        self._cp = ControlParams.from_model(model, mode="per_vm")

    def step(self, st: ServerCapState, util: np.ndarray, alert: bool,
             dt: float = POLL_INTERVAL_S) -> float:
        """One 200 ms control step. `util` = per-core utilization (0-1),
        `alert` = chassis-manager alert. Returns the server power draw
        AFTER the control action (what the next poll would read)."""
        cp = self._cp if dt == self._cp.dt else replace(self._cp, dt=dt)
        fs, p = inband_step(
            cp, st._run_params(self.budget), st._pack(),
            np.asarray(util, np.float32).reshape(1, -1),
            np.array([alert]), np)
        st._unpack(fs)
        return float(p[0])

    def apportion(self, cut_w, dyn_w, floors=None, blind: bool = False):
        """Apportion a required watt cut across criticality classes —
        the model-predictive twin of the feedback loop in `step`, used
        when the controller *knows* each class's committed dynamic draw
        (the serve plane's emergency path, `repro.serve.emergency`,
        knows it exactly from the placement aggregates).

        `dyn_w`: (..., L) full-frequency dynamic watts per class in
        priority order (non-user-facing first); `floors`: per-class
        p-state floors (defaults to this controller's `min_pstate` for
        every class). Delegates to `apportion_watts` — including its
        zero-util-class guard and the critical-before-RAPL cascade —
        and returns the same ``(pstate, take_w, leftover_w)``."""
        dyn_w = np.asarray(dyn_w)
        if floors is None:
            floors = np.full(dyn_w.shape[-1], self.min_pstate, np.int32)
        return apportion_watts(cut_w, dyn_w, floors, np, blind=blind)


class RaplController:
    """Out-of-band full-server capping (existing mechanism, and the
    backup when per-VM capping is insufficient). Throttles the whole
    socket — all cores equally (paper §II-B)."""

    def __init__(self, model: ServerPowerModel, server_budget_w: float):
        self.model = model
        self.budget = server_budget_w
        self._cp = ControlParams.from_model(model, mode="rapl")

    def step(self, st: ServerCapState, util: np.ndarray,
             dt: float = POLL_INTERVAL_S) -> float:
        fs, p = rapl_step(
            self._cp, st._run_params(self.budget), st._pack(),
            np.asarray(util, np.float32).reshape(1, -1),
            np.ones(1, bool), np)
        st._unpack(fs)
        return float(p[0])

    @staticmethod
    def backstop_pstate() -> int:
        """P-state RAPL converges to when it must shed maximum power:
        every core at f_min, criticality-blind (paper §II-B). The serve
        emergency plane forces all classes here when the apportionment
        reports a leftover no floor could absorb."""
        return N_PSTATES - 1


@dataclass(frozen=True)
class ChassisManager:
    """Polls PSUs and raises alerts (paper Fig. 2 step 4). The alert
    threshold sits just below the chassis budget so the in-band
    controller can act before the PSU->BMC hardware path must.

    Batched-friendly: `poll` accepts scalar or array draws (the serve
    emergency plane polls every chassis of a shard at once), and the
    `alert_w`/`target_w` properties expose the thresholds the batched
    kernels need as plain floats."""
    chassis_budget_w: float
    alert_fraction: float = ALERT_FRACTION
    target_margin_w: float = ALERT_MARGIN_W

    @property
    def alert_threshold_w(self) -> float:
        return self.chassis_budget_w * self.alert_fraction

    @property
    def alert_w(self) -> float:
        """Alias of `alert_threshold_w` (the batched kernels' name)."""
        return self.alert_threshold_w

    @property
    def target_w(self) -> float:
        """Power level capping steers to once alerted: the budget minus
        the controller margin (the paper's 225 W target for a 230 W
        cap)."""
        return self.chassis_budget_w - self.target_margin_w

    def poll(self, chassis_power_w):
        """Alert mask: draw at/above the alert threshold. Scalar in,
        bool out; array in, bool-array out (one poll per chassis)."""
        return chassis_power_w >= self.alert_threshold_w
