"""Criticality (user-facing vs non-user-facing) pattern-matching algorithm.

Paper §III-B, "Criticality algorithm": extract 24h/12h/8h median templates
from a VM's 5-weekday, 30-minute CPU-utilization series; a workload is
user-facing iff the 24h template fits *distinctly better* than the 8h
template: Compare8 = dev24/dev8 < threshold (0.72 in the paper, chosen in
Fig. 3 to put all manually-labeled important workloads left of the bar).

The pure-jnp implementation here is the oracle; `repro.kernels.template`
provides the fleet-scale Pallas kernel validated against it.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import timeseries as ts

#: Fig. 3: vertical bar at Compare8 = 0.72 separates (clearly/possibly
#: user-facing) from (machine-generated / clearly non-user-facing).
COMPARE8_THRESHOLD = 0.72

#: Periods, in 30-minute slots: 24h, 12h, 8h. 12h/8h subsume the shorter
#: machine-generated periods (1h, 4h, 6h divide at least one of them).
PERIOD_24H = 48
PERIOD_12H = 24
PERIOD_8H = 16

#: "Shorter workloads cannot be classified and should be conservatively
#: assumed user-facing" — minimum series length (5 weekdays).
MIN_SAMPLES = 5 * ts.SLOTS_PER_DAY


@partial(jax.tree_util.register_dataclass,
         data_fields=("compare8", "compare12", "dev24", "dev12", "dev8"),
         meta_fields=())
@dataclass(frozen=True)
class CriticalityScores:
    compare8: jnp.ndarray    # (B,) dev24/dev8  — the classifier signal
    compare12: jnp.ndarray   # (B,) dev24/dev12 — reported for Fig. 3
    dev24: jnp.ndarray
    dev12: jnp.ndarray
    dev8: jnp.ndarray

    def classify(self, threshold: float = COMPARE8_THRESHOLD) -> jnp.ndarray:
        """True = user-facing (conservative direction)."""
        return self.compare8 < threshold


@partial(jax.jit, static_argnames=("keep_frac",))
def score(series: jnp.ndarray, keep_frac: float = 0.8) -> CriticalityScores:
    """Run the full pattern-matching algorithm on a batch of series.

    series: (B, T) average CPU utilization per 30-minute slot, T % 48 == 0.
    """
    x = ts.preprocess(series)
    dev24 = ts.template_deviation(x, PERIOD_24H, keep_frac)
    dev12 = ts.template_deviation(x, PERIOD_12H, keep_frac)
    dev8 = ts.template_deviation(x, PERIOD_8H, keep_frac)
    eps = 1e-6
    # If dev8 is ~0 the series fits an 8-hour template essentially exactly
    # (machine-generated or flat): the ratio must not classify it as UF.
    compare8 = dev24 / jnp.maximum(dev8, eps)
    compare12 = dev24 / jnp.maximum(dev12, eps)
    return CriticalityScores(compare8, compare12, dev24, dev12, dev8)


def classify(series: jnp.ndarray,
             threshold: float = COMPARE8_THRESHOLD) -> jnp.ndarray:
    """Convenience wrapper: (B, T) -> (B,) bool user-facing labels."""
    return score(series).classify(threshold)


def classify_with_length(series: jnp.ndarray, n_valid: jnp.ndarray,
                         threshold: float = COMPARE8_THRESHOLD) -> jnp.ndarray:
    """Length-aware classification: series shorter than MIN_SAMPLES are
    conservatively labeled user-facing (paper §III-B)."""
    uf = classify(series, threshold)
    return jnp.where(n_valid < MIN_SAMPLES, True, uf)
