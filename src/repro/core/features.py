"""Arrival-time feature extraction for the criticality & P95 models.

Paper §III-B lists the features, all available when a VM arrives:
subscription aggregates (percent user-facing, percent long-lived, VM
count, utilization-bucket mix, average of avg / P95 utilizations) plus
the arriving VM's cores, memory and type. We compute subscription
aggregates from the *historical* population (VMs observed before the
arrival), labeled by the criticality pattern-matching algorithm — exactly
the label-bootstrapping loop the paper uses.
"""
from __future__ import annotations

import numpy as np

from repro.sim.telemetry import VM_TYPES, Population

N_UTIL_BUCKETS = 4

FEATURE_NAMES = (
    ["sub_pct_user_facing", "sub_pct_lived_7d", "sub_total_vms"]
    + [f"sub_pct_util_bucket_{i}" for i in range(N_UTIL_BUCKETS)]
    + ["sub_avg_of_avg_util", "sub_avg_of_p95_util", "vm_cores",
       "vm_memory_gb"]
    + [f"vm_type_{t}" for t in VM_TYPES])


def p95_bucket(p95_util: np.ndarray) -> np.ndarray:
    """Paper buckets: 0-25, 26-50, 51-75, 76-100 (percent)."""
    return np.clip((np.asarray(p95_util) - 1e-9) // 25, 0,
                   N_UTIL_BUCKETS - 1).astype(np.int64)


def subscription_aggregates(history: Population,
                            uf_labels: np.ndarray) -> dict:
    """Per-subscription aggregates from historical VMs. `uf_labels` are
    the criticality-algorithm labels for history.vms (same order)."""
    aggs: dict[int, dict] = {}
    by_sub: dict[int, list] = {}
    for i, vm in enumerate(history.vms):
        by_sub.setdefault(vm.subscription, []).append(i)
    for sub, idxs in by_sub.items():
        vms = [history.vms[i] for i in idxs]
        labels = uf_labels[idxs]
        buckets = p95_bucket(np.array([v.p95_util for v in vms]))
        aggs[sub] = {
            "pct_uf": float(labels.mean()),
            "pct_7d": float(np.mean([v.lifetime_hours >= 168
                                     for v in vms])),
            "total": float(len(vms)),
            "bucket_mix": np.bincount(buckets, minlength=N_UTIL_BUCKETS)
            / len(vms),
            "avg_avg": float(np.mean([v.avg_util for v in vms])),
            "avg_p95": float(np.mean([v.p95_util for v in vms])),
        }
    return aggs


_DEFAULT_AGG = {"pct_uf": 0.5, "pct_7d": 0.2, "total": 0.0,
                "bucket_mix": np.full(N_UTIL_BUCKETS, 1 / N_UTIL_BUCKETS),
                "avg_avg": 30.0, "avg_p95": 50.0}


def build_features(arrivals: Population, aggs: dict) -> np.ndarray:
    """(n_arrivals, len(FEATURE_NAMES)) float32 feature matrix."""
    rows = []
    type_idx = {t: i for i, t in enumerate(VM_TYPES)}
    for vm in arrivals.vms:
        a = aggs.get(vm.subscription, _DEFAULT_AGG)
        onehot = np.zeros(len(VM_TYPES))
        onehot[type_idx[vm.vm_type]] = 1.0
        rows.append(np.concatenate([
            [a["pct_uf"], a["pct_7d"], a["total"]], a["bucket_mix"],
            [a["avg_avg"], a["avg_p95"], float(vm.cores),
             float(vm.memory_gb)], onehot]))
    return np.asarray(rows, np.float32)


def split_history_arrivals(pop: Population, history_frac: float = 0.5):
    """Deterministic temporal split: earlier VMs are history (features
    source), later VMs are arrivals (training/eval examples)."""
    n_hist = int(len(pop.vms) * history_frac)
    hist = Population(vms=pop.vms[:n_hist])
    arr = Population(vms=pop.vms[n_hist:])
    return hist, arr
