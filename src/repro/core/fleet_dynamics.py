"""Batched power-capping dynamics: one pure state-transition function.

This is the compiled heart of the paper's control plane (§III-D). The
per-VM controller, chassis manager, and RAPL backstop that
`repro.core.capping` exposes as small per-server classes are all thin
wrappers around `fleet_step`, a *pure* fixed-shape transition over
padded arrays:

    freq, pstate : (..., n_servers, n_cores)
    capping, rapl_active, clear_since : (..., n_servers)

The leading batch dims `...` are free: `()` for one server, `(B,)` for
a fleet of B chassis, `(G, H)` for a scenario grid. Every operation is
branchless (masked `where`, rank-based top-k selection) and identical
under `xp = numpy` and `xp = jax.numpy`, so:

  * the numpy path is the validation oracle (bit-for-bit the same
    arithmetic the simulator always ran),
  * the jnp path jits, scans over time, and vmaps over chassis
    (`repro.sim.fleet`), making fleet-scale sweeps one compiled call.

Semantics are the paper's hybrid design: on a chassis alert the in-band
controller drops every non-user-facing core to the minimum p-state, then
feedback-raises/lowers N = 4 cores per 200 ms poll against the target
(budget - 5 W); the cap lifts 30 s after the alert clears; RAPL throttles
*all* cores equally as the out-of-band backstop. See DESIGN.md §8 for
the state layout and padding rules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

from repro.core.power_model import (CORES_PER_SERVER, CUBIC_MIX, F_MAX,
                                    F_MIN, N_PSTATES, P_IDLE_FMAX,
                                    P_IDLE_FMIN, P_PEAK_FMAX,
                                    ServerPowerModel, pstate_frequencies)

POLL_INTERVAL_S = 0.2       # 200 ms PSU polling
ALERT_MARGIN_W = 5.0        # controller target sits 5 W under the cap
LIFT_AFTER_S = 30.0         # cap lifted 30 s after alert clears
N_RAISE = 4                 # cores stepped up per feedback iteration
RAPL_STEP_FRAC = 0.05       # RAPL lowers all-core frequency 5 %/poll
                            # (reaches f_min from f_max within 2 s)
RAISE_HEADROOM_W = 2.0      # feedback-raise safety margin below target
PSU_TRIP_MARGIN_W = 2.0     # PSU averaging window: sub-poll transients
                            # this small do not trip the out-of-band path
ALERT_FRACTION = 0.97       # chassis manager alerts at 97 % of budget

#: p-state frequency table, descending f_max .. f_min (float32 so the
#: numpy oracle and the jnp engine run the same precision).
FREQ_TABLE = pstate_frequencies(N_PSTATES).astype(np.float32)

_F32 = np.float32
_I32 = np.int32


class FleetState(NamedTuple):
    """Controller state for a (batch of) server(s); all fixed-shape."""
    freq: Any          # (..., S, C) float32, current core frequency
    pstate: Any        # (..., S, C) int32, index into FREQ_TABLE
    capping: Any       # (..., S) bool, in-band cap engaged
    rapl: Any          # (..., S) bool, out-of-band RAPL engaged
    clear_s: Any       # (..., S) float32, seconds since alert cleared


class RunParams(NamedTuple):
    """Per-run (vmappable) parameters. Scalars have shape `(...,)` (or
    are python floats) matching the state's batch dims; masks have shape
    `(..., S, C)` or `(S, C)`."""
    server_budget_w: Any      # hard per-server budget (RAPL trip level)
    target_w: Any             # in-band controller target (budget - 5 W)
    alert_w: Any              # chassis-manager alert threshold
    min_pstate: Any           # int, NUF frequency floor (p-state index)
    uf_mask: Any              # True = user-facing (never in-band capped)
    active: Any               # True = core exists (False = padding);
                              # None = every core active (lets XLA drop
                              # all padding masks, the common case)


@dataclass(frozen=True)
class ControlParams:
    """Static (hashable) configuration — safe as a jit static arg."""
    mode: str = "per_vm"              # 'none' | 'rapl' | 'per_vm'
    dt: float = POLL_INTERVAL_S
    n_raise: int = N_RAISE
    alert_margin_w: float = ALERT_MARGIN_W
    lift_after_s: float = LIFT_AFTER_S
    rapl_step: float = RAPL_STEP_FRAC * F_MAX
    raise_headroom_w: float = RAISE_HEADROOM_W
    psu_trip_margin_w: float = PSU_TRIP_MARGIN_W
    #: keep calling the RAPL loop while a previous engagement restores
    #: (the chassis simulator does; the framework integration does not)
    rapl_continuation: bool = True
    #: power-model scalars (ServerPowerModel, flattened to hashables)
    p_dyn_per_core: float = (P_PEAK_FMAX - P_IDLE_FMAX) / CORES_PER_SERVER
    cubic_mix: float = CUBIC_MIX

    def __post_init__(self):
        if self.mode not in ("none", "rapl", "per_vm"):
            raise ValueError(f"unknown capping mode {self.mode!r}; "
                             "expected 'none' | 'rapl' | 'per_vm'")

    @classmethod
    def from_model(cls, model: ServerPowerModel, mode: str = "per_vm",
                   **kw) -> "ControlParams":
        return cls(mode=mode, p_dyn_per_core=model.p_dyn_per_core, **kw)


def init_state(batch_shape, n_servers: int, n_cores: int,
               xp=np) -> FleetState:
    """Uncapped initial fleet state — every core at `F_MAX`, no RAPL,
    no capping — with the given leading batch shape (`()` for one
    chassis, `(B,)` for a fleet, `(G, H)` for a scenario grid)."""
    shape_c = tuple(batch_shape) + (n_servers, n_cores)
    shape_s = tuple(batch_shape) + (n_servers,)
    return FleetState(
        freq=xp.full(shape_c, _F32(F_MAX), dtype=_F32),
        pstate=xp.zeros(shape_c, dtype=_I32),
        capping=xp.zeros(shape_s, dtype=bool),
        rapl=xp.zeros(shape_s, dtype=bool),
        clear_s=xp.full(shape_s, _F32(np.inf), dtype=_F32))


def _per_server(x, xp):
    """Broadcast a run scalar to the server axis: shape `(...,)` gains a
    trailing axis (-> `(..., 1)`), a true scalar stays 0-d — either way
    the result broadcasts against `(..., S)` per-server arrays."""
    x = xp.asarray(x)
    return x[..., None] if x.ndim else x


def _per_core(x, xp):
    """Broadcast a run scalar against `(..., S, C)` per-core arrays."""
    x = xp.asarray(x)
    return x[..., None, None] if x.ndim else x


def server_power(util, freq, active, cp: ControlParams, xp):
    """Server power draw, the calibrated model of `core.power_model`:
    P = P_idle(f_mean) + sum_c u_c * p_dyn * g(f_c). Padded cores are
    excluded from both the dynamic sum and the frequency mean
    (active=None means every core is real — no masking work)."""
    fr = xp.asarray(freq, _F32) * _F32(1.0 / F_MAX)
    g = cp.cubic_mix * fr * fr * fr + (1.0 - cp.cubic_mix) * fr
    ug = xp.asarray(util, _F32) * g
    if active is None:
        dyn = xp.sum(ug, axis=-1) * _F32(cp.p_dyn_per_core)
        fmean = xp.mean(fr, axis=-1)
    else:
        dyn = xp.sum(xp.where(active, ug, _F32(0.0)), axis=-1) \
            * _F32(cp.p_dyn_per_core)
        fmean = xp.sum(xp.where(active, fr, _F32(0.0)), axis=-1) \
            / xp.maximum(xp.sum(active, axis=-1), 1)
    idle = _F32(P_IDLE_FMIN) + _F32(P_IDLE_FMAX - P_IDLE_FMIN) \
        * (2.0 * fmean - 1.0)
    return idle + dyn


#: composite selection keys fit int16 (level*(C+1)+idx <= 491 for the
#: 40-core blades); the narrow dtype halves the selection's memory
#: traffic, which matters at fleet scale
_BIG_KEY = np.int16(2 ** 14)


def _first_n_mask(eligible, level, n_levels: int, n_take: int, xp):
    """Mask of the `n_take` eligible cores that come first by ascending
    (level, core index). `level`: (..., C) int32 in [0, n_levels). The
    ordering is total, so numpy and jnp select identical cores.

    Greedy unrolled min-pass (n_take is 4): each pass thresholds at the
    smallest remaining composite key. Keys are unique, so exactly
    min(n_take, #eligible) cores pass, identically in numpy and jnp.
    Measured faster than rank/sort/top_k formulations for the compiled
    fleet step at thousands of chassis (every intermediate is (..., C))."""
    n_cores = eligible.shape[-1]
    if n_levels * (n_cores + 1) + n_cores >= int(_BIG_KEY):
        raise ValueError(
            f"n_cores={n_cores} overflows the int16 selection keys "
            f"(max ~{int(_BIG_KEY) // (n_levels + 1) - 1} cores per "
            "server at n_levels="
            f"{n_levels}); widen _BIG_KEY/keys to int32 first")
    i16 = np.int16
    idx = xp.arange(n_cores, dtype=i16)
    key = xp.where(eligible,
                   level.astype(i16) * i16(n_cores + 1) + idx, _BIG_KEY)
    sel = xp.zeros(eligible.shape, dtype=bool)
    for _ in range(n_take):
        kmin = xp.min(key, axis=-1)
        pick = (key == kmin[..., None]) & (kmin < _BIG_KEY)[..., None]
        sel = sel | pick
        key = xp.where(pick, _BIG_KEY, key)
    return sel


def inband_step(cp: ControlParams, rp: RunParams, st: FleetState,
                util, alert, xp, p_in=None):
    """One in-band per-VM controller poll (paper Fig. 2 steps 4-5).
    `alert`: (..., S) bool. `p_in` optionally carries the already-polled
    entry power. Returns (new_state, power_after_action)."""
    table = xp.asarray(FREQ_TABLE)
    active = rp.active
    low = ~rp.uf_mask if active is None else (~rp.uf_mask) & active
    minp = _per_core(rp.min_pstate, xp)

    p0 = server_power(util, st.freq, active, cp, xp) \
        if p_in is None else p_in                             # (..., S)
    target = _per_server(rp.target_w, xp)
    over_t = p0 > target
    start = alert & over_t & ~st.capping
    quiet = ~(alert | over_t)
    clear = xp.where(st.capping & quiet,
                     st.clear_s + _F32(cp.dt), _F32(0.0))
    lift = st.capping & (clear >= _F32(cp.lift_after_s))
    lower_c = st.capping & ~lift & over_t
    raise_c = st.capping & ~lift & ~over_t

    # one fused selection — lower_c and raise_c are mutually exclusive
    # per server, so a single greedy pass serves both:
    #   lower: N highest-frequency (lowest-pstate) low-prio cores;
    #   raise: N lowest-frequency (highest-pstate) low-prio cores —
    #          committed only if the predicted power keeps headroom
    #          below the target.
    lo_s = lower_c[..., None]
    eligible = low & xp.where(lo_s, st.pstate < minp,
                              raise_c[..., None] & (st.pstate > 0))
    level = xp.where(lo_s, st.pstate, _I32(N_PSTATES - 1) - st.pstate)
    sel = _first_n_mask(eligible, level, N_PSTATES, cp.n_raise, xp)
    sel_lo = sel & lo_s
    sel_hi = sel & raise_c[..., None]
    trial = st.pstate - sel_hi.astype(_I32)
    trial_p = server_power(util, table[trial], active, cp, xp)
    commit = raise_c & (trial_p < target
                        - _F32(cp.raise_headroom_w))

    pstate = xp.where(start[..., None] & low, minp, st.pstate)
    pstate = xp.where(lift[..., None], _I32(0), pstate)
    pstate = pstate + xp.where(sel_lo, _I32(1), _I32(0))
    pstate = xp.where(commit[..., None], trial, pstate)

    capping = (st.capping | start) & ~lift
    rapl = st.rapl & ~lift
    clear_s = xp.where(start, _F32(0.0),
                       xp.where(st.capping & ~lift, clear, _F32(np.inf)))

    intended = table[pstate]
    freq = xp.where(rapl[..., None], xp.minimum(intended, st.freq),
                    intended)
    if active is not None:
        freq = xp.where(active, freq, _F32(F_MAX))
    p1 = server_power(util, freq, active, cp, xp)
    return FleetState(freq, pstate, capping, rapl, clear_s), p1


def rapl_step(cp: ControlParams, rp: RunParams, st: FleetState,
              util, engaged, xp, p_in=None, intended=None):
    """Out-of-band full-server capping (paper §II-B): throttle ALL cores
    equally while over the hard server budget; restore gradually, handing
    control back to the in-band p-state setting. `engaged`: (..., S).
    `p_in`/`intended` optionally carry the entry power and the in-band
    frequency setting already computed by the caller."""
    active = rp.active
    budget = _per_server(rp.server_budget_w, xp)
    p1 = server_power(util, st.freq, active, cp, xp) \
        if p_in is None else p_in
    over = p1 > budget
    cut = engaged & over
    restore = engaged & ~over & st.rapl

    if intended is None:
        intended = xp.asarray(FREQ_TABLE)[st.pstate]
    if active is None:
        f_top = xp.max(st.freq, axis=-1)
    else:
        f_top = xp.max(xp.where(active, st.freq, _F32(F_MIN)), axis=-1)
    uniform = xp.maximum(f_top - _F32(cp.rapl_step), _F32(F_MIN))
    freq = xp.where(cut[..., None],
                    xp.minimum(st.freq, uniform[..., None]), st.freq)
    do_raise = restore & (p1 < budget - _F32(2.0 * cp.alert_margin_w))
    freq = xp.where(do_raise[..., None],
                    xp.minimum(freq + _F32(cp.rapl_step), intended), freq)
    reached = freq >= intended - _F32(1e-9)
    if active is None:
        done = xp.all(reached, axis=-1)
    else:
        freq = xp.where(active, freq, _F32(F_MAX))
        done = xp.all(reached | ~active, axis=-1)
    rapl = xp.where(cut, True, xp.where(restore & done, False, st.rapl))
    p2 = server_power(util, freq, active, cp, xp)
    return FleetState(freq, st.pstate, st.capping, rapl, st.clear_s), p2


class StepOutputs(NamedTuple):
    server_power_w: Any      # (..., S) after control action
    chassis_power_w: Any     # (...,)
    alert: Any               # (...,) chassis-manager alert this poll
    rapl: Any                # (..., S) RAPL engaged after the step


def fleet_step(cp: ControlParams, rp: RunParams, st: FleetState,
               util, xp) -> tuple:
    """One 200 ms poll of a whole (batch of) chassis: PSU poll ->
    chassis-manager alert -> per-VM controllers -> RAPL backstop."""
    active = rp.active
    p0 = server_power(util, st.freq, active, cp, xp)       # (..., S)
    chassis_p = xp.sum(p0, axis=-1)                        # (...,)
    alert = chassis_p >= xp.asarray(rp.alert_w)

    if cp.mode == "none":
        return st, StepOutputs(p0, chassis_p, alert, st.rapl)
    if cp.mode == "rapl":
        engaged = xp.ones(p0.shape, dtype=bool)
        st2, p = rapl_step(cp, rp, st, util, engaged, xp, p_in=p0)
    else:                                                  # 'per_vm'
        st1, p1 = inband_step(cp, rp, st, util,
                              xp.broadcast_to(alert[..., None], p0.shape),
                              xp, p_in=p0)
        engaged = p1 > _per_server(rp.server_budget_w, xp) \
            + _F32(cp.psu_trip_margin_w)
        if cp.rapl_continuation:
            engaged = engaged | st1.rapl
        st2, p = rapl_step(cp, rp, st1, util, engaged, xp, p_in=p1)
    return st2, StepOutputs(p, xp.sum(p, axis=-1), alert, st2.rapl)
