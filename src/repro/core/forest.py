"""Random Forest / Gradient Boosting with *oblivious* trees (paper §III-B).

The paper trains classic Random Forests (plus Gradient Boosting as the
Table III comparison). TPU adaptation (DESIGN.md §3): we train *oblivious*
trees — every node at depth d of a tree shares one (feature, threshold) —
so ensemble inference is dense tensor algebra (one-hot feature gather →
vectorized compare → bit-packed leaf index → one-hot leaf lookup), which
`repro.kernels.forest` executes as two matmuls on the MXU. Training is
host-side numpy (a once-a-day background job in the paper).

`predict_proba_np` is the numpy oracle; `repro.kernels.forest.ref` mirrors
it in jnp and the Pallas kernel is validated against both.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ObliviousForest:
    """Ensemble of oblivious trees.

    feat_idx:    (n_trees, depth) int32 — feature tested at each level
    thresholds:  (n_trees, depth) float32 — go right iff x[f] > t
    leaf_values: (n_trees, 2**depth, n_out) float32 — per-leaf outputs
    kind:        'rf' (leaf = class-prob vector, averaged) or
                 'gb' (leaf = logit increments, summed then softmax)
    """
    feat_idx: np.ndarray
    thresholds: np.ndarray
    leaf_values: np.ndarray
    kind: str
    n_features: int

    @property
    def n_trees(self) -> int:
        return self.feat_idx.shape[0]

    @property
    def depth(self) -> int:
        return self.feat_idx.shape[1]

    @property
    def n_out(self) -> int:
        return self.leaf_values.shape[2]

    def leaf_index_np(self, x: np.ndarray) -> np.ndarray:
        """(B, F) -> (B, n_trees) leaf indices."""
        gathered = x[:, self.feat_idx.reshape(-1)].reshape(
            x.shape[0], self.n_trees, self.depth)
        bits = (gathered > self.thresholds[None]).astype(np.int64)
        weights = (2 ** np.arange(self.depth))[::-1]
        return (bits * weights[None, None, :]).sum(-1)

    def predict_proba_np(self, x: np.ndarray) -> np.ndarray:
        """(B, F) -> (B, n_out) class probabilities (numpy oracle)."""
        leaves = self.leaf_index_np(np.asarray(x, np.float32))
        vals = self.leaf_values[np.arange(self.n_trees)[None, :], leaves]
        if self.kind == "rf":
            return vals.mean(axis=1)
        logits = vals.sum(axis=1)
        logits = logits - logits.max(-1, keepdims=True)
        e = np.exp(logits)
        return e / e.sum(-1, keepdims=True)

    def predict_np(self, x: np.ndarray):
        """Returns (predicted class, confidence). Confidence = max prob —
        the Resource-Central-style score the scheduler gates on (>= 0.6)."""
        p = self.predict_proba_np(x)
        return p.argmax(-1), p.max(-1)


def _fit_oblivious_tree(x: np.ndarray, y: np.ndarray, depth: int,
                        rng: np.random.Generator,
                        feature_frac: float = 1.0,
                        n_thresholds: int = 15) -> tuple:
    """Fit one oblivious regression tree to targets y (B, K) by greedy
    level-wise (feature, threshold) selection maximizing variance
    reduction. Returns (feat_idx (d,), thresholds (d,), leaf_sum
    (2**d, K), leaf_cnt (2**d,))."""
    n, n_feat = x.shape
    k = y.shape[1]
    leaf = np.zeros(n, dtype=np.int64)
    feats, thrs = [], []
    for level in range(depth):
        n_leaves = 1 << level
        if feature_frac < 1.0:
            cand_feats = rng.choice(
                n_feat, max(1, int(feature_frac * n_feat)), replace=False)
        else:
            cand_feats = np.arange(n_feat)
        best = (-np.inf, 0, 0.0)
        for f in cand_feats:
            col = x[:, f]
            qs = np.quantile(col, np.linspace(0.05, 0.95, n_thresholds))
            for t in np.unique(qs):
                bit = (col > t).astype(np.int64)
                new_leaf = leaf * 2 + bit
                cnt = np.bincount(new_leaf, minlength=n_leaves * 2) + 1e-9
                score = 0.0
                for c in range(k):
                    s = np.bincount(new_leaf, weights=y[:, c],
                                    minlength=n_leaves * 2)
                    score += float((s * s / cnt).sum())
                if score > best[0]:
                    best = (score, f, float(t))
        _, f, t = best
        feats.append(f)
        thrs.append(t)
        leaf = leaf * 2 + (x[:, f] > t).astype(np.int64)
    n_leaves = 1 << depth
    cnt = np.bincount(leaf, minlength=n_leaves).astype(np.float64)
    sums = np.stack([np.bincount(leaf, weights=y[:, c], minlength=n_leaves)
                     for c in range(y.shape[1])], axis=1)
    return (np.array(feats, np.int32), np.array(thrs, np.float32),
            sums, cnt)


def train_random_forest(x: np.ndarray, y: np.ndarray, n_classes: int,
                        n_trees: int = 48, depth: int = 6,
                        feature_frac: float = 0.6,
                        seed: int = 0) -> ObliviousForest:
    """Bagged oblivious-forest classifier. y: (B,) int class labels."""
    x = np.asarray(x, np.float32)
    rng = np.random.default_rng(seed)
    onehot = np.eye(n_classes, dtype=np.float64)[y]
    n = x.shape[0]
    fi, th, lv = [], [], []
    prior = onehot.mean(0)
    for _ in range(n_trees):
        idx = rng.integers(0, n, n)                     # bootstrap
        f, t, sums, cnt = _fit_oblivious_tree(
            x[idx], onehot[idx], depth, rng, feature_frac)
        # Laplace-smoothed leaf class probabilities; empty leaves -> prior
        probs = (sums + prior[None] * 2.0) / (cnt[:, None] + 2.0)
        fi.append(f); th.append(t); lv.append(probs.astype(np.float32))
    return ObliviousForest(np.stack(fi), np.stack(th), np.stack(lv),
                           kind="rf", n_features=x.shape[1])


def train_gradient_boosting(x: np.ndarray, y: np.ndarray, n_classes: int,
                            n_trees: int = 48, depth: int = 4,
                            learning_rate: float = 0.25,
                            seed: int = 0) -> ObliviousForest:
    """Softmax gradient boosting with oblivious trees (Table III 'GB').

    Each round fits one tree per run to the multiclass gradient; leaf
    values are Newton steps on the softmax loss.
    """
    x = np.asarray(x, np.float32)
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    onehot = np.eye(n_classes, dtype=np.float64)[y]
    logits = np.zeros((n, n_classes))
    fi, th, lv = [], [], []
    for _ in range(n_trees):
        m = logits - logits.max(-1, keepdims=True)
        p = np.exp(m); p /= p.sum(-1, keepdims=True)
        grad = onehot - p                               # negative gradient
        f, t, sums, cnt = _fit_oblivious_tree(x, grad, depth, rng)
        hess = np.maximum(p * (1 - p), 1e-6)
        hsum = np.zeros_like(sums)
        leaf = ObliviousForest(f[None], t[None], np.zeros((1, 1 << depth, 1),
                               np.float32), "gb", x.shape[1]
                               ).leaf_index_np(x)[:, 0]
        for c in range(n_classes):
            hsum[:, c] = np.bincount(leaf, weights=hess[:, c],
                                     minlength=1 << depth)
        step = learning_rate * sums / (hsum + 1.0)
        logits += step[leaf]
        fi.append(f); th.append(t); lv.append(step.astype(np.float32))
    return ObliviousForest(np.stack(fi), np.stack(th), np.stack(lv),
                           kind="gb", n_features=x.shape[1])


def evaluate(forest: ObliviousForest, x: np.ndarray, y: np.ndarray,
             confidence: float = 0.6) -> dict:
    """Paper Table III metrics: % high-confidence predictions, per-bucket
    recall/precision among high-confidence predictions, and accuracy."""
    pred, conf = forest.predict_np(x)
    hi = conf >= confidence
    out = {"pct_high_conf": float(hi.mean()),
           "accuracy_high_conf": float((pred[hi] == y[hi]).mean())
           if hi.any() else float("nan"),
           "buckets": {}}
    for c in np.unique(y):
        tp = int(((pred == c) & (y == c) & hi).sum())
        fn = int(((pred != c) & (y == c) & hi).sum())
        fp = int(((pred == c) & (y != c) & hi).sum())
        out["buckets"][int(c)] = {
            "recall": tp / max(tp + fn, 1),
            "precision": tp / max(tp + fp, 1)}
    return out
