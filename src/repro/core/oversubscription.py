"""Oversubscription strategy: the 5-step chassis-budget algorithm
(paper §III-E) and the Table IV provisioning scenarios.

Given acceptable capping-event rates (emax_UF, emax_NUF) and frequency
floors (fmin_UF, fmin_NUF), find the lowest chassis power budget such
that, against the historical draws:

  * every over-budget reading can be shaved back to the budget by
    throttling NUF cores to >= fmin_NUF (counts as an NUF event) or, if
    insufficient, additionally throttling UF cores to >= fmin_UF (counts
    as an event on BOTH types);
  * readings whose required shave exceeds even the UF+NUF reduction make
    the candidate budget infeasible;
  * the UF / NUF event *rates* stay within emax_UF / emax_NUF.

Step 5 adds a buffer (default 10 %) for future variability of beta and
chassis utilization growth.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.power_model import F_MAX, ServerPowerModel
from repro.core.resources import ResourceVector


@dataclass(frozen=True)
class OversubConfig:
    emax_uf: float            # max acceptable UF capping-event rate
    fmin_uf: float            # lowest acceptable UF core frequency
    emax_nuf: float
    fmin_nuf: float
    buffer: float = 0.10      # step-5 budget buffer


#: Table IV scenario parameter sets.
SCENARIOS = {
    "state_of_the_art": OversubConfig(       # full-server, no VM insight:
        emax_uf=0.001, fmin_uf=0.75,         # rare events, light throttle,
        emax_nuf=0.0, fmin_nuf=0.75),        # UF and NUF capped together
    "predictions_no_uf_impact": OversubConfig(
        emax_uf=0.0, fmin_uf=1.00, emax_nuf=0.01, fmin_nuf=0.50),
    "predictions_minimal_uf_impact": OversubConfig(
        emax_uf=0.001, fmin_uf=0.75, emax_nuf=0.009, fmin_nuf=0.50),
}


@dataclass(frozen=True)
class FleetProfile:
    """Step-1 estimates from history + step-2 hardware profile inputs."""
    beta: float               # avg fraction of allocated cores that are UF
    util_uf: float            # avg P95 utilization of UF virtual cores
    util_nuf: float
    allocated_frac: float     # allocated cores / physical cores
    servers_per_chassis: int
    model: ServerPowerModel

    def reduction_capacity(self, fmin_uf: float, fmin_nuf: float):
        """Step 2: attainable chassis power reduction (watts) from
        throttling (a) only NUF cores to fmin_nuf, (b) additionally UF
        cores to fmin_uf — derived from the frequency/power curves at
        the historical average utilizations."""
        n_alloc = (self.model.n_cores * self.servers_per_chassis
                   * self.allocated_frac)
        n_uf = self.beta * n_alloc
        n_nuf = (1.0 - self.beta) * n_alloc
        red_nuf = self.model.reducible_power(
            self.util_nuf, F_MAX, fmin_nuf, n_nuf)
        red_uf = self.model.reducible_power(
            self.util_uf, F_MAX, fmin_uf, n_uf)
        return red_nuf, red_uf


@dataclass
class BudgetResult:
    budget_w: float               # final budget (after buffer)
    budget_pre_buffer_w: float    # step-4 output
    provisioned_w: float
    uf_event_rate: float
    nuf_event_rate: float
    n_draws: int

    @property
    def oversubscription(self) -> float:
        """Fraction of provisioned power recovered ('chassis budget
        delta' in Table IV)."""
        return 1.0 - self.budget_w / self.provisioned_w

    def savings_usd(self, campus_mw: float = 128.0,
                    usd_per_watt: float = 10.0) -> float:
        """Table IV: savings = delta x campus power x $/W."""
        return self.oversubscription * campus_mw * 1e6 * usd_per_watt


def compute_budget(draws_w: np.ndarray, provisioned_w: float,
                   cfg: OversubConfig, fleet: FleetProfile,
                   full_server: bool = False) -> BudgetResult:
    """The 5-step algorithm over historical chassis draws (flattened
    array of one reading per chassis per time unit).

    full_server=True models the state-of-the-art baseline: capping is
    criticality-oblivious, so EVERY capping event throttles UF and NUF
    alike (all cores, same floor), and the attainable reduction is the
    whole fleet's at fmin_uf.
    """
    asc = np.sort(np.asarray(draws_w, np.float64))            # step 3
    n = len(asc)
    d_max = asc[-1]
    red_nuf, red_uf = fleet.reduction_capacity(cfg.fmin_uf, cfg.fmin_nuf)
    red_total = red_nuf + red_uf

    # Step 4, vectorized. Candidate budgets sit just below each distinct
    # draw; every constraint is monotone in the budget (lower budget =>
    # more events, larger max shave), so the feasible set is a prefix of
    # the descending candidate walk and we can evaluate all candidates at
    # once with searchsorted instead of the O(n^2) literal walk.
    distinct = np.unique(asc)[::-1]
    budgets = distinct * (1.0 - 1e-6)         # "just below" each draw
    n_over = n - np.searchsorted(asc, budgets, side="right")
    max_shave = d_max - budgets
    if full_server:
        # one pooled criticality-oblivious mechanism: every event hits UF
        # and NUF alike, so the constraint is on the combined rate
        # (paper: "emax_UF + emax_NUF = 0.1%").
        feasible = max_shave <= red_total
        uf_rate_v = n_over / n
        nuf_rate_v = np.zeros_like(uf_rate_v)
        rate_ok = uf_rate_v <= cfg.emax_uf + cfg.emax_nuf + 1e-12
    else:
        # exclusive counting: an event is a UF event iff UF VMs had to be
        # throttled (shave > red_nuf), else an NUF-only event — so
        # emax_UF + emax_NUF bounds the overall rate (paper scenario #4:
        # 0.1 + 0.9 = 1% overall).
        feasible = max_shave <= red_total
        n_uf = n - np.searchsorted(asc, budgets + red_nuf, side="right")
        uf_rate_v = n_uf / n
        nuf_rate_v = (n_over - n_uf) / n
        rate_ok = ((uf_rate_v <= cfg.emax_uf + 1e-12)
                   & (nuf_rate_v <= cfg.emax_nuf + 1e-12))
    ok = feasible & rate_ok
    # prefix property: stop at the first violation in the descending walk
    first_bad = int(np.argmin(ok)) if not ok.all() else len(ok)
    if first_bad == 0:   # cannot even cap the single highest draw
        best = BudgetResult(provisioned_w, provisioned_w, provisioned_w,
                            0.0, 0.0, n)
    else:
        i = first_bad - 1
        best = BudgetResult(budget_w=float(budgets[i]),
                            budget_pre_buffer_w=float(budgets[i]),
                            provisioned_w=provisioned_w,
                            uf_event_rate=float(uf_rate_v[i]),
                            nuf_event_rate=float(nuf_rate_v[i]),
                            n_draws=n)
    # Step 5: buffer — raise the budget by `buffer` (less aggressive),
    # capped at the provisioned power.
    best.budget_w = min(best.budget_pre_buffer_w * (1.0 + cfg.buffer),
                        provisioned_w)
    return best


def scenario_table(draws_w: np.ndarray, provisioned_w: float,
                   fleet: FleetProfile,
                   beta_internal_only: float | None = None,
                   beta_non_premium: float | None = None) -> dict:
    """Reproduce Table IV's eight provisioning approaches.

    beta_internal_only: the UF core fraction when ALL external VMs are
    treated as user-facing (only internal VMs are classified) — beta
    rises, shrinking the cap-able NUF pool. Similarly beta_non_premium
    treats only premium external VMs as UF.
    """
    rows = {"traditional": BudgetResult(provisioned_w, provisioned_w,
                                        provisioned_w, 0.0, 0.0,
                                        len(np.ravel(draws_w)))}
    d = np.ravel(draws_w)
    rows["state_of_the_art"] = compute_budget(
        d, provisioned_w, SCENARIOS["state_of_the_art"], fleet,
        full_server=True)
    rows["predictions_all_no_uf_impact"] = compute_budget(
        d, provisioned_w, SCENARIOS["predictions_no_uf_impact"], fleet)
    rows["predictions_all_minimal_uf_impact"] = compute_budget(
        d, provisioned_w, SCENARIOS["predictions_minimal_uf_impact"],
        fleet)
    for name, beta in (("internal", beta_internal_only),
                       ("internal_non_premium", beta_non_premium)):
        if beta is None:
            continue
        f2 = FleetProfile(beta=beta, util_uf=fleet.util_uf,
                          util_nuf=fleet.util_nuf,
                          allocated_frac=fleet.allocated_frac,
                          servers_per_chassis=fleet.servers_per_chassis,
                          model=fleet.model)
        rows[f"predictions_{name}_no_uf_impact"] = compute_budget(
            d, provisioned_w, SCENARIOS["predictions_no_uf_impact"], f2)
        rows[f"predictions_{name}_minimal_uf_impact"] = compute_budget(
            d, provisioned_w, SCENARIOS["predictions_minimal_uf_impact"],
            f2)
    return rows


def joint_chassis_budget(draws_w: np.ndarray, provisioned_w: float,
                         cfg: OversubConfig, fleet: FleetProfile,
                         cores_per_chassis: float,
                         gb_per_chassis: float,
                         core_ratio: float = 1.0,
                         gb_ratio: float = 1.0,
                         full_server: bool = False
                         ) -> tuple[BudgetResult, ResourceVector]:
    """Joint (watts, cores, GB) chassis budget (DESIGN.md §16).

    The watts axis comes from the paper's 5-step algorithm
    (`compute_budget`); the cores/GB axes are Coach-style
    oversubscription ratios over the *physical* chassis capacity
    (``ratio >= 1`` oversells the axis; the serve plane's per-resource
    admission ledger enforces the result, and `resources.trough_ratios`
    conditions the ratios on the diurnal trough at admission time).
    Returns ``(BudgetResult, ResourceVector)`` — the vector is what
    `serve.admission.resource_caps_from_budget` turns into per-chassis
    (C, R) ceilings."""
    result = compute_budget(draws_w, provisioned_w, cfg, fleet,
                            full_server=full_server)
    vec = ResourceVector(watts=result.budget_w,
                         cores=core_ratio * float(cores_per_chassis),
                         gb=gb_ratio * float(gb_per_chassis))
    return result, vec
