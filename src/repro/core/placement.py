"""Criticality- & utilization-aware VM placement (paper Algorithm 1).

`score_candidates` is the paper's SortCandidates preference rule,
vectorized with numpy over candidate servers (the production scheduler
scores thousands of candidates in ~7 ms; here one vectorized pass).
A pure-python transliteration of Algorithm 1 (`_score_server_scalar`,
`_score_chassis_scalar`) is kept as the oracle for tests.

Note on the paper's pseudo-code: lines 20/22 of Algorithm 1 are garbled
in the text ("(1 + γNUF/MCC)"), but §IV-E states the server score
explicitly: (1/2) * (1 + (γ^NUF - γ^UF) / N^cores) for a user-facing VM,
with the difference reversed for a non-user-facing VM. We implement that.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

ALPHA_DEFAULT = 0.8     # §IV-E: alpha=0.8 strikes the best compromise


@dataclass
class ClusterState:
    """Aggregate per-server / per-chassis state the rule needs.

    Incrementally maintained so scoring is O(candidates), matching the
    production constraint (7 ms budget).
    """
    n_servers: int
    cores_per_server: int
    chassis_of_server: np.ndarray          # (n_servers,) int
    n_chassis: int
    free_cores: np.ndarray = field(default=None)       # (n_servers,)
    gamma_uf: np.ndarray = field(default=None)         # (n_servers,) sum p95*cores, UF VMs
    gamma_nuf: np.ndarray = field(default=None)        # (n_servers,)
    rho_peak: np.ndarray = field(default=None)         # (n_chassis,) sum p95*cores
    rho_max: np.ndarray = field(default=None)          # (n_chassis,) total cores*1.0

    def __post_init__(self):
        if self.free_cores is None:
            self.free_cores = np.full(self.n_servers, self.cores_per_server,
                                      dtype=np.float64)
        if self.gamma_uf is None:
            self.gamma_uf = np.zeros(self.n_servers)
        if self.gamma_nuf is None:
            self.gamma_nuf = np.zeros(self.n_servers)
        if self.rho_peak is None:
            self.rho_peak = np.zeros(self.n_chassis)
        if self.rho_max is None:
            self.rho_max = np.zeros(self.n_chassis)
            np.add.at(self.rho_max, self.chassis_of_server,
                      float(self.cores_per_server))

    def place(self, server: int, cores: int, p95: float, is_uf: bool):
        assert self.free_cores[server] >= cores, "constraint rule violated"
        self.free_cores[server] -= cores
        w = p95 * cores
        if is_uf:
            self.gamma_uf[server] += w
        else:
            self.gamma_nuf[server] += w
        self.rho_peak[self.chassis_of_server[server]] += w

    def remove(self, server: int, cores: int, p95: float, is_uf: bool):
        self.free_cores[server] += cores
        w = p95 * cores
        if is_uf:
            self.gamma_uf[server] -= w
        else:
            self.gamma_nuf[server] -= w
        self.rho_peak[self.chassis_of_server[server]] -= w

    # -- Algorithm 1 ------------------------------------------------------
    def score_chassis(self) -> np.ndarray:
        """ScoreChassis for every chassis: 1 - rho_peak/rho_max."""
        return 1.0 - self.rho_peak / np.maximum(self.rho_max, 1e-9)

    def score_server(self, vm_is_uf: bool) -> np.ndarray:
        """ScoreServer for every server given the arriving VM's type."""
        n_cores = float(self.cores_per_server)
        diff = (self.gamma_nuf - self.gamma_uf) if vm_is_uf else \
            (self.gamma_uf - self.gamma_nuf)
        return 0.5 * (1.0 + diff / n_cores)

    def score_candidates(self, vm_is_uf: bool, candidates: np.ndarray,
                         alpha: float = ALPHA_DEFAULT) -> np.ndarray:
        """SortCandidates: score for each candidate server index.
        Higher is better; caller sorts descending."""
        kappa = self.score_chassis()[self.chassis_of_server[candidates]]
        eta = self.score_server(vm_is_uf)[candidates]
        return alpha * kappa + (1.0 - alpha) * eta

    def feasible(self, cores: int) -> np.ndarray:
        """Constraint rule: servers with enough free cores."""
        return np.nonzero(self.free_cores >= cores)[0]


def _score_chassis_scalar(state: ClusterState, chassis: int) -> float:
    """Literal ScoreChassis (paper lines 8-13) — test oracle."""
    rho_peak = state.rho_peak[chassis]
    rho_max = state.rho_max[chassis]
    return 1.0 - rho_peak / max(rho_max, 1e-9)


def _score_server_scalar(state: ClusterState, server: int,
                         vm_is_uf: bool) -> float:
    """Literal ScoreServer (paper lines 14-22, §IV-E form) — test oracle."""
    g_uf = state.gamma_uf[server]
    g_nuf = state.gamma_nuf[server]
    n = float(state.cores_per_server)
    if vm_is_uf:
        return 0.5 * (1.0 + (g_nuf - g_uf) / n)
    return 0.5 * (1.0 + (g_uf - g_nuf) / n)


def packing_score(state: ClusterState, candidates: np.ndarray) -> np.ndarray:
    """The existing scheduler's packing preference (best-fit): prefer the
    server with the fewest free cores that still fits. Normalized to
    [0, 1], higher = fuller = better packing."""
    return 1.0 - state.free_cores[candidates] / state.cores_per_server


@dataclass(frozen=True)
class SchedulerPolicy:
    """Azure-style rule aggregation (§II-C): each preference rule orders
    candidates; each candidate is weighted by its (normalized, inverted)
    rank under each rule times the rule weight; highest aggregate wins.

    use_power_rule=False reproduces the 'NoRule' baseline of Fig. 7.
    """
    alpha: float = ALPHA_DEFAULT
    use_power_rule: bool = True
    use_utilization_predictions: bool = True   # Fig 7 orange bar: False
    packing_weight: float = 1.0
    power_weight: float = 2.0

    def effective_p95(self, p95_pred: float) -> float:
        """The p95 value recorded into cluster aggregates at placement:
        the prediction, or conservative 100 % when utilization
        predictions are disabled (Fig 7 orange bars)."""
        return p95_pred if self.use_utilization_predictions else 1.0

    def choose(self, state: ClusterState, cores: int, vm_is_uf: bool):
        cands = state.feasible(cores)
        if len(cands) == 0:
            return None                         # deployment failure
        ranks = np.zeros(len(cands))
        pack = packing_score(state, cands)
        ranks += self.packing_weight * _rank_weight(pack)
        if self.use_power_rule:
            power = state.score_candidates(vm_is_uf, cands, self.alpha)
            ranks += self.power_weight * _rank_weight(power)
        return int(cands[int(np.argmax(ranks))])


def _rank_weight(scores: np.ndarray) -> np.ndarray:
    """Order-based weight: best candidate gets 1.0, worst gets ~0
    (ties share by stable ranking)."""
    n = len(scores)
    if n == 1:
        return np.ones(1)
    order = np.argsort(np.argsort(-scores, kind="stable"), kind="stable")
    return 1.0 - order / (n - 1)
