"""Server/chassis power model calibrated to the paper's measurements.

Paper §IV-A: production blades with 40 cores / 2 sockets draw 112 W idle
and 310 W at 100 % CPU at nominal frequency; 111 W idle and 169 W at
100 % at *half* the nominal frequency.

We model per-core dynamic power as a calibrated mix of linear and cubic
frequency terms (voltage scales with frequency over part of the DVFS
range):

    P(server) = P_idle(f_mean) + sum_c u_c * p_dyn * g(f_c)
    g(f) = a*(f/f_max)^3 + (1-a)*(f/f_max)

Calibration from the paper's 4 measured points gives a ~= 0.552 — i.e.
g(0.5) = 0.293 = (169-111)/(310-112).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

F_MAX = 1.0            # nominal ("maximum") core frequency, normalized
F_MIN = 0.5            # minimum p-state = half of maximum (paper §III-D)
N_PSTATES = 11         # f in {0.50, 0.55, ..., 1.00}

P_IDLE_FMAX = 112.0
P_PEAK_FMAX = 310.0
P_IDLE_FMIN = 111.0
P_PEAK_FMIN = 169.0
CORES_PER_SERVER = 40

_DYN_RATIO_HALF = (P_PEAK_FMIN - P_IDLE_FMIN) / (P_PEAK_FMAX - P_IDLE_FMAX)
#: cubic-mix coefficient solving a*0.125 + (1-a)*0.5 = _DYN_RATIO_HALF
CUBIC_MIX = (0.5 - _DYN_RATIO_HALF) / (0.5 - 0.125)


def pstate_frequencies(n: int = N_PSTATES) -> np.ndarray:
    """Available p-state frequencies, descending: f_max .. f_min."""
    return np.linspace(F_MAX, F_MIN, n)


def dyn_scale(f) -> np.ndarray:
    """g(f): dynamic-power multiplier of a core at frequency f (relative
    to f_max). g(1) = 1, g(0.5) ~= 0.293."""
    fr = np.asarray(f, dtype=np.float64) / F_MAX
    return CUBIC_MIX * fr ** 3 + (1.0 - CUBIC_MIX) * fr


def idle_power(f_mean) -> np.ndarray:
    """Idle (static + uncore) power; nearly frequency-flat per the paper
    (112 W @ f_max vs 111 W @ f_max/2)."""
    fr = np.asarray(f_mean, dtype=np.float64) / F_MAX
    return P_IDLE_FMIN + (P_IDLE_FMAX - P_IDLE_FMIN) * (2.0 * fr - 1.0)


@dataclass(frozen=True)
class ServerPowerModel:
    n_cores: int = CORES_PER_SERVER
    p_idle: float = P_IDLE_FMAX
    p_peak: float = P_PEAK_FMAX

    @property
    def p_dyn_per_core(self) -> float:
        return (self.p_peak - self.p_idle) / self.n_cores

    def power(self, util: np.ndarray, freq: np.ndarray) -> np.ndarray:
        """Server power. util/freq: (..., n_cores) per-core utilization
        (0-1) and frequency (F_MIN-F_MAX). Returns (...,) watts."""
        util = np.asarray(util, np.float64)
        freq = np.asarray(freq, np.float64)
        dyn = (util * self.p_dyn_per_core * dyn_scale(freq)).sum(-1)
        return idle_power(freq.mean(-1)) + dyn

    def power_uniform(self, util, freq=F_MAX, active_frac=1.0):
        """Scalar shortcut: all active cores at the same utilization and
        frequency; `active_frac` of cores active, rest idle."""
        util = np.asarray(util, np.float64)
        dyn = (self.n_cores * active_frac * util * self.p_dyn_per_core
               * dyn_scale(freq))
        return idle_power(freq) + dyn

    def reducible_power(self, util, f_from, f_to, n_cores_sub) -> float:
        """Watts shaved by moving `n_cores_sub` cores running at `util`
        from frequency `f_from` down to `f_to` (paper §III-E step 2:
        the power-vs-frequency curve at a given utilization)."""
        per_core = util * self.p_dyn_per_core
        return float(n_cores_sub * per_core
                     * (dyn_scale(f_from) - dyn_scale(f_to)))


def freq_power_curve(model: ServerPowerModel, util: float,
                     n_points: int = N_PSTATES):
    """Paper §III-E step 2: power draw as a function of frequency at a
    fixed average utilization. Returns (freqs, watts) for a full server."""
    freqs = pstate_frequencies(n_points)
    watts = np.array([model.power_uniform(util, f) for f in freqs])
    return freqs, watts
