"""Resource-Central-style prediction service (paper §II-D, §III-B).

Bundles the criticality classifier and the *two-stage* P95-utilization
model behind one query interface with confidence gating:

  * criticality: binary user-facing / non-user-facing forest;
  * P95 utilization: stage 1 predicts whether P95 > 50 %; stage 2 routes
    to a low-bucket forest (buckets 0-1) or high-bucket forest (buckets
    2-3), each trained only on examples stage 1 predicts with >= 60 %
    confidence (paper §III-B "Utilization prediction").

The scheduler discards low-confidence predictions and conservatively
assumes user-facing @ 100 % P95 (paper §IV-B).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.forest import (
    ObliviousForest, evaluate, train_gradient_boosting, train_random_forest)

CONFIDENCE_GATE = 0.6
UF, NUF = 1, 0          # workload-type encoding (bucket 2 in Table III = UF)


@dataclass
class TwoStageP95Model:
    stage1: ObliviousForest          # P95 > 50% ?
    low: ObliviousForest             # buckets {0, 1}
    high: ObliviousForest            # buckets {2, 3}

    def predict(self, x: np.ndarray):
        """Returns (bucket (B,), confidence (B,))."""
        s1, c1 = self.stage1.predict_np(x)
        lo_b, lo_c = self.low.predict_np(x)
        hi_b, hi_c = self.high.predict_np(x)
        bucket = np.where(s1 == 1, hi_b + 2, lo_b)
        conf = np.minimum(c1, np.where(s1 == 1, hi_c, lo_c))
        return bucket, conf


@dataclass
class PredictionService:
    criticality: ObliviousForest
    p95: TwoStageP95Model
    confidence_gate: float = CONFIDENCE_GATE

    def query(self, x: np.ndarray):
        """x: (B, F) features. Returns dict of arrays:
        workload_type (UF/NUF), p95_bucket (0..3), and the conservative
        post-gating values the scheduler actually uses."""
        wt, wt_conf = self.criticality.predict_np(x)
        pb, pb_conf = self.p95.predict(x)
        wt_used = np.where(wt_conf >= self.confidence_gate, wt, UF)
        pb_used = np.where(pb_conf >= self.confidence_gate, pb, 3)
        return {"workload_type": wt, "workload_conf": wt_conf,
                "p95_bucket": pb, "p95_conf": pb_conf,
                "workload_type_used": wt_used, "p95_bucket_used": pb_used}


def bucket_to_p95(bucket: np.ndarray) -> np.ndarray:
    """Bucket midpoint as the utilization estimate (fraction 0-1)."""
    return (np.asarray(bucket) * 25.0 + 12.5) / 100.0


def train_service(x: np.ndarray, uf_labels: np.ndarray,
                  p95_buckets: np.ndarray, model: str = "rf",
                  seed: int = 0, n_trees: int = 48) -> PredictionService:
    """Train the full service. `model` in {'rf', 'gb'} (Table III)."""
    trainer = train_random_forest if model == "rf" else \
        train_gradient_boosting
    crit = trainer(x, uf_labels.astype(np.int64), 2, n_trees=n_trees,
                   seed=seed)

    over50 = (p95_buckets >= 2).astype(np.int64)
    stage1 = trainer(x, over50, 2, n_trees=n_trees, seed=seed + 1)
    _, conf1 = stage1.predict_np(x)
    hi_conf = conf1 >= CONFIDENCE_GATE          # paper: train stage 2 on
    lo_mask = hi_conf & (p95_buckets < 2)       # high-confidence stage-1
    hi_mask = hi_conf & (p95_buckets >= 2)      # examples only
    low = trainer(x[lo_mask], p95_buckets[lo_mask], 2,
                  n_trees=n_trees, seed=seed + 2)
    high = trainer(x[hi_mask], p95_buckets[hi_mask] - 2, 2,
                   n_trees=n_trees, seed=seed + 3)
    return PredictionService(crit, TwoStageP95Model(stage1, low, high))


def table3_metrics(service: PredictionService, x: np.ndarray,
                   uf_labels: np.ndarray, p95_buckets: np.ndarray) -> dict:
    """Reproduce Table III rows for one model family."""
    crit = evaluate(service.criticality, x, uf_labels.astype(np.int64))
    pb, conf = service.p95.predict(x)
    hi = conf >= service.confidence_gate
    p95 = {"pct_high_conf": float(hi.mean()),
           "accuracy_high_conf": float((pb[hi] == p95_buckets[hi]).mean()),
           "buckets": {}}
    for c in range(4):
        tp = int(((pb == c) & (p95_buckets == c) & hi).sum())
        fn = int(((pb != c) & (p95_buckets == c) & hi).sum())
        fp = int(((pb == c) & (p95_buckets != c) & hi).sum())
        p95["buckets"][c] = {"recall": tp / max(tp + fn, 1),
                             "precision": tp / max(tp + fp, 1)}
    return {"criticality": crit, "p95": p95}
