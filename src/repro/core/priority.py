"""Production-deployment lessons from paper §V, implemented:

* **Prioritized throttling list** — "we first consider low priority and
  internal non-production VMs for throttling and throttle production
  (including third-party, if configured) non-user-facing VMs as a last
  resort": the controller walks priority tiers instead of treating all
  NUF cores as one pool.
* **Killing VMs** — "some first-party customers ... prefer their VMs to
  be killed rather than throttled": kill-tagged VMs are shed entirely
  (their cores drop to zero utilization) when throttling the tiers
  below them is insufficient.
* **Per-VM frequency (no core pinning)** — production Azure could not
  restrict a VM to a core subset; the hypervisor carries a per-VM
  frequency to whichever cores it schedules on. We model that by
  tracking frequency per VM and projecting onto the VM's scheduled
  cores each quantum (frequencies change in tens of microseconds vs the
  10 ms quantum, so the projection is exact at our 200 ms step).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum


from repro.core.power_model import (F_MAX, F_MIN, N_PSTATES,
                                    ServerPowerModel, pstate_frequencies)


class Tier(IntEnum):
    """Throttling order: lower tiers are throttled first (paper §V)."""
    LOW_PRIORITY = 0            # internal non-production, spot
    INTERNAL_NUF = 1            # internal production batch
    EXTERNAL_NUF = 2            # third-party non-user-facing (if configured)
    USER_FACING = 3             # never throttled in-band


@dataclass
class PrioritizedVM:
    name: str
    cores: int
    tier: Tier
    kill_preferred: bool = False      # §V: kill instead of throttle
    freq: float = F_MAX               # per-VM frequency (no core pinning)
    alive: bool = True


@dataclass
class TieredController:
    """Per-VM controller with the §V prioritized throttling list.

    step(): given per-VM utilization, enforce the budget by walking
    tiers LOW_PRIORITY -> EXTERNAL_NUF: within a tier, first kill the
    kill-preferred VMs (if enabled), then lower the remaining VMs'
    frequency one p-state per poll. USER_FACING is only touched by the
    out-of-band RAPL model (not here).
    """
    model: ServerPowerModel
    budget_w: float
    enable_kill: bool = True
    vms: list = field(default_factory=list)
    target_margin_w: float = 5.0

    def register(self, vm: PrioritizedVM):
        self.vms.append(vm)

    def power(self, utils: dict) -> float:
        dyn = 0.0
        f_sum, n = 0.0, 0
        for vm in self.vms:
            u = utils.get(vm.name, 0.0) if vm.alive else 0.0
            dyn += vm.cores * u * self.model.p_dyn_per_core \
                * _dyn_scale(vm.freq)
            f_sum += vm.freq * vm.cores
            n += vm.cores
        from repro.core.power_model import idle_power
        return float(idle_power(f_sum / max(n, 1)) + dyn)

    def step(self, utils: dict) -> dict:
        """One 200 ms control step. Returns {power, killed, throttled}."""
        target = self.budget_w - self.target_margin_w
        killed, throttled = [], []
        power = self.power(utils)
        if power > target:
            for tier in (Tier.LOW_PRIORITY, Tier.INTERNAL_NUF,
                         Tier.EXTERNAL_NUF):
                tier_vms = [v for v in self.vms
                            if v.tier == tier and v.alive]
                # 1) kill-preferred VMs shed first within the tier
                if self.enable_kill:
                    for vm in tier_vms:
                        if power <= target:
                            break
                        if vm.kill_preferred:
                            vm.alive = False
                            killed.append(vm.name)
                            power = self.power(utils)
                # 2) throttle the rest one p-state
                for vm in tier_vms:
                    if power <= target:
                        break
                    if vm.alive and vm.freq > F_MIN:
                        vm.freq = _next_pstate_down(vm.freq)
                        throttled.append(vm.name)
                        power = self.power(utils)
                if power <= target:
                    break
        else:
            # recover: raise the HIGHEST tier first (least important
            # VMs stay throttled longest)
            for tier in (Tier.EXTERNAL_NUF, Tier.INTERNAL_NUF,
                         Tier.LOW_PRIORITY):
                for vm in self.vms:
                    if vm.tier != tier or not vm.alive:
                        continue
                    if vm.freq < F_MAX:
                        trial = _next_pstate_up(vm.freq)
                        old = vm.freq
                        vm.freq = trial
                        if self.power(utils) > target:
                            vm.freq = old
        return {"power_w": self.power(utils), "killed": killed,
                "throttled": throttled}

    def impact_report(self) -> dict:
        """§V 'metrics to measure impact': how long/hard VMs are capped
        is tracked by the caller per step; this reports current state."""
        return {vm.name: {"tier": int(vm.tier), "freq": vm.freq,
                          "alive": vm.alive} for vm in self.vms}


def _dyn_scale(f: float) -> float:
    from repro.core.power_model import dyn_scale
    return float(dyn_scale(f))


_TABLE = pstate_frequencies(N_PSTATES)


def _next_pstate_down(f: float) -> float:
    lower = _TABLE[_TABLE < f - 1e-9]
    return float(lower[0]) if len(lower) else F_MIN


def _next_pstate_up(f: float) -> float:
    higher = _TABLE[::-1]
    higher = higher[higher > f + 1e-9]
    return float(higher[0]) if len(higher) else F_MAX
