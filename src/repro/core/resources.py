"""The resource vector: joint (watts, cores, GB) oversubscription
currency (DESIGN.md §16, docs/resources.md).

The paper oversubscribes *power* only; Coach (arxiv 2501.11179) shows
the larger win comes from oversubscribing cores and memory jointly by
exploiting temporal (diurnal) patterns, and CloudPowerCap (arxiv
1403.1289) argues the power budget must be managed *together with* the
other resources. This module is the shared vocabulary for that: every
admission ceiling, token pool, and per-arrival demand in the serve
plane is an (R,) vector over the axes

    0 = watts  — in rho units (``p95 * cores``), the same currency as
        ``rho_peak``; a watt budget converts through the calibrated
        power model (`serve.admission.rho_cap_from_budget`)
    1 = cores  — allocated virtual cores
    2 = gb     — allocated memory, GB

so the scalar watt protocol of DESIGN.md §10 is exactly the R=1
projection: a disabled axis carries +inf (ceilings/pools) or 0
(demands) and every compare is vacuous on it — decision-bit-identical
to the pre-vector code, which the equivalence tests assert.

`ResourceVector` is the host-side budget/quantity triple (`None` =
axis unbudgeted); `demand_vector` builds the per-arrival draw; and
`trough_ratios` is the Coach-style time-of-day conditioning: as the
fleet's diurnal utilization sample drops below a pivot, the cores/GB
axes of a budget ratchet up (power stays put — watts are a physical
breaker limit, not a statistical one), so the trough admits the
oversubscription the peak could not.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

#: Resource-axis order of every (R,) vector in the serve plane.
RESOURCES = ("watts", "cores", "gb")
N_RESOURCES = len(RESOURCES)
R_WATTS, R_CORES, R_GB = range(N_RESOURCES)


@dataclass(frozen=True)
class ResourceVector:
    """A (watts, cores, GB) triple — budget, capacity, or usage.

    ``None`` means "axis not budgeted" and becomes +inf in ceiling /
    pool form (`as_array`) — the compare against it is vacuous, so a
    power-only `ResourceVector(watts=B)` reproduces the scalar watt
    protocol bit for bit. Frozen and hashable so it can ride in
    jit-static config dataclasses."""
    watts: float | None = None
    cores: float | None = None
    gb: float | None = None

    def as_tuple(self) -> tuple:
        return (self.watts, self.cores, self.gb)

    def as_array(self, fill: float = np.inf) -> np.ndarray:
        """(R,) f64 with `fill` substituted for ``None`` axes."""
        return np.asarray([fill if v is None else float(v)
                           for v in self.as_tuple()], np.float64)

    @property
    def power_only(self) -> bool:
        """True when only the watts axis is budgeted — the scalar
        protocol this vector generalizes."""
        return self.cores is None and self.gb is None

    def scaled(self, ratios) -> "ResourceVector":
        """Per-axis multiply (``None`` axes stay ``None``) — how the
        adaptive controller / diurnal conditioning retargets a
        budget."""
        r = np.asarray(ratios, np.float64)
        vals = [None if v is None else float(v) * float(r[i])
                for i, v in enumerate(self.as_tuple())]
        return ResourceVector(*vals)


def demand_vector(cores, p95_eff, mem_gb, xp=np):
    """(..., R) per-VM admission draw: ``(p95*cores, cores, gb)``.

    This is the exact quantity `serve.placement._commit` adds to the
    chassis ledger and subtracts from the token pool — the watts axis
    is rho units, so axis 0 of the ledger IS the legacy ``rho_peak``.
    """
    cores = xp.asarray(cores)
    w = xp.asarray(p95_eff) * cores
    return xp.stack([w, cores, xp.asarray(mem_gb)], axis=-1)


def trough_ratios(util, pivot_util: float = 0.55,
                  cores_boost: float = 0.5, gb_boost: float = 0.5,
                  xp=np):
    """(..., R) Coach-style diurnal conditioning multipliers.

    `util` is the fleet utilization sample (`telemetry.diurnal_util`
    at the current hour on the simulated trace; a measured fleet
    average in production). Relief grows linearly as util falls below
    `pivot_util` (branchless clip):

        relief = clip((pivot - util) / pivot, 0, 1)
        ratios = (1, 1 + cores_boost*relief, 1 + gb_boost*relief)

    Watts never ratchet — a breaker budget is a physical limit; the
    cores/GB axes are statistical commitments that the diurnal trough
    makes temporarily safe to oversell (and the emergency ladder —
    cap, balloon, migrate — backstops when the peak returns)."""
    util = xp.asarray(util)
    relief = xp.clip((pivot_util - util) / pivot_util, 0.0, 1.0)
    one = xp.ones_like(relief)
    return xp.stack([one, one + cores_boost * relief,
                     one + gb_boost * relief], axis=-1)


def lift_caps(cap, n_axes: int = N_RESOURCES, xp=np):
    """Lift a scalar-era (C,) watt-axis ceiling to an (C, R) resource
    ceiling with +inf (vacuous) extra axes; (.., R) passes through.
    The compat shim every placement entry point runs, so legacy
    callers keep their exact decisions."""
    cap = xp.asarray(cap)
    if cap.ndim >= 2:
        return cap
    pad = xp.full(cap.shape + (n_axes - 1,), xp.inf, cap.dtype)
    return xp.concatenate([cap[..., None], pad], axis=-1)


def lift_pool(pool, n_axes: int = N_RESOURCES, xp=np):
    """Lift a scalar token-pool balance to (R,) with +inf extra axes;
    (R,) passes through (same compat rule as `lift_caps`)."""
    pool = xp.asarray(pool)
    if pool.ndim >= 1:
        return pool
    pad = xp.full((n_axes - 1,), xp.inf, pool.dtype)
    return xp.concatenate([pool[None], pad], axis=-1)
