"""Time-series preprocessing for the criticality algorithm (paper §III-B).

All functions are pure jnp, vectorized over a leading batch of VM series.
Series layout: (..., T) where T = days * slots_per_day (default 5 * 48 =
240 half-hour average CPU utilizations over 5 weekdays).
"""
from __future__ import annotations

import jax.numpy as jnp

SLOTS_PER_DAY = 48          # 30-minute intervals
DEFAULT_DAYS = 5
EPS = 1e-6


def rolling_day_mean(x: jnp.ndarray, window: int = SLOTS_PER_DAY) -> jnp.ndarray:
    """Mean of the *previous* `window` samples at each position.

    For t < window we use the running prefix mean (the paper does not
    specify the warm-up; a prefix mean keeps the first day usable instead
    of discarding it). Shape-preserving.
    """
    t = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    zeros = jnp.zeros(x.shape[:-1] + (1,), x.dtype)
    csum0 = jnp.concatenate([zeros, csum], axis=-1)          # (..., T+1)
    idx = jnp.arange(t)
    lo = jnp.maximum(idx - window + 1, 0)                    # inclusive window start
    width = (idx - lo + 1).astype(x.dtype)
    win_sum = jnp.take(csum0, idx + 1, axis=-1) - jnp.take(csum0, lo, axis=-1)
    return win_sum / jnp.maximum(width, 1.0)


def detrend(x: jnp.ndarray, window: int = SLOTS_PER_DAY) -> jnp.ndarray:
    """Paper step 1a: scale each utilization by the mean of the previous
    24 hours, removing multi-day growth/decay trends."""
    base = rolling_day_mean(x, window)
    return x / jnp.maximum(base, EPS)


def normalize(x: jnp.ndarray) -> jnp.ndarray:
    """Paper step 1b: divide by the standard deviation of the whole series."""
    sd = jnp.std(x, axis=-1, keepdims=True)
    return x / jnp.maximum(sd, EPS)


def preprocess(x: jnp.ndarray, window: int = SLOTS_PER_DAY) -> jnp.ndarray:
    """De-trend then normalize (paper §III-B step 1)."""
    return normalize(detrend(x, window))


def extract_template(x: jnp.ndarray, period: int) -> jnp.ndarray:
    """Paper step 2: per-slot 'typical' utilization = median across all
    repetitions of that slot. x: (..., T) with T % period == 0.
    Returns (..., period)."""
    t = x.shape[-1]
    assert t % period == 0, (t, period)
    reps = t // period
    xr = x.reshape(x.shape[:-1] + (reps, period))
    return jnp.median(xr, axis=-2)


def template_deviation(x: jnp.ndarray, period: int,
                       keep_frac: float = 0.8) -> jnp.ndarray:
    """Paper step 3: overlay the template, compute |deviation| for every
    sample, exclude the (1-keep_frac) largest deviations, average the rest.
    Returns (...,) scalar per series."""
    t = x.shape[-1]
    reps = t // period
    template = extract_template(x, period)
    tiled = jnp.tile(template, (1,) * (x.ndim - 1) + (reps,))
    dev = jnp.abs(x - tiled)
    k = int(round(keep_frac * t))
    # keep the k smallest deviations exactly (sort-based; the Pallas kernel
    # uses bisection selection and is tested against this oracle).
    dev_sorted = jnp.sort(dev, axis=-1)
    return jnp.mean(dev_sorted[..., :k], axis=-1)
