"""Deterministic synthetic token pipeline with host-side prefetch.

Production shape: a seeded, stateless source (step -> batch) so any step
is reproducible after restart (checkpoint stores only the step number);
a background thread keeps a bounded prefetch queue full (double
buffering overlaps host batch generation with device compute); shards
slice the global batch by data-parallel rank for multi-host launches.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Zipf-ish token stream with next-token labels; step-indexed and
    fully deterministic (restart-safe)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int, rank: int = 0, world: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % world == 0
        per = cfg.global_batch // world
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, rank]))
        toks = rng.choice(cfg.vocab_size, size=(per, cfg.seq_len + 1),
                          p=self._probs).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Bounded background prefetch: next batches are generated while the
    device step runs (the async/overlap trick at the host level)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2, rank: int = 0, world: int = 1):
        self.source = source
        self.queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self.rank, self.world = rank, world
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step, self.rank, self.world)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple:
        return self.queue.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
