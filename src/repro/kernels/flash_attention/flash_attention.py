"""Pallas TPU kernel: flash attention (prefill), causal + sliding window.

Classic three-dimensional grid (batch*heads, q blocks, kv blocks) with the
kv dimension innermost/sequential; online-softmax running max/sum and the
output accumulator live in VMEM scratch across kv steps. Block shapes are
MXU-aligned (BQ = BK = 128 defaults, head_dim padded to 128 by ops.py).

The CPU dry-run path uses the XLA-chunked equivalent in
`repro.models.attention`; this kernel is the TPU fast path, validated in
interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
BQ = 128
BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *,
                  scale: float, causal: bool, window: int | None,
                  nk: int, q_offset: int, valid_lk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    i = pl.program_id(1)
    bq, bk = s.shape
    qi = (i * bq + q_offset
          + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < valid_lk                 # padded keys are never attended
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                               # (BQ, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # masked -> ~0
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc[...] = acc[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _flush():
        o_ref[0] = (acc[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True, window: int | None = None,
                           bq: int = BQ, bk: int = BK,
                           q_offset: int | None = None,
                           valid_lk: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (BH, Lq, D); k, v: (BH, Lk, D). Lq % bq == Lk % bk == 0.
    Query positions are aligned to the END of the VALID kv sequence
    (q_offset defaults to Lk - Lq); keys at positions >= valid_lk are
    masked (padding)."""
    bh, lq, d = q.shape
    _, lk, _ = k.shape
    nq, nk = lq // bq, lk // bk
    scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, nk=nk,
        q_offset=lk - lq if q_offset is None else q_offset,
        valid_lk=lk if valid_lk is None else valid_lk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
