"""Jitted wrapper: GQA head repetition, block padding, dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D), Hq % Hkv == 0.
    Returns (B, Hq, Lq, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq_ = min(bq, lq)
    pad_q = (-lq) % bq_
    pad_k = (-lk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # padded keys are masked via valid_lk; padded q rows produce zeros
    # that are sliced away.
    out = flash_attention_pallas(
        qp.reshape(b * hq, lq + pad_q, d),
        kp.reshape(b * hq, lk + pad_k, d),
        vp.reshape(b * hq, lk + pad_k, d),
        causal=causal, window=window, bq=bq_, bk=min(bk, lk + pad_k),
        q_offset=lk - lq, valid_lk=lk, interpret=interpret)
    return out.reshape(b, hq, lq + pad_q, d)[:, :, :lq]
