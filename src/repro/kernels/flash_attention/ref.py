"""Naive-softmax oracle for flash attention (f32 throughout)."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  window: int | None = None) -> jnp.ndarray:
    """q: (B, H, Lq, D); k, v: (B, H, Lk, D) (kv heads already repeated).
    Full-materialization reference."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    lq, lk = q.shape[2], k.shape[2]
    qi = jnp.arange(lq)[:, None] + (lk - lq)    # align ends (decode)
    kj = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, NEG)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
