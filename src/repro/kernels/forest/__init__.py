from repro.kernels.forest.ops import forest_predict  # noqa: F401
