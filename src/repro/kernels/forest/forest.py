"""Pallas TPU kernel: oblivious-forest ensemble inference.

TPU adaptation (DESIGN.md §3): classic tree traversal is pointer-chasing;
oblivious trees make the whole ensemble dense algebra that maps onto the
MXU as two matmuls:

  1. feature gather  -> one-hot matmul:  (B, F) @ (F, T*D)  = levels
  2. compare         -> bits = levels > thresholds          (VPU)
  3. leaf index      -> bit-packed:  sum_l bits * 2^(D-1-l) (VPU)
  4. leaf lookup     -> one-hot leaf (B, T*L) built by iota-compare,
                        then (B, T*L) @ (T*L, K) = summed leaf values

Tiling (DESIGN.md §13): the kernel runs on a 2-D ``(batch, trees)``
grid. Each program instance evaluates one (BLOCK_B, BLOCK_T) tile in
two stages — stage 1 is the gather matmul + bit-pack for its tree
slice, stage 2 the one-hot leaf matmul — and accumulates its partial
(BLOCK_B, K) sum into the output block. The tree axis is the innermost
grid dimension, so the output block for a batch tile is revisited on
consecutive iterations: ``@pl.when(j == 0)`` zero-initializes it, every
tree tile adds its partial sum. Tiling over trees bounds the one-hot
scratch at (BLOCK_B x BLOCK_T*L) regardless of ensemble size — the
whole-forest scratch (BLOCK_B x T*L, ~1.5 MiB at T = 48, D = 6,
BLOCK_B = 128) is what previously capped BLOCK_B well below the MXU
sweet spot for deep ensembles.

The ops.py wrapper precomputes the (F, T*D) one-hot gather matrix and
the (T*L, K) flattened leaf table from a trained `ObliviousForest`, so
the kernel itself is shape-static. All tile shapes are parity-tested
against ref.py (tests/test_kernels.py) and measured by
benchmarks/forest_kernel.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128
#: Default tree-tile width (trees per program instance). None = all
#: trees in one tile (the pre-tiling layout, still optimal for the
#: small four-forest serving ensembles).
BLOCK_T = None


def _forest_kernel_tiled(x_ref, gather_ref, thr_ref, leaf_ref, out_ref,
                         *, block_t: int, depth: int):
    """One (batch-tile, tree-tile) program instance: partial leaf sums
    for `block_t` trees, accumulated into the batch tile's output."""
    x = x_ref[...]                                # (B, F)
    gather = gather_ref[...]                      # (F, Tb*D)
    thr = thr_ref[...]                            # (1, Tb*D)
    leaf_tab = leaf_ref[...]                      # (Tb*L, K)
    b = x.shape[0]
    n_leaves = 1 << depth

    # stage 1: feature gather + level compare + leaf-index bit-pack
    levels = jnp.dot(x, gather,
                     preferred_element_type=jnp.float32)     # (B, Tb*D)
    bits = (levels > thr).astype(jnp.float32)
    bits = bits.reshape(b, block_t, depth)
    # 2^(D-1-l) weights, built with iota to avoid captured constants
    lvl = jax.lax.broadcasted_iota(jnp.float32, (1, 1, depth), 2)
    weights = jnp.exp2((depth - 1) - lvl)
    leaf_idx = jnp.sum(bits * weights, axis=-1)              # (B, Tb)

    # stage 2: one-hot leaf lookup matmul for this tree slice
    iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1, n_leaves), 2)
    onehot = (jnp.abs(leaf_idx[:, :, None] - iota) < 0.5) \
        .astype(jnp.float32)                     # (B, Tb, L)
    onehot = onehot.reshape(b, block_t * n_leaves)
    partial = jnp.dot(onehot, leaf_tab,
                      preferred_element_type=jnp.float32)    # (B, K)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def resolve_block_t(n_trees: int, block_t: int | None) -> int:
    """Clamp a requested tree-tile width to a divisor of the ensemble:
    the largest divisor of `n_trees` that is <= the request (so odd
    ensemble sizes degrade to a coarser tile instead of failing)."""
    if block_t is None or block_t >= n_trees:
        return n_trees
    block_t = max(int(block_t), 1)
    while n_trees % block_t:
        block_t -= 1
    return block_t


def forest_predict_pallas(x: jnp.ndarray, gather: jnp.ndarray,
                          thresholds_flat: jnp.ndarray,
                          leaf_table: jnp.ndarray, n_trees: int,
                          depth: int, block_b: int = BLOCK_B,
                          block_t: int | None = BLOCK_T,
                          interpret: bool = False) -> jnp.ndarray:
    """Summed leaf values over trees: (B, K). Caller scales (RF mean) or
    softmaxes (GB). `block_b`/`block_t` pick the (batch, trees) tile;
    `block_t=None` puts the whole ensemble in one tile."""
    b, f = x.shape
    td = gather.shape[1]
    tl, k = leaf_table.shape
    assert b % block_b == 0
    block_t = resolve_block_t(n_trees, block_t)
    n_leaves = 1 << depth
    kernel = functools.partial(_forest_kernel_tiled, block_t=block_t,
                               depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b, n_trees // block_t),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i, j: (i, 0)),
            pl.BlockSpec((f, block_t * depth), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_t * depth), lambda i, j: (0, j)),
            pl.BlockSpec((block_t * n_leaves, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, gather, thresholds_flat, leaf_table)
