"""Pallas TPU kernel: oblivious-forest ensemble inference.

TPU adaptation (DESIGN.md §3): classic tree traversal is pointer-chasing;
oblivious trees make the whole ensemble dense algebra that maps onto the
MXU as two matmuls:

  1. feature gather  -> one-hot matmul:  (B, F) @ (F, T*D)  = levels
  2. compare         -> bits = levels > thresholds          (VPU)
  3. leaf index      -> bit-packed:  sum_l bits * 2^(D-1-l) (VPU)
  4. leaf lookup     -> one-hot leaf (B, T*L) built by iota-compare,
                        then (B, T*L) @ (T*L, K) = summed leaf values

The ops.py wrapper precomputes the (F, T*D) one-hot gather matrix and the
(T*L, K) flattened leaf table from a trained `ObliviousForest`, so the
kernel itself is shape-static. Block layout: (BLOCK_B, ·) tiles in VMEM;
with T = 48 trees, D = 6, K <= 4: gather matrix ~36 KiB, leaf table
~49 KiB, one-hot scratch (BLOCK_B x 3072) ~1.5 MiB at BLOCK_B = 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128


def _forest_kernel(x_ref, gather_ref, thr_ref, leaf_ref, out_ref, *,
                   n_trees: int, depth: int):
    x = x_ref[...]                                # (B, F)
    gather = gather_ref[...]                      # (F, T*D)
    thr = thr_ref[...]                            # (1, T*D)
    leaf_tab = leaf_ref[...]                      # (T*L, K)
    b = x.shape[0]
    n_leaves = 1 << depth

    levels = jnp.dot(x, gather,
                     preferred_element_type=jnp.float32)      # (B, T*D)
    bits = (levels > thr).astype(jnp.float32)
    bits = bits.reshape(b, n_trees, depth)
    # 2^(D-1-l) weights, built with iota to avoid captured constants
    lvl = jax.lax.broadcasted_iota(jnp.float32, (1, 1, depth), 2)
    weights = jnp.exp2((depth - 1) - lvl)
    leaf_idx = jnp.sum(bits * weights, axis=-1)                 # (B, T)

    iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1, n_leaves), 2)
    onehot = (jnp.abs(leaf_idx[:, :, None] - iota) < 0.5) \
        .astype(jnp.float32)                       # (B, T, L)
    onehot = onehot.reshape(b, n_trees * n_leaves)
    out_ref[...] = jnp.dot(onehot, leaf_tab,
                           preferred_element_type=jnp.float32)  # (B, K)


def forest_predict_pallas(x: jnp.ndarray, gather: jnp.ndarray,
                          thresholds_flat: jnp.ndarray,
                          leaf_table: jnp.ndarray, n_trees: int,
                          depth: int, block_b: int = BLOCK_B,
                          interpret: bool = False) -> jnp.ndarray:
    """Summed leaf values over trees: (B, K). Caller scales (RF mean) or
    softmaxes (GB)."""
    b, f = x.shape
    td = gather.shape[1]
    tl, k = leaf_table.shape
    assert b % block_b == 0
    kernel = functools.partial(_forest_kernel, n_trees=n_trees,
                               depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, td), lambda i: (0, 0)),
            pl.BlockSpec((1, td), lambda i: (0, 0)),
            pl.BlockSpec((tl, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(x, gather, thresholds_flat, leaf_table)
