"""Jitted public wrapper: serve a trained ObliviousForest on TPU.

Precomputes the dense gather matrix / flat leaf table once per model
(cheap; models retrain daily in the paper) and pads the query batch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import ObliviousForest
from repro.kernels.forest.forest import (BLOCK_B, BLOCK_T,
                                         forest_predict_pallas)


def pack_forest(forest: ObliviousForest):
    """Build the kernel's static operands from a trained forest."""
    t, d = forest.feat_idx.shape
    f = forest.n_features
    gather = np.zeros((f, t * d), np.float32)
    gather[forest.feat_idx.reshape(-1), np.arange(t * d)] = 1.0
    thr = forest.thresholds.reshape(1, t * d).astype(np.float32)
    leaf_tab = forest.leaf_values.reshape(t * (1 << d),
                                          forest.n_out).astype(np.float32)
    return (jnp.asarray(gather), jnp.asarray(thr), jnp.asarray(leaf_tab),
            t, d, forest.kind)


def normalize_forest_output(summed, kind: str, n_trees: int):
    """Summed leaf values -> class probabilities: RF mean / GB softmax.
    The one definition shared by the kernel wrapper and the serving
    path's ref/stacked formulations."""
    if kind == "rf":
        return summed / n_trees
    m = summed - summed.max(-1, keepdims=True)
    e = jnp.exp(m)
    return e / e.sum(-1, keepdims=True)


def predict_packed(x, gather, thr, leaf_tab, n_trees, depth, kind,
                   interpret, block_b: int = BLOCK_B,
                   block_t: int | None = BLOCK_T):
    """Pad the batch to `block_b`, run the tiled kernel on packed
    operands, and normalize. Traceable — shared by `_predict` and the
    serving path (`repro.serve.inference`)."""
    b = x.shape[0]
    pad = (-b) % block_b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], 0)
    summed = forest_predict_pallas(x.astype(jnp.float32), gather, thr,
                                   leaf_tab, n_trees, depth,
                                   block_b=block_b, block_t=block_t,
                                   interpret=interpret)[:b]
    return normalize_forest_output(summed, kind, n_trees)


_predict = partial(jax.jit,
                   static_argnames=("n_trees", "depth", "kind",
                                    "interpret", "block_b",
                                    "block_t"))(predict_packed)


def forest_predict(forest: ObliviousForest, x, interpret: bool | None = None):
    """(B, F) features -> (B, K) probabilities via the Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    gather, thr, leaf_tab, t, d, kind = pack_forest(forest)
    return _predict(jnp.asarray(x), gather, thr, leaf_tab, t, d, kind,
                    interpret)
