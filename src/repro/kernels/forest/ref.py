"""Pure-jnp oracle for oblivious-forest inference.

Mirrors `repro.core.forest.ObliviousForest.predict_proba_np` (the numpy
oracle used for training-time evaluation) in jnp.
"""
from __future__ import annotations

import jax.numpy as jnp


def forest_predict_ref(x: jnp.ndarray, feat_idx: jnp.ndarray,
                       thresholds: jnp.ndarray, leaf_values: jnp.ndarray,
                       kind: str) -> jnp.ndarray:
    """x: (B, F); feat_idx/thresholds: (T, D); leaf_values: (T, 2**D, K).
    Returns (B, K) class probabilities."""
    n_trees, depth = feat_idx.shape
    gathered = x[:, feat_idx.reshape(-1)].reshape(-1, n_trees, depth)
    bits = (gathered > thresholds[None]).astype(jnp.int32)
    weights = (2 ** jnp.arange(depth))[::-1]
    leaves = (bits * weights[None, None, :]).sum(-1)          # (B, T)
    vals = leaf_values[jnp.arange(n_trees)[None, :], leaves]  # (B, T, K)
    if kind == "rf":
        return vals.mean(axis=1)
    return _softmax(vals.sum(axis=1))


def _softmax(logits: jnp.ndarray) -> jnp.ndarray:
    m = logits - logits.max(-1, keepdims=True)
    e = jnp.exp(m)
    return e / e.sum(-1, keepdims=True)
