"""Jitted wrapper for the SSD kernel: length padding + dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import CHUNK, ssd_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b, c, d=None, chunk: int = CHUNK,
        interpret: bool | None = None):
    """Mamba2 SSD: x (B, L, H, P), dt (B, L, H), a (H,), b/c (B, L, N),
    d (H,) skip. Returns y (B, L, H, P). Pads L to the chunk size with
    dt = 0 steps (exact no-ops)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if d is None:
        d = jnp.zeros(x.shape[2], jnp.float32)
    l = x.shape[1]
    ch = min(chunk, max(l, 8))
    pad = (-l) % ch
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    out = ssd_pallas(x, dt, a, b, c, d, chunk=ch, interpret=interpret)
    return out[:, :l]
