"""Oracles for the Mamba2 SSD kernel.

`ssd_ref` is the exact per-step linear recurrence (lax.scan, f32):

    S_t = S_{t-1} * exp(A_h dt_t) + dt_t * x_t (x) B_t
    y_t = C_t . S_t + D_h x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, a, b, c, d=None):
    """x: (B, L, H, P); dt: (B, L, H); a: (H,) (negative);
    b, c: (B, L, N) shared across heads (ngroups=1); d: (H,) skip.
    Returns y: (B, L, H, P), final state (B, H, P, N)."""
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    bsz, l, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                    # (B,H,P), (B,H), (B,N)...
        decay = jnp.exp(a[None, :] * dtt)        # (B, H)
        inject = (dtt[..., None, None] * xt[..., None]
                  * bt[:, None, None, :])        # (B, H, P, N)
        state = state * decay[..., None, None] + inject
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (B, L, H, P)
    if d is not None:
        y = y + d[None, None, :, None] * x32
    return y.astype(x.dtype), final
