"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD dual form splits the sequence into chunks of Q steps. Within a
chunk the output is an attention-like (Q x Q) masked matmul (MXU); across
chunks a (P x N) recurrent state carries in VMEM scratch, with the chunk
axis innermost in the grid so state persists across sequential grid steps
(the canonical TPU pattern for scans).

    cum_t   = cumsum(A_h dt_t)                      within chunk
    y_intra = ((C B^T) o M) (dt*x),  M_ij = exp(cum_i - cum_j) [i >= j]
    y_inter = exp(cum) * (C S_prev^T)
    S_new   = exp(cum_Q) S_prev + (dt*x*exp(cum_Q - cum))^T B

All exponents are <= 0 (A < 0, dt >= 0) so everything is stable in f32.
Zero-padding the tail chunk is exact: dt = 0 steps neither decay nor
inject state and produce y = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dskip_ref, y_ref,
                s_scr, *, nchunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    a = a_ref[0, 0]                                    # scalar A_h
    bm = b_ref[0].astype(jnp.float32)                  # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                  # (Q, N)
    dskip = dskip_ref[0, 0]

    q = x.shape[0]
    adt = a * dt                                       # (Q,) <= 0
    cum = jnp.cumsum(adt)                              # (Q,)
    total = cum[-1]

    # intra-chunk: masked decay matrix M (Q, Q); mask before exp so the
    # i < j half (positive exponents) cannot overflow
    diff = cum[:, None] - cum[None, :]                 # cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.exp(jnp.where(ii >= jj, diff, -jnp.inf))
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    xdt = x * dt[:, None]                              # (Q, P)
    y = jax.lax.dot(scores * m, xdt,
                    preferred_element_type=jnp.float32)

    # inter-chunk: contribution of carried state
    s_prev = s_scr[...]                                # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (Q, P)

    # state update
    w = jnp.exp(total - cum)[:, None] * xdt            # (Q, P)
    s_scr[...] = jnp.exp(total) * s_prev + jax.lax.dot_general(
        w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (P, N)

    y_ref[0, :, 0, :] = (y + dskip * x).astype(y_ref.dtype)


def ssd_pallas(x, dt, a, b, c, d, chunk: int = CHUNK,
               interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a, d: (H,); b, c: (B, L, N).
    L % chunk == 0. Returns y: (B, L, H, P)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    nchunks = l // chunk
    a2 = a.reshape(h, 1).astype(jnp.float32)
    d2 = d.reshape(h, 1).astype(jnp.float32)
    kernel = functools.partial(_ssd_kernel, nchunks=nchunks)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, cc: (bb, cc, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, cc: (bb, cc, hh)),
            pl.BlockSpec((1, 1), lambda bb, hh, cc: (hh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, cc: (hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bb, hh, cc: (bb, cc, hh, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, b, c, d2)
