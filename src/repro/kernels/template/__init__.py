from repro.kernels.template.ops import criticality_scores  # noqa: F401
