"""Jitted public wrapper for the criticality template kernel.

Pads the VM batch to the block size, dispatches to the Pallas kernel
(interpret=True on CPU — this container's validation mode; compiled
kernel on TPU), and unpads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.template.template import (BLOCK_B,
                                             criticality_scores_pallas)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("keep_frac", "interpret", "block_b"))
def criticality_scores(series: jnp.ndarray, keep_frac: float = 0.8,
                       interpret: bool | None = None,
                       block_b: int = BLOCK_B) -> jnp.ndarray:
    """(B, T) -> (B, 2) [Compare8, Compare12] for a batch of VM series."""
    if interpret is None:
        interpret = not _on_tpu()
    b = series.shape[0]
    pad = (-b) % block_b
    if pad:
        series = jnp.concatenate(
            [series, jnp.ones((pad, series.shape[1]), series.dtype)], 0)
    out = criticality_scores_pallas(series, keep_frac=keep_frac,
                                    block_b=block_b, interpret=interpret)
    return out[:b]
