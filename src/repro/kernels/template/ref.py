"""Pure-jnp oracle for the criticality template kernel.

Delegates to `repro.core.criticality` / `repro.core.timeseries` — the
paper-faithful implementation (exact medians via jnp.median, exact
smallest-k selection via jnp.sort).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.criticality import score as _score


def criticality_scores_ref(series: jnp.ndarray) -> jnp.ndarray:
    """(B, T) -> (B, 2) [Compare8, Compare12]."""
    s = _score(series)
    return jnp.stack([s.compare8, s.compare12], axis=-1)
