"""Pallas TPU kernel: fleet-scale criticality template scoring.

One fused pass over a block of VM utilization series computes the full
paper §III-B algorithm: de-trend (rolling 24 h mean via cumsum),
normalize, extract 24 h/12 h/8 h median templates, deviation scoring
with top-20 % exclusion, and the Compare8/Compare12 ratios.

TPU adaptation (DESIGN.md §3): no data-dependent control flow —
  * per-slot medians use odd-even transposition sort networks
    (branch-free jnp.minimum/maximum ladders on the repetition axis);
  * the "exclude the 20 % largest deviations" selection uses fixed-count
    bisection on the deviation value (24 iterations) with a tie
    correction, instead of a sort of the full series.

Block layout: each grid step processes a (BLOCK_B, T) tile resident in
VMEM (T = 240 -> ~120 KiB per tile at BLOCK_B = 128, well under the
~16 MiB VMEM budget; BLOCK_B stays a multiple of 8 for VPU sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128
BISECT_ITERS = 24
EPS = 1e-6


def _oddeven_sort(x: jnp.ndarray) -> jnp.ndarray:
    """Sort along axis -2 (the repetition axis) with an odd-even
    transposition network: n branch-free passes of pairwise min/max."""
    n = x.shape[-2]
    for p in range(n):
        start = p % 2
        for i in range(start, n - 1, 2):
            a = x[..., i, :]
            b = x[..., i + 1, :]
            lo = jnp.minimum(a, b)
            hi = jnp.maximum(a, b)
            x = x.at[..., i, :].set(lo).at[..., i + 1, :].set(hi)
    return x


def _median_template(x: jnp.ndarray, period: int) -> jnp.ndarray:
    """(B, T) -> (B, period): per-slot median across T//period reps."""
    b, t = x.shape
    reps = t // period
    xr = x.reshape(b, reps, period)
    xs = _oddeven_sort(xr)
    if reps % 2 == 1:
        return xs[:, reps // 2, :]
    return 0.5 * (xs[:, reps // 2 - 1, :] + xs[:, reps // 2, :])


def _trimmed_mean_deviation(x: jnp.ndarray, period: int,
                            keep_frac: float) -> jnp.ndarray:
    """Mean of the k smallest |x - tiled template| (k = keep_frac * T),
    via bisection selection of the k-th smallest value."""
    b, t = x.shape
    reps = t // period
    tmpl = _median_template(x, period)
    dev = jnp.abs(x - jnp.tile(tmpl, (1, reps)))
    k = round(keep_frac * t)

    lo = jnp.zeros((b, 1), x.dtype)
    hi = jnp.max(dev, axis=-1, keepdims=True)
    for _ in range(BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((dev <= mid).astype(x.dtype), axis=-1, keepdims=True)
        go_lo = cnt >= k
        hi = jnp.where(go_lo, mid, hi)
        lo = jnp.where(go_lo, lo, mid)
    thr = hi                                          # ~ k-th smallest
    le = dev <= thr
    cnt_le = jnp.sum(le.astype(x.dtype), axis=-1, keepdims=True)
    sum_le = jnp.sum(jnp.where(le, dev, 0.0), axis=-1, keepdims=True)
    # remove the (cnt_le - k) tied values at the threshold
    sum_k = sum_le - (cnt_le - k) * thr
    return (sum_k / k)[:, 0]


def _criticality_kernel(series_ref, out_ref, *, keep_frac: float):
    x = series_ref[...]                               # (BLOCK_B, T)
    b, t = x.shape
    day = 48

    # --- de-trend: divide by mean of the previous 24 h (prefix mean
    # warm-up), exactly as repro.core.timeseries.rolling_day_mean ---
    csum = jnp.cumsum(x, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    lo_i = jnp.maximum(idx - day + 1, 0)
    width = (idx - lo_i + 1).astype(x.dtype)
    zeros = jnp.zeros((b, 1), x.dtype)
    csum0 = jnp.concatenate([zeros, csum], axis=-1)
    take = functools.partial(jnp.take_along_axis, axis=-1)
    win_sum = take(csum0, jnp.broadcast_to(idx + 1, (b, t))) \
        - take(csum0, jnp.broadcast_to(lo_i, (b, t)))
    base = win_sum / jnp.maximum(width, 1.0)
    x = x / jnp.maximum(base, EPS)

    # --- normalize by whole-series std ---
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.sqrt(jnp.maximum(jnp.mean((x - mu) ** 2, axis=-1,
                                       keepdims=True), EPS * EPS))
    x = x / jnp.maximum(sd, EPS)

    dev24 = _trimmed_mean_deviation(x, 48, keep_frac)
    dev12 = _trimmed_mean_deviation(x, 24, keep_frac)
    dev8 = _trimmed_mean_deviation(x, 16, keep_frac)
    compare8 = dev24 / jnp.maximum(dev8, EPS)
    compare12 = dev24 / jnp.maximum(dev12, EPS)
    out_ref[...] = jnp.stack([compare8, compare12], axis=-1)


def criticality_scores_pallas(series: jnp.ndarray, keep_frac: float = 0.8,
                              block_b: int = BLOCK_B,
                              interpret: bool = False) -> jnp.ndarray:
    """(B, T) -> (B, 2) [Compare8, Compare12]. B % block_b == 0."""
    b, t = series.shape
    assert t % 48 == 0, "series length must be whole days of 48 slots"
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    kernel = functools.partial(_criticality_kernel, keep_frac=keep_frac)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 2), series.dtype),
        interpret=interpret,
    )(series)
