import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape) cell, lower + compile the step on
the production mesh — 16x16 single-pod AND 2x16x16 multi-pod — and record
memory_analysis(), cost_analysis() and the per-device collective traffic
parsed from the post-SPMD HLO. No device allocation happens: parameters,
optimizer state, caches and batches are ShapeDtypeStructs.

The two os.environ lines above MUST precede any other import (jax locks
the device count at first initialization).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --multi-pod --strategy fsdp2d
  PYTHONPATH=src python -m repro.launch.dryrun --list

Each cell's artifact is cached in artifacts/dryrun/<cell>.json; re-runs
skip completed cells (--force to recompute).
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import SHAPES                       # noqa: E402
from repro.configs.registry import ARCHS, cell_is_runnable  # noqa: E402
from repro.launch import sharding as shd                    # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch.steps import step_for_shape               # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "c64": 8, "u64": 8}

_SHAPE_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")


def parse_collectives(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in the per-device HLO.
    Returns {op_name: bytes, 'total': bytes}."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[op] += n * nbytes
        count[op] += 1
    return {"bytes": out, "count": count,
            "total_bytes": sum(out.values())}


def cell_id(arch: str, shape: str, multi_pod: bool, strategy: str) -> str:
    pod = "pod2" if multi_pod else "pod1"
    return f"{arch}__{shape}__{pod}__{strategy}".replace("/", "_")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             strategy: str = "fsdp2d", impl: str = "xla_chunked",
             save: bool = True, verbose: bool = True) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, reason = cell_is_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "strategy": strategy, "impl": impl,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch,
           "param_count": cfg.param_count(),
           "active_param_count": cfg.active_param_count()}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return _finish(rec, save, verbose)
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        strat = shd.make_strategy(strategy, mesh)
        n_data = (mesh.shape.get("pod", 1)) * mesh.shape["data"]
        step, args, names = step_for_shape(cfg, shape, impl=impl,
                                           n_data=n_data)
        in_shardings = []
        for name, arg in zip(names, args):
            if name == "params":
                in_shardings.append(shd.param_shardings(strat, mesh, arg))
            elif name == "opt_state":
                in_shardings.append(shd.opt_shardings(strat, mesh, arg))
            elif name == "cache":
                in_shardings.append(shd.cache_shardings(strat, mesh, arg))
            else:
                in_shardings.append(shd.batch_shardings(strat, mesh, arg))
        donate = tuple(
            i for i, n in enumerate(names)
            if n in ("opt_state", "cache")
            or (n == "params" and "opt_state" in names))
        with shd.use_strategy(strat, mesh), mesh:
            jitted = jax.jit(step, in_shardings=tuple(in_shardings),
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            coll = parse_collectives(hlo_text)
            from repro.launch.roofline import collective_bytes_with_trips
            coll_trips = collective_bytes_with_trips(hlo_text)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes",
                                        None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                      None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            cost={k: cost.get(k) for k in
                  ("flops", "bytes accessed", "transcendentals")
                  if isinstance(cost, dict) and k in cost},
            collectives=coll,
            collectives_trip_corrected=coll_trips,
        )
        if not isinstance(cost, dict):   # older API: list of dicts
            rec["cost"] = {k: cost[0].get(k) for k in
                           ("flops", "bytes accessed")}
    except Exception as e:       # noqa: BLE001 — record the failure
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return _finish(rec, save, verbose)


def _finish(rec: dict, save: bool, verbose: bool) -> dict:
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        path = os.path.join(ARTIFACT_DIR, cell_id(
            rec["arch"], rec["shape"], rec["multi_pod"],
            rec["strategy"]) + ".json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(rec, f, indent=1)
        os.replace(tmp, path)
    if verbose:
        status = rec["status"]
        extra = ""
        if status == "ok":
            arg_gb = (rec["memory"]["argument_bytes"] or 0) / 2**30
            tmp_gb = (rec["memory"]["temp_bytes"] or 0) / 2**30
            fl = rec["cost"].get("flops") or 0
            extra = (f" args/dev={arg_gb:.2f}GiB temp/dev={tmp_gb:.2f}GiB"
                     f" flops/dev={fl:.3g}"
                     f" coll/dev={rec['collectives']['total_bytes']/2**30:.2f}GiB"
                     f" compile={rec.get('compile_s')}s")
        elif status == "error":
            extra = " " + rec["error"][:160]
        elif status == "skipped":
            extra = " " + rec["reason"]
        print(f"[dryrun] {cell_id(rec['arch'], rec['shape'], rec['multi_pod'], rec['strategy'])}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--strategy", default="fsdp2d")
    ap.add_argument("--impl", default="xla_chunked")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = [False, True]
    if args.multi_pod and not args.single_pod:
        pods = [True]
    if args.single_pod and not args.multi_pod:
        pods = [False]

    if args.list:
        for a in archs:
            for s in shapes:
                ok, reason = cell_is_runnable(ARCHS[a], SHAPES[s])
                print(a, s, "runnable" if ok else f"SKIP ({reason})")
        return

    t0 = time.time()
    done = 0
    for mp in pods:
        for a in archs:
            for s in shapes:
                path = os.path.join(ARTIFACT_DIR, cell_id(
                    a, s, mp, args.strategy) + ".json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] cached: {os.path.basename(path)}"
                              f" ({prev['status']})", flush=True)
                        continue
                run_cell(a, s, mp, args.strategy, impl=args.impl)
                done += 1
    print(f"[dryrun] finished {done} cells in {time.time()-t0:.0f}s",
          flush=True)


if __name__ == "__main__":
    main()
