"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — required because the dry-run must
set XLA_FLAGS before any JAX initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CPU-host sharding tests (8 fake devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
