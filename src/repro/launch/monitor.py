"""Observability snapshot reporting (DESIGN.md §14, §17,
docs/observability.md).

Renders one `repro.obs.Observability` bundle as a human report — the
metric catalog with current values, per-stage span timings, SLO
burn-rate states with any active alerts, the prediction-quality
scorecard, flight-recorder incidents, and the most recent audit-trail
decisions — and writes the machine-readable snapshot (registry JSON +
span totals + audit tail + slo/quality/windows/incidents sections)
that the CI smoke job uploads as an artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.monitor --sim --shards 4 \
      --days 0.25 --out obs_snapshot.json --alerts obs_alerts.json

The ``--sim`` driver runs a short metrics-enabled sharded simulation
(`sim.scheduler_sim.simulate` with the power-emergency plane on) so a
snapshot can be produced in any container without live traffic; the
report/snapshot functions work on any bundle a serving process filled.
"""
from __future__ import annotations

import argparse
import json

from repro.obs import Observability

__all__ = ["render_report", "snapshot_dict", "write_snapshot",
           "write_alerts", "main"]


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def render_report(obs: Observability, audit_tail: int = 8) -> str:
    """One multi-section text report of the whole bundle: every
    counter/gauge with its current value, histogram quantiles, span
    totals from the tracer, per-rule SLO burn rates (active alerts
    flagged), the prediction scorecard, flight-recorder incidents,
    and the trailing audit decisions (`AuditRecord.describe` lines).
    Sections for pillars that are off are omitted."""
    lines = ["== metrics =="]
    for (name, labels), m in sorted(obs.registry._metrics.items()):
        label = _fmt_labels(dict(labels))
        if m.kind == "histogram":
            lines.append(
                f"  {name}{label}  count={m.count} sum={m.sum:.6g} "
                f"p50={m.quantile(0.5):.3g} "
                f"p99={m.quantile(0.99):.3g}")
        else:
            lines.append(f"  {name}{label}  {m.value:.6g}")
    if obs.tracer is not None and len(obs.tracer):
        lines.append("== spans ==")
        for span, (count, total) in sorted(obs.tracer.totals().items()):
            mean_ms = 1e3 * total / max(count, 1)
            lines.append(f"  {span:<12} n={count:<8.0f} "
                         f"total={total:.3f}s mean={mean_ms:.2f}ms")
    if obs.slo is not None:
        lines.append("== slo ==")
        for name, s in sorted(obs.slo.summary().items()):
            burns = " ".join(f"{w}:{b:.3g}x"
                             for w, b in s["burn_rates"].items())
            flag = "  ** ALERT **" if s["active"] else ""
            lines.append(
                f"  {name:<18} consumed={s['consumed']:.6g}"
                f"/{s['budget']:.6g} burn[{burns}] "
                f"alerts={s['alerts']}{flag}")
    if obs.quality is not None and obs.quality.n_scored:
        q = obs.quality.summary()
        lines.append("== quality ==")
        lines.append(
            f"  scored={q['n_scored']} "
            f"crit_acc={_num(q['crit_accuracy'])} "
            f"p95_acc={_num(q['p95_accuracy'])} "
            f"stale={q['model_stale']}")
        lines.append(
            f"  drift " + " ".join(f"{c}={v:.3g}"
                                   for c, v in q["drift"].items())
            + f" throttle_rate={q['throttle_rate']:.3g}")
    if obs.recorder is not None and obs.recorder.incidents:
        lines.append(f"== incidents (last "
                     f"{len(obs.recorder.incidents)}) ==")
        for inc in obs.recorder.incidents:
            lines.append(f"  t={inc.t:.6g} alarms={inc.alarms} "
                         f"seq={inc.seq}")
    if obs.audit is not None and len(obs.audit):
        lines.append(f"== audit (last {audit_tail} of "
                     f"{obs.audit.total_recorded}) ==")
        rows = obs.audit.tail(audit_tail)
        from repro.obs import AuditRecord
        lines.extend("  " + AuditRecord(r).describe() for r in rows)
        rej = obs.audit.rejected(audit_tail)
        if rej:
            lines.append("== audit: recent rejections ==")
            lines.extend("  " + r.describe() for r in rej)
    return "\n".join(lines)


def _num(x) -> str:
    """Format a maybe-None scorecard number."""
    return "n/a" if x is None else f"{x:.4g}"


def snapshot_dict(obs: Observability, audit_tail: int = 64) -> dict:
    """JSON-serializable snapshot of the bundle: the full registry
    snapshot plus span totals, the audit tail (decoded to plain Python
    scalars), and — for pillars that are on — the SLO rule states,
    the quality scorecard, the windowed aggregates, and the flight
    recorder's occupancy/incidents. This is the artifact schema the
    CI smoke job uploads."""
    out = {"metrics": obs.registry.snapshot()}
    if obs.tracer is not None:
        out["spans"] = {k: {"count": int(c), "total_s": float(s)}
                        for k, (c, s) in obs.tracer.totals().items()}
    if obs.audit is not None:
        rows = obs.audit.tail(audit_tail)
        out["audit"] = {
            "total_recorded": obs.audit.total_recorded,
            "tail": [{k: r[k].item() for k in rows.dtype.names}
                     for r in rows],
        }
    if obs.slo is not None:
        out["slo"] = {"rules": obs.slo.summary(),
                      "active_alerts": obs.slo.active_alerts()}
    if obs.quality is not None:
        out["quality"] = obs.quality.summary()
    if obs.windows is not None:
        out["windows"] = obs.windows.summary()
    if obs.recorder is not None:
        out["incidents"] = obs.recorder.summary()
    return out


def write_snapshot(obs: Observability, path: str,
                   audit_tail: int = 64) -> None:
    """Write `snapshot_dict` to `path` as indented JSON."""
    with open(path, "w") as f:
        json.dump(snapshot_dict(obs, audit_tail), f, indent=2)
        f.write("\n")


def write_alerts(obs: Observability, path: str) -> None:
    """Write the SLO monitor's active alerts (plus per-rule burn
    states) to `path` as indented JSON — the pageable artifact the CI
    smoke job uploads. An empty ``active`` list is the good case."""
    alerts = {"active": [], "rules": {}}
    if obs.slo is not None:
        alerts["active"] = obs.slo.active_alerts()
        alerts["rules"] = obs.slo.summary()
    with open(path, "w") as f:
        json.dump(alerts, f, indent=2)
        f.write("\n")


def _run_sim(shards: int, days: float, seed: int) -> Observability:
    """Drive a short metrics-enabled sharded sim (emergency plane on,
    warm-started near the alarm threshold) and return its bundle."""
    from repro.core.placement import SchedulerPolicy
    from repro.core.resources import ResourceVector
    from repro.serve.emergency import EmergencyConfig
    from repro.sim.scheduler_sim import (PredictionChannel,
                                         ServeBackendSpec, SimSpec,
                                         simulate)

    obs = Observability.full()
    simulate(SchedulerPolicy(), PredictionChannel(),
             SimSpec(days=days, seed=seed, prefill_core_ratio=0.5,
                     serve=ServeBackendSpec(
                         backend="serve-sharded", shards=shards,
                         cluster_budget=ResourceVector(watts=2.0e6)),
                     emergency=EmergencyConfig.from_model(1480.0)),
             obs=obs)
    return obs


def main(argv=None) -> None:
    """CLI: run the ``--sim`` driver (or fail fast without it — there
    is no live bundle to read from a fresh process), print the report,
    and optionally write the JSON snapshot / Prometheus text / active
    SLO alerts."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sim", action="store_true",
                    help="drive a short metrics-enabled sharded sim")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the JSON snapshot here")
    ap.add_argument("--prom", default=None,
                    help="write Prometheus exposition text here")
    ap.add_argument("--alerts", default=None,
                    help="write active SLO alerts (JSON) here")
    args = ap.parse_args(argv)
    if not args.sim:
        ap.error("--sim is the only driver in this container "
                 "(a serving process renders its own bundle via "
                 "render_report)")
    obs = _run_sim(args.shards, args.days, args.seed)
    print(render_report(obs))
    if args.out:
        write_snapshot(obs, args.out)
        print(f"[monitor] snapshot -> {args.out}")
    if args.prom:
        with open(args.prom, "w") as f:
            f.write(obs.registry.to_prometheus())
        print(f"[monitor] prometheus -> {args.prom}")
    if args.alerts:
        write_alerts(obs, args.alerts)
        print(f"[monitor] alerts -> {args.alerts}")


if __name__ == "__main__":
    main()
