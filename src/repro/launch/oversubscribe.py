"""Operator CLI: compute oversubscribed chassis budgets from telemetry.

The planning tool the paper's §III-E implies: feed historical chassis
draws (an .npy file or the synthetic generator), pick a scenario, get
the budget, event rates, and how many extra servers the recovered power
buys.

  PYTHONPATH=src python -m repro.launch.oversubscribe \
      --scenario predictions_minimal_uf_impact --chassis 1440 --days 90
  PYTHONPATH=src python -m repro.launch.oversubscribe --draws draws.npy
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.oversubscription import (SCENARIOS, FleetProfile,
                                         compute_budget)
from repro.core.power_model import P_PEAK_FMAX, ServerPowerModel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--draws", default=None,
                    help=".npy of chassis power readings (watts)")
    ap.add_argument("--scenario", default="predictions_minimal_uf_impact",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--chassis", type=int, default=256)
    ap.add_argument("--days", type=int, default=30)
    ap.add_argument("--servers-per-chassis", type=int, default=12)
    ap.add_argument("--beta", type=float, default=0.40)
    ap.add_argument("--util-uf", type=float, default=0.65)
    ap.add_argument("--util-nuf", type=float, default=0.44)
    ap.add_argument("--allocated-frac", type=float, default=0.85)
    ap.add_argument("--campus-mw", type=float, default=128.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    provisioned = args.servers_per_chassis * P_PEAK_FMAX
    if args.draws:
        draws = np.load(args.draws)
    else:
        from repro.sim.telemetry import generate_chassis_telemetry
        draws = generate_chassis_telemetry(
            args.chassis, args.days, provisioned, seed=args.seed)
        print(f"[oversubscribe] synthetic telemetry: {args.chassis} "
              f"chassis x {args.days} days")

    fleet = FleetProfile(beta=args.beta, util_uf=args.util_uf,
                         util_nuf=args.util_nuf,
                         allocated_frac=args.allocated_frac,
                         servers_per_chassis=args.servers_per_chassis,
                         model=ServerPowerModel())
    cfg = SCENARIOS[args.scenario]
    res = compute_budget(np.ravel(draws), provisioned, cfg, fleet,
                         full_server=args.scenario == "state_of_the_art")

    extra_servers = int(res.oversubscription * provisioned
                        / P_PEAK_FMAX * args.chassis)
    print(f"[oversubscribe] scenario           : {args.scenario}")
    print(f"[oversubscribe] provisioned/chassis: {provisioned:.0f} W")
    print(f"[oversubscribe] recommended budget : {res.budget_w:.0f} W "
          f"(pre-buffer {res.budget_pre_buffer_w:.0f} W)")
    print(f"[oversubscribe] oversubscription   : "
          f"{res.oversubscription:.1%}")
    print(f"[oversubscribe] UF event rate      : {res.uf_event_rate:.5f}"
          f"  (limit {cfg.emax_uf})")
    print(f"[oversubscribe] NUF event rate     : "
          f"{res.nuf_event_rate:.5f}  (limit {cfg.emax_nuf})")
    print(f"[oversubscribe] extra servers      : ~{extra_servers} "
          f"across the fleet")
    print(f"[oversubscribe] campus savings     : "
          f"${res.savings_usd(args.campus_mw)/1e6:.1f}M "
          f"({args.campus_mw:.0f} MW at $10/W)")
    return res


if __name__ == "__main__":
    main()
