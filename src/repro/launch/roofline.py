"""Roofline analysis from dry-run artifacts (deliverable g).

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI link bandwidth  50 GB/s

Three terms per (arch x shape x mesh), in seconds:
    compute    = FLOPs_per_device / 197e12
    memory     = HBM_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9

Methodology notes (documented in EXPERIMENTS.md §Roofline):
  * XLA's cost_analysis() counts while-loop bodies ONCE, not x trip
    count, so a scan-over-layers model under-reports ~L x. We therefore
    use an ANALYTIC FLOPs/bytes model (exact matmul accounting per
    architecture, including remat recompute and attention/SSD chunk
    math), cross-validated against cost_analysis() on scan-free probes.
  * collective bytes come from the compiled per-device HLO with
    trip-count-aware accounting: while-op bodies are scaled by their
    trip counts (parsed from the loop-condition constants).
  * memory-per-device comes from compiled.memory_analysis() (exact).
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "u64": 8}


# --------------------------------------------------------------------------
# analytic FLOPs / HBM-bytes model
# --------------------------------------------------------------------------

@dataclass
class CostEstimate:
    flops_global: float
    hbm_bytes_global: float

    def per_device(self, chips: int):
        return self.flops_global / chips, self.hbm_bytes_global / chips


def _attn_flops(cfg, s_q: int, s_kv: int) -> float:
    """Per-token-batch=1 attention score+value FLOPs for one layer
    (2*s_q*s_kv*hd per head pair, x2 for scores and values)."""
    window = cfg.sliding_window
    if window is not None and s_kv > window:
        eff = window
    else:
        eff = s_kv
    # causal halves the average effective kv length for self-attention
    if s_q == s_kv:
        eff = eff / 2 if window is None else min(eff, s_kv / 2)
    return 2 * 2 * cfg.n_heads * s_q * eff * cfg.head_dim


def _layer_matmul_flops(cfg, tokens: float) -> float:
    """Weight-matmul FLOPs for one layer over `tokens` tokens (fwd)."""
    d = cfg.d_model
    hd = cfg.head_dim
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * d
        n = cfg.ssm_state
        nheads = d_inner // cfg.ssm_head_dim
        proj = 2 * tokens * d * (2 * d_inner + 2 * n + nheads) \
            + 2 * tokens * d_inner * d
        # SSD chunked: intra-chunk (Q^2 terms) + state updates
        q = 128.0
        intra = 2 * tokens * q * (n + cfg.ssm_head_dim) * nheads
        inter = 2 * tokens * cfg.ssm_head_dim * n * nheads
        return proj + intra + inter
    attn_proj = 2 * tokens * d * hd * (cfg.n_heads * 2
                                       + cfg.n_kv_heads * 2)
    if cfg.n_experts > 0:
        eff = cfg.moe_d_ff or cfg.d_ff
        ffn = 2 * tokens * cfg.experts_per_token * 3 * d * eff
        if cfg.moe_dense_residual:
            ffn += 2 * tokens * 3 * d * cfg.d_ff
        ffn += 2 * tokens * d * cfg.n_experts          # router
    else:
        mult = 3 if cfg.mlp == "swiglu" else 2
        ffn = 2 * tokens * mult * d * cfg.d_ff
    return attn_proj + ffn


def analytic_cost(cfg, shape) -> CostEstimate:
    """Global FLOPs and HBM bytes for one step of the given shape."""
    b, s = shape.global_batch, shape.seq_len
    d, v = cfg.d_model, cfg.vocab_size
    p_active = cfg.active_param_count()

    if shape.kind == "decode":
        tokens = float(b)                       # one token per sequence
        layer = _layer_matmul_flops(cfg, tokens)
        attn = 0.0
        if cfg.family not in ("ssm",):
            s_kv = s if cfg.sliding_window is None else \
                min(s, cfg.sliding_window)
            n_attn = cfg.n_layers if cfg.family != "hybrid" else \
                cfg.n_layers // cfg.attn_every
            attn = n_attn * b * 2 * 2 * cfg.n_heads * s_kv * cfg.head_dim
        head = 2 * tokens * d * v
        flops = cfg.n_layers * layer + attn + head
        # decode HBM traffic: every active parameter + the KV/state cache
        # is read once per token
        cache_bytes = _cache_bytes(cfg, b, s)
        hbm = p_active * 2 + cache_bytes + tokens * d * 200
        return CostEstimate(flops, hbm)

    tokens = float(b) * s
    fwd = cfg.n_layers * _layer_matmul_flops(cfg, tokens)
    if cfg.family not in ("ssm",):
        n_attn = cfg.n_layers if cfg.family != "hybrid" else \
            cfg.n_layers // cfg.attn_every
        fwd += n_attn * b * _attn_flops(cfg, s, s)
    if cfg.family == "audio":
        ftok = float(b) * cfg.encoder_frames
        fwd += cfg.encoder_layers * _layer_matmul_flops(cfg, ftok)
        fwd += cfg.encoder_layers * b * _attn_flops(
            cfg, cfg.encoder_frames, cfg.encoder_frames)
        # cross attention in every decoder layer
        fwd += cfg.n_layers * (2 * tokens * d * cfg.head_dim
                               * cfg.n_kv_heads * 2
                               + b * 2 * 2 * cfg.n_heads * s
                               * cfg.encoder_frames * cfg.head_dim)
    fwd += 2 * tokens * d * v                   # lm head
    if shape.kind == "prefill":
        hbm = cfg.param_count() * 2 + tokens * d * 2 * 14 * 2
        return CostEstimate(fwd, hbm)
    # train: bwd = 2x fwd, remat = +1x fwd => 4x fwd total
    flops = 4 * fwd
    p_total = cfg.param_count()
    opt_mult = 12 if cfg.optimizer == "adamw" else 6
    hbm = (p_total * 2 * 3                      # weights fwd+bwd+remat
           + p_total * opt_mult                 # grads + moments r/w
           + cfg.n_layers * tokens * d * 2 * 14)  # activation traffic
    return CostEstimate(flops, hbm)


def _cache_bytes(cfg, b: int, s: int) -> float:
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        return (cfg.n_layers * b * nheads * cfg.ssm_head_dim
                * cfg.ssm_state * 4)
    length = s if cfg.sliding_window is None else min(
        s, cfg.sliding_window)
    kv = cfg.n_layers * b * cfg.n_kv_heads * length * cfg.head_dim \
        * 2 * 2
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_head_dim
        kv = groups * b * cfg.n_kv_heads * s * cfg.head_dim * 2 * 2 \
            + cfg.n_layers * b * nheads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4
    return kv


# --------------------------------------------------------------------------
# trip-count-aware collective accounting from compiled HLO
# --------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:.*?)condition=%?([\w.\-]+).*?body=%?([\w.\-]+)",
    re.S)
_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)]*?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    buf = []
    for line in hlo.splitlines():
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            if cur:
                comps[cur] = "\n".join(buf)
            cur = m.group(1)
            buf = [line]
        elif cur:
            buf.append(line)
            if line.strip() == "}":
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
    if cur:
        comps[cur] = "\n".join(buf)
    return comps


def collective_bytes_with_trips(hlo: str) -> dict:
    """Per-device collective bytes, scaling while-loop bodies by their
    trip counts (max s32 constant in the loop condition, a documented
    heuristic that matches lax.scan/fori lowering)."""
    comps = _split_computations(hlo)
    entry = None
    for name, body in comps.items():
        if "ENTRY" in body.splitlines()[0]:
            entry = name
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n]))

    def own_bytes(body: str) -> dict:
        out = {}
        for m in _COLL_RE.finditer(body):
            dtype, dims, op = m.groups()
            n = 1
            if dims:
                for dd in dims.split(","):
                    n *= int(dd)
            out[op] = out.get(op, 0) + n * _DTYPE_BYTES.get(dtype, 4)
        return out

    def trip_of(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST_RE.findall(body)]
        return max(consts) if consts else 1

    seen = {}

    def total(name: str, depth=0) -> dict:
        if name in seen or depth > 12 or name not in comps:
            return {}
        body = comps[name]
        agg = own_bytes(body)
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.groups()
            trips = trip_of(cond)
            sub = total(wbody, depth + 1)
            for k, v in sub.items():
                agg[k] = agg.get(k, 0) + v * trips
        # calls / fusions that may contain collectives
        for cm in re.finditer(r"(?:call|fusion)\(.*?to_apply=%?"
                              r"([\w.\-]+)", body):
            sub = total(cm.group(1), depth + 1)
            for k, v in sub.items():
                agg[k] = agg.get(k, 0) + v
        return agg

    agg = total(entry)
    # monotone-safety: computations the regex walk fails to associate
    # would be silently dropped; never report less than the flat
    # (once-per-op) parse over the whole module.
    flat = own_bytes(hlo)
    for k, v in flat.items():
        agg[k] = max(agg.get(k, 0), v)
    agg["total"] = sum(v for k, v in agg.items())
    return agg


# --------------------------------------------------------------------------
# report
# --------------------------------------------------------------------------

def roofline_row(rec: dict, cfg, shape, chips: int = 256,
                 hlo_text: str | None = None) -> dict:
    est = analytic_cost(cfg, shape)
    flops_dev, hbm_dev = est.per_device(chips)
    coll_dev = rec.get("collectives", {}).get("total_bytes", 0)
    if hlo_text is not None:
        coll_dev = collective_bytes_with_trips(hlo_text)["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    # MODEL_FLOPS: 6*N_active*D for training (fwd+bwd), 2*N_active*D for
    # inference, D = tokens processed this step.
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * cfg.active_param_count() * tokens
    bound = max(t_compute, t_memory, t_coll)
    return {
        "arch": cfg.name, "shape": shape.name,
        "flops_dev": flops_dev, "hbm_dev": hbm_dev,
        "coll_dev": coll_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_global": model_flops,
        # how much of compiled compute is "useful" (catches remat /
        # routing / recompute waste)
        "useful_ratio": model_flops / max(est.flops_global, 1),
        # fraction of roofline under perfect overlap (1.0 = compute-
        # bound at peak) and under no overlap (serial lower bound)
        "roofline_overlapped": t_compute / max(bound, 1e-12),
        "roofline_serial": t_compute / max(
            t_compute + t_memory + t_coll, 1e-12),
    }


def load_artifacts(artifact_dir: str) -> list:
    out = []
    for name in sorted(os.listdir(artifact_dir)):
        if name.endswith(".json"):
            with open(os.path.join(artifact_dir, name)) as f:
                out.append(json.load(f))
    return out
