"""Serving launcher: batched greedy decoding with KV caches, tagged with
the job's predicted criticality — a user-facing job the per-VM capping
controller protects.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-tiny \
      --reduced --requests 8 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as T


def serve_batch(cfg, params, prompts: np.ndarray, gen_tokens: int,
                impl: str = "naive"):
    """Greedy-decode `gen_tokens` for a batch of same-length prompts."""
    b, prompt_len = prompts.shape
    max_len = prompt_len + gen_tokens
    cache = T.init_cache(cfg, b, max_len)
    if cfg.family == "audio":
        frames = jnp.zeros((b, cfg.encoder_frames, cfg.d_model),
                           jnp.bfloat16)
        cache["cross"] = T.prime_cross_cache(cfg, params,
                                             {"frames": frames})
    step = jax.jit(make_serve_step(cfg, impl=impl), donate_argnums=(1,))

    toks = jnp.asarray(prompts, jnp.int32)
    out = []
    # prefill token-by-token through the decode path (batch prefill via
    # forward() is the production path; this exercises cache writes)
    last = None
    for i in range(prompt_len):
        last, cache = step(params, cache,
                           {"tokens": toks[:, i:i + 1],
                            "cache_index": jnp.asarray(i, jnp.int32)})
    cur = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    for i in range(gen_tokens):
        out.append(np.asarray(cur)[:, 0])
        last, cache = step(params, cache,
                           {"tokens": cur,
                            "cache_index": jnp.asarray(prompt_len + i,
                                                       jnp.int32)})
        cur = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    return np.stack(out, 1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, rng)
    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab_size, (args.requests, args.prompt_len))
    t0 = time.time()
    tokens = serve_batch(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    total = args.requests * args.gen
    print(f"[serve] {cfg.name}: {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s), output shape {tokens.shape}")
    return tokens


if __name__ == "__main__":
    main()
