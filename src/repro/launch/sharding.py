"""Sharding strategies and the activation-constraint hook.

Two strategies (DESIGN.md §6):

  * fsdp2d — parameters 2D-sharded (row dim over 'data', column dim over
    'model'; ZeRO-3 x tensor-storage), activations batch-sharded over
    ('pod','data'). Head-count agnostic: compiles for every architecture
    and shape. XLA inserts the weight all-gathers (FSDP semantics).
  * tp — Megatron tensor parallelism over 'model' (attention heads, FFN
    hidden, vocab) with FSDP over 'data'; used by §Perf hillclimbs on
    archs whose head counts divide the model axis.

Model code calls `constrain(x, tag)`; the active strategy maps tags to
PartitionSpecs. Outside a strategy context the hook is the identity, so
single-device smoke tests run the exact same model code.
"""
from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_strategy():
    return getattr(_state, "strategy", None)


@contextmanager
def use_strategy(strategy, mesh):
    prev = (getattr(_state, "strategy", None),
            getattr(_state, "mesh", None))
    _state.strategy, _state.mesh = strategy, mesh
    try:
        yield
    finally:
        _state.strategy, _state.mesh = prev


def constrain(x, tag: str):
    strat = getattr(_state, "strategy", None)
    mesh = getattr(_state, "mesh", None)
    if strat is None or mesh is None:
        return x
    rule = strat.activation_rules.get(tag)
    if rule is None:
        return x
    candidates = rule if isinstance(rule, (list, tuple)) \
        and not isinstance(rule, P) else [rule]
    fitted = [_fit_spec_to_rank(s, x.ndim) for s in candidates]
    spec = None
    for s in fitted:
        if _divisible(x.shape, s, mesh):
            spec = s
            break
    if spec is None:
        # keep the divisible axes (e.g. batch) and release the rest
        spec = _drop_nondivisible(x.shape, fitted[0], mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def _fit_spec_to_rank(spec: P, rank: int) -> P:
    parts = list(spec)
    if len(parts) < rank:
        parts = parts + [None] * (rank - len(parts))
    return P(*parts[:rank])


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        size = 1
        for a in axis:
            size *= _axis_size(mesh, a)
        return size
    return mesh.shape[axis] if axis in mesh.axis_names else 0


def _divisible(shape, spec, mesh) -> bool:
    for dim, axis in zip(shape, spec):
        size = _axis_size(mesh, axis)
        if size == 0:
            return False            # axis not present in this mesh
        if size > 1 and dim % size != 0:
            return False
    return True


@dataclass(frozen=True)
class Strategy:
    name: str
    #: regex on '/'.joined param path -> spec builder over dims
    param_rules: tuple = ()
    activation_rules: dict = field(default_factory=dict)

    def param_spec(self, path: str, shape: tuple, mesh) -> P:
        for pattern, spec in self.param_rules:
            if re.search(pattern, path):
                # a rule may carry fallback candidates (tuple of specs):
                # the first fully-divisible one wins — e.g. MoE expert
                # stacks shard the expert dim when E divides the axis,
                # else the within-expert dims (mixtral E=8 < data=16)
                candidates = spec if isinstance(spec, (list, tuple)) \
                    and not isinstance(spec, P) else [spec]
                fitted = [_fit_spec_to_rank_nd(s, len(shape))
                          for s in candidates]
                for s in fitted:
                    if _divisible(shape, s, mesh):
                        return s
                return _drop_nondivisible(shape, fitted[0], mesh)
        return P(*([None] * len(shape)))


def _fit_spec_to_rank_nd(spec: P, rank: int) -> P:
    """Right-align the spec onto the trailing dims (stacked-layer params
    carry leading layer/group dims that stay unsharded)."""
    parts = list(spec)
    if len(parts) < rank:
        parts = [None] * (rank - len(parts)) + parts
    return P(*parts[-rank:])


def _drop_nondivisible(shape, spec, mesh) -> P:
    parts = []
    for dim, axis in zip(shape, spec):
        size = _axis_size(mesh, axis)
        parts.append(axis if size and dim % max(size, 1) == 0 and size > 1
                     else None)
    return P(*parts)


def _dp(mesh_axes) -> tuple:
    return ("pod", "data") if "pod" in mesh_axes else ("data",)


def make_strategy(name: str, mesh, cfg=None) -> Strategy:
    dp = _dp(mesh.axis_names)
    if name == "fsdp2d":
        return Strategy(
            name="fsdp2d",
            param_rules=(
                # embeddings: vocab over model (gather-friendly)
                (r"embed/w$", P("model", "data")),
                (r"lm_head/w$", P("data", "model")),
                # MoE expert stacks (E, d_in, d_out): shard experts over
                # data (expert-parallel storage) and d_out over model;
                # when E < data (mixtral: 8 < 16) fall back to 2D
                # within-expert sharding so optimizer state still
                # shards 256-way
                (r"moe/(gate|up|down)/?w?$",
                 (P("data", None, "model"), P(None, "data", "model"))),
                (r"router/w$", P(None, None)),
                # conv / small ssm vectors: replicate
                (r"conv_w$|conv_b$|a_log$|dt_bias$|d_skip$", P(None)),
                # biases and norms: replicate
                (r"/b$|scale$|bias$", P(None)),
                # every remaining 2D matmul weight: row over data,
                # col over model
                (r"/w$", P("data", "model")),
            ),
            activation_rules={
                # NOTE: we tried sequence-sharding the residual stream
                # here (Megatron-SP style, P(dp,'model',None)) to cut the
                # per-layer saved activations; the SPMD partitioner hit
                # "involuntary full rematerialization" on the chunked-
                # attention reshapes and memory got WORSE (llama3 train:
                # 21.6 -> 38.8 GiB). Gradient accumulation in
                # make_train_step is the production fix. See
                # EXPERIMENTS.md §Perf iteration log.
                "residual": P(dp, None, None),
                "logits": P(dp, None, "model"),
                "kv_cache": P(dp, None, "model", None),
                "logits_blocks": P(dp, "model", None),
                # (E, C, d) buffers: expert-sharded when E divides, else
                # capacity-sharded (mixtral E=8 < data=16)
                "moe_buffer": (P("data", None, None),
                               P(None, "data", "model")),
                "moe_hidden": (P("data", None, "model"),
                               P(None, "data", "model")),
                "moe_tokens": P(dp, None),
                "moe_routing": P(dp, None),
                "ssm_heads": P(dp, None, "model", None),
            },
        )
    if name == "tp":
        return Strategy(
            name="tp",
            param_rules=(
                (r"embed/w$", P("model", "data")),
                (r"lm_head/w$", P("data", "model")),
                (r"moe/(gate|up|down)/?w?$",
                 (P("data", None, "model"), P(None, "data", "model"))),
                (r"router/w$", P(None, None)),
                (r"conv_w$|conv_b$|a_log$|dt_bias$|d_skip$", P(None)),
                (r"attn/w[qkv]/w$", P("data", "model")),
                (r"attn/wo/w$", P("model", "data")),
                (r"(gate|up)/w$", P("data", "model")),
                (r"down/w$", P("model", "data")),
                (r"in_proj/w$", P("data", "model")),
                (r"out_proj/w$", P("model", "data")),
                (r"/b$|scale$|bias$", P(None)),
                (r"/w$", P("data", "model")),
            ),
            activation_rules={
                "residual": P(dp, None, None),
                "logits": P(dp, None, "model"),
                "attn_heads": P(dp, "model", None, None),
                "attn_kv_heads": P(dp, "model", None, None),
                "attn_out": P(dp, None, "model"),
                "ffn_hidden": P(dp, None, "model"),
                "kv_cache": P(dp, "model", None, None),
                "logits_blocks": P(dp, "model", None),
                "moe_buffer": (P("data", None, None),
                               P(None, "data", "model")),
                "moe_hidden": (P("data", None, "model"),
                               P(None, "data", "model")),
                "moe_tokens": P(dp, None),
                "moe_routing": P(dp, None),
                "ssm_heads": P(dp, None, "model", None),
            },
        )
    if name == "tp_serve":
        # pure tensor-parallel weights for SERVING: no row ('data')
        # sharding, so decode has no per-layer FSDP weight gathers —
        # only the two small activation all-reduces per layer (classic
        # Megatron inference). Memory: params/16 per device, no
        # optimizer state at serve time.
        return Strategy(
            name="tp_serve",
            param_rules=(
                (r"embed/w$", P("model", None)),
                (r"lm_head/w$", P(None, "model")),
                (r"moe/(gate|up|down)/?w?$",
                 (P("data", None, "model"), P(None, None, "model"))),
                (r"router/w$", P(None, None)),
                (r"conv_w$|conv_b$|a_log$|dt_bias$|d_skip$", P(None)),
                (r"attn/w[qkv]/w$", P(None, "model")),
                (r"attn/wo/w$", P("model", None)),
                (r"(gate|up)/w$", P(None, "model")),
                (r"down/w$", P("model", None)),
                (r"in_proj/w$", P(None, "model")),
                (r"out_proj/w$", P("model", None)),
                (r"/b$|scale$|bias$", P(None)),
                (r"/w$", P(None, "model")),
            ),
            activation_rules={
                "residual": P(dp, None, None),
                "logits": P(dp, None, "model"),
                "logits_blocks": P(dp, "model", None),
                "attn_heads": P(dp, "model", None, None),
                "attn_kv_heads": (P(dp, "model", None, None),
                                  P(dp, None, None, None)),
                "attn_out": P(dp, None, "model"),
                "ffn_hidden": P(dp, None, "model"),
                "kv_cache": (P(dp, "model", None, None),
                             P(dp, None, "model", None)),
                "moe_buffer": (P("data", None, None),
                               P(None, "data", "model")),
                "moe_hidden": (P("data", None, "model"),
                               P(None, "data", "model")),
                "moe_tokens": P(dp, None),
                "moe_routing": P(dp, None),
                "ssm_heads": P(dp, None, "model", None),
            },
        )
    raise KeyError(name)


def param_shardings(strategy: Strategy, mesh, params_shape) -> dict:
    """Pytree of NamedShardings matching a params (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
        spec = strategy.param_spec(path_str, leaf.shape, mesh)
        specs.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_shardings(strategy: Strategy, mesh, opt_shape) -> dict:
    """Optimizer-state shardings derived from the parameter rules.

    AdamW moments ('m/...', 'v/...') shard exactly like their parameter.
    Adafactor row stats ('stats/<param>/vr') drop the parameter's last
    spec entry; column stats ('vc') drop the second-to-last. Scalars
    ('count') replicate.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_shape)
    out = []
    for path, leaf in flat:
        parts = [str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path]
        if parts and parts[0] in ("m", "v"):
            param_path = "/".join(parts[1:])
            spec = strategy.param_spec(param_path, leaf.shape, mesh)
        elif parts and parts[0] == "stats":
            stat = parts[-1]
            param_path = "/".join(parts[1:-1])
            # derive from a pseudo parameter spec of matching rank + 1
            pseudo = strategy.param_spec(param_path,
                                         leaf.shape + (1,), mesh)
            pparts = list(pseudo)
            if stat == "vr":                    # param shape minus last
                spec = P(*pparts[:-1])
            elif stat == "vc":                  # minus second-to-last
                spec = P(*(pparts[:-2] + pparts[-1:]))
            else:                               # 'v' 1D stat
                spec = P(*pparts[:-1])
            spec = _drop_nondivisible(leaf.shape, _fit_spec_to_rank(
                spec, leaf.ndim), mesh)
        else:
            spec = P(*([None] * leaf.ndim))
        if not _divisible(leaf.shape, spec, mesh):
            spec = _drop_nondivisible(leaf.shape, spec, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(strategy: Strategy, mesh, batch_shape) -> dict:
    """Batch inputs: leading dim over (pod, data) when divisible."""
    dp = _dp(mesh.axis_names)

    def spec_for(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = P(dp, *([None] * (leaf.ndim - 1)))
        if not _divisible(leaf.shape, spec, mesh):
            # batch=1 long-context cells: replicate batch
            spec = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, spec)

    return jax.tree.map(spec_for, batch_shape)


def cache_shardings(strategy: Strategy, mesh, cache_shape) -> dict:
    """KV caches: batch over dp, sequence dim over 'model' (stacked
    layout (L, B, H, S, hd)); SSM states: batch over dp, heads over
    'model' when divisible."""
    dp = _dp(mesh.axis_names)

    def spec_for(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        if "ssm" in names and leaf.ndim == 5:   # (L, B, H, P, N) states
            spec = P(None, dp, "model", None, None)
        elif leaf.ndim == 5:        # (L, B, H, S, hd) kv stack
            spec = P(None, dp, None, "model", None)
        elif leaf.ndim == 4 and "conv" in names:
            spec = P(None, dp, None, "model")
        elif leaf.ndim == 2:        # pos buffers (L, S)
            spec = P(None, "model")
        else:
            spec = P(*([None] * leaf.ndim))
        if not _divisible(leaf.shape, spec, mesh):
            spec = _drop_nondivisible(leaf.shape, spec, mesh)
        return NamedSharding(mesh, spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])
