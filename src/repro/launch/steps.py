"""train_step / serve_step builders + ShapeDtypeStruct input specs.

These are the functions every launcher (train.py, serve.py, dryrun.py)
jits. Everything is built from (ModelConfig, ShapeConfig, Strategy); the
dry-run lowers them against input_specs() stand-ins with no allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as shd
from repro.models import transformer as T
from repro.models.loss import chunked_ce
from repro.optim import get_optimizer


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no device allocation)
# --------------------------------------------------------------------------

N_PATCHES = 256          # vision stub: prefix patch embeddings


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one step of the given kind."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, N_PATCHES, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, N_PATCHES, cfg.d_model), jnp.bfloat16)
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a KV/state cache of length s
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_index": jax.ShapeDtypeStruct((), i32)}


def params_spec(cfg: ModelConfig):
    """Parameter shapes via eval_shape (no allocation)."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(T.init_params, cfg), rng)


def cache_spec(cfg: ModelConfig, shape: ShapeConfig):
    spec = jax.eval_shape(
        functools.partial(T.init_cache, cfg, shape.global_batch,
                          shape.seq_len))
    if cfg.family == "audio":
        # cross K/V primed from a (B, frames, d) encode
        def prime(params):
            batch = {"frames": jnp.zeros(
                (shape.global_batch, cfg.encoder_frames, cfg.d_model),
                jnp.bfloat16)}
            return T.prime_cross_cache(cfg, params, batch)
        spec["cross"] = jax.eval_shape(prime, params_spec(cfg))
    return spec


def opt_state_spec(cfg: ModelConfig):
    opt = get_optimizer(cfg.optimizer)
    return jax.eval_shape(opt.init, params_spec(cfg))


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def default_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                         n_data: int, budget_bytes: float = 6e9) -> int:
    """Gradient-accumulation factor sized so the remat-saved per-layer
    residuals (n_layers x B_dev x S x d x 2B) fit the activation budget
    (~6 GiB of the 16 GiB HBM; the rest is params/optimizer/workspace)."""
    b_dev = max(shape.global_batch // n_data, 1)
    resid = cfg.n_layers * b_dev * shape.seq_len * cfg.d_model * 2
    if cfg.n_experts > 0:
        budget_bytes *= 0.6         # MoE dispatch transients add overhead
    micro = 1
    while resid / micro > budget_bytes and micro < b_dev:
        micro *= 2
    return micro


def make_train_step(cfg: ModelConfig, impl: str = "xla_chunked",
                    lr: float = 3e-4, grad_compression: bool = False,
                    microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 runs gradient accumulation: the global batch is
    split on the (already data-sharded) batch dim and scanned, with f32
    gradient accumulators sharded like the parameters.
    """
    opt = get_optimizer(cfg.optimizer)

    def loss_fn(p, mb):
        hidden = T.forward(cfg, p, mb, impl=impl)
        return chunked_ce(hidden, p["lm_head"]["w"], mb["labels"])

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(a):
                return a.reshape((microbatches,
                                  a.shape[0] // microbatches)
                                 + a.shape[1:])
            mbs = jax.tree.map(split, batch)

            acc_dtype = jnp.dtype(cfg.grad_accum_dtype)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        if grad_compression:
            from repro.optim.grad_compress import compress_decompress
            grads = compress_decompress(grads)
        params, opt_state, gnorm = opt.update(grads, opt_state, params,
                                              lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg: ModelConfig, impl: str = "xla_chunked"):
    def eval_step(params, batch):
        hidden = T.forward(cfg, params, batch, impl=impl)
        return chunked_ce(hidden, params["lm_head"]["w"],
                          batch["labels"])
    return eval_step


def make_prefill_step(cfg: ModelConfig, impl: str = "xla_chunked"):
    """Serving prefill: forward over the full prompt, return last-token
    logits (cache construction omitted in the dry-run cell; decode cells
    carry their own cache)."""
    def prefill_step(params, batch):
        hidden = T.forward(cfg, params, batch, impl=impl)
        return T.logits_from_hidden(cfg, params, hidden[:, -1:])[:, 0]
    return prefill_step


def make_serve_step(cfg: ModelConfig, impl: str = "naive",
                    return_logits: bool = True):
    """One-token decode: (params, cache, batch) -> (out, cache).

    return_logits=False emits greedy token ids instead: returning the
    full (B, vocab) logits from a vocab-sharded head costs a ~100 MiB
    all-gather per step on the 256k-vocab archs — the dominant decode
    collective (EXPERIMENTS.md §Perf iteration 2). Production serving
    returns sampled tokens; the argmax reduces across vocab shards in
    O(B) bytes.
    """
    def serve_step(params, cache, batch):
        logits, new_cache = T.decode_step(
            cfg, params, cache, batch["tokens"], batch["cache_index"],
            impl=impl)
        if return_logits:
            return logits, new_cache
        return _sharded_greedy(cfg, logits), new_cache
    return serve_step


def _sharded_greedy(cfg, logits, n_blocks: int = 16):
    """argmax over a vocab-sharded axis without gathering the logits:
    a plain argmax makes the partitioner all-gather the full (B, V) f32
    tensor (131 MiB/step on 256k vocabularies). Blocking the vocab dim
    and constraining the block axis to 'model' keeps the inner argmax
    shard-local; only the (B, n_blocks) maxima cross shards."""
    from repro.launch import sharding as shd
    b, v = logits.shape
    if v % n_blocks:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    lb = logits.reshape(b, n_blocks, v // n_blocks)
    lb = shd.constrain(lb, "logits_blocks")
    loc_max = jnp.max(lb, -1)                       # (B, n_blocks)
    loc_arg = jnp.argmax(lb, -1).astype(jnp.int32)
    blk = jnp.argmax(loc_max, -1)                   # (B,)
    inner = jnp.take_along_axis(loc_arg, blk[:, None], 1)[:, 0]
    return (blk.astype(jnp.int32) * (v // n_blocks) + inner)


def step_for_shape(cfg: ModelConfig, shape: ShapeConfig,
                   impl: str = "xla_chunked", n_data: int = 16,
                   microbatches: int | None = None):
    """The jit target + its abstract arguments for a dry-run cell."""
    if shape.kind == "train":
        if microbatches is None:
            microbatches = default_microbatches(cfg, shape, n_data)
        step = make_train_step(cfg, impl=impl, microbatches=microbatches)
        args = (params_spec(cfg), opt_state_spec(cfg),
                input_specs(cfg, shape))
        return step, args, ("params", "opt_state", "batch")
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, impl=impl)
        args = (params_spec(cfg), input_specs(cfg, shape))
        return step, args, ("params", "batch")
    step = make_serve_step(cfg, return_logits=False)
    args = (params_spec(cfg), cache_spec(cfg, shape),
            input_specs(cfg, shape))
    return step, args, ("params", "cache", "batch")
