"""Training launcher: real steps on the available devices (CPU here,
TPU pod in production), with the full production stack: config-driven
model, data pipeline with prefetch, fault-tolerant loop with
checkpoint/restart, and the paper's power control plane governing the
job (criticality tag -> placement -> capping).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
      --reduced --steps 200 --batch 8 --seq 128 [--power-capped]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim import get_optimizer
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           FaultTolerantLoop)
from repro.runtime.power_control import (ChassisPowerSim, JobSpec,
                                         ThrottledLoop)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failures", type=float, default=0.0)
    ap.add_argument("--power-capped", action="store_true",
                    help="run under the paper's per-VM capping controller")
    ap.add_argument("--chassis-budget", type=float, default=2450.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"opt={cfg.optimizer}")

    rng = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, rng)
    opt = get_optimizer(cfg.optimizer)
    opt_state = opt.init(params)

    step_fn_inner = make_train_step(cfg, impl="naive", lr=args.lr)
    jitted = jax.jit(step_fn_inner, donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch,
                                  seed=args.seed))
    prefetch = Prefetcher(data)

    ckpt = Checkpointer(args.ckpt_dir, keep_last=2)
    ft = FaultTolerantLoop(
        FaultToleranceConfig(checkpoint_every=args.ckpt_every,
                             inject_failure_rate=args.inject_failures),
        ckpt, rng_seed=args.seed)

    throttle = None
    if args.power_capped:
        chassis = ChassisPowerSim(budget_w=args.chassis_budget)
        # this training job is batch (non-user-facing); a co-hosted
        # user-facing serving job shares the chassis
        chassis.register(JobSpec("serve-frontend", cores=120,
                                 user_facing=True, p95_util=0.65))
        chassis.register(JobSpec("this-train-job", cores=360,
                                 user_facing=False, p95_util=0.95))
        throttle = ThrottledLoop(chassis, "this-train-job")
        print("[train] power control: non-user-facing job under chassis "
              f"budget {args.chassis_budget:.0f} W")

    state = {"params": params, "opt_state": opt_state}

    def step_fn(state, batch):
        b = {k: jnp.asarray(v) for k, v in batch[1].items()}
        if throttle is not None:
            (p, o, metrics), pw = throttle.run_step(
                jitted, state["params"], state["opt_state"], b)
            metrics = dict(metrics, **pw)
        else:
            p, o, metrics = jitted(state["params"], state["opt_state"], b)
        return {"params": p, "opt_state": o}, metrics

    t0 = time.time()
    losses = []

    def batch_fn(step):
        return prefetch.next()

    state, history = ft.run(state, step_fn, batch_fn, args.steps)
    losses = [float(h["loss"]) for h in history]
    prefetch.close()
    dt = time.time() - t0
    print(f"[train] {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1)*1e3:.0f} ms/step) "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"restarts={ft.state.restarts}")
    return losses


if __name__ == "__main__":
    main()
