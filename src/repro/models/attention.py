"""GQA attention with RoPE / M-RoPE, optional QKV bias, sliding window,
KV-cache decode, and three implementations:

  * 'naive'       — full (Lq, Lk) score matrix; smoke tests & tiny shapes.
  * 'xla_chunked' — flash-style online-softmax double scan over Q and KV
                    blocks in pure jnp; this is what the 32k/500k dry-run
                    cells lower (bounded per-step score tiles, so
                    memory_analysis stays honest).
  * 'pallas'      — repro.kernels.flash_attention (TPU fast path).

Decode attends a single new token against the cache; the cache sequence
dim is sharded over the 'model' mesh axis (launch/sharding.py) — XLA
turns the dynamic-update-slice + masked softmax into per-shard partial
attention with a small cross-shard reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models.layers import (apply_mrope, apply_rope, dense,
                                 dense_init)

NEG = -1e30


def attention_init(rng, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    hd = cfg.head_dim
    r = jax.random.split(rng, 4)
    return {
        "wq": dense_init(r[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias,
                         dtype=dtype),
        "wk": dense_init(r[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                         dtype=dtype),
        "wv": dense_init(r[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias,
                         dtype=dtype),
        "wo": dense_init(r[3], cfg.n_heads * hd, d, dtype=dtype),
    }


def _split_heads(x, n_heads, hd):
    b, l, _ = x.shape
    return x.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, l, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, l, h * hd)


def _apply_positions(q, k, cfg, positions):
    if cfg.mrope:
        if positions.ndim == 2:                  # text-only: t = h = w
            positions = jnp.broadcast_to(
                positions[:, None, :], (positions.shape[0], 3,
                                        positions.shape[1]))
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _naive_attention(q, k, v, causal, window, q_offset=0):
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    lq, lk = q.shape[2], k.shape[2]
    qi = jnp.arange(lq)[:, None] + q_offset
    kj = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def _chunked_attention(q, k, v, causal, window, bq=512, bk=1024):
    """Flash-style double scan in jnp (f32 accumulators)."""
    b, h, lq, d = q.shape
    lk = k.shape[2]
    bq = min(bq, lq)
    bk = min(bk, lk)
    pad_q = (-lq) % bq
    pad_k = (-lk) % bk
    q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (lq + pad_q) // bq
    nk = (lk + pad_k) // bk
    scale = d ** -0.5
    q_offset = lk - lq
    qs = q.reshape(b, h, nq, bq, d).transpose(2, 0, 1, 3, 4)
    ks = k.reshape(b, h, nk, bk, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, h, nk, bk, d).transpose(2, 0, 1, 3, 4)

    def q_block(_, qi_blk):
        qi_idx, qb = qi_blk

        # checkpoint: without it, AD saves every kv step's (bq, bk) score
        # tile as a linearization residual — the full S^2 matrix, erasing
        # the flash-attention memory win (whisper train: 16.8 GiB temp).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_block(carry, kv):
            m_prev, l_prev, acc = carry
            kj_idx, kb, vb = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb
                           ).astype(jnp.float32) * scale
            qpos = (qi_idx * bq + jnp.arange(bq)[:, None] + q_offset)
            kpos = kj_idx * bk + jnp.arange(bk)[None, :]
            mask = kpos < lk
            if causal:
                mask &= qpos >= kpos
            if window is not None:
                mask &= (qpos - kpos) < window
            s = jnp.where(mask[None, None], s, NEG)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p, -1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(qb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((b, h, bq, 1), NEG, jnp.float32),
                jnp.zeros((b, h, bq, 1), jnp.float32),
                jnp.zeros((b, h, bq, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(nk), ks, vs))
        out = (acc / jnp.maximum(l, 1e-30)).astype(qb.dtype)
        return None, out

    _, outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, nq * bq, d)
    return out[:, :, :lq]


def attention_apply(params, x, cfg, positions, causal=True,
                    impl="xla_chunked", kv_cache=None, cache_index=None,
                    x_kv=None):
    """x: (B, L, d). If kv_cache is given (decode), x is the single new
    token (L=1) and cache_index its position. x_kv enables cross-attention
    (whisper decoder): keys/values come from x_kv with no causal mask."""
    hd = cfg.head_dim
    q = _split_heads(dense(params["wq"], x), cfg.n_heads, hd)
    src = x if x_kv is None else x_kv
    k = _split_heads(dense(params["wk"], src), cfg.n_kv_heads, hd)
    v = _split_heads(dense(params["wv"], src), cfg.n_kv_heads, hd)
    q = shd.constrain(q, "attn_heads")
    k = shd.constrain(k, "attn_kv_heads")
    v = shd.constrain(v, "attn_kv_heads")

    if x_kv is None:                 # self-attention: rotary on q and k
        if kv_cache is not None:
            # decode: one new token at position `cache_index` (scalar)
            pos_q = jnp.full((x.shape[0], 1), cache_index, jnp.int32)
            q, k = _apply_positions(q, k, cfg, pos_q)
        else:
            q, k = _apply_positions(q, k, cfg, positions)

    if kv_cache is not None:
        # cache: dict(k=(B, Hkv, S, hd), v=(B, Hkv, S, hd)[, pos=(S,)]).
        # Keys are stored post-RoPE. A 'pos' buffer marks a rolling
        # sliding-window cache: slot = index % S, validity from stored
        # positions instead of slot order.
        lk = kv_cache["k"].shape[2]
        rolling = "pos" in kv_cache
        slot = cache_index % lk if rolling else cache_index
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, 0, slot, 0))
        ck = shd.constrain(ck, "kv_cache")
        cv = shd.constrain(cv, "kv_cache")
        new_cache = {"k": ck, "v": cv}
        if rolling:
            pos_buf = jax.lax.dynamic_update_slice(
                kv_cache["pos"],
                jnp.asarray(cache_index, jnp.int32)[None], (slot,))
            new_cache["pos"] = pos_buf
            valid = ((pos_buf >= 0) & (pos_buf <= cache_index))
            if cfg.sliding_window is not None:
                valid &= (cache_index - pos_buf) < cfg.sliding_window
            valid = valid[None, None, None, :]
        else:
            kpos = jnp.arange(lk)[None, None, None, :]
            valid = kpos <= slot
            if cfg.sliding_window is not None:
                valid &= (slot - kpos) < cfg.sliding_window
        # GQA without materializing repeated K/V: jnp.repeat on the
        # seq-sharded cache forced the SPMD partitioner into full
        # rematerialization (replicated 68 GiB caches for qwen2-vl);
        # grouping the query heads keeps the cache layout intact
        # (EXPERIMENTS.md §Perf iteration 3).
        rep = cfg.n_heads // cfg.n_kv_heads
        b_, _, lq_, _ = q.shape
        qg = q.reshape(b_, cfg.n_kv_heads, rep * lq_, hd)
        s = jnp.einsum("bgqd,bgkd->bgqk", qg, ck
                       ).astype(jnp.float32) * hd ** -0.5
        s = jnp.where(valid, s, NEG)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bgqk,bgkd->bgqd", p, cv)
        out = out.reshape(b_, cfg.n_heads, lq_, hd)
        out = _merge_heads(out)
        return dense(params["wo"], out), new_cache

    rep = cfg.n_heads // cfg.n_kv_heads
    if rep > 1:
        k = jnp.repeat(k, rep, 1)
        v = jnp.repeat(v, rep, 1)
    window = cfg.sliding_window
    if impl == "naive":
        out = _naive_attention(q, k, v, causal, window)
    elif impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = _chunked_attention(q, k, v, causal, window)
    out = _merge_heads(out)
    out = shd.constrain(out, "attn_out")
    return dense(params["wo"], out), None


def init_kv_cache(cfg, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.bfloat16):
    hd = cfg.head_dim
    return {
        "k": jnp.zeros((n_layers, batch, cfg.n_kv_heads, max_len, hd),
                       dtype),
        "v": jnp.zeros((n_layers, batch, cfg.n_kv_heads, max_len, hd),
                       dtype),
    }
