"""Shared neural building blocks: norms, RoPE / M-RoPE, MLP variants,
embeddings. All functions are pure; parameters are plain dict pytrees.

Conventions: parameters stored in bf16 (configurable), math that needs
range (normalization statistics, softmax, rotary) runs in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.bfloat16):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    return layernorm_init, layernorm


def dense_init(rng, d_in: int, d_out: int, bias: bool = False,
               dtype=jnp.bfloat16, scale: float | None = None):
    if scale is None:
        scale = d_in ** -0.5
    w = (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale
         ).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# --- rotary embeddings -----------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (B, H, L, D); positions: (B, L) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[:, None, :, None].astype(jnp.float32) \
        * freqs[None, None, None, :]                         # (B,1,L,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray,
                sections=(16, 24, 24), theta: float = 1e4) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the head_dim/2 frequency slots are split
    into (temporal, height, width) sections, each rotated by its own
    position stream. x: (B, H, L, D); positions: (B, 3, L)."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(d, theta)                       # (half,)
    # build per-slot positions by section
    sec_id = np.concatenate([np.full(s, i) for i, s in
                             enumerate(sections)])           # (half,)
    sec_id = jnp.asarray(sec_id)
    pos = positions.astype(jnp.float32)[:, sec_id, :]        # (B, half, L)
    angles = jnp.einsum("bfl,f->bfl", pos, freqs)            # (B, half, L)
    angles = jnp.moveaxis(angles, 1, -1)[:, None]            # (B,1,L,half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLP variants ----------------------------------------------------------

def mlp_init(rng, d: int, d_ff: int, kind: str, dtype=jnp.bfloat16):
    r1, r2, r3 = jax.random.split(rng, 3)
    if kind == "swiglu":
        return {"gate": dense_init(r1, d, d_ff, dtype=dtype),
                "up": dense_init(r2, d, d_ff, dtype=dtype),
                "down": dense_init(r3, d_ff, d, dtype=dtype)}
    return {"up": dense_init(r1, d, d_ff, dtype=dtype),
            "down": dense_init(r2, d_ff, d, dtype=dtype)}


def mlp_apply(params, x, kind: str, act_tag=None):
    from repro.launch import sharding as shd
    if kind == "swiglu":
        h = jax.nn.silu(dense(params["gate"], x)) * dense(params["up"], x)
    elif kind == "relu2":          # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(dense(params["up"], x)))
    else:                          # gelu (whisper)
        h = jax.nn.gelu(dense(params["up"], x))
    h = shd.constrain(h, "ffn_hidden")
    return dense(params["down"], h)


def embedding_init(rng, vocab: int, d: int, dtype=jnp.bfloat16):
    w = (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02
         ).astype(dtype)
    return {"w": w}


def embed(params, tokens):
    return jnp.take(params["w"], tokens, axis=0)


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    pos = np.arange(length)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d)
    out = np.zeros((length, d), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)
