"""Sequence-chunked cross-entropy.

Computing (B, S, V) logits at once costs hundreds of GiB for the 128k+
vocabularies; instead we scan the sequence in chunks, computing each
chunk's logits -> CE under jax.checkpoint, so only the (B, S, d) hidden
states are resident and the backward pass recomputes per-chunk logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd


def chunked_ce(hidden: jnp.ndarray, head_w: jnp.ndarray,
               labels: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """hidden: (B, S, d); head_w: (d, V); labels: (B, S) int32.
    Returns mean token CE in f32."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-1)
    n = (s + pad) // chunk
    hs = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, y):
        logits = h @ head_w                       # (B, C, V)
        logits = shd.constrain(logits, "logits")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = y >= 0
        y_safe = jnp.maximum(y, 0)
        gold = jnp.take_along_axis(logits, y_safe[..., None],
                                   axis=-1)[..., 0]
        ce = jnp.where(mask, lse - gold, 0.0)
        return ce.sum(), mask.sum()

    def body(carry, xs):
        tot, cnt = carry
        h, y = xs
        c_tot, c_cnt = chunk_loss(h, y)
        return (tot + c_tot, cnt + c_cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (hs, ls))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
