"""Mixture-of-Experts FFN: token-choice top-k routing with capacity,
scatter/gather dispatch (TPU-friendly dense layout), optional dense
SwiGLU residual branch (Arctic).

Dispatch layout: tokens are scattered into an (E, C, d) buffer (C =
capacity per expert), expert FFNs run as one batched einsum over E, and
outputs are gathered back weighted by the router gate. Tokens beyond an
expert's capacity are dropped for that expert (standard capacity-factor
semantics); top-k gates are renormalized over the kept assignments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models.layers import dense_init


def moe_init(rng, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    dff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    r = jax.random.split(rng, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(r[0], d, e, dtype=jnp.float32),
        "gate": (jax.random.normal(r[1], (e, d, dff), jnp.float32)
                 * scale).astype(dtype),
        "up": (jax.random.normal(r[2], (e, d, dff), jnp.float32)
               * scale).astype(dtype),
        "down": (jax.random.normal(r[3], (e, dff, d), jnp.float32)
                 * dff ** -0.5).astype(dtype),
    }
    if cfg.moe_dense_residual:
        from repro.models.layers import mlp_init
        p["dense_residual"] = mlp_init(r[4], d, cfg.d_ff, "swiglu", dtype)
    return p


DISPATCH_CHUNK = 65536


def moe_apply(params, x, cfg, capacity_factor: float | None = 1.25,
              dispatch_chunk: int = DISPATCH_CHUNK):
    """x: (B, L, d) -> (B, L, d).

    capacity_factor=None runs DROPLESS (capacity = T*k): the decode path
    uses it so serving logits are exact; training keeps the capacity
    discipline that bounds the all-to-all buffers at scale.

    Token counts beyond `dispatch_chunk` are dispatched in chunks under
    a lax.scan: XLA SPMD replicates scatter/gather operands it cannot
    shard (EXPERIMENTS.md §Perf iteration 2), so the chunk bounds that
    replication at ~chunk x d bytes while loop-invariant expert weights
    are hoisted out of the loop.
    """
    b, l, d = x.shape
    t = b * l
    chunk_l = max(dispatch_chunk // max(b, 1), 1)
    if t > dispatch_chunk and l % chunk_l == 0 and l // chunk_l > 1:
        # chunk along LENGTH, keeping the (dp-sharded) batch dim intact:
        # flattening tokens first merged the sharded batch axis away and
        # the scan inputs came back replicated (mixtral prefill temp
        # regressed 26 -> 31 GiB; §Perf iteration 2, refuted variant).
        n_chunks = l // chunk_l
        xc = x.reshape(b, n_chunks, chunk_l, d).swapaxes(0, 1)

        def body(_, xk):                        # xk: (b, chunk_l, d)
            return None, _moe_dispatch(params, xk, cfg, capacity_factor)

        _, yc = jax.lax.scan(body, None, xc)    # (n, b, chunk_l, d)
        y = yc.swapaxes(0, 1).reshape(b, l, d)
    else:
        y = _moe_dispatch(params, x, cfg, capacity_factor)
    if "dense_residual" in params:              # Arctic
        from repro.models.layers import mlp_apply
        y = y + mlp_apply(params["dense_residual"], x, "swiglu")
    return y


def _moe_dispatch(params, x, cfg, capacity_factor):
    """Core dispatch over an (b, lc, d) slab; returns (b, lc, d)."""
    b, lc, d = x.shape
    t = b * lc
    e = cfg.n_experts
    k = cfg.experts_per_token
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"]["w"])   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renorm

    if capacity_factor is None or t * k <= 4096:
        # dropless: exact routing. Capacity discipline only matters at
        # scale (it bounds the dispatch buffers / all-to-all payload);
        # for small token counts the bound is the buffer itself.
        capacity = t * k
    else:
        capacity = max(-(-int(capacity_factor * k * t) // e), 1)
    # position of each (token, slot) within its expert's buffer.
    # every (T*k, ·) dispatch intermediate is sharding-constrained on the
    # token dim: without this the SPMD partitioner replicated the
    # gather/scatter operands and the mixtral train cell needed 218 GiB
    # of temp per device (EXPERIMENTS.md §Perf iteration 1).
    flat_e = expert_ids.reshape(-1)                             # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)         # (T*k, E)
    onehot = shd.constrain(onehot, "moe_routing")
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                   # (T*k, E)
    pos_in_e = shd.constrain(pos_in_e, "moe_routing")
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None],
                              axis=1)[:, 0]                     # (T*k,)
    keep = pos < capacity
    tok_ids = jnp.repeat(jnp.arange(t), k)

    # scatter tokens into (E, C, d)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    contrib = jnp.where(keep[:, None], xf[tok_ids], 0)
    contrib = shd.constrain(contrib, "moe_tokens")
    buf = buf.at[flat_e, safe_pos].add(contrib)
    buf = shd.constrain(buf, "moe_buffer")

    # expert FFN (SwiGLU), batched over experts
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["up"])
    h = shd.constrain(h, "moe_hidden")
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["down"])
    out_buf = shd.constrain(out_buf, "moe_buffer")

    # gather back, weighted by gates
    gathered = out_buf[flat_e, safe_pos]                        # (T*k, d)
    gathered = shd.constrain(gathered, "moe_tokens")
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = (gate_vals.reshape(-1)[:, None]).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[tok_ids].add(gathered * w)
    y = shd.constrain(y, "moe_tokens")
    return y.reshape(b, lc, d)


def aux_load_balance_loss(logits: jnp.ndarray, expert_ids: jnp.ndarray,
                          e: int) -> jnp.ndarray:
    """Switch-style auxiliary loss (exposed for training drivers)."""
    probs = jax.nn.softmax(logits, -1)
    me = probs.mean(0)
    ce = jax.nn.one_hot(expert_ids[:, 0], e).mean(0)
    return e * jnp.sum(me * ce)
