"""Mamba2 (SSD) block: in-projection -> short causal conv -> SiLU ->
selective state-space scan (chunked dual form) -> gated out-projection.

The sequence scan has three interchangeable implementations:
  * 'xla_chunked' — the SSD dual form as a lax.scan over chunks (same
    math as the Pallas kernel; used by the dry-run),
  * 'pallas'      — repro.kernels.ssd,
  * plus the exact per-step recurrence for decode (stateful, O(1)/token).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models.layers import dense, dense_init

CONV_WIDTH = 4


def ssm_init(rng, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    nheads = d_inner // cfg.ssm_head_dim
    r = jax.random.split(rng, 4)
    conv_ch = d_inner + 2 * n          # conv over x, B, C streams
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (n), C (n), dt (nheads)]
        "in_proj": dense_init(r[0], d, 2 * d_inner + 2 * n + nheads,
                              dtype=dtype),
        "conv_w": (jax.random.normal(r[1], (CONV_WIDTH, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)
                         ).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "out_proj": dense_init(r[2], d_inner, d, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B, L, C), w (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + b[None, None, :]


def _ssd_chunked_xla(x, dt, a, bm, cm, dskip, chunk=128):
    """SSD dual form in jnp (same math as kernels/ssd). x: (B,L,H,P)."""
    bsz, l, h, p = x.shape
    n = bm.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
    nc = (l + pad) // chunk

    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)
    dtc = dt.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)
    bc = bm.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = cm.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]

    # checkpoint: keeps AD from saving each chunk's (Q, Q) decay matrix
    # and score tile as linearization residuals (see attention.py note)
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def step(state, inp):
        xq, dtq, bq, cq = inp      # (B,H,Q,P), (B,H,Q), (B,Q,N), (B,Q,N)
        adt = a[None, :, None] * dtq                     # (B,H,Q) <= 0
        cum = jnp.cumsum(adt, axis=-1)
        total = cum[..., -1]
        # mask BEFORE exp: for i < j the exponent is positive and can
        # overflow; exp(inf)*0 poisons the where() gradient with NaNs
        diff = cum[..., :, None] - cum[..., None, :]
        diff = jnp.where(ii >= jj, diff, -jnp.inf)
        m = jnp.exp(diff)
        scores = jnp.einsum("bqn,bkn->bqk", cq, bq)      # (B,Q,Q)
        xdt = xq * dtq[..., None]                        # (B,H,Q,P)
        y = jnp.einsum("bhqk,bhkp->bhqp",
                       scores[:, None] * m, xdt)
        y += jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhpn->bhqp", cq, state)
        w = jnp.exp(total[..., None] - cum)[..., None] * xdt
        state = jnp.exp(total)[..., None, None] * state \
            + jnp.einsum("bhqp,bqn->bhpn", w, bq)
        return state, y

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (xc.astype(jnp.float32),
                                    dtc.astype(jnp.float32),
                                    bc.astype(jnp.float32),
                                    cc.astype(jnp.float32)))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, l + pad, h, p)
    y = y + dskip[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :l].astype(x.dtype)


def ssm_apply(params, x, cfg, impl="xla_chunked", state=None):
    """x: (B, L, d). If `state` is given (decode), L == 1 and the exact
    recurrence updates {conv, ssm} state in O(1).
    Returns (y, new_state)."""
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nheads = d_inner // hd

    zxbcdt = dense(params["in_proj"], x)
    z, xin, bm, cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n,
                 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)

    a = -jnp.exp(params["a_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None])   # (B,L,H)

    if state is not None:
        # --- decode: exact recurrence, one step ---
        conv_state = state["conv"]                 # (B, W-1, C)
        window = jnp.concatenate([conv_state, conv_in], axis=1)
        conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) \
            + params["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None]  # (B,1,C)
        new_conv = window[:, 1:]
        xs, bs, cs = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
        xh = xs.reshape(-1, 1, nheads, hd)[:, 0]             # (B,H,P)
        dt1 = dt[:, 0]                                       # (B,H)
        decay = jnp.exp(a[None] * dt1)                       # (B,H)
        inject = (dt1[..., None, None] * xh[..., None]
                  * bs[:, 0][:, None, None, :])
        s_new = state["ssm"] * decay[..., None, None] + inject
        y = jnp.einsum("bhpn,bn->bhp", s_new, cs[:, 0])
        y = y + params["d_skip"][None, :, None] * xh
        y = y.reshape(-1, 1, d_inner)
        y = y * jax.nn.silu(z)
        out = dense(params["out_proj"], y.astype(x.dtype))
        return out, {"conv": new_conv, "ssm": s_new}

    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"]))
    xs, bs, cs = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    bsz, l, _ = xs.shape
    xh = xs.reshape(bsz, l, nheads, hd)
    xh = shd.constrain(xh, "ssm_heads")
    if impl == "pallas":
        from repro.kernels.ssd.ops import ssd
        y = ssd(xh, dt, a, bs, cs, params["d_skip"])
    else:
        y = _ssd_chunked_xla(xh, dt, a, bs, cs, params["d_skip"])
    y = y.reshape(bsz, l, d_inner)
    y = y * jax.nn.silu(z)
    return dense(params["out_proj"], y.astype(x.dtype)), None


def init_ssm_state(cfg, batch: int, n_layers: int, dtype=jnp.float32):
    d_inner = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nheads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * n
    return {
        "conv": jnp.zeros((n_layers, batch, CONV_WIDTH - 1, conv_ch),
                          dtype),
        "ssm": jnp.zeros((n_layers, batch, nheads, cfg.ssm_head_dim, n),
                         dtype),
    }
