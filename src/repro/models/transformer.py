"""Model assembly for all 10 assigned architectures.

One parameter pytree + three pure entry points per model:

  * init_params(cfg, rng)            — real weights (smoke tests) or via
                                       jax.eval_shape (dry-run).
  * forward(cfg, params, batch)      — teacher-forced hidden states
                                       (B, S, d); combine with
                                       loss.chunked_ce for training.
  * decode_step(cfg, params, cache, tokens, index)
                                     — one-token serve step with caches.

Homogeneous layer stacks are lax.scan'd with per-layer jax.checkpoint
(remat), so HLO size and activation memory are O(1) in depth. The hybrid
(zamba2) model scans groups of `attn_every` SSM layers with a weight-
shared attention block between groups; whisper is enc-dec.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.launch import sharding as shd
from repro.models import layers as L
from repro.models.attention import (attention_apply, attention_init)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import init_ssm_state, ssm_apply, ssm_init


# --------------------------------------------------------------------------
# per-layer blocks
# --------------------------------------------------------------------------

def _block_init(rng, cfg, dtype=jnp.bfloat16):
    ninit, _ = L.make_norm(cfg.norm)
    r = jax.random.split(rng, 4)
    if cfg.family in ("ssm", "hybrid"):     # hybrid: SSM backbone layers
        return {"norm": ninit(cfg.d_model, dtype),
                "ssm": ssm_init(r[0], cfg, dtype)}
    p = {"norm1": ninit(cfg.d_model, dtype),
         "attn": attention_init(r[0], cfg, dtype),
         "norm2": ninit(cfg.d_model, dtype)}
    if cfg.n_experts > 0:
        p["moe"] = moe_init(r[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(r[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def _block_apply(params, x, cfg, positions, impl, causal=True):
    _, norm = L.make_norm(cfg.norm)
    if cfg.family in ("ssm", "hybrid"):
        h, _ = ssm_apply(params["ssm"], norm(params["norm"], x), cfg,
                         impl="xla_chunked" if impl == "naive" else impl)
        return x + h
    a, _ = attention_apply(params["attn"], norm(params["norm1"], x), cfg,
                           positions, causal=causal, impl=impl)
    x = x + a
    if cfg.n_experts > 0:
        x = x + moe_apply(params["moe"], norm(params["norm2"], x), cfg)
    else:
        x = x + L.mlp_apply(params["mlp"], norm(params["norm2"], x),
                            cfg.mlp)
    return shd.constrain(x, "residual")


def _block_decode(params, x, cfg, cache, index, impl):
    _, norm = L.make_norm(cfg.norm)
    if cfg.family in ("ssm", "hybrid"):
        h, new_state = ssm_apply(params["ssm"], norm(params["norm"], x),
                                 cfg, state=cache)
        return x + h, new_state
    a, new_cache = attention_apply(
        params["attn"], norm(params["norm1"], x), cfg, None,
        kv_cache=cache, cache_index=index)
    x = x + a
    if cfg.n_experts > 0:
        # dropless MoE in decode: serving logits must be exact
        x = x + moe_apply(params["moe"], norm(params["norm2"], x), cfg,
                          capacity_factor=None)
    else:
        x = x + L.mlp_apply(params["mlp"], norm(params["norm2"], x),
                            cfg.mlp)
    return x, new_cache


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def _stack_init(rng, n, init_fn):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def init_params(cfg, rng, dtype=jnp.bfloat16):
    ninit, _ = L.make_norm(cfg.norm)
    r = jax.random.split(rng, 8)
    params = {
        "embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                  dtype),
        "layers": _stack_init(r[1], cfg.n_layers,
                              lambda k: _block_init(k, cfg, dtype)),
        "final_norm": ninit(cfg.d_model, dtype),
        "lm_head": L.dense_init(r[2], cfg.d_model, cfg.vocab_size,
                                dtype=dtype),
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = attention_init(r[3], cfg, dtype)
        params["shared_norm"] = ninit(cfg.d_model, dtype)
    if cfg.family == "audio":
        enc_cfg = cfg.encoder_cfg()
        params["enc_layers"] = _stack_init(
            r[4], cfg.encoder_layers,
            lambda k: _block_init(k, enc_cfg, dtype))
        params["enc_norm"] = ninit(cfg.d_model, dtype)
        params["cross_layers"] = _stack_init(
            r[5], cfg.n_layers,
            lambda k: {"norm": ninit(cfg.d_model, dtype),
                       "attn": attention_init(k, cfg, dtype)})
    return params


# --------------------------------------------------------------------------
# forward (teacher-forced)
# --------------------------------------------------------------------------

def _scan_layers(stacked, x, fn, remat=True):
    def body(carry, lp):
        return fn(carry, lp), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    out, _ = jax.lax.scan(body, x, stacked)
    return out


def forward(cfg, params, batch, impl="xla_chunked"):
    """Returns final hidden states (B, S, d_model)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    x = shd.constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if cfg.family == "audio":
        enc = _encode(cfg, params, batch)
        return _decode_stack_ed(cfg, params, x, positions, enc, impl)

    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]),
            params["layers"])

        def group_fn(x, gparams):
            x = _scan_layers(gparams, x,
                             lambda h, lp: _block_apply(
                                 lp, h, cfg, positions, impl),
                             remat=cfg.remat)
            _, norm = L.make_norm(cfg.norm)
            a, _ = attention_apply(
                params["shared_attn"], norm(params["shared_norm"], x),
                cfg, positions, causal=True, impl=impl)
            return x + a

        def gbody(carry, gp):
            return group_fn(carry, gp), None
        x, _ = jax.lax.scan(gbody, x, grouped)
    else:
        x = _scan_layers(params["layers"], x,
                         lambda h, lp: _block_apply(
                             lp, h, cfg, positions, impl),
                         remat=cfg.remat)

    _, norm = L.make_norm(cfg.norm)
    return norm(params["final_norm"], x)


def _encode(cfg, params, batch):
    """Whisper encoder over precomputed conv-frontend frames."""
    frames = batch["frames"]                       # (B, F, d) stub
    b, f, _ = frames.shape
    pos_tab = L.sinusoidal_positions(f, cfg.d_model)
    x = frames + pos_tab[None].astype(frames.dtype)
    enc_cfg = cfg.encoder_cfg()
    positions = jnp.broadcast_to(jnp.arange(f)[None], (b, f))
    x = _scan_layers(params["enc_layers"], x,
                     lambda h, lp: _block_apply(
                         lp, h, enc_cfg, positions, "xla_chunked",
                         causal=False),
                     remat=cfg.remat)
    _, norm = L.make_norm(cfg.norm)
    return norm(params["enc_norm"], x)


def _decode_stack_ed(cfg, params, x, positions, enc, impl):
    """Whisper decoder: self-attention + cross-attention + MLP."""
    _, norm = L.make_norm(cfg.norm)

    def layer(h, lp):
        blk, cross = lp
        a, _ = attention_apply(blk["attn"], norm(blk["norm1"], h), cfg,
                               positions, causal=True, impl=impl)
        h = h + a
        c, _ = attention_apply(cross["attn"], norm(cross["norm"], h),
                               cfg, None, causal=False, impl=impl,
                               x_kv=enc)
        h = h + c
        h = h + L.mlp_apply(blk["mlp"], norm(blk["norm2"], h), cfg.mlp)
        return h

    def body(carry, lp):
        return layer(carry, lp), None
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["layers"],
                                  params["cross_layers"]))
    return norm(params["final_norm"], x)


def logits_from_hidden(cfg, params, hidden):
    out = hidden @ params["lm_head"]["w"]
    return shd.constrain(out, "logits")


# --------------------------------------------------------------------------
# decode (serving)
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache pytree for one-token decode at kv length `max_len`."""
    cache = {}
    hd = cfg.head_dim

    def kv(n_layers, length, heads):
        c = {"k": jnp.zeros((n_layers, batch, heads, length, hd), dtype),
             "v": jnp.zeros((n_layers, batch, heads, length, hd), dtype)}
        if cfg.sliding_window is not None and length >= cfg.sliding_window:
            c["pos"] = jnp.full((n_layers, length), -1, jnp.int32)
        return c

    if cfg.family == "ssm":
        cache["ssm"] = init_ssm_state(cfg, batch, cfg.n_layers)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        cache["ssm"] = init_ssm_state(cfg, batch, cfg.n_layers)
        cache["shared_kv"] = kv(groups, max_len, cfg.n_kv_heads)
    elif cfg.family == "audio":
        cache["kv"] = kv(cfg.n_layers, max_len, cfg.n_kv_heads)
        cache["cross"] = None        # filled by prime_cross_cache
    else:
        length = max_len if cfg.sliding_window is None else \
            min(max_len, cfg.sliding_window)
        cache["kv"] = kv(cfg.n_layers, length, cfg.n_kv_heads)
    return cache


def prime_cross_cache(cfg, params, batch_inputs):
    """Whisper: run the encoder once, precompute per-layer cross K/V."""
    enc = _encode(cfg, params, batch_inputs)        # (B, F, d)

    def layer_kv(cross_lp):
        k = L.dense(cross_lp["attn"]["wk"], enc)
        v = L.dense(cross_lp["attn"]["wv"], enc)
        b, f, _ = k.shape
        k = k.reshape(b, f, cfg.n_kv_heads, cfg.head_dim
                      ).transpose(0, 2, 1, 3)
        v = v.reshape(b, f, cfg.n_kv_heads, cfg.head_dim
                      ).transpose(0, 2, 1, 3)
        return {"k": k, "v": v}

    return jax.vmap(layer_kv)(params["cross_layers"])


def decode_step(cfg, params, cache, tokens, index, impl="naive"):
    """tokens: (B, 1) int32; index: scalar int32 position.
    Returns (logits (B, vocab), new_cache)."""
    x = L.embed(params["embed"], tokens)
    _, norm = L.make_norm(cfg.norm)

    if cfg.family == "ssm":
        def body(carry, inp):
            lp, st = inp
            h, new_st = _block_decode(lp, carry, cfg, st, index, impl)
            return h, new_st
        x, new_ssm = jax.lax.scan(body, x, (params["layers"],
                                            cache["ssm"]))
        new_cache = {"ssm": new_ssm}
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]),
            params["layers"])
        g_ssm = jax.tree.map(
            lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]),
            cache["ssm"])

        def gbody(carry, inp):
            gp, st, skv = inp

            def body(c2, inp2):
                lp, st2 = inp2
                h, new_st = _block_decode(lp, c2, cfg, st2, index, impl)
                return h, new_st
            h, new_st = jax.lax.scan(body, carry, (gp, st))
            a, new_skv = attention_apply(
                params["shared_attn"], norm(params["shared_norm"], h),
                cfg, None, kv_cache=skv, cache_index=index)
            return h + a, (new_st, new_skv)

        x, (new_ssm, new_skv) = jax.lax.scan(
            gbody, x, (grouped, g_ssm, cache["shared_kv"]))
        new_ssm = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), new_ssm)
        new_cache = {"ssm": new_ssm, "shared_kv": new_skv}
    elif cfg.family == "audio":
        def body(carry, inp):
            lp, cross_lp, kv, cross_kv = inp
            a, new_kv = attention_apply(
                lp["attn"], norm(lp["norm1"], carry), cfg, None,
                kv_cache=kv, cache_index=index)
            h = carry + a
            # cross-attention over primed encoder K/V (no update)
            c = _cross_decode(cfg, cross_lp, norm(cross_lp["norm"], h),
                              cross_kv)
            h = h + c
            h = h + L.mlp_apply(lp["mlp"], norm(lp["norm2"], h), cfg.mlp)
            return h, new_kv
        x, new_kv = jax.lax.scan(
            body, x, (params["layers"], params["cross_layers"],
                      cache["kv"], cache["cross"]))
        new_cache = {"kv": new_kv, "cross": cache["cross"]}
    else:
        # fori_loop with indexed in-place cache updates instead of a
        # scan over stacked cache leaves: scan ys forced a second copy
        # of the (donated) KV cache (qwen2-vl decode: +5 GiB/device;
        # EXPERIMENTS.md §Perf iteration 3). XLA aliases while-loop
        # carries, so dynamic_update_index_in_dim stays in place.
        has_pos = "pos" in cache["kv"]

        def body(li, carry):
            h, ck, cv, cpos = carry
            lp = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, li, 0, keepdims=False), params["layers"])
            kv = {"k": jax.lax.dynamic_index_in_dim(ck, li, 0, False),
                  "v": jax.lax.dynamic_index_in_dim(cv, li, 0, False)}
            if has_pos:
                kv["pos"] = jax.lax.dynamic_index_in_dim(
                    cpos, li, 0, False)
            h, new_kv = _block_decode(lp, h, cfg, kv, index, impl)
            ck = jax.lax.dynamic_update_index_in_dim(
                ck, new_kv["k"], li, 0)
            cv = jax.lax.dynamic_update_index_in_dim(
                cv, new_kv["v"], li, 0)
            if has_pos:
                cpos = jax.lax.dynamic_update_index_in_dim(
                    cpos, new_kv["pos"], li, 0)
            return (h, ck, cv, cpos)

        cpos0 = cache["kv"].get("pos",
                                jnp.zeros((cfg.n_layers, 1), jnp.int32))
        x, ck, cv, cpos = jax.lax.fori_loop(
            0, cfg.n_layers, body,
            (x, cache["kv"]["k"], cache["kv"]["v"], cpos0))
        new_kv = {"k": ck, "v": cv}
        if has_pos:
            new_kv["pos"] = cpos
        new_cache = {"kv": new_kv}

    x = norm(params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x)[:, 0]
    return logits, new_cache


def _cross_decode(cfg, cross_lp, x, cross_kv):
    """Single-query cross-attention against fixed encoder K/V."""
    hd = cfg.head_dim
    b = x.shape[0]
    q = L.dense(cross_lp["attn"]["wq"], x).reshape(
        b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    rep = cfg.n_heads // cfg.n_kv_heads
    k = jnp.repeat(cross_kv["k"], rep, 1)
    v = jnp.repeat(cross_kv["v"], rep, 1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) \
        * hd ** -0.5
    p = jax.nn.softmax(s, -1).astype(q.dtype)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * hd)
    return L.dense(cross_lp["attn"]["wo"], o)
