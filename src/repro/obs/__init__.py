"""Fleet observability plane (DESIGN.md §14, §17).

Pillars, one bundle:

  * `registry` — a host-side `MetricsRegistry` of counters, gauges,
    and log-bucketed histograms, fed by the in-scan counter outputs
    of the placement/sharding/emergency kernels and exported as
    Prometheus text or a JSON snapshot.
  * `audit` — an `AuditTrail` ring recording one decision tuple per
    arrival (chosen chassis, rule, fail reason, pool state) so a
    capped critical VM can be explained post-hoc.
  * `tracer` — a `SpanTracer` timing each pipeline stage per batch
    (ingest -> merge -> featurize -> infer -> place -> commit, plus
    emergency sweeps and migrations) with an optional
    ``jax.profiler`` hook.
  * `windows` — a `WindowPlane` of watermark-aligned tumbling/rolling
    time windows and fixed-bucket histograms (`obs.windows`).
  * `quality` — a `PredictionScorecard` joining predictions recorded
    at admission against ground-truth labels and throttle outcomes:
    rolling confusion matrices, calibration, PSI drift, and the
    ``model_stale`` gauge (`obs.quality`).
  * `slo` — an `SLOMonitor` evaluating declarative budget rules with
    multi-window burn-rate alerting (`obs.slo`).
  * `recorder` — a `FlightRecorder` of the merged event stream and
    placement decisions, with deterministic incident replay
    (`obs.recorder`).

All of it lives on the host side of the dispatch boundary: kernels
gained *extra outputs*, never extra inputs, so an instrumented run is
decision-bit-identical to an uninstrumented one (asserted in
``tests/test_obs.py`` and ``tests/test_obs_quality.py``). Construct
one `Observability` per pipeline and pass it as the ``obs=`` keyword
of `serve.pipeline.ServePipeline` / `ShardedServePipeline` /
`sim.scheduler_sim.simulate`; render it with `launch.monitor`.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .audit import (AdaptiveRecord, AdaptiveTrail, AuditRecord,
                    AuditTrail, OUTCOME_NAMES)
from .quality import PredictionScorecard
from .recorder import FlightRecorder
from .registry import (LEVEL_NAMES, Counter, Gauge, Histogram,
                       MetricsRegistry)
from .slo import SLOMonitor
from .tracing import Span, SpanTracer
from .windows import WindowPlane

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "LEVEL_NAMES",
    "AuditRecord", "AuditTrail", "OUTCOME_NAMES",
    "AdaptiveRecord", "AdaptiveTrail",
    "Span", "SpanTracer",
    "WindowPlane", "PredictionScorecard", "SLOMonitor",
    "FlightRecorder",
    "Observability", "record_sim_metrics",
]


@dataclass
class Observability:
    """The per-pipeline observability bundle: one registry, one audit
    ring, one span tracer, sharing lifetime with the pipeline they
    instrument. ``audit=None`` / ``tracer=None`` at construction turn
    those pillars off individually (the registry is always present —
    it is the cheap pillar)."""

    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    audit: AuditTrail | None = None
    tracer: SpanTracer | None = None
    #: adaptive-controller decision ring (`serve.adaptive`); None
    #: turns the reason rows off while the gauges/counters stay on
    adaptive: AdaptiveTrail | None = None
    #: watermark-aligned windowed aggregation (`obs.windows`)
    windows: WindowPlane | None = None
    #: online prediction scorecard + drift (`obs.quality`)
    quality: PredictionScorecard | None = None
    #: declarative SLO burn-rate monitor (`obs.slo`)
    slo: SLOMonitor | None = None
    #: incident flight recorder (`obs.recorder`)
    recorder: FlightRecorder | None = None

    @classmethod
    def full(cls, audit_capacity: int = 4096,
             span_capacity: int = 4096,
             recorder_rows: int = 65536) -> "Observability":
        """Every pillar on — the configuration the overhead
        benchmarks (`benchmarks/serve_obs.py`,
        `benchmarks/serve_quality.py`) measure."""
        reg = MetricsRegistry()
        return cls(registry=reg,
                   audit=AuditTrail(capacity=audit_capacity),
                   tracer=SpanTracer(reg, capacity=span_capacity),
                   adaptive=AdaptiveTrail(),
                   windows=WindowPlane(registry=reg),
                   quality=PredictionScorecard(registry=reg),
                   slo=SLOMonitor(registry=reg),
                   recorder=FlightRecorder(capacity_rows=recorder_rows))

    def span(self, name: str):
        """Span context for `name` (no-op context when tracing off)."""
        if self.tracer is not None:
            return self.tracer.span(name)
        import contextlib
        return contextlib.nullcontext()


def record_sim_metrics(registry: MetricsRegistry, metrics) -> None:
    """Export a `sim.scheduler_sim.SimMetrics` into `registry` under
    the serve-plane schema, so sim runs and live serve runs snapshot
    identically: per-level throttled-seconds become
    ``emergency_throttled_seconds_total{level=...}`` (level order =
    `LEVEL_NAMES` = the emergency plane's apportionment priority
    order), alarms/migrations/placements/failures become counters,
    and the scalar quality ratios become gauges."""
    g = registry.gauge
    c = registry.counter
    c("sim_placements_total",
      help="VM placements committed by the simulator").inc(
          metrics.placements)
    c("sim_failures_total",
      help="VM placements rejected by the simulator").inc(
          metrics.failures)
    g("sim_failure_rate", help="failures / placements").set(
        metrics.failure_rate)
    g("sim_empty_server_ratio",
      help="mean ratio of empty servers over samples").set(
          metrics.empty_server_ratio)
    g("sim_chassis_score_std",
      help="mean std of chassis packing scores").set(
          metrics.chassis_score_std)
    g("sim_server_score_std",
      help="mean std of server packing scores").set(
          metrics.server_score_std)
    for level, secs in zip(LEVEL_NAMES, metrics.throttled_s):
        c("emergency_throttled_seconds_total",
          help="seconds of frequency capping by criticality level",
          level=level).inc(float(secs))
    c("emergency_alarms_total",
      help="power-emergency alarms raised").inc(metrics.alarms)
    c("emergency_migrations_total",
      help="mitigation migrations executed").inc(metrics.migrations)
    g("adaptive_ratio",
      help="oversubscription ratio of the adaptive controller "
      "(1.0 when the controller is off)").set(metrics.adaptive_ratio)
    c("adaptive_ratchet_total",
      help="adaptive-controller up-steps taken").inc(
          metrics.adaptive_ratchets)
    c("adaptive_backoff_total",
      help="adaptive-controller down-steps taken").inc(
          metrics.adaptive_backoffs)
    scored = int(metrics.crit_confusion.sum())
    if scored:
        c("sim_pred_scored_total",
          help="predictions scored against ground truth by the "
          "simulator").inc(scored)
        g("sim_pred_crit_accuracy",
          help="measured criticality-prediction accuracy over the "
          "run (output, not the channel's generative constant)").set(
              metrics.measured_crit_accuracy)
        g("sim_pred_p95_accuracy",
          help="measured P95-bucket-prediction accuracy over the "
          "run").set(metrics.measured_p95_accuracy)
