"""Placement audit trail: a bounded ring of per-arrival decisions.

The paper's black-box constraint means a customer whose VM got capped
can only be answered from telemetry the provider kept — the serve
plane must be able to say, after the fact, *which* chassis a VM
landed on, *which* admission rule admitted it (or which budget
rejected it), and what the power-token pool looked like at that
moment. `AuditTrail` keeps exactly that: one structured-numpy record
per arrival, written from the already-materialised outputs of the
placement kernels (`servers`, outcome codes, pool level), so the
audited path is decision-bit-identical to the unaudited one — the
kernels never see the trail.

Bounded by construction: a power-of-two-sized ring indexed by a
monotone sequence number, so memory is O(capacity) no matter how long
the pipeline runs, and `tail`/`explain` reconstruct recent history in
order. Outcome codes follow `serve.placement` (server id >= 0 admits;
-1 capacity, -2 chassis power, -3 pool tokens).
"""
from __future__ import annotations

import numpy as np

__all__ = ["AuditRecord", "AuditTrail", "OUTCOME_NAMES",
           "AdaptiveRecord", "AdaptiveTrail"]

#: Decision-outcome code -> human name (codes from `serve.placement`).
OUTCOME_NAMES = {
    0: "admitted",
    -1: "fail_capacity",
    -2: "fail_chassis_power",
    -3: "fail_pool_tokens",
}

#: One decision record. ``server``/``chassis`` are -1 on rejection;
#: ``rule`` is the admission-policy index that produced the decision;
#: ``pool_left`` is the token pool *after* the batch committed.
_DTYPE = np.dtype([
    ("seq", np.int64),          # monotone arrival sequence number
    ("t", np.float64),          # wall-clock seconds (time.time)
    ("batch", np.int64),        # pipeline batch index
    ("slot", np.int32),         # row within the batch
    ("server", np.int32),       # chosen server id, or -1
    ("chassis", np.int32),      # chosen chassis id, or -1
    ("outcome", np.int8),       # 0 admitted / -1 / -2 / -3
    ("rule", np.int8),          # admission policy index
    ("cores", np.float32),      # requested cores
    ("is_uf", np.bool_),        # user-facing criticality flag
    ("p95_eff", np.float32),    # effective p95 utilisation used
    ("conservative", np.bool_),  # admission fell back to conservative
    ("pool_left", np.float32),  # pool tokens after the batch committed
])


class AuditRecord:
    """Read-only view of one audit row with named attributes and a
    human rendering (`AuditTrail.explain` returns these)."""

    __slots__ = ("_row",)

    def __init__(self, row: np.void):
        self._row = row

    def __getattr__(self, name):
        try:
            return self._row[name].item()
        except (KeyError, ValueError):
            raise AttributeError(name) from None

    @property
    def outcome_name(self) -> str:
        """Decision outcome as a string (``admitted`` / ``fail_*``)."""
        code = int(self._row["outcome"])
        return OUTCOME_NAMES.get(code, f"outcome_{code}")

    def describe(self) -> str:
        """One-line human rendering of the decision, the shape quoted
        in the docs/observability.md audit walkthrough."""
        r = self._row
        crit = "UF" if r["is_uf"] else "NUF"
        head = (f"seq={int(r['seq'])} batch={int(r['batch'])}"
                f" slot={int(r['slot'])} {crit}"
                f" cores={float(r['cores']):g}"
                f" p95_eff={float(r['p95_eff']):.4f}")
        if int(r["outcome"]) == 0:
            where = (f"-> server {int(r['server'])}"
                     f" chassis {int(r['chassis'])}"
                     f" rule {int(r['rule'])}")
        else:
            where = f"-> REJECTED ({self.outcome_name})"
        return (f"{head} {where}"
                f" pool_left={float(r['pool_left']):.3f}"
                + (" [conservative]" if r["conservative"] else ""))


class AuditTrail:
    """Bounded ring buffer of placement decisions.

    `record_batch` appends one row per *valid* arrival in a placed
    batch, vectorised (one structured-array write, no per-row Python
    loop on the hot path). Capacity is rounded up to a power of two so
    the ring index is a mask, and the oldest rows are overwritten once
    ``len() == capacity``.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = 1 << (capacity - 1).bit_length()
        self._ring = np.zeros(self.capacity, _DTYPE)
        self._next_seq = 0

    def __len__(self) -> int:
        return min(self._next_seq, self.capacity)

    @property
    def total_recorded(self) -> int:
        """Total rows ever written (>= ``len`` once the ring wraps)."""
        return self._next_seq

    def record_batch(self, *, t: float, batch: int, servers, chassis,
                     rule, cores, is_uf, p95_eff, valid,
                     conservative, pool_left: float) -> int:
        """Append every row of one placed batch where ``valid`` is
        True. All array arguments are batch-shaped ((B,) or scalar-
        broadcastable); ``servers`` < 0 encodes the fail reason.
        Returns the number of rows written."""
        valid = np.asarray(valid, bool)
        n = int(valid.sum())
        if n == 0:
            return 0
        rows = np.zeros(n, _DTYPE)
        rows["seq"] = self._next_seq + np.arange(n)
        rows["t"] = t
        rows["batch"] = batch
        rows["slot"] = np.nonzero(valid)[0]
        srv = np.broadcast_to(np.asarray(servers), valid.shape)[valid]
        rows["server"] = np.where(srv >= 0, srv, -1)
        rows["chassis"] = np.broadcast_to(
            np.asarray(chassis), valid.shape)[valid]
        rows["outcome"] = np.minimum(srv, 0)
        rows["rule"] = np.broadcast_to(
            np.asarray(rule), valid.shape)[valid]
        rows["cores"] = np.broadcast_to(
            np.asarray(cores), valid.shape)[valid]
        rows["is_uf"] = np.broadcast_to(
            np.asarray(is_uf, bool), valid.shape)[valid]
        rows["p95_eff"] = np.broadcast_to(
            np.asarray(p95_eff), valid.shape)[valid]
        rows["conservative"] = np.broadcast_to(
            np.asarray(conservative, bool), valid.shape)[valid]
        rows["pool_left"] = pool_left
        idx = (self._next_seq + np.arange(n)) & (self.capacity - 1)
        self._ring[idx] = rows
        self._next_seq += n
        return n

    def tail(self, n: int = 32) -> np.ndarray:
        """The most recent `n` records, oldest first, as a structured
        array (a copy — safe to hold across further recording)."""
        n = min(n, len(self))
        if n == 0:
            return np.zeros(0, _DTYPE)
        idx = (self._next_seq - n + np.arange(n)) & (self.capacity - 1)
        return self._ring[idx].copy()

    def explain(self, seq: int) -> AuditRecord:
        """Look up one decision by sequence number (raises KeyError if
        it has fallen out of the ring or was never recorded)."""
        if not (0 <= seq < self._next_seq) \
                or seq < self._next_seq - self.capacity:
            raise KeyError(f"seq {seq} not in audit ring "
                           f"(kept: [{max(0, self._next_seq - self.capacity)}"
                           f", {self._next_seq}))")
        return AuditRecord(self._ring[seq & (self.capacity - 1)])

    def rejected(self, n: int = 32) -> list:
        """The most recent rejected decisions (up to `n`), oldest
        first — the starting point of a "why was my VM capped/denied"
        investigation."""
        rows = self.tail(len(self))
        bad = rows[rows["outcome"] < 0]
        return [AuditRecord(r) for r in bad[-n:]]


#: One adaptive-controller decision row (`serve.adaptive`). ``action``
#: is +1 ratchet / 0 hold / -1 backoff; ``reason`` indexes
#: `repro.serve.adaptive.REASON_NAMES`; ``shard`` is -1 unsharded.
_ADAPTIVE_DTYPE = np.dtype([
    ("seq", np.int64),          # monotone decision sequence number
    ("t", np.float64),          # wall-clock seconds (time.time)
    ("shard", np.int16),        # owning shard, or -1 unsharded
    ("ratio", np.float32),      # post-decision oversubscription ratio
    ("stable_frac", np.float32),  # stable / known chassis this scan
    ("n_known", np.int32),      # chassis with enough window history
    ("n_stable", np.int32),     # known chassis scored stable
    ("action", np.int8),        # +1 ratchet / 0 hold / -1 backoff
    ("reason", np.int8),        # index into adaptive.REASON_NAMES
])

_ACTION_NAMES = {1: "ratchet", 0: "hold", -1: "backoff"}


class AdaptiveRecord:
    """Read-only view of one adaptive-controller decision row with
    named attributes and a human rendering (`AdaptiveTrail.explain`
    returns these)."""

    __slots__ = ("_row",)

    def __init__(self, row: np.void):
        self._row = row

    def __getattr__(self, name):
        try:
            return self._row[name].item()
        except (KeyError, ValueError):
            raise AttributeError(name) from None

    @property
    def action_name(self) -> str:
        """Controller action as a string (ratchet / hold / backoff)."""
        return _ACTION_NAMES.get(int(self._row["action"]),
                                 f"action_{int(self._row['action'])}")

    @property
    def reason_name(self) -> str:
        """Decision reason as a string (the `serve.adaptive.
        REASON_NAMES` entry the recorded index points at)."""
        from repro.serve.adaptive import REASON_NAMES
        code = int(self._row["reason"])
        if 0 <= code < len(REASON_NAMES):
            return REASON_NAMES[code]
        return f"reason_{code}"

    def describe(self) -> str:
        """One-line human rendering of the controller decision."""
        r = self._row
        where = "" if int(r["shard"]) < 0 else f" shard={int(r['shard'])}"
        return (f"seq={int(r['seq'])}{where} {self.action_name}"
                f" ({self.reason_name})"
                f" ratio={float(r['ratio']):.3f}"
                f" stable={int(r['n_stable'])}/{int(r['n_known'])}"
                f" frac={float(r['stable_frac']):.3f}")


class AdaptiveTrail:
    """Bounded ring of adaptive-ratio controller decisions — the "why
    did the budget move" sibling of the placement `AuditTrail`, with
    the same power-of-two ring mechanics. One row per controller scan
    (per shard, sharded), written host-side from outputs the kernel
    already returned, so recording never perturbs a decision."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = 1 << (capacity - 1).bit_length()
        self._ring = np.zeros(self.capacity, _ADAPTIVE_DTYPE)
        self._next_seq = 0

    def __len__(self) -> int:
        return min(self._next_seq, self.capacity)

    @property
    def total_recorded(self) -> int:
        """Total rows ever written (>= ``len`` once the ring wraps)."""
        return self._next_seq

    def record(self, *, t: float, shard: int, ratio: float,
               stable_frac: float, n_known: int, n_stable: int,
               action: int, reason: int) -> int:
        """Append one controller decision row; returns its seq."""
        seq = self._next_seq
        row = self._ring[seq & (self.capacity - 1)]
        row["seq"], row["t"], row["shard"] = seq, t, shard
        row["ratio"], row["stable_frac"] = ratio, stable_frac
        row["n_known"], row["n_stable"] = n_known, n_stable
        row["action"], row["reason"] = action, reason
        self._next_seq += 1
        return seq

    def tail(self, n: int = 32) -> np.ndarray:
        """The most recent `n` rows, oldest first (a copy)."""
        n = min(n, len(self))
        if n == 0:
            return np.zeros(0, _ADAPTIVE_DTYPE)
        idx = (self._next_seq - n + np.arange(n)) & (self.capacity - 1)
        return self._ring[idx].copy()

    def explain(self, seq: int) -> AdaptiveRecord:
        """Look up one decision by sequence number (KeyError if it has
        fallen out of the ring or was never recorded)."""
        if not (0 <= seq < self._next_seq) \
                or seq < self._next_seq - self.capacity:
            raise KeyError(
                f"seq {seq} not in adaptive ring (kept: "
                f"[{max(0, self._next_seq - self.capacity)}, "
                f"{self._next_seq}))")
        return AdaptiveRecord(self._ring[seq & (self.capacity - 1)])

    def backoffs(self, n: int = 32) -> list:
        """The most recent back-off decisions (up to `n`), oldest
        first — the starting point of a "why did my budget shrink"
        investigation."""
        rows = self.tail(len(self))
        bad = rows[rows["action"] < 0]
        return [AdaptiveRecord(r) for r in bad[-n:]]
