"""Online prediction scorecard: were the predictions *right*?
(DESIGN.md §17.)

The paper's safety argument rests on prediction quality — criticality
and P95-bucket predictions gate how hard admission oversubscribes —
yet counting decisions says nothing about whether those predictions
held. This module joins the predictions recorded at admission
(criticality, P95 bucket, per-head confidence) against realized
outcomes (the ground-truth columns `sim.telemetry.ArrivalBatch`
carries for evaluation, and the emergency plane's throttle counters)
into:

  * rolling confusion matrices over the *used* (post confidence-gate)
    decisions — the operational accuracy the admission path actually
    ran on;
  * the same high-confidence confusion over the *raw* head outputs,
    shaped exactly like `core.forest.evaluate` so the online scorecard
    reconciles with offline Table-III scoring on the same trace
    (asserted in tests);
  * calibration-by-confidence-bucket (per-head reliability curves and
    an ECE summary);
  * a PSI-style drift statistic per distribution component
    (criticality predictions, P95-bucket predictions, realized P95
    buckets) against a frozen training-time reference;
  * a `model_stale` verdict the hot-swap path and the adaptive
    controller can consult to force conservative fallback
    (`serve.adaptive.gate_ratio_on_stale`).

Everything is a host-side fold of values the serving path already
materializes — scoring can never perturb a decision.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = ["psi", "PredictionScorecard"]

#: Drift components tracked against the reference snapshot.
COMPONENTS = ("crit_pred", "p95_pred", "p95_realized")


def psi(expected, actual, eps: float = 1e-4) -> float:
    """Population Stability Index between two count vectors.

    ``sum((a - e) * ln(a / e))`` over bucket fractions, with ``eps``
    Laplace smoothing so empty buckets stay finite. The conventional
    reading: < 0.1 stable, 0.1-0.25 drifting, > 0.25 shifted."""
    e = np.asarray(expected, np.float64)
    a = np.asarray(actual, np.float64)
    if e.shape != a.shape:
        raise ValueError(f"shape mismatch: {e.shape} vs {a.shape}")
    if e.sum() <= 0 or a.sum() <= 0:
        return 0.0
    e = e / e.sum() + eps
    a = a / a.sum() + eps
    e, a = e / e.sum(), a / a.sum()
    return float(np.sum((a - e) * np.log(a / e)))


class _Head:
    """One prediction head's online stats (criticality or P95
    bucket): used-decision and raw high-confidence confusion, plus
    confidence-binned calibration."""

    def __init__(self, n_classes: int, gate: float, n_conf_bins: int):
        self.n_classes = n_classes
        self.gate = gate
        self.n_conf_bins = n_conf_bins
        self.reset()

    def reset(self) -> None:
        self.used_cm = np.zeros((self.n_classes,) * 2, np.int64)
        self.hi_cm = np.zeros((self.n_classes,) * 2, np.int64)
        self.n_total = 0
        self.n_hi = 0
        # calibration over RAW predictions: per confidence bin,
        # (count, sum conf, correct)
        self.bin_n = np.zeros(self.n_conf_bins, np.int64)
        self.bin_conf = np.zeros(self.n_conf_bins, np.float64)
        self.bin_correct = np.zeros(self.n_conf_bins, np.int64)

    def record(self, true, used, raw=None, conf=None) -> None:
        true = np.asarray(true, np.int64).ravel()
        used = np.asarray(used, np.int64).ravel()
        np.add.at(self.used_cm, (true, used), 1)
        self.n_total += len(true)
        if raw is None:
            return
        raw = np.asarray(raw, np.int64).ravel()
        if conf is None:
            return
        conf = np.asarray(conf, np.float64).ravel()
        hi = conf >= self.gate
        self.n_hi += int(hi.sum())
        np.add.at(self.hi_cm, (true[hi], raw[hi]), 1)
        bins = np.clip((conf * self.n_conf_bins).astype(np.int64), 0,
                       self.n_conf_bins - 1)
        np.add.at(self.bin_n, bins, 1)
        np.add.at(self.bin_conf, bins, conf)
        np.add.at(self.bin_correct, bins, (raw == true).astype(np.int64))

    @property
    def accuracy(self) -> float:
        n = self.used_cm.sum()
        return float(np.trace(self.used_cm) / n) if n else float("nan")

    @property
    def ece(self) -> float:
        """Expected calibration error over the raw-head confidence
        bins: sum_b (n_b/N) |acc_b - conf_b| (NaN before any scored
        confidence)."""
        n = self.bin_n.sum()
        if n == 0:
            return float("nan")
        mask = self.bin_n > 0
        acc = self.bin_correct[mask] / self.bin_n[mask]
        conf = self.bin_conf[mask] / self.bin_n[mask]
        return float(np.sum(self.bin_n[mask] / n * np.abs(acc - conf)))

    def offline_style(self) -> dict:
        """`core.forest.evaluate`-shaped dict from the online
        counters: pct/accuracy over high-confidence raw predictions
        and per-class recall/precision among them."""
        out = {"pct_high_conf": self.n_hi / self.n_total
               if self.n_total else float("nan"),
               "accuracy_high_conf": float(
                   np.trace(self.hi_cm) / self.n_hi)
               if self.n_hi else float("nan"),
               "buckets": {}}
        for c in range(self.n_classes):
            if self.hi_cm[c].sum() == 0 and self.hi_cm[:, c].sum() == 0:
                continue
            tp = int(self.hi_cm[c, c])
            fn = int(self.hi_cm[c].sum()) - tp
            fp = int(self.hi_cm[:, c].sum()) - tp
            out["buckets"][c] = {"recall": tp / max(tp + fn, 1),
                                 "precision": tp / max(tp + fp, 1)}
        return out


class PredictionScorecard:
    """Online predicted-vs-realized scorecard with drift detection.

    `record` folds a batch of scored arrivals in (vectorized); the
    first ``reference_n`` scored arrivals freeze into the drift
    reference unless `set_reference` installed a training-time
    snapshot explicitly. `model_stale` goes True once enough arrivals
    are scored and either a drift component's PSI crosses
    ``stale_psi`` or the used-decision criticality accuracy falls
    under ``stale_accuracy`` — the conservative-fallback signal
    exported as the ``quality_model_stale`` gauge."""

    def __init__(self, registry=None, confidence_gate: float = 0.6,
                 n_conf_bins: int = 10, reference_n: int = 256,
                 stale_psi: float = 0.25, stale_accuracy: float = 0.5,
                 min_scored: int = 64):
        if not 0.0 <= confidence_gate <= 1.0:
            raise ValueError(
                f"confidence_gate must be in [0, 1], got "
                f"{confidence_gate}")
        if min_scored < 1:
            raise ValueError(f"min_scored must be >= 1, got {min_scored}")
        self.registry = registry
        self.confidence_gate = float(confidence_gate)
        self.reference_n = int(reference_n)
        self.stale_psi = float(stale_psi)
        self.stale_accuracy = float(stale_accuracy)
        self.min_scored = int(min_scored)
        self.crit = _Head(2, self.confidence_gate, n_conf_bins)
        self.bucket = _Head(4, self.confidence_gate, n_conf_bins)
        self._ref: dict | None = None    # component -> counts
        self._ref_frozen_explicit = False
        self._cur = {c: np.zeros(4 if c != "crit_pred" else 2, np.int64)
                     for c in COMPONENTS}
        # throttle-outcome join (emergency sweeps)
        self.alarms_seen = 0
        self.samples_seen = 0
        self.cut_watts_seen = 0.0

    # -- recording ---------------------------------------------------------
    @property
    def n_scored(self) -> int:
        """Arrivals scored against ground truth so far."""
        return self.crit.n_total

    def record(self, true_crit, true_bucket, crit_used, bucket_used,
               crit_raw=None, crit_conf=None, bucket_raw=None,
               bucket_conf=None, conservative=None) -> None:
        """Fold one batch of scored arrivals in (scalars or arrays).

        ``*_used`` are the post-confidence-gate values the admission
        path ran on; ``*_raw``/``*_conf`` are the ungated head outputs
        (None when the caller has no confidences — the sim channel),
        which feed the calibration bins and the
        `core.forest.evaluate`-style reconciliation counters."""
        self.crit.record(true_crit, crit_used, crit_raw, crit_conf)
        self.bucket.record(true_bucket, bucket_used, bucket_raw,
                           bucket_conf)
        cp = np.asarray(crit_used if crit_raw is None else crit_raw,
                        np.int64).ravel()
        bp = np.asarray(bucket_used if bucket_raw is None else bucket_raw,
                        np.int64).ravel()
        tb = np.asarray(true_bucket, np.int64).ravel()
        self._cur["crit_pred"] += np.bincount(cp, minlength=2)[:2]
        self._cur["p95_pred"] += np.bincount(bp, minlength=4)[:4]
        self._cur["p95_realized"] += np.bincount(tb, minlength=4)[:4]
        if self._ref is None and self.n_scored >= self.reference_n:
            self._ref = {c: v.copy() for c, v in self._cur.items()}
        self._export()

    def observe_alarms(self, alarms: int, cut_w: float = 0.0,
                       samples: int = 0) -> None:
        """Join one emergency sweep's throttle outcome in — the
        realized-pressure context of the drift verdict."""
        self.alarms_seen += int(alarms)
        self.samples_seen += int(samples)
        self.cut_watts_seen += float(cut_w)
        self._export()

    def set_reference(self, crit_counts, p95_pred_counts,
                      p95_realized_counts) -> None:
        """Install the training-snapshot distributions PSI drifts
        against (per-component count vectors: (2,), (4,), (4,))."""
        ref = {"crit_pred": np.asarray(crit_counts, np.float64),
               "p95_pred": np.asarray(p95_pred_counts, np.float64),
               "p95_realized": np.asarray(p95_realized_counts,
                                          np.float64)}
        for c, v in ref.items():
            want = 2 if c == "crit_pred" else 4
            if v.shape != (want,):
                raise ValueError(
                    f"{c} reference must have shape ({want},), got "
                    f"{v.shape}")
        self._ref = ref
        self._ref_frozen_explicit = True

    def on_hot_swap(self) -> None:
        """Reset the per-model stats after a model hot-swap: the old
        model's confusion/calibration/drift say nothing about the
        newly installed one. An explicitly installed reference
        survives only until the swap too — the retrain ships a new
        training snapshot (re-`set_reference` it, or let the first
        ``reference_n`` scored arrivals re-freeze)."""
        self.crit.reset()
        self.bucket.reset()
        self._ref = None
        self._ref_frozen_explicit = False
        for c in self._cur:
            self._cur[c][:] = 0
        self._export()

    # -- verdicts ----------------------------------------------------------
    @property
    def crit_accuracy(self) -> float:
        """Used-decision criticality accuracy (NaN before any score)."""
        return self.crit.accuracy

    @property
    def p95_accuracy(self) -> float:
        """Used-decision P95-bucket accuracy (NaN before any score).
        This is the *measured* counterpart of the constant the sim's
        `PredictionChannel.p95_accuracy` assumes."""
        return self.bucket.accuracy

    @property
    def throttle_rate(self) -> float:
        """Alarms per emergency sample consumed (0 before any)."""
        return self.alarms_seen / max(self.samples_seen, 1)

    def drift(self) -> dict:
        """Per-component PSI vs the reference (all 0.0 before the
        reference freezes)."""
        if self._ref is None:
            return {c: 0.0 for c in COMPONENTS}
        return {c: psi(self._ref[c], self._cur[c]) for c in COMPONENTS}

    @property
    def model_stale(self) -> bool:
        """Conservative-fallback verdict: enough arrivals scored AND
        (drift past ``stale_psi`` on any component, or used criticality
        accuracy under ``stale_accuracy``)."""
        if self.n_scored < self.min_scored:
            return False
        if max(self.drift().values()) > self.stale_psi:
            return True
        acc = self.crit_accuracy
        return not math.isnan(acc) and acc < self.stale_accuracy

    def offline_style(self, head: str = "crit") -> dict:
        """`core.forest.evaluate`-shaped dict for one head ('crit' or
        'bucket') from the online high-confidence counters — the
        reconciliation surface against offline Table-III scoring."""
        if head not in ("crit", "bucket"):
            raise ValueError(f"head must be 'crit' or 'bucket', "
                             f"got {head!r}")
        return (self.crit if head == "crit" else
                self.bucket).offline_style()

    # -- export ------------------------------------------------------------
    def _export(self) -> None:
        if self.registry is None:
            return
        reg = self.registry
        reg.gauge("quality_scored",
                  help="arrivals scored against ground truth").set(
                      self.n_scored)
        acc = self.crit_accuracy
        if not math.isnan(acc):
            reg.gauge("quality_crit_accuracy",
                      help="used-decision criticality accuracy").set(acc)
        acc = self.p95_accuracy
        if not math.isnan(acc):
            reg.gauge("quality_p95_accuracy",
                      help="used-decision P95-bucket accuracy").set(acc)
        for head, h in (("crit", self.crit), ("bucket", self.bucket)):
            e = h.ece
            if not math.isnan(e):
                reg.gauge("quality_ece",
                          help="expected calibration error, by head",
                          head=head).set(e)
        for comp, v in self.drift().items():
            reg.gauge("quality_psi",
                      help="population stability index vs the training "
                      "reference, by component", component=comp).set(v)
        reg.gauge("quality_model_stale",
                  help="1 when the scorecard demands conservative "
                  "fallback").set(1.0 if self.model_stale else 0.0)

    def summary(self) -> dict:
        """JSON-ready scorecard view for the monitor (NaN reads — no
        data yet — become None so the snapshot stays strict JSON)."""
        def _f(x):
            return None if math.isnan(x) else x
        return {
            "n_scored": self.n_scored,
            "crit_accuracy": _f(self.crit_accuracy),
            "p95_accuracy": _f(self.p95_accuracy),
            "crit_confusion": self.crit.used_cm.tolist(),
            "p95_confusion": self.bucket.used_cm.tolist(),
            "ece": {"crit": _f(self.crit.ece),
                    "bucket": _f(self.bucket.ece)},
            "drift": self.drift(),
            "reference_frozen": self._ref is not None,
            "model_stale": self.model_stale,
            "alarms_seen": self.alarms_seen,
            "samples_seen": self.samples_seen,
            "cut_watts_seen": self.cut_watts_seen,
            "throttle_rate": self.throttle_rate,
        }
