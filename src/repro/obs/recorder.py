"""Bounded incident flight recorder with deterministic replay
(DESIGN.md §17).

The serve pipelines are deterministic functions of their merged event
stream: micro-batch formation depends only on accumulated arrival
counts, departures and cap windows apply at their merged-stream
positions, and placement is a pure jitted kernel. So a recorder that
copies every merged *run* (arrivals / departures / chassis power
samples) plus every placement decision is enough to reconstruct an
incident exactly — no RNG state, no wall clock, no device state.

`FlightRecorder` keeps one ordered, row-bounded timeline of those
runs (a single deque, so eviction keeps the timeline consistent — we
never hold a decision whose causing arrivals were dropped) and a
small ring of `Incident` markers stamped by the emergency plane when
alarms fire. `replay` re-drives a fresh caller-built pipeline through
the recorded stream via the public `submit_to` / `depart_to` /
`cap_to` API; `verify_replay` asserts the replayed placement
decisions are bit-identical to the recorded ones — the
decision-identity acceptance check, and the post-incident "can we
reproduce it?" tool.

Only the streamed (queue) path is recorded: direct `serve()` calls
bypass the ingest merge and are not replayable. Recording is
host-side copying only — the decision path never reads the recorder,
preserving the PR 7 on/off bit-identity invariant.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Run", "Incident", "FlightRecorder", "replay",
           "verify_replay"]

#: Run kinds on the recorded timeline.
KINDS = ("arrival", "departure", "capping", "decision")


@dataclass(frozen=True)
class Run:
    """One recorded merged-stream run: ``kind`` (see ``KINDS``), a
    monotone sequence number, the per-event stamp array ``t`` (None
    for decision rows, which carry the serving watermark in
    ``payload``), and a dict of copied numpy columns."""
    seq: int
    kind: str
    t: object
    payload: dict

    @property
    def rows(self) -> int:
        """Row count this run charges against the capacity budget."""
        n = 0
        for v in self.payload.values():
            if isinstance(v, np.ndarray):
                n = max(n, len(v))
        return max(n, 1)


@dataclass(frozen=True)
class Incident:
    """One alarm marker: the watermark ``t`` it fired at, the alarm
    count, a counter snapshot, and the timeline sequence number it
    points into (`FlightRecorder.incident_window` slices around it)."""
    seq: int
    t: float
    alarms: int
    counters: dict = field(default_factory=dict)


class FlightRecorder:
    """Row-bounded timeline of merged-stream runs + incident markers.

    ``capacity_rows`` bounds total payload rows (oldest runs evicted
    first; ``wrapped`` reports whether anything was lost — `replay`
    refuses a wrapped recorder because the stream prefix is gone).
    ``incident_capacity`` bounds the marker ring."""

    def __init__(self, capacity_rows: int = 65536,
                 incident_capacity: int = 64):
        if capacity_rows < 1 or incident_capacity < 1:
            raise ValueError(
                f"capacities must be >= 1, got {capacity_rows}, "
                f"{incident_capacity}")
        self.capacity_rows = int(capacity_rows)
        self.timeline: deque = deque()
        self.incidents: deque = deque(maxlen=int(incident_capacity))
        self.rows = 0
        self.dropped_runs = 0
        self._seq = 0

    @property
    def wrapped(self) -> bool:
        """True once any run has been evicted (replay impossible)."""
        return self.dropped_runs > 0

    # -- recording ---------------------------------------------------------
    def _push(self, kind: str, t, payload: dict) -> None:
        run = Run(self._seq, kind, t, payload)
        self._seq += 1
        self.timeline.append(run)
        self.rows += run.rows
        while self.rows > self.capacity_rows and len(self.timeline) > 1:
            gone = self.timeline.popleft()
            self.rows -= gone.rows
            self.dropped_runs += 1

    @staticmethod
    def _copy_soa(batch) -> dict:
        """Copy a SoA dataclass batch field-by-field (None passes
        through for optional columns)."""
        out = {}
        for name in type(batch).__dataclass_fields__:
            v = getattr(batch, name)
            out[name] = None if v is None else np.array(v, copy=True)
        return out

    def record_arrivals(self, t, batch) -> None:
        """Record one merged arrival run (an `ArrivalBatch` slice,
        ground-truth columns included) stamped ``t``."""
        self._push("arrival", np.array(t, copy=True),
                   self._copy_soa(batch))

    def record_departures(self, t, batch) -> None:
        """Record one merged departure run (a `DepartureBatch`
        slice) stamped ``t``."""
        self._push("departure", np.array(t, copy=True),
                   self._copy_soa(batch))

    def record_caps(self, t, batch) -> None:
        """Record one merged chassis power-sample run (a `CapBatch`
        slice) stamped ``t``."""
        self._push("capping", np.array(t, copy=True),
                   self._copy_soa(batch))

    def record_decision(self, servers, watermark: float = 0.0) -> None:
        """Record one micro-batch's placement decision (assigned
        server per arrival, -1 = rejected) at the serving
        watermark."""
        self._push("decision", None,
                   {"server": np.array(servers, copy=True),
                    "watermark": float(watermark)})

    def mark_incident(self, t: float, alarms: int,
                      counters: dict | None = None) -> Incident:
        """Stamp an alarm marker at the current timeline position with
        a copy of whatever counter values the caller passes."""
        inc = Incident(self._seq, float(t), int(alarms),
                       dict(counters or {}))
        self.incidents.append(inc)
        return inc

    # -- reads -------------------------------------------------------------
    def incident_window(self, incident: Incident,
                        context_runs: int = 64) -> list:
        """The up-to-``context_runs`` timeline runs leading up to (and
        including) the incident's sequence position."""
        runs = [r for r in self.timeline if r.seq <= incident.seq]
        return runs[-context_runs:]

    def decisions(self) -> np.ndarray:
        """All recorded placement decisions, concatenated in stream
        order (empty int32 array when none)."""
        parts = [r.payload["server"] for r in self.timeline
                 if r.kind == "decision"]
        if not parts:
            return np.zeros(0, np.int32)
        return np.concatenate(parts)

    def summary(self) -> dict:
        """JSON-ready view: occupancy, per-kind run counts, and the
        incident markers."""
        kinds = {k: 0 for k in KINDS}
        for r in self.timeline:
            kinds[r.kind] += 1
        return {"rows": self.rows, "capacity_rows": self.capacity_rows,
                "runs": len(self.timeline), "by_kind": kinds,
                "dropped_runs": self.dropped_runs,
                "wrapped": self.wrapped,
                "incidents": [
                    {"seq": i.seq, "t": i.t, "alarms": i.alarms,
                     "counters": dict(i.counters)}
                    for i in self.incidents]}


def replay(recorder: FlightRecorder, pipeline) -> np.ndarray:
    """Re-drive ``pipeline`` (a fresh, caller-built pipeline in the
    same configuration — same model, budget, shard count, and
    emergency/adaptive planes) through the recorded stream and return
    the replayed placement decisions in stream order.

    Everything is pushed through host 0 of the public queue API with
    the recorded stamps: the merge already serialized the original
    multi-host stream into watermark order, so a single-host replay
    of that order reproduces the identical merged stream. Raises if
    the recorder wrapped (the stream prefix was evicted) — a partial
    replay would diverge and assert nothing."""
    from ..serve.ingest import CapBatch, DepartureBatch
    from ..sim.telemetry import ArrivalBatch

    if recorder.wrapped:
        raise ValueError(
            f"recorder wrapped ({recorder.dropped_runs} runs "
            "evicted); cannot replay a truncated stream — raise "
            "capacity_rows or snapshot earlier")
    out = []
    for run in recorder.timeline:
        if run.kind == "arrival":
            res = pipeline.submit_to(
                0, ArrivalBatch(**run.payload), t=run.t)
        elif run.kind == "departure":
            d = DepartureBatch(**run.payload)
            res = pipeline.depart_to(
                0, d.server, d.cores, d.p95_eff, d.is_uf,
                t=run.t, mem_gb=d.mem_gb)
        elif run.kind == "capping":
            c = CapBatch(**run.payload)
            res = pipeline.cap_to(0, c.chassis, c.power_w, t=run.t)
        else:                        # decision rows are the *expected*
            continue                 # outputs, not inputs
        out.extend(np.asarray(r.server) for r in res)
    tail = pipeline.flush()
    if tail is not None:
        out.append(np.asarray(tail.server))
    if not out:
        return np.zeros(0, np.int32)
    return np.concatenate(out)


def verify_replay(recorder: FlightRecorder, pipeline) -> np.ndarray:
    """`replay` + assert the replayed decisions match the recorded
    ones bit-for-bit; returns the decisions on success."""
    got = replay(recorder, pipeline)
    want = recorder.decisions()
    if got.shape != want.shape:
        raise AssertionError(
            f"replay decision count {got.shape} != recorded "
            f"{want.shape}")
    if not np.array_equal(got, want):
        bad = np.flatnonzero(got != want)
        raise AssertionError(
            f"replay diverged at {bad.size} / {want.size} decisions "
            f"(first at stream index {bad[0]}: replayed "
            f"{got[bad[0]]}, recorded {want[bad[0]]})")
    return got
