"""Host-side metrics registry (DESIGN.md §14, docs/observability.md).

The serve plane's jitted kernels emit small counter pytrees
(`serve.placement.PlacementCounters`, `serve.placement.SweepCounters`,
the per-shard round counters of `serve.sharding._round_fn`); this
module is where those device scalars — and the host-side stream/sim
counters that ride along — accumulate into something an operator can
scrape. Three metric kinds, mirroring the Prometheus data model the
exporters speak:

  * **Counter** — monotone float accumulator (`inc`); negative
    increments are rejected so a scrape can always be rate()d.
  * **Gauge** — last-write-wins level (`set`), e.g. remaining
    power-pool tokens.
  * **Histogram** — log-bucketed distribution (`observe`): bucket
    upper bounds grow geometrically from `lo` by `base`, so the whole
    span from microseconds to minutes (or watts to megawatts) costs a
    few dozen integer cells, exactly the classic HDR/Prometheus trick.

Metrics are identified by name plus an optional frozen label set
(``registry.counter("serve_rejects_total", reason="capacity")``), one
time series per distinct label value — the same convention both
exporters render. Everything is plain Python + numpy on the host: the
registry is never traced, never enters a jit, and therefore can never
perturb a placement decision (the bit-identity tests assert exactly
that).

Snapshots come in two formats: `MetricsRegistry.snapshot` (a plain
JSON-able dict, the artifact the CI obs smoke job uploads) and
`MetricsRegistry.to_prometheus` (the text exposition format, so a
scrape endpoint is one ``http.server`` handler away).
"""
from __future__ import annotations

import json
import math

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LEVEL_NAMES"]

#: Canonical criticality-level label values, in the emergency plane's
#: apportionment priority order (`serve.emergency.CRIT_NUF` = 0 first)
#: — the one spelling both the sim and serve exporters use, fixing the
#: historical `uf_throttled_s` vs per-level-array naming drift.
LEVEL_NAMES = ("nuf", "uf")


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_labels(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotone accumulator. `inc` rejects negative deltas — a counter
    that can go down cannot be rate()d, use a `Gauge` for levels."""

    kind = "counter"

    def __init__(self, name: str, labels: tuple, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        """Add `v` (>= 0) to the counter."""
        v = float(v)
        if not v >= 0.0:        # also catches NaN
            raise ValueError(
                f"counter {self.name} increment must be >= 0, got {v}")
        self.value += v

    def _sample(self):
        return {"value": self.value}

    def _expose(self) -> list:
        return [f"{self.name}{_render_labels(self.labels)} "
                f"{self.value:g}"]


class Gauge:
    """Last-write-wins level (`set`), with `inc`/`dec` conveniences."""

    kind = "gauge"

    def __init__(self, name: str, labels: tuple, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        """Set the gauge to `v`."""
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        """Add `v` (may be negative) to the gauge."""
        self.value += float(v)

    def dec(self, v: float = 1.0) -> None:
        """Subtract `v` from the gauge."""
        self.value -= float(v)

    def _sample(self):
        return {"value": self.value}

    def _expose(self) -> list:
        return [f"{self.name}{_render_labels(self.labels)} "
                f"{self.value:g}"]


class Histogram:
    """Log-bucketed distribution.

    Bucket upper bounds are ``lo * base**k`` for ``k = 0..n_buckets-1``
    plus a +inf overflow bucket; an observation lands in the first
    bucket whose bound is >= the value (values <= `lo` land in bucket
    0, so `lo` is the resolution floor, not a clamp of the recorded
    `sum`). With the defaults (lo=1e-6, base=2, 64 buckets) one
    histogram spans microseconds to ~2.5 weeks at 2x resolution for
    128 integer cells — the reason the serve path can afford a
    histogram per span kind."""

    kind = "histogram"

    def __init__(self, name: str, labels: tuple, help: str = "",
                 lo: float = 1e-6, base: float = 2.0,
                 n_buckets: int = 64):
        if not (lo > 0 and base > 1):
            raise ValueError("need lo > 0 and base > 1")
        self.name = name
        self.labels = labels
        self.help = help
        self.lo = float(lo)
        self.base = float(base)
        self.bounds = lo * np.power(base, np.arange(n_buckets))
        self.counts = np.zeros(n_buckets + 1, np.int64)  # [+inf overflow]
        self.sum = 0.0
        self.count = 0

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        k = math.ceil(math.log(v / self.lo) / math.log(self.base))
        return min(max(k, 0), len(self.bounds))

    def observe(self, v: float) -> None:
        """Record one observation (negative values clamp to bucket 0;
        the exact value still lands in `sum`)."""
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Approximate q-quantile: the upper bound of the bucket where
        the cumulative count crosses ``q * count`` (NaN when empty).
        Log bucketing bounds the relative error by `base`."""
        if not self.count:
            return float("nan")
        target = q * self.count
        cum = np.cumsum(self.counts)
        k = int(np.searchsorted(cum, target))
        return float(self.bounds[min(k, len(self.bounds) - 1)])

    def _sample(self):
        nz = np.nonzero(self.counts)[0]
        return {"sum": self.sum, "count": self.count,
                "buckets": {
                    ("+inf" if k == len(self.bounds)
                     else f"{self.bounds[k]:.6g}"): int(self.counts[k])
                    for k in nz}}

    def _expose(self) -> list:
        lab = dict(self.labels)
        lines, cum = [], 0
        for k, c in enumerate(self.counts):
            if not c:
                continue
            cum_k = int(self.counts[:k + 1].sum())
            le = "+Inf" if k == len(self.bounds) \
                else f"{self.bounds[k]:.6g}"
            key = _label_key({**lab, "le": le})
            lines.append(f"{self.name}_bucket{_render_labels(key)} "
                         f"{cum_k}")
            cum = cum_k
        if cum != self.count:       # render a closing +Inf bucket
            key = _label_key({**lab, "le": "+Inf"})
            lines.append(f"{self.name}_bucket{_render_labels(key)} "
                         f"{self.count}")
        lines.append(f"{self.name}_sum{_render_labels(self.labels)} "
                     f"{self.sum:g}")
        lines.append(f"{self.name}_count{_render_labels(self.labels)} "
                     f"{self.count}")
        return lines


class MetricsRegistry:
    """Flat namespace of counters/gauges/histograms, one time series
    per (name, label set). Accessors are get-or-create and idempotent,
    so instrumented code never has to pre-declare its metrics; asking
    for an existing name with a different metric kind is an error (the
    exporters could not render it coherently)."""

    def __init__(self):
        self._metrics: dict = {}    # (name, labelkey) -> metric
        self._help: dict = {}       # name -> help string

    def _get(self, cls, name: str, help: str, labels: dict, **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], help=help or self._help.get(name, ""),
                    **kw)
            self._metrics[key] = m
            if help:
                self._help.setdefault(name, help)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        """Get-or-create the counter `name` with the given labels."""
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        """Get-or-create the gauge `name` with the given labels."""
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "", lo: float = 1e-6,
                  base: float = 2.0, n_buckets: int = 64,
                  **labels) -> Histogram:
        """Get-or-create the log-bucketed histogram `name`; `lo`/
        `base`/`n_buckets` set the bucket geometry on first creation
        (ignored on later lookups)."""
        return self._get(Histogram, name, help, labels, lo=lo,
                         base=base, n_buckets=n_buckets)

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge (0.0 when the series does
        not exist — absent and never-incremented read the same, like a
        Prometheus scrape)."""
        m = self._metrics.get((name, _label_key(labels)))
        return 0.0 if m is None else m.value

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able dict of every series: ``name -> [{labels, kind,
        ...samples}]`` — the artifact format `launch.monitor` writes
        and the CI obs smoke job uploads."""
        out: dict = {}
        for (name, labels), m in sorted(self._metrics.items()):
            out.setdefault(name, []).append(
                {"labels": dict(labels), "kind": m.kind, **m._sample()})
        return out

    def to_json(self, indent: int | None = 2) -> str:
        """`snapshot` as a JSON string."""
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one ``# TYPE`` header per
        metric name, histogram bucket series cumulative)."""
        by_name: dict = {}
        for (name, _), m in sorted(self._metrics.items()):
            by_name.setdefault(name, []).append(m)
        lines = []
        for name, series in by_name.items():
            help_ = self._help.get(name, "")
            if help_:
                lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {series[0].kind}")
            for m in series:
                lines.extend(m._expose())
        return "\n".join(lines) + "\n"
