"""Declarative SLOs with multi-window burn-rate alerting
(DESIGN.md §17).

An `SLORule` states a budget in the metric's own units over a period
("at most 60 critical throttled-seconds per day"); the `SLOMonitor`
tracks each rule's cumulative consumption on the ingest watermark
clock and computes the *burn rate* over several trailing windows —
``burn = (consumed in window / budget) * (period / window)``, i.e.
1.0 means "spending exactly the budget". An alert fires only when
EVERY window exceeds its threshold (the SRE multi-window pattern: the
short window proves the problem is current, the long window proves it
is material), and clears the same way.

Consumption has two equivalent feeds: `sample(t, registry)` reads the
cumulative counters the pipelines already export (summing a labeled
family when the rule pins no labels), and `ingest(t, metric, delta)`
accepts deltas directly (the simulator path, which must not touch the
registry counters its end-of-run export owns). Alerts and burn rates
are exported back through the registry (``slo_alerts_total{slo=}``,
``slo_burn_rate{slo=,window=}``, ``slo_alert_active{slo=}``) and
rendered by `launch/monitor.py`.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

__all__ = ["SLORule", "SLOMonitor", "default_slos"]

#: (window_seconds, burn-rate threshold) pairs: the canonical fast/slow
#: multi-window pair — 5 minutes at 14.4x (2% of a day's budget in 5
#: minutes) and 1 hour at 6x.
DEFAULT_WINDOWS = ((300.0, 14.4), (3600.0, 6.0))

DAY_S = 86400.0


@dataclass(frozen=True)
class SLORule:
    """One service-level objective: at most ``budget`` units of
    ``metric`` consumed per ``period_s`` seconds.

    ``labels`` restricts which series of a labeled family count
    (``(("level", "uf"),)``); empty means every series of the name is
    summed. ``windows`` is the multi-window burn-rate ladder —
    ``((window_s, threshold), ...)``; ALL windows must exceed their
    threshold to alert."""
    name: str
    metric: str
    budget: float
    period_s: float = DAY_S
    labels: tuple = ()
    windows: tuple = DEFAULT_WINDOWS
    description: str = ""

    def __post_init__(self):
        if not self.budget > 0:
            raise ValueError(
                f"SLO {self.name!r}: budget must be > 0, got "
                f"{self.budget}")
        if not self.period_s > 0:
            raise ValueError(
                f"SLO {self.name!r}: period_s must be > 0, got "
                f"{self.period_s}")
        if not self.windows:
            raise ValueError(f"SLO {self.name!r}: needs >= 1 window")
        for w, thr in self.windows:
            if not (w > 0 and thr > 0):
                raise ValueError(
                    f"SLO {self.name!r}: window/threshold must be > 0, "
                    f"got ({w}, {thr})")


def default_slos() -> tuple:
    """The serve plane's standing objectives (paper-motivated
    defaults; pass custom rules to `SLOMonitor` to replace them)."""
    return (
        SLORule(
            name="critical_throttle",
            metric="emergency_throttled_seconds_total",
            labels=(("level", "uf"),),
            budget=60.0, period_s=DAY_S,
            description="critical (UF) VMs throttled at most 60 "
            "seconds per day — the paper's Table-4 harm axis"),
        SLORule(
            name="watt_overrun",
            metric="emergency_leftover_watts_total",
            budget=1.0e4, period_s=DAY_S,
            description="demanded watts no frequency floor could "
            "absorb (RAPL backstop engaged) stay under 10 kW-sweeps "
            "per day"),
        SLORule(
            name="alarm_rate",
            metric="emergency_alarms_total",
            budget=200.0, period_s=DAY_S,
            description="power-emergency alarms under 200 per day — "
            "above that the oversubscription ratio is mis-set"),
        SLORule(
            name="reject_rate",
            metric="serve_rejects_total",
            budget=1.0e4, period_s=DAY_S,
            description="admission rejections (all reasons) under "
            "10k per day"),
    )


class _RuleState:
    """Per-rule cumulative samples on the watermark clock."""

    def __init__(self, rule: SLORule):
        self.rule = rule
        span = max(w for w, _ in rule.windows)
        self.span = span
        self.samples: deque = deque()    # (t, cumulative) non-decreasing
        self.cum = 0.0
        self.active = False
        self.alerts = 0

    def push(self, t: float, cum: float) -> None:
        self.cum = max(self.cum, cum)
        self.samples.append((t, self.cum))
        # keep one sample at or before t - span so windows always
        # have an anchor; drop everything older than that
        cutoff = t - self.span
        s = self.samples
        while len(s) >= 2 and s[1][0] <= cutoff:
            s.popleft()

    def burn(self, t: float, window: float) -> float:
        """Burn rate over the trailing ``window`` ending at ``t``."""
        if not self.samples:
            return 0.0
        t0 = t - window
        anchor = None
        for ts, cum in self.samples:
            if ts <= t0:
                anchor = cum
            else:
                break
        if anchor is None:
            # stream younger than the window: burn against the span
            # actually observed (never divide by more than asked)
            anchor = self.samples[0][1]
        delta = self.cum - anchor
        r = self.rule
        return (delta / r.budget) * (r.period_s / window)


class SLOMonitor:
    """Evaluates a rule set against the metric stream and raises/
    clears multi-window burn-rate alerts (see module docstring)."""

    def __init__(self, rules=None, registry=None):
        rules = tuple(rules) if rules is not None else default_slos()
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO rule names: {names}")
        self.rules = rules
        self.registry = registry
        self._state = {r.name: _RuleState(r) for r in rules}
        self.t = -math.inf

    # -- feeds -------------------------------------------------------------
    def ingest(self, t: float, metric: str, delta: float,
               **labels) -> None:
        """Add ``delta`` units of ``metric`` consumption at watermark
        ``t`` (the simulator feed). Labels must cover every label a
        matching rule pins; rules the labels don't match ignore the
        delta."""
        self.t = max(self.t, float(t))
        for st in self._state.values():
            r = st.rule
            if r.metric != metric:
                continue
            if any(labels.get(k) != v for k, v in r.labels):
                continue
            if not st.samples:
                # delta streams start from zero consumption: seed the
                # anchor so the first delta itself counts as burn
                # (sample() deliberately does NOT — counters may hold
                # pre-attach totals that would alert spuriously)
                st.push(self.t, st.cum)
            st.push(self.t, st.cum + float(delta))

    def sample(self, t: float, registry) -> None:
        """Read every rule's cumulative consumption out of the
        registry's counters (the pipeline feed). A rule with pinned
        labels reads that one series; otherwise every series of the
        metric name is summed."""
        self.t = max(self.t, float(t))
        for st in self._state.values():
            r = st.rule
            if r.labels:
                total = registry.value(r.metric, **dict(r.labels))
            else:
                total = 0.0
                for (name, _), m in registry._metrics.items():
                    if name == r.metric:
                        total += float(m.value)
            st.push(self.t, total)

    # -- evaluation --------------------------------------------------------
    def evaluate(self, t: float | None = None) -> list:
        """Evaluate every rule at watermark ``t`` (default: the last
        fed watermark); returns the list of newly raised alert dicts.
        Raising is edge-triggered (``slo_alerts_total`` counts
        transitions); ``slo_alert_active`` tracks the level."""
        if t is not None:
            self.t = max(self.t, float(t))
        raised = []
        for st in self._state.values():
            r = st.rule
            burns = [st.burn(self.t, w) for w, _ in r.windows]
            firing = all(b >= thr for b, (_, thr)
                         in zip(burns, r.windows))
            if self.registry is not None:
                for (w, _), b in zip(r.windows, burns):
                    self.registry.gauge(
                        "slo_burn_rate",
                        help="burn rate (1.0 = spending exactly the "
                        "budget), by SLO and window",
                        slo=r.name, window=f"{w:g}s").set(b)
                self.registry.gauge(
                    "slo_alert_active",
                    help="1 while the SLO's multi-window alert fires",
                    slo=r.name).set(1.0 if firing else 0.0)
            if firing and not st.active:
                st.alerts += 1
                if self.registry is not None:
                    self.registry.counter(
                        "slo_alerts_total",
                        help="multi-window burn-rate alerts raised, "
                        "by SLO", slo=r.name).inc()
                raised.append(self._alert_dict(st, burns))
            st.active = firing
        return raised

    def _alert_dict(self, st: _RuleState, burns) -> dict:
        r = st.rule
        return {"slo": r.name, "t": self.t, "metric": r.metric,
                "burn_rates": {f"{w:g}s": b for (w, _), b
                               in zip(r.windows, burns)},
                "consumed": st.cum, "budget": r.budget,
                "description": r.description}

    def active_alerts(self) -> list:
        """Alert dicts for every rule currently firing."""
        out = []
        for st in self._state.values():
            if st.active:
                burns = [st.burn(self.t, w) for w, _ in st.rule.windows]
                out.append(self._alert_dict(st, burns))
        return out

    def summary(self) -> dict:
        """JSON-ready per-rule view (burn rates, consumption, alert
        state) for the monitor."""
        out = {}
        for st in self._state.values():
            r = st.rule
            out[r.name] = {
                "metric": r.metric, "labels": dict(r.labels),
                "budget": r.budget, "period_s": r.period_s,
                "consumed": st.cum,
                "burn_rates": {f"{w:g}s": st.burn(self.t, w)
                               for w, _ in r.windows},
                "active": st.active, "alerts": st.alerts,
                "description": r.description}
        return out
