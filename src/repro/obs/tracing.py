"""Span tracing for the serve pipeline.

Each pipeline stage (ingest -> merge -> featurize -> infer -> place ->
commit, plus emergency sweeps and migrations) runs under a `Span`
context manager that records wall-clock duration twice: into a
bounded ring (so `launch.monitor` can render the most recent batches
as a timeline) and into a log-bucketed histogram in the
`MetricsRegistry` (``serve_span_seconds{span=...}``, so long-run
latency distributions survive after the ring wraps).

Timings use `time.perf_counter` and happen entirely on the host —
spans wrap the *dispatch* of jitted kernels, not their internals, so
tracing can never perturb a placement decision. For device-level
detail, `SpanTracer.jax_profile` brackets a region with
``jax.profiler.start_trace``/``stop_trace`` (lazily imported; a
no-op context if the profiler is unavailable in the container).
"""
from __future__ import annotations

import contextlib
import time

import numpy as np

from .registry import MetricsRegistry

__all__ = ["Span", "SpanTracer"]

_SPAN_DTYPE = np.dtype([
    ("seq", np.int64),      # monotone span sequence number
    ("name", "U24"),        # span name (truncated to 24 chars)
    ("t0", np.float64),     # perf_counter start
    ("dur", np.float64),    # seconds
])


class Span:
    """One timed region. Use via ``with tracer.span("place"):`` —
    entering stamps the clock, exiting records the duration into the
    tracer's ring and histogram. Re-entrant use of the same tracer is
    fine (spans nest independently)."""

    __slots__ = ("tracer", "name", "t0", "dur")

    def __init__(self, tracer: "SpanTracer", name: str):
        self.tracer = tracer
        self.name = name
        self.t0 = 0.0
        self.dur = float("nan")

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dur = time.perf_counter() - self.t0
        self.tracer._record(self)


class SpanTracer:
    """Bounded span recorder bound to a `MetricsRegistry`.

    The ring holds the most recent `capacity` spans (power-of-two
    sized, mask-indexed); every span additionally feeds
    ``serve_span_seconds{span=<name>}`` in the registry, so aggregate
    latency outlives the ring."""

    def __init__(self, registry: MetricsRegistry,
                 capacity: int = 4096):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.registry = registry
        self.capacity = 1 << (capacity - 1).bit_length()
        self._ring = np.zeros(self.capacity, _SPAN_DTYPE)
        self._next_seq = 0

    def __len__(self) -> int:
        return min(self._next_seq, self.capacity)

    def span(self, name: str) -> Span:
        """Context manager timing one region under `name`."""
        return Span(self, name)

    def _record(self, span: Span) -> None:
        i = self._next_seq & (self.capacity - 1)
        self._ring[i] = (self._next_seq, span.name[:24], span.t0,
                         span.dur)
        self._next_seq += 1
        self.registry.histogram(
            "serve_span_seconds",
            help="wall-clock span durations by pipeline stage",
            span=span.name).observe(span.dur)

    def tail(self, n: int = 64) -> np.ndarray:
        """The most recent `n` spans, oldest first (a copy)."""
        n = min(n, len(self))
        if n == 0:
            return np.zeros(0, _SPAN_DTYPE)
        idx = (self._next_seq - n + np.arange(n)) & (self.capacity - 1)
        return self._ring[idx].copy()

    def totals(self) -> dict:
        """``{span name: (count, total seconds)}`` over the whole run,
        read back from the registry histograms (not just the ring)."""
        out = {}
        for (name, labels), m in self.registry._metrics.items():
            if name == "serve_span_seconds":
                span = dict(labels).get("span", "?")
                out[span] = (m.count, m.sum)
        return out

    @contextlib.contextmanager
    def jax_profile(self, log_dir: str):
        """Bracket a region with ``jax.profiler.start_trace(log_dir)``
        / ``stop_trace`` for device-level timelines (view with
        TensorBoard or Perfetto). Degrades to a no-op if the profiler
        backend is unavailable in this container."""
        try:
            from jax import profiler as _prof
            _prof.start_trace(log_dir)
            started = True
        except Exception:
            started = False
        try:
            yield
        finally:
            if started:
                with contextlib.suppress(Exception):
                    _prof.stop_trace()
