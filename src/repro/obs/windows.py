"""Watermark-aligned windowed aggregation (DESIGN.md §17).

Counters answer "how much, ever"; operations needs "how much,
*lately*". This module adds the time dimension to the obs plane with
three primitives, all host-side and all fed exclusively by values the
jitted kernels already emit as scan-carried *outputs* (never inputs —
the PR 7 bit-identity invariant survives untouched):

  * `FixedHistogram` — streaming fixed-bucket histogram with explicit
    bounds (the registry's log-bucketed histograms cover magnitudes;
    SLO math wants linear buckets over a known range).
  * `TumblingWindow` — non-overlapping buckets aligned to multiples of
    the window width on the *ingest watermark clock* (the merged-stream
    event stamps, not wall time), closed only when the watermark
    passes their end — late events past the watermark are counted,
    never silently folded into a closed window.
  * `RollingWindow` — trailing-width sliding aggregate (sum / rate /
    mean) over the same clock, the burn-rate primitive `obs.slo`
    builds on.

`WindowPlane` bundles named signals of all three behind one
`observe`/`advance` pair and mirrors the trailing aggregates into the
metrics registry as `obs_window_sum{signal=}` /
`obs_window_rate_per_s{signal=}` gauges, so windowed views ride the
same Prometheus/JSON export as everything else.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

__all__ = ["FixedHistogram", "WindowAgg", "TumblingWindow",
           "RollingWindow", "WindowPlane"]


class FixedHistogram:
    """Streaming histogram over ``n_bins`` equal-width buckets spanning
    ``[lo, hi)``, with explicit underflow/overflow counts. O(1) per
    observation, O(n_bins) memory, and a quantile read that never
    needs the raw samples back."""

    def __init__(self, lo: float, hi: float, n_bins: int = 32):
        if not (hi > lo):
            raise ValueError(f"need hi > lo, got [{lo}, {hi})")
        if n_bins < 1:
            raise ValueError(f"n_bins must be >= 1, got {n_bins}")
        self.lo, self.hi, self.n_bins = float(lo), float(hi), int(n_bins)
        self._width = (self.hi - self.lo) / self.n_bins
        self.counts = [0] * self.n_bins
        self.underflow = 0
        self.overflow = 0
        self.total = 0
        self.sum = 0.0

    def observe(self, value: float, n: int = 1) -> None:
        """Fold ``n`` occurrences of ``value`` in (NaN is counted as
        overflow — a poisoned stat should be visible, not dropped)."""
        v = float(value)
        self.total += n
        if math.isnan(v) or v >= self.hi:
            self.overflow += n
            self.sum += 0.0 if math.isnan(v) else v * n
            return
        self.sum += v * n
        if v < self.lo:
            self.underflow += n
            return
        self.counts[int((v - self.lo) / self._width)] += n

    def quantile(self, q: float) -> float:
        """Approximate q-quantile (bucket upper edge; ``lo``/``hi``
        for mass in the under/overflow buckets). NaN when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.total == 0:
            return float("nan")
        rank = q * self.total
        seen = self.underflow
        if rank <= seen and self.underflow:
            return self.lo
        for i, c in enumerate(self.counts):
            seen += c
            if rank <= seen and c:
                return self.lo + (i + 1) * self._width
        return self.hi

    @property
    def mean(self) -> float:
        """Mean of everything observed (NaN when empty)."""
        return self.sum / self.total if self.total else float("nan")

    def snapshot(self) -> dict:
        """JSON-ready view: bounds, counts, and p50/p99 reads."""
        return {"lo": self.lo, "hi": self.hi, "counts": list(self.counts),
                "underflow": self.underflow, "overflow": self.overflow,
                "total": self.total, "sum": self.sum,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}


@dataclass
class WindowAgg:
    """One window's aggregate: [t0, t1) bounds, count/sum/min/max."""
    t0: float
    t1: float
    count: int = 0
    sum: float = 0.0
    vmin: float = math.inf
    vmax: float = -math.inf

    def observe(self, v: float, n: int = 1) -> None:
        """Fold ``n`` occurrences of ``v`` into the aggregate."""
        self.count += n
        self.sum += v * n
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    @property
    def mean(self) -> float:
        """Mean value in the window (NaN when empty)."""
        return self.sum / self.count if self.count else float("nan")

    def as_dict(self) -> dict:
        """JSON-ready view of the aggregate."""
        return {"t0": self.t0, "t1": self.t1, "count": self.count,
                "sum": self.sum, "min": self.vmin, "max": self.vmax}


class TumblingWindow:
    """Non-overlapping aggregation buckets aligned to multiples of
    ``width`` on the watermark clock.

    ``observe(t, v)`` lands in the bucket ``floor(t / width)``;
    ``advance(watermark)`` closes every open bucket whose end is at or
    before the watermark into a bounded history (newest-last,
    ``keep`` deep). Events stamped before the watermark's closed
    frontier bump ``late`` instead of mutating closed windows — the
    merge already promises watermark order, so a late event here is a
    contract violation worth counting, not hiding."""

    def __init__(self, width: float, keep: int = 64):
        if not width > 0:
            raise ValueError(f"width must be > 0, got {width}")
        self.width = float(width)
        self.keep = int(keep)
        self._open: dict = {}            # bucket index -> WindowAgg
        self.closed: deque = deque(maxlen=keep)
        self.watermark = -math.inf
        self.late = 0

    def observe(self, t: float, v: float = 1.0, n: int = 1) -> None:
        """Fold ``n`` occurrences of ``v`` stamped ``t`` in."""
        idx = math.floor(t / self.width)
        if (idx + 1) * self.width <= self.watermark:
            self.late += n
            return
        agg = self._open.get(idx)
        if agg is None:
            agg = self._open[idx] = WindowAgg(
                idx * self.width, (idx + 1) * self.width)
        agg.observe(v, n)

    def advance(self, watermark: float) -> list:
        """Move the watermark forward, closing (and returning) every
        bucket whose end it passed. The watermark never moves back."""
        self.watermark = max(self.watermark, float(watermark))
        done = sorted(i for i in self._open
                      if (i + 1) * self.width <= self.watermark)
        out = [self._open.pop(i) for i in done]
        self.closed.extend(out)
        return out

    @property
    def last(self) -> WindowAgg | None:
        """Most recently closed window (None before the first close)."""
        return self.closed[-1] if self.closed else None


class RollingWindow:
    """Sliding trailing-``width`` aggregate over (t, value) samples:
    O(1) amortized observe, exact trailing sum/count, and a per-second
    rate — the multi-window burn-rate primitive."""

    def __init__(self, width: float):
        if not width > 0:
            raise ValueError(f"width must be > 0, got {width}")
        self.width = float(width)
        self._q: deque = deque()        # (t, v) in stamp order
        self._sum = 0.0
        self.t = -math.inf

    def observe(self, t: float, v: float = 1.0) -> None:
        """Fold one sample in and evict everything older than
        ``t - width``."""
        self._q.append((float(t), float(v)))
        self._sum += float(v)
        self.advance(t)

    def advance(self, t: float) -> None:
        """Move the clock forward (evicting expired samples) without
        adding a sample."""
        self.t = max(self.t, float(t))
        cutoff = self.t - self.width
        q = self._q
        while q and q[0][0] <= cutoff:
            self._sum -= q.popleft()[1]

    @property
    def sum(self) -> float:
        """Sum of values in the trailing window."""
        return self._sum

    @property
    def count(self) -> int:
        """Number of samples in the trailing window."""
        return len(self._q)

    @property
    def rate(self) -> float:
        """Trailing per-second rate (``sum / width``)."""
        return self._sum / self.width


class WindowPlane:
    """Named-signal front door over the window primitives.

    ``observe(t, name, v)`` lazily creates one tumbling + one rolling
    window per signal and feeds both; ``advance(watermark)`` closes
    tumbling buckets everywhere and mirrors each signal's trailing
    aggregates into the registry (``obs_window_sum{signal=}`` /
    ``obs_window_rate_per_s{signal=}`` gauges).
    ``observe_hist(name, v, ...)`` maintains fixed-bucket value
    histograms beside the time windows."""

    def __init__(self, registry=None, width: float = 60.0,
                 rolling: float = 300.0, keep: int = 64):
        if not (width > 0 and rolling > 0):
            raise ValueError(
                f"width and rolling must be > 0, got {width}, {rolling}")
        self.registry = registry
        self.width = float(width)
        self.rolling = float(rolling)
        self.keep = int(keep)
        self.signals: dict = {}          # name -> (Tumbling, Rolling)
        self.hists: dict = {}            # name -> FixedHistogram
        self.watermark = -math.inf

    def _signal(self, name: str):
        pair = self.signals.get(name)
        if pair is None:
            pair = self.signals[name] = (
                TumblingWindow(self.width, self.keep),
                RollingWindow(self.rolling))
        return pair

    def observe(self, t: float, name: str, v: float = 1.0,
                n: int = 1) -> None:
        """Fold ``n`` occurrences of ``v`` stamped ``t`` into signal
        ``name`` (created lazily on first use)."""
        tum, rol = self._signal(name)
        tum.observe(t, v, n)
        for _ in range(n):
            rol.observe(t, v)

    def observe_hist(self, name: str, value: float, lo: float = 0.0,
                     hi: float = 1.0, n_bins: int = 32) -> None:
        """Fold ``value`` into the fixed-bucket histogram ``name``
        (bounds fix at first call; later bounds are ignored)."""
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = FixedHistogram(lo, hi, n_bins)
        h.observe(value)

    def advance(self, watermark: float) -> None:
        """Advance every signal to the new watermark and export the
        trailing aggregates as registry gauges."""
        self.watermark = max(self.watermark, float(watermark))
        for name, (tum, rol) in self.signals.items():
            tum.advance(self.watermark)
            rol.advance(self.watermark)
            if self.registry is not None:
                self.registry.gauge(
                    "obs_window_sum",
                    help="trailing-window sum, by signal",
                    signal=name).set(rol.sum)
                self.registry.gauge(
                    "obs_window_rate_per_s",
                    help="trailing-window per-second rate, by signal",
                    signal=name).set(rol.rate)

    def summary(self) -> dict:
        """JSON-ready view: per-signal trailing aggregates, last
        closed tumbling window, late counts, and histograms."""
        out: dict = {"watermark": self.watermark, "signals": {},
                     "histograms": {}}
        for name, (tum, rol) in sorted(self.signals.items()):
            last = tum.last
            out["signals"][name] = {
                "rolling_sum": rol.sum, "rolling_count": rol.count,
                "rate_per_s": rol.rate, "late": tum.late,
                "closed_windows": len(tum.closed),
                "last_window": None if last is None else last.as_dict()}
        for name, h in sorted(self.hists.items()):
            out["histograms"][name] = h.snapshot()
        return out
