"""Adafactor (factored second moments, no first moment by default).

Used for arctic-480b and qwen2-vl-72b: fp32 Adam moments for 468B
parameters (3.7 TiB) exceed the single-pod HBM budget even fully sharded;
Adafactor's row/column statistics are O(d_in + d_out) per matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer, clip_by_global_norm


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        def stat(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(stat, params,
                                      is_leaf=lambda x: hasattr(x, "ndim")),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd_dense(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if p.ndim >= 2:
                vr = beta * st["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * st["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(
                             vr.mean(-1)[..., None, None], eps))
                step = g32 * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                step = g32 * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            p_new = p.astype(jnp.float32) - lr * (
                step + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), new_st

        # NOTE: we tried scanning the update over the stacked layer dim
        # to bound the f32 transients on the giant expert-stack leaves;
        # measured +1 GiB on arctic train (scan output stacking beats
        # XLA's own leaf-by-leaf scheduling) — refuted, reverted.
        # EXPERIMENTS.md §Perf iteration log.
        upd = upd_dense

        # stats carry a dict per parameter leaf, so flatten relative to
        # the grads treedef and map manually.
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state["stats"])
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        params_new = jax.tree_util.tree_unflatten(
            treedef, [o[0] for o in outs])
        stats_new = jax.tree_util.tree_unflatten(
            treedef, [o[1] for o in outs])
        return params_new, {"stats": stats_new, "count": count}, gnorm

    return Optimizer(init=init, update=update)
