"""AdamW, built from scratch (no optax): fp32 moments, decoupled weight
decay, global-norm clipping. Moments inherit the parameter shardings, so
under fsdp2d the optimizer state is sharded 256/512-way."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable                 # (grads, state, params, lr) -> ...


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0,
          moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        count = state["count"] + 1
        cf = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** cf
        bc2 = 1.0 - b2 ** cf

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return (p_new.astype(p.dtype), m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        params_new = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return params_new, {"m": m_new, "v": v_new, "count": count}, gnorm

    return Optimizer(init=init, update=update)
