"""int8 gradient compression with error feedback (distributed-
optimization trick for cross-pod gradient reduction).

Per-tensor symmetric quantization to int8 before the (pod-axis)
all-reduce, dequantization after; the quantization residual is carried
in an error-feedback buffer so the compression is unbiased over time.

`compress_decompress` is the stateless variant used inside jit (models
the precision loss; XLA still all-reduces the dequantized values —
on real hardware the int8 reduction halves cross-pod DCN bytes 4x).
`make_error_feedback` provides the stateful production form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant(g):
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(grads):
    def f(g):
        q, s = _quant(g)
        return _dequant(q, s).astype(g.dtype)
    return jax.tree.map(f, grads)


def make_error_feedback():
    """Returns (init, apply): apply(grads, err) -> (compressed, new_err)
    with error feedback: e' = g + e - Q(g + e)."""
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(grads, err):
        def f(g, e):
            corrected = g.astype(jnp.float32) + e
            q, s = _quant(corrected)
            deq = _dequant(q, s)
            return deq.astype(g.dtype), corrected - deq
        out = jax.tree.map(f, grads, err)
        comp = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return comp, new_err

    return init, apply
