"""Elastic scaling: resume the same logical run on a different mesh.

Checkpoints store gathered (unsharded) arrays (checkpoint/checkpointer),
so scale-up/scale-down is: build the new mesh, derive new shardings from
the same Strategy, and restore with placement. The batch schedule is
step-indexed and stateless (data/pipeline), so data order is preserved
regardless of the data-parallel width.
"""
from __future__ import annotations

import jax

from repro.launch import sharding as shd


def reshard_plan(strategy_name: str, old_mesh, new_mesh, params_shape):
    """Shardings before/after an elastic event, for audit/logging."""
    old = shd.param_shardings(
        shd.make_strategy(strategy_name, old_mesh), old_mesh,
        params_shape)
    new = shd.param_shardings(
        shd.make_strategy(strategy_name, new_mesh), new_mesh,
        params_shape)
    return old, new


def elastic_restore(checkpointer, tree_like, strategy_name, new_mesh):
    """Restore the newest checkpoint onto `new_mesh` (different device
    count/topology than at save time)."""
    strat = shd.make_strategy(strategy_name, new_mesh)
    shardings = shd.param_shardings(strat, new_mesh, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree_like))
    return checkpointer.restore(tree_like, shardings=shardings)
