"""Fault-tolerant training runtime: checkpoint/restart, failure
injection, straggler mitigation, elastic scaling hooks.

At 1000+ nodes, SOME node is always failing; the loop is structured so
that every failure mode maps to 'restore newest committed checkpoint and
continue', and slow steps (stragglers) are detected against a rolling
deadline and surfaced to the power controller (the paper's capping can
CAUSE deliberate stragglers on non-critical jobs — the runtime must not
confuse throttling with failure; see power_control.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint import Checkpointer


@dataclass
class FaultToleranceConfig:
    """Knobs for `FaultTolerantLoop`: checkpoint cadence/retention,
    straggler detection, and the chaos-injection channel."""
    checkpoint_every: int = 50
    keep_last: int = 3
    #: a step slower than median * this factor counts as a straggler
    straggler_factor: float = 3.0
    #: consecutive straggler steps before mitigation kicks in
    straggler_patience: int = 5
    #: probability per step of an injected failure (tests/chaos)
    inject_failure_rate: float = 0.0
    max_restarts: int = 100


class InjectedFailure(RuntimeError):
    """The chaos channel: the ONLY exception the loop retries.

    Raised by the loop itself (`inject_failure_rate`) or by a test's
    step_fn to stand in for a node crash; any other exception is a
    real defect and propagates (tests/test_fault_tolerance.py)."""


@dataclass
class RunState:
    """Mutable run bookkeeping: current step, restart/mitigation
    counters, and the trailing step-time window the straggler
    deadline is computed from."""
    step: int = 0
    restarts: int = 0
    straggler_steps: int = 0
    mitigations: int = 0
    step_times: list = field(default_factory=list)

    def median_step_time(self) -> float:
        if not self.step_times:
            return float("inf")
        return float(np.median(self.step_times[-50:]))


class FaultTolerantLoop:
    """Drives (state, batch) -> state steps with checkpoint/restart.

    The caller provides pure functions; the loop owns persistence and
    failure handling so a node crash (or injected failure) resumes from
    the newest committed step — including after elastic re-shard.
    """

    def __init__(self, cfg: FaultToleranceConfig, checkpointer:
                 Checkpointer, rng_seed: int = 0):
        self.cfg = cfg
        self.ckpt = checkpointer
        self.state = RunState()
        self._rng = np.random.default_rng(rng_seed)
        self.on_straggler = None          # callback(state) -> None

    def resume_or_init(self, init_fn, tree_like=None, shardings=None):
        """Returns (train_state, start_step)."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return init_fn(), 0
        tree = tree_like if tree_like is not None else init_fn()
        restored, step = self.ckpt.restore(tree, shardings=shardings)
        return restored, step

    def run(self, train_state, step_fn, batch_fn, n_steps: int,
            start_step: int = 0):
        """step_fn(train_state, batch) -> (train_state, metrics).

        `InjectedFailure` (the chaos channel, raised by the loop
        itself or by step_fn) triggers restore-and-continue up to
        max_restarts: rewind to the newest committed checkpoint, or
        to the pre-loop snapshot if nothing committed yet. Any OTHER
        exception from step_fn/batch_fn propagates to the caller
        unchanged — a real defect must fail the job loudly, not spin
        the restore loop (pinned in tests/test_fault_tolerance.py)."""
        step = start_step
        history = []
        # snapshot for failures before the first checkpoint commits
        initial_state = jax.tree.map(lambda x: x, train_state)
        while step < n_steps:
            try:
                t0 = time.time()
                if (self.cfg.inject_failure_rate > 0 and
                        self._rng.random() < self.cfg.inject_failure_rate):
                    raise InjectedFailure(f"injected at step {step}")
                batch = batch_fn(step)
                train_state, metrics = step_fn(train_state, batch)
                dt = time.time() - t0
                self._track_straggler(dt)
                self.state.step_times.append(dt)
                self.state.step = step
                history.append(metrics)
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, train_state)
            except InjectedFailure:
                self.state.restarts += 1
                if self.state.restarts > self.cfg.max_restarts:
                    raise
                latest = self.ckpt.latest_step()
                if latest is not None:
                    train_state, step = self.ckpt.restore(train_state)
                else:
                    # failed before any commit: rewind to the snapshot
                    train_state = jax.tree.map(lambda x: x,
                                               initial_state)
                    step = start_step
        return train_state, history

    def _track_straggler(self, dt: float):
        med = self.state.median_step_time()
        if med != float("inf") and dt > self.cfg.straggler_factor * med:
            self.state.straggler_steps += 1
            if self.state.straggler_steps >= self.cfg.straggler_patience:
                self.state.mitigations += 1
                self.state.straggler_steps = 0
                if self.on_straggler is not None:
                    self.on_straggler(self.state)
        else:
            self.state.straggler_steps = 0
