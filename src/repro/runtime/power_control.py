"""Power-control integration: the paper's per-VM capping controller
governing training/serving jobs (the 'VMs' of this framework).

Each job registers with a ChassisPowerSim carrying its predicted
criticality tag (from core.predictor) and utilization. The sim:

  * reports job power to the chassis model (core.power_model) from the
    measured step-time duty cycle;
  * applies frequency caps from the per-VM controller when the chassis
    manager raises an alert;
  * maps the DVFS frequency to a throughput multiplier: the training
    loop sleeps (1/f - 1) x step_time, exactly how a p-state cap
    manifests to a compute-bound job.

Criticality-aware semantics from the paper: user-facing (serving) jobs
are in the high-priority core group and are never throttled by the
in-band path; batch (training) jobs absorb the frequency cuts; RAPL
remains the hardware backstop.

This is the jnp twin the capping docstring promises: the control step
is the SAME `repro.core.fleet_dynamics.fleet_step` the simulators scan,
jit-compiled here (one server, jnp path) so the control plane runs
compiled alongside the training loop. `backend='numpy'` keeps the
oracle path for environments without jax.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.capping import ChassisManager, ServerCapState
from repro.core.fleet_dynamics import (ALERT_FRACTION, ALERT_MARGIN_W,
                                       ControlParams, FleetState,
                                       RunParams, fleet_step)
from repro.core.power_model import F_MAX, N_PSTATES, ServerPowerModel


@dataclass
class JobSpec:
    name: str
    cores: int
    user_facing: bool                  # prediction from core.predictor
    p95_util: float                    # predicted bucket midpoint


@functools.lru_cache(maxsize=None)
def _jit_step(cp: ControlParams):
    """Compiled one-chassis control step (cached per static config)."""
    import jax
    import jax.numpy as jnp
    return jax.jit(lambda rp, st, util: fleet_step(cp, rp, st, util, jnp))


@dataclass
class ChassisPowerSim:
    """One simulated chassis hosting framework jobs on its servers."""
    budget_w: float
    model: ServerPowerModel = field(default_factory=ServerPowerModel)
    jobs: list = field(default_factory=list)
    backend: str = "jax"

    def __post_init__(self):
        self.state = None
        self.manager = ChassisManager(self.budget_w)
        # the framework integration trips RAPL exactly at the budget and
        # does not keep polling it through the restore phase (the seed's
        # semantics), unlike the chassis simulator
        self._cp = ControlParams.from_model(
            self.model, mode="per_vm", psu_trip_margin_w=0.0,
            rapl_continuation=False)
        self._rp = None

    def register(self, job: JobSpec):
        self.jobs.append(job)
        n_cores = sum(j.cores for j in self.jobs)
        uf_mask = np.concatenate([
            np.full(j.cores, j.user_facing) for j in self.jobs])
        self.state = ServerCapState(n_cores, uf_mask)
        self._rp = RunParams(
            server_budget_w=np.float32(self.budget_w),
            target_w=np.float32(self.budget_w - ALERT_MARGIN_W),
            alert_w=np.float32(self.budget_w * ALERT_FRACTION),
            min_pstate=np.int32(N_PSTATES - 1),
            uf_mask=np.asarray(uf_mask, bool).reshape(1, -1),
            active=None)

    def job_slice(self, name: str) -> slice:
        start = 0
        for j in self.jobs:
            if j.name == name:
                return slice(start, start + j.cores)
            start += j.cores
        raise KeyError(name)

    def step(self, utils: np.ndarray) -> dict:
        """One 200 ms control step; utils = per-core utilization."""
        util = np.asarray(utils, np.float32).reshape(1, -1)
        st = self.state._pack()
        if self.backend == "jax":
            st2, outs = _jit_step(self._cp)(self._rp, st, util)
        else:
            st2, outs = fleet_step(self._cp, self._rp, st, util, np)
        self.state._unpack(FleetState(*(np.asarray(x) for x in st2)))
        return {"power_w": float(outs.chassis_power_w),
                "alert": bool(outs.alert),
                "freq": self.state.freq.copy()}

    def job_frequency(self, name: str) -> float:
        return float(self.state.freq[self.job_slice(name)].mean())


class ThrottledLoop:
    """Wraps a training step with the DVFS-cap duty cycle: at frequency
    f the job runs at f x nominal throughput, i.e. each step stretches
    by 1/f. (On real hardware the p-state does this in silicon; here we
    make the effect visible to wall-clock metrics.)"""

    def __init__(self, chassis: ChassisPowerSim, job: str,
                 utilization: float = 1.0):
        self.chassis = chassis
        self.job = job
        self.utilization = utilization

    def run_step(self, fn, *args):
        t0 = time.time()
        out = fn(*args)
        dt = time.time() - t0
        utils = np.zeros(self.chassis.state.n_cores)
        for j in self.chassis.jobs:
            utils[self.chassis.job_slice(j.name)] = \
                self.utilization if j.name == self.job else j.p95_util
        self.chassis.step(utils)
        f = self.chassis.job_frequency(self.job)
        if f < F_MAX:
            time.sleep(dt * (F_MAX / f - 1.0))
        return out, {"freq": f, "step_s": dt}
