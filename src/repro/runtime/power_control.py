"""Power-control integration: the paper's per-VM capping controller
governing training/serving jobs (the 'VMs' of this framework).

Each job registers with a JobPowerAgent carrying its predicted
criticality tag (from core.predictor) and utilization. The agent:

  * reports job power to the chassis model (core.power_model) from the
    measured step-time duty cycle;
  * receives frequency caps from the per-VM controller (core.capping)
    when the chassis manager raises an alert;
  * maps the DVFS frequency to a throughput multiplier: the training
    loop sleeps (1/f - 1) x step_time, exactly how a p-state cap
    manifests to a compute-bound job.

Criticality-aware semantics from the paper: user-facing (serving) jobs
are in the high-priority core group and are never throttled by the
in-band path; batch (training) jobs absorb the frequency cuts; RAPL
remains the hardware backstop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.capping import (ChassisManager, PerVMController,
                                RaplController, ServerCapState)
from repro.core.power_model import F_MAX, ServerPowerModel


@dataclass
class JobSpec:
    name: str
    cores: int
    user_facing: bool                  # prediction from core.predictor
    p95_util: float                    # predicted bucket midpoint


@dataclass
class ChassisPowerSim:
    """One simulated chassis hosting framework jobs on its servers."""
    budget_w: float
    model: ServerPowerModel = field(default_factory=ServerPowerModel)
    jobs: list = field(default_factory=list)

    def __post_init__(self):
        self.state = None
        self.controller = None
        self.rapl = None
        self.manager = ChassisManager(self.budget_w)

    def register(self, job: JobSpec):
        self.jobs.append(job)
        n_cores = sum(j.cores for j in self.jobs)
        uf_mask = np.concatenate([
            np.full(j.cores, j.user_facing) for j in self.jobs])
        self.state = ServerCapState(n_cores, uf_mask)
        self.controller = PerVMController(self.model, self.budget_w)
        self.rapl = RaplController(self.model, self.budget_w)

    def job_slice(self, name: str) -> slice:
        start = 0
        for j in self.jobs:
            if j.name == name:
                return slice(start, start + j.cores)
            start += j.cores
        raise KeyError(name)

    def step(self, utils: np.ndarray) -> dict:
        """One 200 ms control step; utils = per-core utilization."""
        power = self.model.power(utils, self.state.freq)
        alert = self.manager.poll(power)
        p = self.controller.step(self.state, utils, alert)
        if p > self.controller.budget:
            p = self.rapl.step(self.state, utils)
        return {"power_w": p, "alert": alert,
                "freq": self.state.freq.copy()}

    def job_frequency(self, name: str) -> float:
        return float(self.state.freq[self.job_slice(name)].mean())


class ThrottledLoop:
    """Wraps a training step with the DVFS-cap duty cycle: at frequency
    f the job runs at f x nominal throughput, i.e. each step stretches
    by 1/f. (On real hardware the p-state does this in silicon; here we
    make the effect visible to wall-clock metrics.)"""

    def __init__(self, chassis: ChassisPowerSim, job: str,
                 utilization: float = 1.0):
        self.chassis = chassis
        self.job = job
        self.utilization = utilization

    def run_step(self, fn, *args):
        t0 = time.time()
        out = fn(*args)
        dt = time.time() - t0
        utils = np.zeros(self.chassis.state.n_cores)
        for j in self.chassis.jobs:
            utils[self.chassis.job_slice(j.name)] = \
                self.utilization if j.name == self.job else j.p95_util
        self.chassis.step(utils)
        f = self.chassis.job_frequency(self.job)
        if f < F_MAX:
            time.sleep(dt * (F_MAX / f - 1.0))
        return out, {"freq": f, "step_s": dt}
