"""Online prediction-and-admission serving pipeline (DESIGN.md §9).

Device-resident Resource-Central path from arrival stream to placement
decision: micro-batched featurization, batched two-stage forest
inference with confidence gating, vectorized Algorithm-1 scoring, and
power-headroom admission — one compiled flow per micro-batch, with
double-buffered model hot-swap for the paper's daily retrain."""
from repro.serve.adaptive import (AdaptiveConfig, AdaptiveOutputs,
                                  AdaptiveState, REASON_NAMES,
                                  adaptive_step, decision_reason,
                                  init_adaptive, offered_power,
                                  retarget_pool)
from repro.core.resources import (RESOURCES, ResourceVector,
                                  demand_vector, trough_ratios)
from repro.serve.admission import (
    headroom_w, projected_chassis_power, resource_caps_from_budget,
    rho_cap_from_budget)
from repro.serve.ballooning import (BalloonOutputs, BalloonState,
                                    BallooningConfig, balloon_demand_w,
                                    balloon_step, init_ballooning,
                                    total_ballooned_gb)
from repro.serve.emergency import (CRIT_NUF, CRIT_UF, N_LEVELS,
                                   EmergencyConfig, EmergencyOutputs,
                                   EmergencyState, chassis_rho_levels,
                                   emergency_step, init_emergency,
                                   masked_step, mitigation_due,
                                   reset_dwell, sampled_power,
                                   scatter_samples, throttled_by_level,
                                   util_from_power)
from repro.serve.featurizer import (
    SubscriptionTable, empty_table, featurize, featurize_batch,
    ingest_population, shard_table, table_from_history, update_table)
from repro.serve.inference import (
    PackedService, ServiceMeta, bucket_to_p95_jnp, pack_service,
    resolve_kernel, served_query)
from repro.serve.ingest import (
    ARRIVAL, CAPPING, DEPARTURE, CapBatch, DepartureBatch, HostQueue,
    IngestMux, MergedEvents, empty_arrivals, empty_caps, empty_departures,
    kway_merge, slice_soa)
from repro.serve.mitigation import (LiveVMs, MigrationPlan, plan_migrations)
from repro.serve.pipeline import (
    PlaneBundle, ServeConfig, ServePipeline, ServeResult,
    ShardedServeConfig, ShardedServePipeline)
from repro.serve.placement import (FAIL_CAPACITY, FAIL_POWER,
                                   FAIL_TOKENS, DeviceClusterState,
                                   SweepCounters, device_state,
                                   fresh_state, outcome_counters,
                                   place_batch, place_batch_caps,
                                   place_batch_pooled, remove_batch,
                                   score_chassis_batch,
                                   score_server_batch)
from repro.serve.sharding import (SHARD_AXIS, ShardedState,
                                  apply_adaptive_sharded,
                                  apply_caps_ballooned_sharded,
                                  apply_caps_sharded, chassis_to_shard,
                                  consume_departures,
                                  device_put_sharded_state,
                                  init_adaptive_sharded,
                                  init_ballooning_sharded,
                                  init_emergency_sharded,
                                  place_group_sharded, remove_sharded,
                                  resource_pool_from_budget,
                                  rho_pool_from_budget, route_shard,
                                  shard_mesh, shard_state, split_caps,
                                  split_departures, unshard_state)

__all__ = [
    "SubscriptionTable", "empty_table", "featurize", "featurize_batch",
    "ingest_population", "shard_table", "table_from_history",
    "update_table",
    "PackedService", "ServiceMeta", "pack_service", "served_query",
    "bucket_to_p95_jnp", "resolve_kernel",
    "ARRIVAL", "DEPARTURE", "CAPPING", "CapBatch", "DepartureBatch",
    "HostQueue", "IngestMux", "MergedEvents", "empty_arrivals",
    "empty_caps", "empty_departures", "kway_merge", "slice_soa",
    "CRIT_NUF", "CRIT_UF", "N_LEVELS", "EmergencyConfig",
    "EmergencyOutputs", "EmergencyState", "chassis_rho_levels",
    "emergency_step", "init_emergency", "masked_step",
    "mitigation_due", "reset_dwell", "sampled_power",
    "scatter_samples", "throttled_by_level", "util_from_power",
    "LiveVMs", "MigrationPlan", "plan_migrations",
    "DeviceClusterState", "SweepCounters", "device_state", "fresh_state",
    "outcome_counters", "place_batch", "place_batch_caps",
    "place_batch_pooled", "remove_batch", "score_chassis_batch",
    "score_server_batch",
    "FAIL_CAPACITY", "FAIL_POWER", "FAIL_TOKENS",
    "RESOURCES", "ResourceVector", "demand_vector", "trough_ratios",
    "rho_cap_from_budget", "resource_caps_from_budget",
    "projected_chassis_power", "headroom_w",
    "BallooningConfig", "BalloonOutputs", "BalloonState",
    "balloon_demand_w", "balloon_step", "init_ballooning",
    "total_ballooned_gb",
    "PlaneBundle", "ServeConfig", "ServePipeline", "ServeResult",
    "ShardedServeConfig", "ShardedServePipeline",
    "SHARD_AXIS", "ShardedState", "apply_caps_sharded",
    "apply_caps_ballooned_sharded",
    "apply_adaptive_sharded", "chassis_to_shard", "consume_departures",
    "device_put_sharded_state", "init_adaptive_sharded",
    "init_ballooning_sharded", "init_emergency_sharded",
    "place_group_sharded", "remove_sharded",
    "resource_pool_from_budget", "rho_pool_from_budget",
    "route_shard", "shard_mesh", "shard_state", "split_caps",
    "split_departures", "unshard_state",
    "AdaptiveConfig", "AdaptiveOutputs", "AdaptiveState",
    "REASON_NAMES", "adaptive_step", "decision_reason",
    "init_adaptive", "offered_power", "retarget_pool",
]
