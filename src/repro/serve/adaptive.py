"""Closed-loop adaptive oversubscription (DESIGN.md §15, docs/adaptive.md).

The paper picks its oversubscription ratio *offline* from historical
utilization percentiles (§IV, Table 4); this module closes the loop
online. Every chassis power sample flowing through the ingest stream
(the CAPPING event kind of `repro.serve.ingest`) also lands in a
rolling per-chassis utilization window, and a vectorized *stability
assesser* scores every window in-scan:

  * **percentile spread** — the distance between a low and a high
    percentile of the window (ScroogeVM's percentile assesser): a
    tight band means the chassis' draw is predictable;
  * **sign-change rate** — the fraction of consecutive utilization
    deltas that reverse direction (a GMR-style oscillation score):
    few reversals mean the window is trending, not thrashing.

A chassis whose window is long enough (``min_history``), whose spread
and flip-rate are under their thresholds, and whose *latest* sample is
below the ``hot_util`` level is **stable**. The fleet-level controller
is then ScroogeVM's ratchet-up/back-off-fast rule:

  * when the stable fraction of known chassis reaches
    ``ratchet_quorum`` and nothing is hot, the oversubscription ratio
    creeps up by ``step_up``;
  * when any chassis runs hot or the stable fraction drops below
    ``backoff_quorum``, the ratio collapses by ``step_down`` (several
    times the up-step);
  * otherwise it holds. The ratio is clamped to
    ``[ratio_min, ratio_max]`` and **starts at 1.0 — no history, no
    oversubscription**.

The ratio widens or shrinks the effective watt budget between batches:
it scales the per-chassis admission ceiling (`ServePipeline.rho_cap`)
and, sharded, retargets the free `rho_pool` token allowance
(`retarget_pool`). Tokens already committed to placed VMs are **never
revoked** — a shrink only drains the free pool (floored at zero), so
the reserve/commit conservation invariants of DESIGN.md §10 hold
unchanged; the emergency plane (`serve.emergency`) remains the safety
net for commitment the controller can no longer cover.

Everything is branchless, fixed-shape, and xp-generic with leading
batch dims (the sharded plane carries a leading shard axis): the
numpy call is the oracle, and the sim backends
(`sim.scheduler_sim.simulate` with ``SimSpec(adaptive=...)``) assert
the compiled
jnp twin bit-identical on every scan. Controller decisions export
through the observability plane (`adaptive_ratio` gauge,
`adaptive_backoff_total` counter, `obs.audit.AdaptiveTrail` reason
rows).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

from repro.core.power_model import F_MAX, ServerPowerModel, idle_power

__all__ = [
    "AdaptiveConfig", "AdaptiveState", "AdaptiveOutputs",
    "init_adaptive", "adaptive_step", "offered_power",
    "retarget_pool", "gate_ratio_on_stale", "decision_reason",
    "REASON_NAMES",
]

#: Human names of the controller decision reasons recorded into the
#: `obs.audit.AdaptiveTrail` ring (`decision_reason` computes them).
REASON_NAMES = (
    "hold_no_history",      # 0: no chassis has enough window yet
    "hold_band",            # 1: stable frac between the quorums
    "ratchet_quorum",       # 2: stable quorum met -> step up
    "ratchet_ceiling",      # 3: quorum met but ratio pinned at max
    "backoff_hot",          # 4: a chassis ran hot -> step down fast
    "backoff_quorum",       # 5: stable frac under the floor quorum
    "backoff_floor",        # 6: back-off demanded but ratio at min
)


@dataclass(frozen=True)
class AdaptiveConfig:
    """Static (hashable) knobs of the adaptive-ratio controller — safe
    as a jit static argument, like `serve.emergency.EmergencyConfig`.

    The stability thresholds follow ScroogeVM's shape: a window is
    stable when its ``[spread_q_lo, spread_q_hi]`` percentile spread is
    at most ``spread_thresh`` *and* its sign-change rate is at most
    ``flip_thresh`` *and* its latest sample is at or below
    ``hot_util``. ``step_down`` should be several times ``step_up``
    (ratchet up, back off fast). The power-model fields convert CAPPING
    power samples back into utilization exactly like
    `serve.emergency.util_from_power`."""
    window: int = 16
    min_history: int = 4
    spread_q_lo: float = 0.1
    spread_q_hi: float = 0.9
    spread_thresh: float = 0.25
    flip_thresh: float = 0.6
    hot_util: float = 0.85
    ratchet_quorum: float = 0.9
    backoff_quorum: float = 0.5
    step_up: float = 0.05
    step_down: float = 0.25
    ratio_min: float = 1.0
    ratio_max: float = 2.0
    blades_per_chassis: int = 12
    p_dyn_per_core: float = ServerPowerModel().p_dyn_per_core
    idle_w_per_server: float = float(idle_power(F_MAX))
    #: when True, the pipelines clamp the *applied* ratio to
    #: ``ratio_min`` while the prediction scorecard reports
    #: ``model_stale`` (`obs.quality`) — the controller state keeps
    #: integrating, so the ratio resumes the moment the model is
    #: fresh again. Host-side gate; off by default to preserve the
    #: obs on/off bit-identity invariant.
    hold_on_stale: bool = False

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"window must be >= 2, got {self.window}")
        if not 1 <= self.min_history <= self.window:
            raise ValueError(
                f"min_history must be in [1, window={self.window}], "
                f"got {self.min_history}")
        if not 0 <= self.spread_q_lo < self.spread_q_hi <= 1:
            raise ValueError(
                f"need 0 <= spread_q_lo < spread_q_hi <= 1, got "
                f"({self.spread_q_lo}, {self.spread_q_hi})")
        if not self.backoff_quorum <= self.ratchet_quorum:
            raise ValueError(
                f"backoff_quorum {self.backoff_quorum} must not exceed "
                f"ratchet_quorum {self.ratchet_quorum} (the hold band "
                "between them is what damps oscillation)")
        if not 0 < self.ratio_min <= self.ratio_max:
            raise ValueError(
                f"need 0 < ratio_min <= ratio_max, got "
                f"({self.ratio_min}, {self.ratio_max})")
        if self.step_up <= 0 or self.step_down <= 0:
            raise ValueError("step_up and step_down must be positive")

    @property
    def static_w(self) -> float:
        """Frequency-independent chassis floor (watts): every blade's
        idle draw — the intercept subtracted before a power sample is
        read back as utilization."""
        return self.blades_per_chassis * self.idle_w_per_server

    @classmethod
    def from_model(cls, model: ServerPowerModel | None = None,
                   **kw) -> "AdaptiveConfig":
        """Build a config calibrated to a `ServerPowerModel`."""
        model = model or ServerPowerModel()
        return cls(p_dyn_per_core=model.p_dyn_per_core, **kw)


class AdaptiveState(NamedTuple):
    """Controller state; all fixed-shape, batchable with leading dims
    (the sharded plane carries a leading shard axis). ``util`` is a
    per-chassis ring buffer — ``head`` is the next write slot and
    ``count`` saturates at the window length."""
    util: Any          # (..., C, W) — rolling utilization samples
    count: Any         # (..., C) i32 — valid samples, saturates at W
    head: Any          # (..., C) i32 — ring write position
    ratio: Any         # (...,) — current oversubscription ratio
    ratchets: Any      # (...,) i32 — cumulative up-steps taken
    backoffs: Any      # (...,) i32 — cumulative down-steps taken


class AdaptiveOutputs(NamedTuple):
    """Per-scan observables of one controller step."""
    ratio: Any         # (...,) — post-step ratio
    stable_frac: Any   # (...,) — stable / known chassis (0 if none)
    n_known: Any       # (...,) i32 — chassis with enough history
    n_stable: Any      # (...,) i32 — known chassis scored stable
    ratchet: Any       # (...,) bool — stepped up this scan
    backoff: Any       # (...,) bool — stepped down this scan
    hot: Any           # (...,) bool — some chassis over hot_util
    spread: Any        # (..., C) — percentile-spread score
    flip_rate: Any     # (..., C) — sign-change-rate score
    stable: Any        # (..., C) bool — per-chassis verdict


def init_adaptive(cfg: AdaptiveConfig, n_chassis: int, batch_shape=(),
                  xp=np, dtype=np.float32) -> AdaptiveState:
    """Fresh controller state at ratio 1.0 with empty windows — a
    controller that has seen nothing oversubscribes nothing."""
    shape_c = tuple(batch_shape) + (n_chassis,)
    return AdaptiveState(
        util=xp.zeros(shape_c + (cfg.window,), dtype),
        count=xp.zeros(shape_c, np.int32),
        head=xp.zeros(shape_c, np.int32),
        ratio=xp.ones(batch_shape, dtype),
        ratchets=xp.zeros(batch_shape, np.int32),
        backoffs=xp.zeros(batch_shape, np.int32))


def offered_power(cfg: AdaptiveConfig, rho_lv, util, xp=np):
    """Chassis draw implied by committed per-level ``p95*cores``
    aggregates at a utilization sample — the synthetic power feed the
    simulator pushes through the controller (the live pipeline gets
    real samples from the CAPPING stream instead):
    ``static + p_dyn * sum_l rho_l * util``."""
    rho = xp.sum(xp.asarray(rho_lv), axis=-1)
    return cfg.static_w + cfg.p_dyn_per_core * rho * xp.asarray(util)


def _util_from_power(cfg: AdaptiveConfig, rho_lv, power_w, xp):
    """Inverse of `offered_power` with the zero-commitment guard of
    `serve.emergency.util_from_power` (empty chassis read as idle)."""
    rho = xp.sum(rho_lv, axis=-1)
    dyn = xp.maximum(xp.asarray(power_w) - cfg.static_w, 0)
    return xp.where(rho > 0,
                    dyn / (cfg.p_dyn_per_core * xp.where(rho > 0, rho, 1)),
                    0.0)


def adaptive_step(cfg: AdaptiveConfig, st: AdaptiveState, rho_lv,
                  power_w, mask, xp=np):
    """One controller scan over a (batch of) chassis.

    rho_lv: (..., C, L) committed ``p95*cores`` per criticality level
    (`serve.emergency.chassis_rho_levels`) — converts the masked power
    samples back into utilization; power_w/mask: (..., C) — only
    ``mask`` rows carry a fresh sample (unmasked chassis keep their
    window and still participate in scoring with their old history).

    Returns ``(new_state, AdaptiveOutputs)``. Branchless and identical
    under numpy and jnp: cross-chassis reductions are integer sums and
    percentiles are sort + integer-index gathers (never interpolating
    ``percentile``), so the compiled twin is *bit-equal* to the numpy
    oracle — asserted on every scan by the sim backends."""
    rho_lv = xp.asarray(rho_lv)
    dtype = rho_lv.dtype
    W = cfg.window
    u_new = _util_from_power(cfg, rho_lv, power_w, xp).astype(dtype)

    # masked ring write: one-hot at head, then advance head/count
    slot = xp.arange(W, dtype=np.int32)
    write = mask[..., None] & (slot == st.head[..., None])
    util = xp.where(write, u_new[..., None], xp.asarray(st.util, dtype))
    count = xp.where(mask, xp.minimum(st.count + 1, W), st.count)
    head = xp.where(mask, (st.head + 1) % W, st.head)

    # chronological view (oldest -> newest); the valid samples are the
    # trailing `count` entries of the gather
    idx = (head[..., None] + slot) % W
    chrono = xp.take_along_axis(util, idx.astype(np.int32), axis=-1)
    valid = slot >= (W - count)[..., None]                # (..., C, W)

    # percentile spread: sort with invalid rows pushed to +inf, then
    # gather fixed integer indices (floor(q * (n-1)) — identical in
    # numpy and jnp, unlike interpolating percentile kernels)
    inf = dtype.type(np.inf)
    svals = xp.sort(xp.where(valid, chrono, inf), axis=-1)
    nm1 = xp.maximum(count - 1, 0).astype(dtype)
    i_lo = (dtype.type(cfg.spread_q_lo) * nm1).astype(np.int32)
    i_hi = (dtype.type(cfg.spread_q_hi) * nm1).astype(np.int32)
    q_lo = xp.take_along_axis(svals, i_lo[..., None], axis=-1)[..., 0]
    q_hi = xp.take_along_axis(svals, i_hi[..., None], axis=-1)[..., 0]
    zero = xp.zeros_like(q_lo)
    q_lo = xp.where(xp.isfinite(q_lo), q_lo, zero)
    q_hi = xp.where(xp.isfinite(q_hi), q_hi, zero)
    spread = q_hi - q_lo

    # sign-change rate over consecutive valid deltas (validity is a
    # suffix, so a pair is valid iff its left endpoint is)
    d = xp.where(valid[..., :-1], chrono[..., 1:] - chrono[..., :-1], 0)
    flips = xp.sum(
        ((xp.sign(d[..., 1:]) * xp.sign(d[..., :-1])) < 0).astype(
            np.int32), axis=-1)
    flip_rate = flips.astype(dtype) \
        / xp.maximum(count - 2, 1).astype(dtype)

    latest = chrono[..., -1]
    hot_c = (count >= 1) & (latest > dtype.type(cfg.hot_util))
    known = count >= cfg.min_history
    stable = known & (spread <= dtype.type(cfg.spread_thresh)) \
        & (flip_rate <= dtype.type(cfg.flip_thresh)) & ~hot_c

    # fleet decision: integer sums keep the reduction exact in f32
    n_known = xp.sum(known.astype(np.int32), axis=-1)
    n_stable = xp.sum(stable.astype(np.int32), axis=-1)
    hot = xp.sum(hot_c.astype(np.int32), axis=-1) > 0
    frac = n_stable.astype(dtype) \
        / xp.maximum(n_known, 1).astype(dtype)
    ratchet = (n_known > 0) & ~hot \
        & (frac >= dtype.type(cfg.ratchet_quorum))
    backoff = hot | ((n_known > 0)
                     & (frac < dtype.type(cfg.backoff_quorum)))
    ratio = xp.clip(
        xp.asarray(st.ratio, dtype)
        + dtype.type(cfg.step_up) * ratchet.astype(dtype)
        - dtype.type(cfg.step_down) * backoff.astype(dtype),
        dtype.type(cfg.ratio_min), dtype.type(cfg.ratio_max))

    st2 = AdaptiveState(util=util, count=count, head=head, ratio=ratio,
                        ratchets=st.ratchets + ratchet.astype(np.int32),
                        backoffs=st.backoffs + backoff.astype(np.int32))
    return st2, AdaptiveOutputs(
        ratio=ratio, stable_frac=frac, n_known=n_known,
        n_stable=n_stable, ratchet=ratchet, backoff=backoff, hot=hot,
        spread=spread, flip_rate=flip_rate, stable=stable)


def retarget_pool(cfg: AdaptiveConfig, base_pool, ratio, committed,
                  xp=np):
    """Free-pool token level after the controller retargets the watt
    allowance: ``max(base_pool * ratio - committed, 0)``.

    ``base_pool`` is the ratio-1.0 rho-unit allowance
    (`serve.sharding.rho_pool_from_budget` of the *unscaled* budget,
    per shard), ``committed`` the rho already reserved by placed VMs.
    Minting (ratio grew) widens the free pool; retiring (ratio shrank)
    only drains it — the floor at zero is what keeps tokens committed
    to placed VMs irrevocable, so the conservation invariant
    ``committed + free == max(base*ratio, committed)`` holds through
    any mint/retire sequence."""
    base_pool = xp.asarray(base_pool)
    return xp.maximum(base_pool * ratio - xp.asarray(committed), 0)


def gate_ratio_on_stale(cfg: AdaptiveConfig, ratio, stale: bool,
                        xp=np):
    """Conservative-fallback gate on the *applied* oversubscription
    ratio: when the prediction scorecard reports ``stale`` (PSI drift
    or measured accuracy collapse — `obs.quality`), clamp the ratio
    to ``cfg.ratio_min``; otherwise pass it through unchanged.

    Pure and shape-generic (scalar or batched ratio). The controller
    state is never rewritten — staleness suppresses the aggressive
    ratio only while it lasts, and the integrated ratio resumes as
    soon as the model scores fresh again (the paper's "fall back to
    conservative when predictions can't be trusted" rule, made
    automatic)."""
    ratio = xp.asarray(ratio)
    if not stale:
        return ratio
    return xp.minimum(ratio, xp.asarray(cfg.ratio_min,
                                        dtype=ratio.dtype))


def decision_reason(before_ratio: float, out_ratio: float,
                    n_known: int, ratchet: bool, backoff: bool,
                    hot: bool) -> int:
    """Index into `REASON_NAMES` for one (scalar) controller decision —
    the host-side classification recorded into the audit ring."""
    if backoff:
        if out_ratio == before_ratio:
            return 6                       # backoff_floor
        return 4 if hot else 5             # backoff_hot / backoff_quorum
    if ratchet:
        return 3 if out_ratio == before_ratio else 2
    return 0 if n_known == 0 else 1        # hold_no_history / hold_band
