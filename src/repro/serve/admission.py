"""Power-headroom admission control (serve-pipeline stage 4).

The fleet engine and the oversubscription strategy (paper §III-E)
budget each chassis in watts; the scheduler's aggregates track
`rho_peak = sum(p95 * cores)` per chassis. Under the calibrated server
power model those are linearly related — a chassis of S blades drawing
its VMs' P95 utilizations at nominal frequency consumes

    P(chassis) = S * P_idle(f_max) + p_dyn_per_core * rho_peak

so a watt budget becomes a ceiling on `rho_peak` that the placement
scan checks in O(1) per arrival (`serve.placement.place_batch`),
exactly the quantity `ClusterState` already maintains. Placements that
would exceed it are rejected with FAIL_POWER before mutating state —
the serving-path analogue of the fleet engine's alert threshold, which
then only has to handle *prediction misses*, not knowingly-oversold
chassis.

The sharded pipeline layers a *cluster*-level budget on top: the same
watt→rho conversion at fleet granularity becomes the power-token pool
the shards draw from (`serve.sharding.rho_pool_from_budget`,
docs/sharding.md) — per-chassis ceilings stay local to each shard,
the global pool bounds what all shards admit together.
"""
from __future__ import annotations

import numpy as np

from repro.core.power_model import F_MAX, ServerPowerModel, idle_power
from repro.core.resources import N_RESOURCES, ResourceVector
from repro.serve.placement import DeviceClusterState


def rho_cap_from_budget(budget_w, blades_per_chassis: int,
                        n_chassis: int,
                        model: ServerPowerModel | None = None) -> np.ndarray:
    """(C,) ceiling on per-chassis sum(p95*cores) implied by a chassis
    watt budget. `budget_w`: scalar or (C,); None/inf disables."""
    if budget_w is None:
        return np.full(n_chassis, np.inf, np.float32)
    model = model or ServerPowerModel()
    budget = np.broadcast_to(np.asarray(budget_w, np.float64), (n_chassis,))
    static = blades_per_chassis * float(idle_power(F_MAX))
    cap = (budget - static) / model.p_dyn_per_core
    return np.where(np.isfinite(budget), np.maximum(cap, 0.0),
                    np.inf).astype(np.float32)


def resource_caps_from_budget(budget: ResourceVector,
                              blades_per_chassis: int, n_chassis: int,
                              model: ServerPowerModel | None = None,
                              ratios=None) -> np.ndarray:
    """(C, R) per-chassis admission ceilings from a per-chassis
    `ResourceVector` budget (DESIGN.md §16).

    The watts axis converts through the power model exactly like
    `rho_cap_from_budget` (a ceiling on chassis ``sum(p95*cores)``);
    the cores/GB axes are already ledger currency (allocatable virtual
    cores / GB per chassis, typically ``ratio * physical capacity``
    from `core.oversubscription.joint_chassis_budget`). ``None`` axes
    disable (+inf column) — `ResourceVector(watts=B)` reproduces the
    scalar watt ceilings bit for bit.

    `ratios`, an optional (R,) multiplier (e.g.
    `core.resources.trough_ratios` at the current diurnal sample),
    conditions the ceilings on time of day — Coach-style: cores/GB
    ratchet up on the trough while the watts breaker limit stays
    fixed (pass ratios with ``ratios[0] == 1``)."""
    vec = budget.as_array()
    if ratios is not None:
        vec = vec * np.asarray(ratios, np.float64)
    caps = np.broadcast_to(vec, (n_chassis, N_RESOURCES)).copy()
    caps[:, 0] = rho_cap_from_budget(
        None if budget.watts is None else vec[0], blades_per_chassis,
        n_chassis, model)
    return caps.astype(np.float32)


def projected_chassis_power(state: DeviceClusterState,
                            blades_per_chassis: int,
                            model: ServerPowerModel | None = None) \
        -> np.ndarray:
    """(C,) projected peak draw of each chassis if every placed VM runs
    at its effective P95 at nominal frequency (the admission model)."""
    model = model or ServerPowerModel()
    rho = np.asarray(state.rho_peak, np.float64)
    return (blades_per_chassis * float(idle_power(F_MAX))
            + model.p_dyn_per_core * rho).astype(np.float32)


def headroom_w(state: DeviceClusterState, budget_w,
               blades_per_chassis: int,
               model: ServerPowerModel | None = None) -> np.ndarray:
    """(C,) watts of remaining admission headroom (can be negative when
    the budget is tightened below current commitments; +inf when
    `budget_w` is None — no budget)."""
    proj = projected_chassis_power(state, blades_per_chassis, model)
    if budget_w is None:
        return np.full(proj.shape, np.inf, np.float32)
    budget = np.broadcast_to(np.asarray(budget_w, np.float64),
                             proj.shape)
    return (budget - proj).astype(np.float32)
