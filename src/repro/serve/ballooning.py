"""Memory ballooning: the middle rung of the mitigation ladder
(cap -> balloon -> migrate; DESIGN.md §16, docs/resources.md).

When a chassis alarms, the emergency plane first apportions the watt
cut across frequency floors (`serve.emergency`). If the cut exceeds
what the *non-critical* floor can absorb, the overflow throttles
critical VMs and — after a dwell — triggers live migration
(`serve.mitigation`). Both are expensive; migration doubly so. But a
joint (watts, cores, GB) ledger knows something the watt-only plane
did not: how much reclaimable memory the chassis' non-user-facing VMs
hold. Ballooning that memory out powers its DRAM down, shaving
``w_per_gb`` watts per reclaimed GB *without touching any critical
core* — so the rung fires exactly when the NUF frequency floor is
insufficient but on-chassis memory headroom exists, and the ladder
becomes: cap NUF, then balloon NUF memory, and only then throttle
UF / migrate.

How much to reclaim — closed form. The emergency plane's sampled
power model is affine in utilization: ``p = static + dyn`` with
``dyn = p_dyn_per_core * sum(rho_lv) * util``. Absorbing ``A`` watts
of DRAM rescales the inferred utilization (and with it every level's
full-frequency draw) by ``s = (dyn - A) / dyn``. The critical level
stays untouched iff the cut fits inside the NUF floor's capacity at
the *adjusted* utilization:

    cut - A <= s * cap_nuf,   cap_nuf = dyn_nuf * frac(floor_nuf)

which solves to the demand

    A* = (cut - cap_nuf) * dyn / (dyn - cap_nuf)        (when > 0)

`balloon_step` grabs ``min(A*/w_per_gb, headroom)`` GB where
``headroom = reclaim_frac * mem_nuf - ballooned``; a fully served
demand provably zeroes both the UF p-state and the RAPL leftover of
the subsequent `emergency.masked_step`, which is the benchmarked
ladder effect (`benchmarks/serve_resources.py`): fewer critical
throttled-seconds and fewer `mitigation_due` chassis, hence fewer
migrations, at identical watt budgets.

Same kernel discipline as `serve.emergency`: every function is
branchless and xp-generic — the simulator runs the numpy call as its
own oracle and asserts the jitted jnp twin bit-equal on every scan
(`tests/test_ballooning.py`, x64).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

from repro.core.capping import reducible_fracs
from repro.serve.emergency import (EmergencyConfig, _TOL_W,
                                   util_from_power)

#: Leftover/demand below this is float fuzz, not a deficit (the
#: emergency plane's own tolerance — one ladder, one epsilon).
TOL_W = _TOL_W


@dataclass(frozen=True)
class BallooningConfig:
    """Static knobs of the ballooning rung (jit-static, hashable).

    w_per_gb:     DRAM power per resident GB — what powering a
                  ballooned-out GB down gives back. Default models
                  ~3 W per 8 GB DIMM rank.
    reclaim_frac: fraction of a chassis' NUF-committed GB the balloon
                  driver may take (guest working sets keep the rest).
    """
    w_per_gb: float = 0.375
    reclaim_frac: float = 0.5


class BalloonState(NamedTuple):
    """Carried ballooning state; leading batch axes mirror
    `EmergencyState` (vmapped sweeps share the layout)."""
    ballooned_gb: Any    # (..., C) currently reclaimed GB per chassis


class BalloonOutputs(NamedTuple):
    """Per-step outputs of `balloon_step` (all (..., C))."""
    power_adj_w: Any     # sample with DRAM absorption subtracted —
                         # what feeds `emergency.masked_step`
    reclaimed_gb: Any    # newly ballooned-out GB this step
    released_gb: Any     # GB handed back this step (alarm cleared)
    absorbed_w: Any      # total DRAM watts absorbed this step
    inflated: Any        # bool: rung fired on this chassis


def init_ballooning(n_chassis: int, batch_shape: tuple = (),
                    xp=np, dtype=np.float64) -> BalloonState:
    """All-deflated state (no memory ballooned out)."""
    return BalloonState(
        ballooned_gb=xp.zeros(batch_shape + (n_chassis,), dtype))


def balloon_demand_w(ecfg: EmergencyConfig, rho_lv, power_w, xp=np):
    """(alarm, demand) of the closed form above, from a raw power
    sample: ``alarm`` (..., C) bool mirrors `emergency_step`'s alarm
    predicate; ``demand`` (..., C) is the DRAM watt absorption that
    keeps the cut inside the NUF floor (0 where the floor already
    suffices, or where no alarm)."""
    rho_lv = xp.asarray(rho_lv)
    dtype = rho_lv.dtype
    util = util_from_power(ecfg, rho_lv, power_w, xp=xp)
    dyn_full = dtype.type(ecfg.p_dyn_per_core) * rho_lv * util[..., None]
    dyn = xp.sum(dyn_full, axis=-1)                       # (..., C)
    p_full = dtype.type(ecfg.static_w) + dyn
    alarm = p_full >= dtype.type(ecfg.alert_w)
    cut = xp.maximum(p_full - dtype.type(ecfg.target_w), 0)
    frac_nuf = dtype.type(float(reducible_fracs()[ecfg.floors[0]]))
    cap_nuf = dyn_full[..., 0] * frac_nuf
    deficit = xp.maximum(cut - cap_nuf, 0)
    denom = xp.maximum(dyn - cap_nuf, dtype.type(TOL_W))
    # +TOL_W margin so the served demand lands the adjusted cut
    # strictly inside the NUF capacity — exact equality would let
    # float rounding tip an epsilon share onto the critical level.
    demand = xp.where(alarm & (deficit > dtype.type(TOL_W)),
                      (deficit + dtype.type(TOL_W)) * dyn / denom,
                      dtype.type(0))
    return alarm, demand


def balloon_step(cfg: BallooningConfig, ecfg: EmergencyConfig,
                 st: BalloonState, rho_lv, power_w, mem_nuf_gb,
                 mask, xp=np) -> tuple[BalloonState, BalloonOutputs]:
    """One ballooning sweep over the chassis that sampled this step.

    rho_lv:     (..., C, L) per-criticality rho levels
                (`emergency.chassis_rho_levels`).
    power_w:    (..., C) raw sampled draws — DRAM-blind, i.e. NOT yet
                credited for standing balloons (the simulator's
                `sampled_power` knows nothing of DRAM; this step owns
                the correction).
    mem_nuf_gb: (..., C) GB currently committed to NUF VMs
                (`DeviceClusterState.mem_nuf`).
    mask:       (..., C) bool — chassis that sampled this step;
                unmasked chassis keep their state bit-for-bit and
                pass their power through untouched.

    The step first credits the standing balloon against the sample
    (``p0 = power - w_per_gb * ballooned``), evaluates alarm/demand
    on that corrected draw, inflates up to the headroom on alarmed
    chassis and schedules a full deflate on cleared ones (the
    returned GB re-powers its DRAM *next* sample, so this step's
    ``power_adj_w`` still credits it). Feed ``power_adj_w`` to
    `emergency.masked_step` in place of the raw sample.
    """
    ballooned = xp.asarray(st.ballooned_gb)
    dtype = ballooned.dtype
    w_per_gb = dtype.type(cfg.w_per_gb)
    mask = xp.asarray(mask)
    power_w = xp.asarray(power_w, dtype)

    standing_w = w_per_gb * ballooned
    p0 = power_w - standing_w
    alarm, demand_w = balloon_demand_w(ecfg, rho_lv, p0, xp=xp)

    headroom = xp.maximum(
        dtype.type(cfg.reclaim_frac) * xp.asarray(mem_nuf_gb, dtype)
        - ballooned, 0)
    want_gb = demand_w / w_per_gb
    grab = xp.where(mask & alarm, xp.minimum(want_gb, headroom),
                    dtype.type(0))
    release = xp.where(mask & ~alarm, ballooned, dtype.type(0))
    ballooned_new = ballooned + grab - release

    absorbed = xp.where(mask, standing_w + w_per_gb * grab,
                        dtype.type(0))
    power_adj = power_w - absorbed
    out = BalloonOutputs(power_adj_w=power_adj, reclaimed_gb=grab,
                         released_gb=release, absorbed_w=absorbed,
                         inflated=grab > dtype.type(TOL_W))
    return BalloonState(ballooned_gb=ballooned_new), out


def total_ballooned_gb(st: BalloonState) -> float:
    """Fleet-wide GB currently ballooned out (host-side reduction)."""
    return float(np.asarray(st.ballooned_gb).sum())
