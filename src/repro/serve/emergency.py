"""Online power-emergency control plane (DESIGN.md §12, docs/emergency.md).

The serve pipeline admits against *projected* peak draw
(`serve.admission`); emergencies are what happens when reality beats
the projection — a chassis' measured draw trips the protective-capping
alarm and watts must come off *now*, with minimum impact to critical
workloads (the paper's §V headline property). This module is the
batched online twin of the chassis-manager + per-VM-controller +
RAPL-backstop stack of `repro.core.capping`:

  * **Alarms** — `ChassisManager` semantics in bulk: every chassis of
    a shard is polled in one compare against ``alert_fraction *
    chassis_budget_w`` (`EmergencyConfig.alert_w`).
  * **Criticality-aware apportionment** — the required cut
    (sampled draw minus the capped target) is apportioned across
    criticality levels lowest-first by
    `repro.core.capping.apportion_watts`: non-critical dynamic draw is
    shaved down to its frequency floor before critical VMs lose a
    hertz, critical levels are capped to *their* (higher) floor next,
    and only a cut no floor can absorb engages the RAPL backstop
    (all cores to f_min, criticality-blind). Unlike the in-band
    feedback loop of `core.capping.PerVMController.step`, the serve
    plane *knows* each level's committed dynamic draw from the
    placement aggregates, so the controller is one-shot
    model-predictive: the post-action draw lands at or under the
    target in the same scan that raised the alarm.
  * **Hysteresis** — an alarmed chassis re-apportions every sample; a
    chassis whose draw falls back below the alert threshold holds its
    cap for `lift_after_s` (the paper's 30 s lift delay) and then
    restores nominal frequency.
  * **Dwell** — `capped_s` tracks how long each chassis has been
    continuously capped; `mitigation_due` flags chassis whose
    *critical* levels have been throttled past `dwell_s` — the signal
    `repro.serve.mitigation` turns into a migration plan.

Everything is branchless, fixed-shape, and xp-generic: the numpy call
is the oracle, `jax.vmap` batches it per shard on one device, and
`jax.shard_map` runs one copy per mesh device
(`repro.serve.sharding.apply_caps_sharded`) — all three asserted equal
in `tests/test_serve_emergency.py`. Power samples reach the plane as
the third stream-event kind (`repro.serve.ingest.CAPPING`), so
emergencies merge deterministically with arrivals and departures
across ingest hosts.

Observability (DESIGN.md §17): every sweep's in-scan counters
(alarms, samples, demanded/leftover watts, per-level cuts) are
scan-carried *outputs* the pipeline folds host-side into the metrics
registry, the windowed aggregates, the SLO burn-rate monitor
(critical throttled-seconds and alarm-rate budgets), and — when a
sweep raises alarms — a flight-recorder incident marker with the
surrounding event stream (`obs.recorder`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import numpy as np

from repro.core.capping import (ChassisManager, RaplController,
                                apportion_watts)
from repro.core.fleet_dynamics import (ALERT_FRACTION, ALERT_MARGIN_W,
                                       FREQ_TABLE, LIFT_AFTER_S)
from repro.core.power_model import (F_MAX, N_PSTATES, ServerPowerModel,
                                    dyn_scale, idle_power)

#: Criticality levels, in apportionment priority order: level 0
#: (non-user-facing) absorbs the cut first, level 1 (user-facing /
#: critical) only when level 0's floor is insufficient.
CRIT_NUF = 0
CRIT_UF = 1
N_LEVELS = 2

#: Default frequency floor of the *critical* level: p-state 5 = 0.75
#: f_max — critical VMs may be politely trimmed this far by the
#: criticality-aware stage; anything deeper takes the RAPL backstop.
UF_FLOOR_PSTATE = 5

_TOL_W = 1e-6          # leftover below this is float fuzz, not a deficit


@dataclass(frozen=True)
class EmergencyConfig:
    """Static (hashable) knobs of the power-emergency plane — safe as a
    jit static argument, like `core.fleet_dynamics.ControlParams`.

    `floors` is the per-criticality-level p-state floor in priority
    order: how deep the criticality-aware stage may cap each level
    before the leftover falls through to the RAPL backstop. The default
    lets non-critical VMs reach f_min while critical VMs are never
    trimmed below 0.75 f_max without RAPL."""
    chassis_budget_w: float
    alert_fraction: float = ALERT_FRACTION
    target_margin_w: float = ALERT_MARGIN_W
    floors: tuple = (N_PSTATES - 1, UF_FLOOR_PSTATE)
    lift_after_s: float = LIFT_AFTER_S
    dwell_s: float = 30.0
    criticality_blind: bool = False
    blades_per_chassis: int = 12
    p_dyn_per_core: float = ServerPowerModel().p_dyn_per_core
    idle_w_per_server: float = float(idle_power(F_MAX))

    @property
    def alert_w(self) -> float:
        """Protective-capping alarm threshold (watts)."""
        return self.chassis_budget_w * self.alert_fraction

    @property
    def target_w(self) -> float:
        """Draw the apportionment steers an alarmed chassis to."""
        return self.chassis_budget_w - self.target_margin_w

    @property
    def static_w(self) -> float:
        """Frequency-independent chassis floor: every blade's idle
        draw at nominal frequency (the admission model's intercept)."""
        return self.blades_per_chassis * self.idle_w_per_server

    def manager(self) -> ChassisManager:
        """The equivalent per-chassis `core.capping.ChassisManager`
        (the oracle tests poll through it)."""
        return ChassisManager(self.chassis_budget_w, self.alert_fraction,
                              self.target_margin_w)

    @classmethod
    def from_model(cls, chassis_budget_w: float,
                   model: ServerPowerModel | None = None,
                   **kw) -> "EmergencyConfig":
        """Build a config calibrated to a `ServerPowerModel`."""
        model = model or ServerPowerModel()
        return cls(chassis_budget_w=chassis_budget_w,
                   p_dyn_per_core=model.p_dyn_per_core, **kw)


class EmergencyState(NamedTuple):
    """Per-chassis controller state; all fixed-shape, batchable with
    leading dims (the sharded plane carries a leading shard axis)."""
    pstate: Any        # (..., C, L) i32 — per-level uniform p-state
    rapl: Any          # (..., C) bool — RAPL backstop engaged
    capped_s: Any      # (..., C) f32 — continuous seconds capped (dwell)
    clear_s: Any       # (..., C) f32 — seconds since the alarm cleared
    throttled_s: Any   # (..., C, L) f32 — cumulative per-level
    last_t: Any        # (..., C) — stamp of the last applied sample


class EmergencyOutputs(NamedTuple):
    """Per-sample observables of one emergency scan."""
    power_w: Any       # (..., C) — offered (uncapped) draw this sample
    power_after_w: Any  # (..., C) — draw at the post-action settings
    alarm: Any         # (..., C) bool
    cut_w: Any         # (..., C) — required reduction past the target
    leftover_w: Any    # (..., C) — cut no floor absorbed (RAPL trigger)
    cut_by_level_w: Any  # (..., C, L) — watts removed per crit level


def init_emergency(n_chassis: int, batch_shape=(), xp=np,
                   dtype=np.float32) -> EmergencyState:
    """Uncapped initial emergency state — nominal frequency everywhere,
    no alarms ever seen (``last_t = -inf``)."""
    shape_c = tuple(batch_shape) + (n_chassis,)
    shape_l = shape_c + (N_LEVELS,)
    return EmergencyState(
        pstate=xp.zeros(shape_l, np.int32),
        rapl=xp.zeros(shape_c, bool),
        capped_s=xp.zeros(shape_c, dtype),
        clear_s=xp.full(shape_c, np.inf, dtype),
        throttled_s=xp.zeros(shape_l, dtype),
        last_t=xp.full(shape_c, -np.inf, dtype))


def chassis_rho_levels(gamma_nuf, gamma_uf, chassis_servers, xp=np):
    """(C, L) committed ``sum(p95*cores)`` per chassis per criticality
    level, gathered from the per-server placement aggregates through
    the (C, K) chassis->servers table — the emergency plane's view of
    what is drawing power where. Level order is apportionment priority
    (non-critical first)."""
    nuf = xp.sum(gamma_nuf[chassis_servers], axis=-1)
    uf = xp.sum(gamma_uf[chassis_servers], axis=-1)
    return xp.stack([nuf, uf], axis=-1)


def sampled_power(cfg: EmergencyConfig, rho_lv, util, pstate, rapl,
                  xp=np):
    """Chassis draw at the given control settings under the admission
    power model: ``static + p_dyn * sum_l rho_l * util * g(f_l)``,
    with RAPL-engaged chassis at f_min on every level."""
    dtype = xp.asarray(rho_lv).dtype
    gtab = xp.asarray(dyn_scale(FREQ_TABLE), dtype)
    g = xp.where(xp.asarray(rapl)[..., None],
                 gtab[RaplController.backstop_pstate()], gtab[pstate])
    util = xp.asarray(util, dtype)
    dyn = cfg.p_dyn_per_core * rho_lv * util[..., None]
    return cfg.static_w + xp.sum(dyn * g, axis=-1)


def util_from_power(cfg: EmergencyConfig, rho_lv, power_w, xp=np):
    """Implied utilization of the committed P95 behind a sampled
    *uncapped* draw: ``(power - static) / (p_dyn * sum_l rho_l)``,
    clipped at 0 (a draw below the static floor reads as idle) with a
    zero-commitment guard (an empty chassis implies util 0, not a
    division by its zero rho)."""
    rho = xp.sum(rho_lv, axis=-1)
    dyn = xp.maximum(xp.asarray(power_w) - cfg.static_w, 0)
    return xp.where(rho > 0,
                    dyn / (cfg.p_dyn_per_core * xp.where(rho > 0, rho, 1)),
                    0.0)


def emergency_step(cfg: EmergencyConfig, st: EmergencyState, rho_lv,
                   util, t, xp=np):
    """One emergency scan over a (batch of) chassis.

    rho_lv: (..., C, L) committed p95*cores per level
    (`chassis_rho_levels`); util: scalar or (..., C) utilization sample
    scaling the commitment into an offered draw; `t`: sample stamp
    (scalar or (..., C)) — elapsed time against `last_t` accrues the
    dwell clock and per-level throttled-seconds at the settings that
    held over the interval.

    Returns ``(new_state, EmergencyOutputs)``. Branchless; identical
    under numpy and jnp (the numpy call is the oracle the jax
    executions are asserted against)."""
    rho_lv = xp.asarray(rho_lv)
    dtype = rho_lv.dtype
    util = xp.asarray(util, dtype)
    dyn_full = cfg.p_dyn_per_core * rho_lv * util[..., None]
    p_full = cfg.static_w + xp.sum(dyn_full, axis=-1)     # (..., C)
    alarm = p_full >= dtype.type(cfg.alert_w)

    t = xp.asarray(t, st.last_t.dtype)
    dt = xp.where(xp.isfinite(st.last_t),
                  xp.maximum(t - st.last_t, 0), 0).astype(dtype)

    # accrue dwell + throttled-seconds at the settings that held over
    # [last_t, t)
    was_thr = (st.pstate > 0) | st.rapl[..., None]        # (..., C, L)
    throttled_s = st.throttled_s + dt[..., None] * was_thr
    was_capped = xp.any(was_thr, axis=-1)
    capped_accum = (st.capped_s + dt) * was_capped
    clear_accum = xp.where(alarm, 0,
                           xp.where(was_capped, st.clear_s + dt,
                                    dtype.type(np.inf)))
    lift = was_capped & ~alarm \
        & (clear_accum >= dtype.type(cfg.lift_after_s))
    hold = was_capped & ~alarm & ~lift

    cut = xp.maximum(p_full - dtype.type(cfg.target_w), 0)
    pst_new, _, leftover = apportion_watts(
        cut, dyn_full, cfg.floors, xp, blind=cfg.criticality_blind)
    pstate = xp.where(alarm[..., None], pst_new,
                      xp.where(hold[..., None], st.pstate, 0))
    rapl = xp.where(alarm, leftover > _TOL_W,
                    xp.where(hold, st.rapl, False))

    now_capped = xp.any(pstate > 0, axis=-1) | rapl
    capped_s = xp.where(now_capped, capped_accum, 0).astype(dtype)
    clear_s = xp.where(alarm, 0,
                       xp.where(now_capped, clear_accum,
                                dtype.type(np.inf))).astype(dtype)
    last_t = xp.broadcast_to(t, st.last_t.shape).astype(st.last_t.dtype)

    p_after = sampled_power(cfg, rho_lv, util, pstate, rapl, xp)
    # per-level watts removed at the post-action settings — the same
    # g as `sampled_power` uses, so cut_by_level decomposes the
    # (p_full - p_after) reduction by criticality level
    gtab = xp.asarray(dyn_scale(FREQ_TABLE), dtype)
    g = xp.where(rapl[..., None],
                 gtab[RaplController.backstop_pstate()], gtab[pstate])
    cut_lv = dyn_full * (1 - g)
    st2 = EmergencyState(pstate, rapl, capped_s, clear_s,
                         throttled_s.astype(dtype), last_t)
    return st2, EmergencyOutputs(p_full, p_after, alarm, cut, leftover,
                                 cut_lv)


def masked_step(cfg: EmergencyConfig, st: EmergencyState, rho_lv,
                power_w, mask, t, xp=np):
    """`emergency_step` driven by *sampled draws* for a subset of
    chassis — the dense, vmappable form the stream-event path uses.

    power_w/mask/t: (..., C) — only ``mask`` rows carry a fresh sample
    (their utilization is implied via `util_from_power`); unmasked
    chassis carry their state forward untouched, including their
    clocks (their elapsed time accrues when they are next sampled)."""
    util = util_from_power(cfg, rho_lv, power_w, xp)
    st2, out = emergency_step(cfg, st, rho_lv, util, t, xp)

    def sel(new, old):
        m = mask[..., None] if new.ndim == mask.ndim + 1 else mask
        return xp.where(m, new, old)

    st3 = EmergencyState(*(sel(n, xp.asarray(o))
                           for n, o in zip(st2, st)))
    zero = xp.zeros_like(out.power_w)
    return st3, EmergencyOutputs(
        xp.where(mask, out.power_w, zero),
        xp.where(mask, out.power_after_w, zero),
        mask & out.alarm,
        xp.where(mask, out.cut_w, zero),
        xp.where(mask, out.leftover_w, zero),
        xp.where(mask[..., None], out.cut_by_level_w,
                 zero[..., None]))


def scatter_samples(n_chassis: int, chassis, power_w, t, xp=np,
                    dtype=np.float32):
    """Densify one sparse sample batch: (B,) chassis ids (assumed
    unique — the pipeline splits duplicate-bearing windows) with their
    draws and stamps become the (C,) ``(power_w, mask, t)`` operands of
    `masked_step`."""
    chassis = np.asarray(chassis, np.int64)
    if xp is np:
        pw = np.zeros(n_chassis, dtype)
        mask = np.zeros(n_chassis, bool)
        ts = np.zeros(n_chassis, np.float64)
        pw[chassis] = power_w
        mask[chassis] = True
        ts[chassis] = t
        return pw, mask, ts
    pw = xp.zeros(n_chassis, dtype).at[chassis].set(
        xp.asarray(power_w, dtype))
    mask = xp.zeros(n_chassis, bool).at[chassis].set(True)
    ts = xp.zeros(n_chassis, dtype).at[chassis].set(xp.asarray(t, dtype))
    return pw, mask, ts


def mitigation_due(cfg: EmergencyConfig, st: EmergencyState, xp=np):
    """(..., C) bool — chassis whose cap has dwelled past
    ``cfg.dwell_s`` with the *critical* level throttled (a polite NUF
    cap that clears fast never migrates anyone). The trigger
    `repro.serve.mitigation.plan_migrations` consumes."""
    crit_thr = (st.pstate[..., CRIT_UF] > 0) | st.rapl
    return crit_thr & (st.capped_s >= cfg.dwell_s)


def reset_dwell(st: EmergencyState, chassis_mask, xp=np) -> EmergencyState:
    """Zero the dwell clock of the masked chassis — called after a
    migration plan is emitted for them, so one persistent emergency
    yields one plan per dwell period, not one per sample."""
    return st._replace(
        capped_s=xp.where(chassis_mask, 0, st.capped_s))


def throttled_by_level(st: EmergencyState) -> np.ndarray:
    """(L,) total throttled-seconds per criticality level, summed over
    chassis (and any leading batch dims) — the paper's Table-4-style
    impact axis: index `CRIT_UF` is the critical number that the
    criticality-aware apportionment keeps low."""
    return np.asarray(st.throttled_s).reshape(-1, N_LEVELS).sum(0)
