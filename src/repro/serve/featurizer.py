"""Device-resident twin of `core/features.py` (serve-pipeline stage 1).

The offline featurizer walks python dicts of per-subscription
aggregates; at serving rates that walk *is* the latency budget. Here
the aggregates live as device arrays indexed by subscription id —
`SubscriptionTable` holds running *sums* (not means), so ingesting a
newly-labeled VM is one scatter-add and featurizing a whole arrival
micro-batch is one gather + a few elementwise ops, all inside a single
jit. Feature order matches `core.features.FEATURE_NAMES` exactly; the
parity test drives both paths with the same history.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.sim.telemetry import (
    VM_TYPES, ArrivalBatch, Population, arrival_batch)

N_FEATURES = len(F.FEATURE_NAMES)
N_VM_TYPES = len(VM_TYPES)

#: `core.features._DEFAULT_AGG` as a flat row for unseen subscriptions.
_DEFAULT_ROW = np.array(
    [F._DEFAULT_AGG["pct_uf"], F._DEFAULT_AGG["pct_7d"],
     F._DEFAULT_AGG["total"], *F._DEFAULT_AGG["bucket_mix"],
     F._DEFAULT_AGG["avg_avg"], F._DEFAULT_AGG["avg_p95"]], np.float32)


class SubscriptionTable(NamedTuple):
    """Running per-subscription sums (device arrays, capacity rows).

    Means are formed at featurize time, so an update is pure
    scatter-add and the table composes with jit/donation."""
    count: jnp.ndarray          # (N,) f32 — VMs observed
    uf_sum: jnp.ndarray         # (N,) f32 — sum of criticality labels
    lived7d_sum: jnp.ndarray    # (N,) f32 — sum of lifetime >= 168 h
    bucket_sum: jnp.ndarray     # (N, 4) f32 — P95-bucket histogram
    avg_util_sum: jnp.ndarray   # (N,) f32
    p95_util_sum: jnp.ndarray   # (N,) f32

    @property
    def capacity(self) -> int:
        return self.count.shape[0]


def empty_table(capacity: int) -> SubscriptionTable:
    """Fresh all-zero table with `capacity` subscription rows."""
    z = jnp.zeros(capacity, jnp.float32)
    return SubscriptionTable(z, z, z, jnp.zeros((capacity, 4), jnp.float32),
                             z, z)


def shard_table(table: SubscriptionTable, mesh,
                axis: str = "shard") -> SubscriptionTable:
    """Row-partition the table over a device mesh axis.

    Pads the capacity up to a multiple of the axis size and pins each
    row block to its device with a NamedSharding, so `update_table`
    scatter-adds and `featurize` gathers run distributed under jit —
    no featurizer code changes. The sharded serve pipeline applies
    this when a mesh is active (DESIGN.md §10).

    Capacity semantics of the padded window: because capacity is
    derived from the array shape, ids in [old capacity, padded
    capacity) become *valid* rows — `featurize` serves them the
    unseen-subscription defaults until ingested (they start all-zero),
    but `update_table` stores rather than drops them. Size the
    original capacity for your id space (as `from_history` does) and
    the window is never reached."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = mesh.shape[axis]
    cap = -(-table.capacity // n) * n

    def put(x):
        pad = [(0, cap - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        spec = P(axis, *([None] * (x.ndim - 1)))
        return jax.device_put(jnp.pad(x, pad), NamedSharding(mesh, spec))

    return SubscriptionTable(*(put(a) for a in table))


def p95_bucket_jnp(p95_util: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of `core.features.p95_bucket` (0-25/26-50/51-75/76-100).

    The host's `(x - 1e-9) // 25` epsilon pushes exact multiples of 25
    into the lower bucket, but 1e-9 underflows in float32 (eps at 25.0
    is ~3e-6). `ceil(x/25) - 1` encodes the same half-open-below
    boundary exactly — integer-percent inputs, the common telemetry
    case, are f32-representable and bucket identically to the f64
    host."""
    return jnp.clip(jnp.ceil(p95_util / 25.0) - 1, 0,
                    F.N_UTIL_BUCKETS - 1).astype(jnp.int32)


@jax.jit
def update_table(table: SubscriptionTable, subscription: jnp.ndarray,
                 uf_label: jnp.ndarray, lifetime_hours: jnp.ndarray,
                 p95_util: jnp.ndarray,
                 avg_util: jnp.ndarray) -> SubscriptionTable:
    """Ingest a batch of labeled VMs (the daily label-bootstrap loop —
    paper §III-B — run incrementally). All args (B,); percent units.
    Ids outside [0, capacity) are dropped (XLA scatter semantics) —
    those subscriptions simply stay on the default-aggregates fallback
    that `featurize` serves for unseen ids."""
    sub = subscription.astype(jnp.int32)
    # out-of-range -> capacity: positive out-of-bounds scatter updates
    # are dropped (negative ones would wrap)
    sub = jnp.where((sub >= 0) & (sub < table.capacity), sub,
                    table.capacity)
    one = jnp.ones_like(uf_label, jnp.float32)
    bucket = jax.nn.one_hot(p95_bucket_jnp(p95_util), F.N_UTIL_BUCKETS,
                            dtype=jnp.float32)
    return SubscriptionTable(
        count=table.count.at[sub].add(one),
        uf_sum=table.uf_sum.at[sub].add(uf_label.astype(jnp.float32)),
        lived7d_sum=table.lived7d_sum.at[sub].add(
            (lifetime_hours >= 168).astype(jnp.float32)),
        bucket_sum=table.bucket_sum.at[sub].add(bucket),
        avg_util_sum=table.avg_util_sum.at[sub].add(avg_util),
        p95_util_sum=table.p95_util_sum.at[sub].add(p95_util))


def ingest_population(table: SubscriptionTable, history: Population,
                      uf_labels: np.ndarray) -> SubscriptionTable:
    """Fold a labeled population into the aggregates (one update)."""
    b = arrival_batch(history)
    avg = np.array([v.avg_util for v in history.vms], np.float32)
    return update_table(table, jnp.asarray(b.subscription),
                        jnp.asarray(np.asarray(uf_labels, np.float32)),
                        jnp.asarray(b.lifetime_hours),
                        jnp.asarray(b.p95_util), jnp.asarray(avg))


def table_from_history(history: Population, uf_labels: np.ndarray,
                       capacity: int) -> SubscriptionTable:
    """Bulk-load a table from an offline labeled history."""
    return ingest_population(empty_table(capacity), history, uf_labels)


@jax.jit
def featurize(table: SubscriptionTable, subscription: jnp.ndarray,
              cores: jnp.ndarray, memory_gb: jnp.ndarray,
              vm_type_idx: jnp.ndarray) -> jnp.ndarray:
    """(B,) arrival columns -> (B, N_FEATURES) f32, same layout as
    `core.features.build_features`. Unseen subscriptions — including
    ids outside [0, capacity), which XLA gathers would otherwise clamp
    onto the last row — fall back to the offline path's default
    aggregates."""
    sub = subscription.astype(jnp.int32)
    in_range = (sub >= 0) & (sub < table.capacity)
    sub = jnp.where(in_range, sub, 0)
    cnt = table.count[sub]                                   # (B,)
    seen = in_range & (cnt > 0)
    denom = jnp.maximum(cnt, 1.0)
    aggs = jnp.stack(
        [table.uf_sum[sub] / denom,
         table.lived7d_sum[sub] / denom,
         cnt], -1)                                           # (B, 3)
    bucket_mix = table.bucket_sum[sub] / denom[:, None]      # (B, 4)
    util = jnp.stack([table.avg_util_sum[sub] / denom,
                      table.p95_util_sum[sub] / denom], -1)  # (B, 2)
    agg_row = jnp.concatenate([aggs, bucket_mix, util], -1)  # (B, 9)
    agg_row = jnp.where(seen[:, None], agg_row, _DEFAULT_ROW[None])
    onehot = jax.nn.one_hot(vm_type_idx, N_VM_TYPES, dtype=jnp.float32)
    return jnp.concatenate(
        [agg_row, cores[:, None].astype(jnp.float32),
         memory_gb[:, None].astype(jnp.float32), onehot], -1)


@partial(jax.jit, static_argnames=("pad_to",))
def _featurize_padded(table, subscription, cores, memory_gb, vm_type_idx,
                      pad_to):
    def pad(a):
        return jnp.pad(a, (0, pad_to - a.shape[0]))
    return featurize(table, pad(subscription), pad(cores), pad(memory_gb),
                     pad(vm_type_idx))


def featurize_batch(table: SubscriptionTable, batch: ArrivalBatch,
                    pad_to: int | None = None) -> jnp.ndarray:
    """Featurize one ingest micro-batch, optionally padded to a fixed
    batch size so the serving jit never re-specializes (padding rows
    use subscription 0 / type 0 and are dropped by the caller)."""
    args = (jnp.asarray(batch.subscription), jnp.asarray(batch.cores),
            jnp.asarray(batch.memory_gb), jnp.asarray(batch.vm_type_idx))
    if pad_to is None or pad_to == len(batch):
        return featurize(table, *args)
    return _featurize_padded(table, *args, pad_to=pad_to)
