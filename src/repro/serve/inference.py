"""Batched two-stage inference (serve-pipeline stage 2).

One jitted call evaluates all four forests of a trained
`PredictionService` (criticality, P95 stage 1, low- and high-bucket
stage 2) on an arrival micro-batch and fuses the paper's confidence
gating: low-confidence queries fall back to the conservative
user-facing @ bucket-3 answer the production scheduler uses (§IV-B).

Kernel routing mirrors `kernels/forest/ops`: on TPU the packed
operands feed the Pallas oblivious-forest kernel; elsewhere the same
operands run through the identical dense math in plain jnp (the
kernel's `ref.py` formulation) — interpret-mode Pallas is for
correctness tests, not serving. Operands are packed once per model
(`pack_service`), which is what makes hot-swap cheap."""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import ObliviousForest
from repro.core.predictor import CONFIDENCE_GATE, UF, PredictionService
from repro.kernels.forest.ops import (
    normalize_forest_output, pack_forest, predict_packed)


class PackedForest(NamedTuple):
    gather: jnp.ndarray      # (F, T*D) one-hot feature gather
    thr: jnp.ndarray         # (1, T*D)
    leaf: jnp.ndarray        # (T*2**D, K) flat leaf table


@dataclass(frozen=True)
class ForestMeta:
    n_trees: int
    depth: int
    kind: str


class PackedService(NamedTuple):
    """Device operands of the four forests (same shapes across daily
    retrains with fixed hyperparameters — the hot-swap invariant)."""
    criticality: PackedForest
    stage1: PackedForest
    low: PackedForest
    high: PackedForest


@dataclass(frozen=True)
class ServiceMeta:
    """Static (hashable) companion of a PackedService for jit."""
    criticality: ForestMeta
    stage1: ForestMeta
    low: ForestMeta
    high: ForestMeta
    confidence_gate: float = CONFIDENCE_GATE
    n_features: int = 0


def _pack_one(forest: ObliviousForest) -> tuple[PackedForest, ForestMeta]:
    gather, thr, leaf, t, d, kind = pack_forest(forest)
    return PackedForest(gather, thr, leaf), ForestMeta(t, d, kind)


def pack_service(svc: PredictionService) \
        -> tuple[PackedService, ServiceMeta]:
    """Pack all four of a service's forests into device operands +
    static metadata — done once per (re)trained model; this is what
    makes the pipeline's hot swap a buffer flip."""
    forests = (svc.criticality, svc.p95.stage1, svc.p95.low, svc.p95.high)
    packed, metas = zip(*(_pack_one(f) for f in forests))
    return (PackedService(*packed),
            ServiceMeta(*metas, confidence_gate=svc.confidence_gate,
                        n_features=svc.criticality.n_features))


def _proba_ref(x, pf: PackedForest, meta: ForestMeta):
    """The Pallas kernel's math in plain jnp (XLA path off-TPU)."""
    t, d = meta.n_trees, meta.depth
    levels = jnp.dot(x, pf.gather, preferred_element_type=jnp.float32)
    bits = (levels > pf.thr).astype(jnp.int32).reshape(-1, t, d)
    weights = (2 ** jnp.arange(d))[::-1]
    leaf_idx = (bits * weights[None, None]).sum(-1)           # (B, T)
    leaf = pf.leaf.reshape(t, 1 << d, -1)
    summed = leaf[jnp.arange(t)[None], leaf_idx].sum(1)       # (B, K)
    return _finish(summed, meta)


def _proba_pallas(x, pf: PackedForest, meta: ForestMeta, interpret,
                  block_b=None, block_t=None):
    kw = {} if block_b is None else {"block_b": block_b}
    return predict_packed(x, pf.gather, pf.thr, pf.leaf, meta.n_trees,
                          meta.depth, meta.kind, interpret,
                          block_t=block_t, **kw)


def _finish(summed, meta: ForestMeta):
    return normalize_forest_output(summed, meta.kind, meta.n_trees)


@lru_cache(maxsize=None)
def _measured_fallback() -> str:
    """Pick the off-TPU kernel by measurement, once per process: time
    the interpret-mode tiled Pallas kernel against the plain-jnp
    reference on a tiny synthetic forest and return the faster name.
    In practice XLA's fused dense math wins by orders of magnitude
    (interpret mode emulates the grid program-by-program), but the
    routing is measured rather than assumed — a backend where
    interpret mode compiles well would flip automatically, and
    `benchmarks/forest_kernel.py` tracks the same ratio."""
    import time

    t, d, f, k, b = 4, 3, 8, 2, 32
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
    gather = jnp.asarray(
        np.eye(f, dtype=np.float32)[:, rng.integers(0, f, t * d)])
    thr = jnp.asarray(rng.normal(size=(1, t * d)).astype(np.float32))
    leaf = jnp.asarray(
        rng.normal(size=(t * (1 << d), k)).astype(np.float32))
    pf = PackedForest(gather, thr, leaf)
    meta = ForestMeta(t, d, "rf")

    def timed(fn):
        fn().block_until_ready()            # compile outside the clock
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fn().block_until_ready()
            best = min(best, time.perf_counter() - t0)
        return best

    t_ref = timed(jax.jit(lambda: _proba_ref(x, pf, meta)).lower()
                  .compile())
    t_pal = timed(jax.jit(
        lambda: _proba_pallas(x, pf, meta, interpret=True,
                              block_b=b, block_t=2)).lower().compile())
    return "ref" if t_ref <= t_pal else "pallas_interpret"


def resolve_kernel(kernel: str = "auto") -> str:
    """Resolve 'auto' to the Pallas kernel on TPU and the *measured*
    faster of {jnp reference, interpret-mode Pallas} elsewhere
    (`_measured_fallback`); explicit names pass through."""
    if kernel == "auto":
        return "pallas" if jax.default_backend() == "tpu" \
            else _measured_fallback()
    return kernel


def _proba4_ref_stacked(x, packed: PackedService, meta: ForestMeta):
    """All four forests in one gather-matmul pass (they share shapes
    whenever `train_service` used one hyperparameter set — the common
    case). Returns a list of four (B, K) probability arrays."""
    t, d = meta.n_trees, meta.depth
    pfs = list(packed)
    gather = jnp.concatenate([pf.gather for pf in pfs], 1)  # (F, 4*T*D)
    thr = jnp.concatenate([pf.thr for pf in pfs], 1)
    leaf = jnp.stack([pf.leaf.reshape(t, 1 << d, -1) for pf in pfs])
    levels = jnp.dot(x, gather, preferred_element_type=jnp.float32)
    bits = (levels > thr).astype(jnp.int32).reshape(-1, 4 * t, d)
    weights = (2 ** jnp.arange(d))[::-1]
    leaf_idx = (bits * weights[None, None]).sum(-1) \
        .reshape(-1, 4, t)                                   # (B, 4, T)
    fi = jnp.arange(4)[None, :, None]
    ti = jnp.arange(t)[None, None, :]
    vals = leaf[fi, ti, leaf_idx]                            # (B, 4, T, K)
    return [_finish(vals[:, f].sum(1), meta) for f in range(4)]


@partial(jax.jit, static_argnames=("meta", "kernel"))
def served_query(packed: PackedService, meta: ServiceMeta,
                 x: jnp.ndarray, kernel: str = "ref") -> dict:
    """x: (B, F) features -> the `PredictionService.query` dict as
    device arrays, with the conservative fallback fused in. Extra key
    `conservative` marks arrivals that hit either fallback.

    Both the gated (``*_used``) and raw (``workload_type`` /
    ``p95_bucket``) heads plus their confidences are returned: the
    pipeline places on the gated values, and the prediction scorecard
    (`obs.quality`) fetches the raw heads alongside them in the same
    commit `device_get` — outputs only, so scoring can never perturb
    a decision."""
    assert x.shape[1] == meta.n_features, \
        f"feature width {x.shape[1]} != model's {meta.n_features}"
    x = x.astype(jnp.float32)
    metas = (meta.criticality, meta.stage1, meta.low, meta.high)
    if kernel == "ref" and len(set(metas)) == 1:
        pc, p1, plo, phi = _proba4_ref_stacked(x, packed,
                                               meta.criticality)
    else:
        if kernel == "pallas":
            proba = partial(_proba_pallas, interpret=False)
        elif kernel == "pallas_interpret":
            proba = partial(_proba_pallas, interpret=True)
        else:
            proba = _proba_ref
        pc = proba(x, packed.criticality, meta.criticality)
        p1 = proba(x, packed.stage1, meta.stage1)
        plo = proba(x, packed.low, meta.low)
        phi = proba(x, packed.high, meta.high)

    wt, wt_conf = pc.argmax(-1), pc.max(-1)
    s1 = p1.argmax(-1)
    bucket = jnp.where(s1 == 1, phi.argmax(-1) + 2, plo.argmax(-1))
    pb_conf = jnp.minimum(p1.max(-1),
                          jnp.where(s1 == 1, phi.max(-1), plo.max(-1)))
    gate = meta.confidence_gate
    wt_used = jnp.where(wt_conf >= gate, wt, UF)
    pb_used = jnp.where(pb_conf >= gate, bucket, 3)
    return {"workload_type": wt, "workload_conf": wt_conf,
            "p95_bucket": bucket, "p95_conf": pb_conf,
            "workload_type_used": wt_used, "p95_bucket_used": pb_used,
            "conservative": (wt_conf < gate) | (pb_conf < gate)}


def bucket_to_p95_jnp(bucket: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of `core.predictor.bucket_to_p95` (bucket midpoint)."""
    return (bucket.astype(jnp.float32) * 25.0 + 12.5) / 100.0
