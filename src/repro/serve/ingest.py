"""Per-host ingest: timestamp-merged arrival/departure streams.

The paper's production setting has many hosts feeding placement
concurrently — there is no global arrival queue in Azure's deployment.
This module removes the serve pipeline's last single-host stage (the
one host-side micro-batching queue of DESIGN.md §9) and replaces it
with the cross-host ingest subsystem of DESIGN.md §11
(runbook: docs/ingest.md):

  * **One queue per host.** Each ingest host owns a `HostQueue` — a
    FIFO of *stamped* event chunks (arrival micro-batches and
    departure batches); stamps are non-decreasing within a chunk and
    every chunk starts strictly after the host's last stamp. Hosts
    never talk to each other; pushing is a local append.
  * **Deterministic timestamp merge.** `IngestMux.poll` runs a stable
    watermark-based k-way merge over the host queues: only events
    with ``t <= min over hosts of last-pushed t`` are released (no
    host can later push an earlier event), in ``(t, host_id, seq)``
    order — ties across hosts break toward the smaller host id, ties
    within a host toward the earlier push. The merge walks the K
    sorted host windows with vectorized two-way merges
    (`numpy.searchsorted`); the full stream is **never sorted** and
    never lives in one queue.
  * **Departures ride the same streams.** A host's departure batches
    interleave with its arrivals at their stamped position, so freed
    capacity and power tokens become visible to later arrivals in one
    deterministic order — the sharded pipeline credits each shard's
    token pool from per-shard departure batches
    (`serve.sharding.consume_departures`) instead of a pre-routed
    host array.

When every event carries a globally unique timestamp the merged order
— and therefore every placement decision downstream — is invariant to
how events were dealt across host queues (asserted in
`tests/test_serve_ingest.py`). With one host the merge is the
identity and the pipeline degenerates to the single-queue path it
replaced.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple

import numpy as np

from repro.sim.telemetry import ArrivalBatch

#: Event kinds in a merged stream (`MergedEvents.kind`).
ARRIVAL = 0
DEPARTURE = 1
CAPPING = 2


@dataclass
class DepartureBatch:
    """Struct-of-arrays batch of VM departures — the departure twin of
    `repro.sim.telemetry.ArrivalBatch` (global server ids; negative
    ids are ignored by every consumer). Rows with ``cores < 0`` are
    *pinned arrivals* (an exact placement onto `server` — the encoding
    `serve.mitigation` uses for the arrive leg of a migration pair):
    `remove_batch` and the sharded pool credit are sign-symmetric, so
    the same consumers handle both directions."""
    server: np.ndarray              # (B,) int32 — global server id
    cores: np.ndarray               # (B,) float32
    p95_eff: np.ndarray             # (B,) float32 — p95 recorded at placement
    is_uf: np.ndarray               # (B,) bool
    mem_gb: np.ndarray = None       # (B,) float32 — GB recorded at placement

    def __post_init__(self):
        if self.mem_gb is None:
            self.mem_gb = np.zeros_like(
                np.asarray(self.cores, np.float32))

    def __len__(self) -> int:
        return len(self.server)


@dataclass
class CapBatch:
    """Struct-of-arrays batch of per-chassis power samples — the third
    stream-event kind (`CAPPING`), feeding the online power-emergency
    plane (`repro.serve.emergency`, DESIGN.md §12).

    A sample at/above the alarm threshold is a *cap* event (the
    emergency controller apportions a cut at the event's merged
    position); a sample below it is an *uncap* event (it starts or
    advances the lift clock). Routing raw samples instead of
    pre-chewed cap/uncap verdicts keeps every host stateless — the
    hysteresis lives in one place, the emergency state, and applies in
    deterministic merged order."""
    chassis: np.ndarray             # (B,) int32 — global chassis id
    power_w: np.ndarray             # (B,) float32 — sampled chassis draw

    def __len__(self) -> int:
        return len(self.chassis)


def slice_soa(batch, lo: int, hi: int):
    """Row-slice a struct-of-arrays dataclass (`ArrivalBatch` or
    `DepartureBatch`)."""
    cls = type(batch)
    return cls(*(getattr(batch, f.name)[lo:hi]
                 for f in dataclasses.fields(cls)))


def _concat_soa(cls, parts: list):
    """Concatenate struct-of-arrays dataclass batches. An empty parts
    list yields the typed empty batch — column dtypes must survive
    (downstream indexing and the jitted serve kernels depend on
    them)."""
    if not parts:
        return _empty_of(cls)
    return cls(*(np.concatenate([getattr(p, f.name) for p in parts])
                 for f in dataclasses.fields(cls)))


def empty_departures() -> DepartureBatch:
    """A zero-length `DepartureBatch` (typed empty columns)."""
    return DepartureBatch(np.empty(0, np.int32), np.empty(0, np.float32),
                          np.empty(0, np.float32), np.empty(0, bool),
                          np.empty(0, np.float32))


def empty_arrivals() -> ArrivalBatch:
    """A zero-length `ArrivalBatch` (typed empty columns)."""
    return ArrivalBatch(np.empty(0, np.int32), np.empty(0, np.float32),
                        np.empty(0, np.float32), np.empty(0, np.int32),
                        np.empty(0, bool), np.empty(0, np.float32),
                        np.empty(0, np.float32))


def empty_caps() -> CapBatch:
    """A zero-length `CapBatch` (typed empty columns)."""
    return CapBatch(np.empty(0, np.int32), np.empty(0, np.float32))


#: Payload batch type / empty-batch factory of each event kind,
#: indexed by kind code.
_KIND_CLS = (ArrivalBatch, DepartureBatch, CapBatch)
_KIND_EMPTY = (empty_arrivals, empty_departures, empty_caps)
_N_KINDS = len(_KIND_CLS)


def _empty_of(cls):
    return _KIND_EMPTY[_KIND_CLS.index(cls)]()


class HostQueue:
    """One ingest host's local event queue.

    Events are pushed in stamped chunks (an `ArrivalBatch` or a
    `DepartureBatch` plus per-row timestamps); stamps are
    non-decreasing within a chunk (ties keep push order — the seq
    tie-break) and every chunk must start strictly after the host's
    last stamp. That monotonicity is what lets the mux release events
    at or below the fleet watermark without risking a late
    out-of-order push. Pushing is purely local: no lock, no
    cross-host traffic.
    """

    def __init__(self, host_id: int):
        self.host_id = int(host_id)
        self._chunks: list = []       # [stamps, kind, payload, offset]
        self._last_t = -np.inf
        self._closed = False
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def watermark(self) -> float:
        """Highest timestamp this host can no longer push below:
        its last-pushed stamp, ``+inf`` once closed, ``-inf`` while it
        has never pushed (an idle host holds the whole merge back —
        close it or advance its clock with `heartbeat`)."""
        return np.inf if self._closed else self._last_t

    def _stamp(self, t, n: int) -> np.ndarray:
        if self._closed:
            raise ValueError(f"host {self.host_id} is closed")
        if t is None:
            base = 0.0 if np.isinf(self._last_t) else self._last_t
            stamps = base + np.arange(1, n + 1, dtype=np.float64)
        else:
            stamps = np.broadcast_to(
                np.asarray(t, np.float64), (n,)).copy() \
                if np.ndim(t) == 0 else np.asarray(t, np.float64)
            if stamps.shape != (n,):
                raise ValueError(
                    f"need {n} stamps, got shape {stamps.shape}")
        if n and not (stamps[0] > self._last_t
                      and (np.diff(stamps) >= 0).all()):
            raise ValueError(
                f"host {self.host_id}: chunk stamps must be "
                f"non-decreasing and start strictly after the last "
                f"push (last={self._last_t})")
        return stamps

    def heartbeat(self, t) -> None:
        """Advance this host's clock to `t` without pushing events —
        the idle host's promise that nothing earlier than `t` is
        coming, so it stops holding the fleet watermark back."""
        if self._closed:
            raise ValueError(f"host {self.host_id} is closed")
        t = float(t)
        if not t > self._last_t:
            raise ValueError(
                f"host {self.host_id}: heartbeat {t} must be strictly "
                f"after the last stamp ({self._last_t})")
        self._last_t = t

    def _push(self, kind: int, batch, t) -> None:
        """Shared append of one stamped chunk of any event kind (an
        empty batch with a scalar `t` degrades to a `heartbeat`)."""
        if not len(batch):
            if t is not None and np.ndim(t) == 0:
                self.heartbeat(t)
            return
        stamps = self._stamp(t, len(batch))
        self._chunks.append([stamps, kind, batch, 0])
        self._last_t = float(stamps[-1])
        self._n += len(batch)

    def push_arrivals(self, batch: ArrivalBatch, t=None) -> None:
        """Append a stamped arrival chunk. `t`: per-row stamps ((B,)
        array, non-decreasing, first strictly after the host's last
        push), a scalar stamping the whole chunk, or None for the
        host-local unit clock (last + 1, +2, ...). An empty batch with
        a scalar `t` is a `heartbeat`."""
        self._push(ARRIVAL, batch, t)

    def push_departures(self, batch: DepartureBatch, t=None) -> None:
        """Append a stamped departure chunk (same stamping contract as
        `push_arrivals` — all kinds share the host's clock)."""
        self._push(DEPARTURE, batch, t)

    def push_caps(self, batch: CapBatch, t=None) -> None:
        """Append a stamped chassis power-sample chunk (`CAPPING` — the
        emergency plane's cap/uncap events; same stamping contract as
        `push_arrivals`, all three kinds share the host's clock)."""
        self._push(CAPPING, batch, t)

    def close(self) -> None:
        """Mark the stream ended: the host's watermark becomes +inf so
        it never again holds the fleet merge back."""
        self._closed = True

    def _take(self, up_to: float):
        """Consume this host's window of events with ``t <= up_to``:
        returns (stamps, kind, per-kind payload batches, kind-local
        index) in push order. Chunks are internally sorted, so the cut
        is one searchsorted per touched chunk."""
        ts, kinds, kidx = [], [], []
        parts = [[] for _ in range(_N_KINDS)]
        counts = [0] * _N_KINDS
        keep = 0
        for chunk in self._chunks:
            stamps, kind, payload, off = chunk
            hi = int(np.searchsorted(stamps[off:], up_to, side="right")) \
                + off
            if hi > off:
                ts.append(stamps[off:hi])
                kinds.append(np.full(hi - off, kind, np.int8))
                kidx.append(counts[kind] + np.arange(hi - off))
                parts[kind].append(slice_soa(payload, off, hi))
                counts[kind] += hi - off
                self._n -= hi - off
                chunk[3] = hi
            if hi < len(stamps):
                self._chunks[keep] = chunk
                keep += 1
        del self._chunks[keep:]
        if not ts:
            return None
        return (np.concatenate(ts), np.concatenate(kinds),
                tuple(_concat_soa(cls, p)
                      for cls, p in zip(_KIND_CLS, parts)),
                np.concatenate(kidx).astype(np.int64))


class MergedEvents(NamedTuple):
    """One poll's released events in merged ``(t, host, seq)`` order.

    `kind[e]` says whether event *e* is an arrival, a departure, or a
    chassis power sample; the payload rows live packed (in merged
    order, per kind) in `arrivals` / `departures` / `caps`, so
    consecutive same-kind events form contiguous row runs — `runs()`
    walks them."""
    t: np.ndarray                   # (E,) f64 — merged stamps
    host: np.ndarray                # (E,) i32 — source host
    kind: np.ndarray                # (E,) i8  — ARRIVAL|DEPARTURE|CAPPING
    arrivals: ArrivalBatch          # arrival-event rows, merged order
    departures: DepartureBatch      # departure-event rows, merged order
    caps: CapBatch                  # power-sample rows, merged order

    def __len__(self) -> int:
        return len(self.t)

    def runs(self):
        """Yield ``(kind, lo, hi)`` maximal same-kind runs; (lo, hi)
        index into the kind's packed batch (`arrivals` for ARRIVAL
        runs, `departures` for DEPARTURE runs, `caps` for CAPPING
        runs)."""
        if not len(self.kind):
            return
        bounds = np.flatnonzero(np.diff(self.kind)) + 1
        starts = np.concatenate([[0], bounds, [len(self.kind)]])
        cursors = [0] * _N_KINDS
        for s, e in zip(starts[:-1], starts[1:]):
            k, n = int(self.kind[s]), int(e - s)
            yield k, cursors[k], cursors[k] + n
            cursors[k] += n


def _merge_two(a: dict, b: dict) -> dict:
    """Stable two-way merge of two sorted event windows. Every host id
    in `a` must be smaller than every host id in `b`, so an exact
    timestamp tie resolves toward `a` (``side='right'``) — exactly the
    (t, host_id) order the k-way merge promises."""
    pos = np.searchsorted(a["t"], b["t"], side="right")
    n = len(a["t"]) + len(b["t"])
    from_b = np.zeros(n, bool)
    from_b[pos + np.arange(len(b["t"]))] = True
    out = {}
    for key in a:
        va, vb = a[key], b[key]
        merged = np.empty(n, va.dtype)
        merged[~from_b] = va
        merged[from_b] = vb
        out[key] = merged
    return out


def _merge_windows(windows: list) -> dict | None:
    """Tournament-reduce the per-host windows with `_merge_two`:
    merging *adjacent* pairs keeps every left window's host ids below
    every right window's (inputs are in host-id order), so ties stay
    correct at every level — and each event is copied O(log K) times,
    not O(K) as a left fold would."""
    if not windows:
        return None
    while len(windows) > 1:
        windows = [_merge_two(windows[i], windows[i + 1])
                   if i + 1 < len(windows) else windows[i]
                   for i in range(0, len(windows), 2)]
    return windows[0]


def kway_merge(stamps_by_host: list) -> tuple:
    """Stable watermark-free k-way merge of per-host stamp arrays.

    Each input array must be sorted (a host stream is); returns
    ``(host, idx)`` — the merged order as (source host, index within
    that host's array), sorted by ``(t, host, seq)`` with ties broken
    toward the smaller host id and, within a host, the earlier event.
    This is the exact merge `IngestMux` runs per poll, exposed for the
    scheduler simulation and for oracle tests (it must agree with an
    ``np.lexsort`` of the concatenated keys)."""
    merged = _merge_windows(
        [{"t": np.asarray(s, np.float64),
          "host": np.full(len(s), h, np.int32),
          "idx": np.arange(len(s), dtype=np.int64)}
         for h, s in enumerate(stamps_by_host)])
    if merged is None:
        return (np.empty(0, np.int32), np.empty(0, np.int64))
    return merged["host"], merged["idx"]


class IngestMux:
    """N per-host event queues + the deterministic timestamp merge.

    The mux is the cross-host ingest stage of the serve pipeline
    (DESIGN.md §11): producers push stamped arrival/departure chunks
    into their own `HostQueue`; `poll` releases the merged prefix of
    events no host can still get in front of (the fleet watermark);
    `drain` releases everything regardless of watermark (end of
    stream, or a flush). There is no global queue and the merge never
    sorts the full stream — it k-way-merges the K already-sorted host
    windows."""

    def __init__(self, n_hosts: int = 1):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        self.hosts = [HostQueue(h) for h in range(n_hosts)]

    @property
    def n_hosts(self) -> int:
        """Number of per-host queues."""
        return len(self.hosts)

    @property
    def n_pending(self) -> int:
        """Events pushed but not yet released by a poll/drain."""
        return sum(len(h) for h in self.hosts)

    @property
    def watermark(self) -> float:
        """Fleet watermark: ``min`` over hosts of their last-pushed
        stamp — the largest t no host can still push at or below."""
        return min(h.watermark for h in self.hosts)

    def submit_to(self, host: int, batch: ArrivalBatch, t=None) -> None:
        """Push a stamped arrival chunk into `host`'s queue."""
        self.hosts[host].push_arrivals(batch, t)

    def depart_to(self, host: int, batch: DepartureBatch,
                  t=None) -> None:
        """Push a stamped departure chunk into `host`'s queue."""
        self.hosts[host].push_departures(batch, t)

    def cap_to(self, host: int, batch: CapBatch, t=None) -> None:
        """Push a stamped chassis power-sample chunk into `host`'s
        queue (the emergency plane's cap/uncap events)."""
        self.hosts[host].push_caps(batch, t)

    def heartbeat(self, host: int, t) -> None:
        """Advance `host`'s clock to `t` without events (see
        `HostQueue.heartbeat`) — the idle-host escape hatch."""
        self.hosts[host].heartbeat(t)

    def close(self, host: int) -> None:
        """Close one host's stream (its watermark becomes +inf)."""
        self.hosts[host].close()

    def _emit(self, up_to: float) -> MergedEvents:
        taken = [(h.host_id, h._take(up_to)) for h in self.hosts]
        windows = []
        by_host = [{} for _ in range(_N_KINDS)]
        for hid, w in taken:
            if w is None:
                continue
            ts, kinds, batches, kidx = w
            windows.append({"t": ts,
                            "host": np.full(len(ts), hid, np.int32),
                            "kind": kinds, "kidx": kidx})
            for k in range(_N_KINDS):
                by_host[k][hid] = batches[k]
        merged = _merge_windows(windows)
        if merged is None:
            return MergedEvents(np.empty(0), np.empty(0, np.int32),
                                np.empty(0, np.int8), empty_arrivals(),
                                empty_departures(), empty_caps())

        def pack(empty, kind):
            # the typed empty batch is the dtype authority: a host
            # window may hold zero rows of this kind, and its columns
            # must not leak a default dtype into the merged batch
            sel = merged["kind"] == kind
            n = int(sel.sum())
            if n == 0:
                return empty
            src_host, src_idx = merged["host"][sel], merged["kidx"][sel]
            cols = []
            for f in dataclasses.fields(type(empty)):
                col = np.empty(n, getattr(empty, f.name).dtype)
                for hid, b in by_host[kind].items():
                    mine = src_host == hid
                    if mine.any():
                        col[mine] = getattr(b, f.name)[src_idx[mine]]
                cols.append(col)
            return type(empty)(*cols)

        return MergedEvents(
            merged["t"], merged["host"], merged["kind"],
            pack(empty_arrivals(), ARRIVAL),
            pack(empty_departures(), DEPARTURE),
            pack(empty_caps(), CAPPING))

    def poll(self) -> MergedEvents:
        """Release every event at or below the fleet watermark, in
        merged ``(t, host, seq)`` order. Safe: per-host stamps are
        strictly increasing, so no host can later push an event that
        belonged before anything released here."""
        w = self.watermark
        if np.isneginf(w):
            return self._emit(-np.inf)
        return self._emit(w)

    def drain(self) -> MergedEvents:
        """Release everything currently queued, watermark ignored (in
        the same merged order). Deterministic given the queue contents
        — used by `ServePipeline.flush` and at end of stream. Queues
        stay open; later pushes must still advance each host's
        clock."""
        return self._emit(np.inf)
