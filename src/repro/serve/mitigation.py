"""Mitigation planning: migrate persistently capped critical VMs
(DESIGN.md §12, docs/emergency.md).

Criticality-aware capping (`repro.serve.emergency`) protects critical
VMs from *transient* emergencies; a chassis that stays capped past the
dwell threshold with its critical level throttled needs its load
*moved*, not shaved (the paper's §V mitigation: "persistently capped
critical VMs are migrated to chassis with headroom"). This module
plans those moves deterministically and expresses them in the ingest
subsystem's own vocabulary, so everything PR 4 proved about
cross-host streams carries over:

  * **Plan** — `plan_migrations` walks the dwell-flagged chassis in id
    order and greedily moves their *cheapest* critical VMs (smallest
    committed ``p95*cores`` — least power to re-home, tie-broken by
    registry order) to the chassis with the most power headroom that
    can actually hold them, until the source's offered draw fits back
    under the capping target. Working copies of the aggregates see
    every earlier move, so the plan is a pure deterministic function
    of its inputs — two hosts planning from the same snapshot emit the
    same plan.
  * **Paired depart/arrive events** — `MigrationPlan.as_events` turns
    each move into a departure row on the source server plus a
    *pinned* arrival on the destination, encoded as a negated-cores
    `DepartureBatch` row: `serve.placement.remove_batch` with
    ``cores < 0`` is exactly a placement, and the sharded departure
    consumer (`serve.sharding.consume_departures`) credits the freed
    ``p95*cores`` tokens to the source shard's pool while the negated
    row debits the destination shard's — token totals are conserved
    through a full cap -> migrate -> uncap cycle (asserted in
    `tests/test_serve_emergency.py`). `paired_stamps` gives each pair
    adjacent unique timestamps, so the merged stream orders depart
    before arrive and the whole plan is invariant to how its rows are
    dealt across ingest hosts.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serve.emergency import (CRIT_UF, EmergencyConfig,
                                   sampled_power)
from repro.serve.ingest import DepartureBatch


@dataclass
class LiveVMs:
    """Struct-of-arrays registry of the VMs currently placed — the
    per-VM view the aggregate-only serve state cannot reconstruct, so
    the component that owns placements (the scheduler simulation, or a
    production inventory service) supplies it. `token` is the caller's
    stable VM identity (defaults to the row index)."""
    server: np.ndarray              # (V,) int32 — current server
    cores: np.ndarray               # (V,) float
    p95_eff: np.ndarray             # (V,) float — p95 at placement
    is_uf: np.ndarray               # (V,) bool
    token: np.ndarray = None        # (V,) int64 — caller's VM id
    mem_gb: np.ndarray = None       # (V,) float — GB at placement

    def __post_init__(self):
        if self.token is None:
            self.token = np.arange(len(self.server), dtype=np.int64)
        if self.mem_gb is None:
            self.mem_gb = np.zeros(len(self.server), np.float64)

    def __len__(self) -> int:
        return len(self.server)


@dataclass
class MigrationPlan:
    """One deterministic batch of planned moves, in plan order."""
    vm: np.ndarray                  # (M,) int64 — row into the registry
    token: np.ndarray               # (M,) int64 — caller's VM id
    src_server: np.ndarray          # (M,) int32
    dst_server: np.ndarray          # (M,) int32
    cores: np.ndarray               # (M,) float
    p95_eff: np.ndarray             # (M,) float
    is_uf: np.ndarray               # (M,) bool
    mem_gb: np.ndarray = None       # (M,) float — GB moving with the VM

    def __post_init__(self):
        if self.mem_gb is None:
            self.mem_gb = np.zeros(len(self.vm), np.float64)

    def __len__(self) -> int:
        return len(self.vm)

    def as_events(self) -> tuple:
        """The plan as paired stream events: ``(departs, arrives)``
        `DepartureBatch` pairs, row i of each being move i. The arrive
        leg is the *pinned placement* encoding — the same server-keyed
        wire format with negated cores, which `remove_batch` and the
        sharded pool credit turn into an exact placement + token
        debit. Push row i of `departs` strictly before row i of
        `arrives` (see `paired_stamps`)."""
        dep = DepartureBatch(self.src_server.astype(np.int32),
                             self.cores.astype(np.float32),
                             self.p95_eff.astype(np.float32),
                             self.is_uf.astype(bool),
                             self.mem_gb.astype(np.float32))
        arr = DepartureBatch(self.dst_server.astype(np.int32),
                             (-self.cores).astype(np.float32),
                             self.p95_eff.astype(np.float32),
                             self.is_uf.astype(bool),
                             (-self.mem_gb).astype(np.float32))
        return dep, arr

    def paired_stamps(self, t0: float, eps: float = 1e-7) -> tuple:
        """``(depart_t, arrive_t)`` stamps strictly after `t0`: move
        i departs at ``t0 + (2i+1)*eps`` and arrives at
        ``t0 + (2i+2)*eps`` — globally unique, depart-before-arrive
        per pair, plan-ordered across pairs. Unique stamps are what
        make the merged event order (and therefore every downstream
        decision) invariant to which ingest host each row lands on."""
        i = np.arange(len(self.vm), dtype=np.float64)
        return t0 + (2 * i + 1) * eps, t0 + (2 * i + 2) * eps


def _empty_plan() -> MigrationPlan:
    return MigrationPlan(np.empty(0, np.int64), np.empty(0, np.int64),
                         np.empty(0, np.int32), np.empty(0, np.int32),
                         np.empty(0, np.float64), np.empty(0, np.float64),
                         np.empty(0, bool), np.empty(0, np.float64))


def plan_migrations(cfg: EmergencyConfig, live: LiveVMs,
                    chassis_of: np.ndarray, free_cores: np.ndarray,
                    rho_lv: np.ndarray, util: float, due: np.ndarray,
                    max_moves_per_chassis: int = 2,
                    max_moves: int = 32, *,
                    mem_chassis: np.ndarray = None,
                    gb_cap: np.ndarray = None) -> MigrationPlan:
    """Plan migrations for every dwell-flagged chassis.

    chassis_of: (S,) server->chassis; free_cores: (S,) current free
    cores; rho_lv: (C, L) committed p95*cores per criticality level
    (`serve.emergency.chassis_rho_levels`); util: the current
    utilization sample (the emergency plane's view of how hot the
    commitment is running); due: (C,) bool from
    `serve.emergency.mitigation_due`.

    Per due chassis (ascending id): move its cheapest critical VMs —
    smallest ``p95*cores``, ties toward the earlier registry row —
    to the eligible chassis with the most post-move power headroom
    (ties toward the smaller chassis id; destination server is the
    emptiest feasible blade, ties toward the smaller id), until the
    source's offered draw fits under ``cfg.target_w`` or the move caps
    run out. A destination is eligible while it is not itself due and
    its post-move draw stays under the alarm threshold — mitigation
    must never *create* an emergency. All greedy state lives in
    working copies, so the returned plan is a pure function of the
    inputs (asserted under event permutation in tests).

    mem_chassis/gb_cap: (C,) committed GB and GB capacity per chassis
    (`DeviceClusterState.res_peak[:, R_GB]` and the admission
    ceiling's GB column). When given, a destination must also hold
    the VM's memory — the watt-only planner treated memory as free
    and could pick a chassis with cores but no GB, wedging the move
    at execution time. ``None`` (either) disables the check (the
    scalar-era behavior)."""
    due = np.asarray(due, bool)
    if not due.any() or not len(live):
        return _empty_plan()
    chassis_of = np.asarray(chassis_of)
    n_chassis = rho_lv.shape[0]
    free = np.asarray(free_cores, np.float64).copy()
    rho = np.asarray(rho_lv, np.float64).copy()
    util = float(util)
    check_mem = mem_chassis is not None and gb_cap is not None
    if check_mem:
        mem_c = np.asarray(mem_chassis, np.float64).copy()
        cap_gb = np.broadcast_to(
            np.asarray(gb_cap, np.float64), (n_chassis,))
    # per-chassis server lists, id-ordered (deterministic dst pick)
    servers_of = [np.flatnonzero(chassis_of == c)
                  for c in range(n_chassis)]
    vm_chassis = chassis_of[live.server]
    w_vm = np.asarray(live.p95_eff, np.float64) \
        * np.asarray(live.cores, np.float64)
    moved = np.zeros(len(live), bool)

    def offered(c: int) -> float:
        return float(sampled_power(
            cfg, rho[c], util, np.zeros(rho.shape[-1], np.int32),
            False, np))

    rows = {"vm": [], "token": [], "src": [], "dst": [], "cores": [],
            "p95": [], "uf": [], "mem": []}
    for c in np.flatnonzero(due):
        # cheapest critical VMs on this chassis, registry order on ties
        cand = np.flatnonzero((vm_chassis == c) & np.asarray(live.is_uf)
                              & ~moved)
        cand = cand[np.argsort(w_vm[cand], kind="stable")]
        moves_left = max_moves_per_chassis
        for v in cand:
            if moves_left == 0 or len(rows["vm"]) >= max_moves:
                break
            if offered(c) <= cfg.target_w:
                break
            cores_v = float(live.cores[v])
            mem_v = float(live.mem_gb[v])
            # eligible destinations: not due, can hold the VM (cores
            # on a blade AND GB on the chassis), and stay under the
            # alarm threshold after taking it
            dst_c, dst_s, best_head = -1, -1, -np.inf
            for c2 in range(n_chassis):
                if c2 == c or due[c2]:
                    continue
                if check_mem and mem_c[c2] + mem_v > cap_gb[c2]:
                    continue
                srv = servers_of[c2]
                fit = srv[free[srv] >= cores_v]
                if not len(fit):
                    continue
                after = rho[c2].copy()
                after[CRIT_UF] += w_vm[v]
                p_after = float(sampled_power(
                    cfg, after, util, np.zeros(rho.shape[-1], np.int32),
                    False, np))
                head = cfg.alert_w - p_after
                if head <= 0 or head <= best_head:
                    continue
                dst_c, best_head = c2, head
                dst_s = int(fit[np.argmax(free[fit])])
            if dst_c < 0:
                continue
            src_s = int(live.server[v])
            free[src_s] += cores_v
            free[dst_s] -= cores_v
            rho[c, CRIT_UF] -= w_vm[v]
            rho[dst_c, CRIT_UF] += w_vm[v]
            if check_mem:
                mem_c[c] -= mem_v
                mem_c[dst_c] += mem_v
            moved[v] = True
            moves_left -= 1
            rows["vm"].append(int(v))
            rows["token"].append(int(live.token[v]))
            rows["src"].append(src_s)
            rows["dst"].append(dst_s)
            rows["cores"].append(cores_v)
            rows["p95"].append(float(live.p95_eff[v]))
            rows["uf"].append(bool(live.is_uf[v]))
            rows["mem"].append(mem_v)
    return MigrationPlan(
        np.asarray(rows["vm"], np.int64),
        np.asarray(rows["token"], np.int64),
        np.asarray(rows["src"], np.int32),
        np.asarray(rows["dst"], np.int32),
        np.asarray(rows["cores"], np.float64),
        np.asarray(rows["p95"], np.float64),
        np.asarray(rows["uf"], bool),
        np.asarray(rows["mem"], np.float64))
