"""Online prediction-and-admission serving pipeline (paper §II-D).

`ServePipeline` is the device-resident Resource-Central path from
arrival stream to placement decision: a micro-batching ingest queue
feeds one compiled flow per batch —

    featurize (serve.featurizer)  ->  two-stage inference + gating
    (serve.inference)  ->  Algorithm-1 scoring with fused power
    admission (serve.placement / serve.admission)

with all model operands, subscription aggregates, and cluster
aggregates living on device between batches. The paper's daily retrain
maps to `hot_swap`: the new forest is packed into the standby model
buffer while the active one keeps serving, then an atomic flip routes
the next batch to it — no arrival is dropped and no recompilation
happens (retrained forests share shapes, so the serving jits are
already specialized).

`ShardedServePipeline` swaps the placement stage for the sharded
consistent-placement protocol (`serve.sharding`) when the cluster is
partitioned over a device mesh — everything upstream of placement is
shard-agnostic and shared.

Arrivals and departures enter through the cross-host ingest subsystem
(`serve.ingest`, DESIGN.md §11): each ingest host owns its own stamped
queue and a deterministic watermark-based timestamp merge produces the
micro-batches. `submit`/`depart` are the 1-host special case;
`submit_to`/`depart_to` are the per-host path.
"""
from __future__ import annotations

import contextlib
import time
import warnings
from dataclasses import dataclass, field, replace
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features
from repro.core.placement import SchedulerPolicy
from repro.core.power_model import ServerPowerModel
from repro.core.predictor import UF, PredictionService
from repro.core.resources import N_RESOURCES, RESOURCES, ResourceVector
from repro.obs import LEVEL_NAMES, Observability
from repro.serve import (admission, adaptive, ballooning, emergency,
                         placement, sharding)
from repro.serve.featurizer import (
    SubscriptionTable, featurize_batch, ingest_population, shard_table,
    table_from_history)
from repro.serve.inference import (
    bucket_to_p95_jnp, pack_service, resolve_kernel, served_query)
from repro.serve.ingest import (
    ARRIVAL, CAPPING, CapBatch, DepartureBatch, IngestMux, MergedEvents,
    slice_soa)
from repro.sim.telemetry import ArrivalBatch, Population


@dataclass(frozen=True)
class PlaneBundle:
    """Every control-plane attachment of a pipeline, in one field
    (DESIGN.md §16) — what used to sprawl across five constructor
    kwargs (``chassis_budget_w``, ``cluster_budget_w``,
    ``emergency_cfg``, ``adaptive_cfg``, ``obs``), now carried by
    `ServeConfig.planes` so a pipeline's whole wiring is one value you
    can name, log, and reuse.

    chassis_budget: per-chassis admission budget as a `ResourceVector`
        — the watts axis converts through the power model into the
        legacy rho ceiling, the cores/GB axes are ledger currency
        (`serve.admission.resource_caps_from_budget`); a power-only
        vector reproduces ``chassis_budget_w`` bit for bit.
    cluster_budget: sharded pipelines only — the global token-pool
        budget (`serve.sharding.resource_pool_from_budget`); a
        power-only vector reproduces ``cluster_budget_w``.
    emergency / adaptive / ballooning: the emergency-capping plane,
        the closed-loop oversubscription controller, and the memory
        ballooning rung between them and migration (ballooning
        requires emergency — its probe reuses the alarm arithmetic).
    obs: the observability plane (decision-neutral, host-side)."""
    chassis_budget: ResourceVector | None = None
    cluster_budget: ResourceVector | None = None
    emergency: emergency.EmergencyConfig | None = None
    adaptive: adaptive.AdaptiveConfig | None = None
    ballooning: ballooning.BallooningConfig | None = None
    obs: Observability | None = None


@dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 256
    kernel: str = "auto"            # 'pallas' | 'ref' | 'auto'
    policy: SchedulerPolicy = field(default_factory=SchedulerPolicy)
    n_ingest_hosts: int = 1         # per-host queues (serve.ingest)
    planes: PlaneBundle = field(default_factory=PlaneBundle)


@dataclass
class ServeResult:
    """Per-arrival decisions for one served batch (host arrays)."""
    server: np.ndarray              # (B,) int32; FAIL_* codes on reject
    workload_type: np.ndarray       # (B,) post-gating UF/NUF
    p95_bucket: np.ndarray          # (B,) post-gating bucket
    p95_eff: np.ndarray             # (B,) p95 recorded into aggregates
    conservative: np.ndarray        # (B,) bool — hit a confidence gate

    @property
    def admitted(self) -> np.ndarray:
        return self.server >= 0

    @property
    def n_admitted(self) -> int:
        return int(self.admitted.sum())

    @property
    def n_capacity_rejected(self) -> int:
        return int((self.server == placement.FAIL_CAPACITY).sum())

    @property
    def n_power_rejected(self) -> int:
        return int((self.server == placement.FAIL_POWER).sum())

    @property
    def n_token_rejected(self) -> int:
        """Rejections by an exhausted shard power-token pool — only the
        sharded pipeline under a `cluster_budget_w` produces these.
        admitted + capacity + power + token == batch size."""
        return int((self.server == placement.FAIL_TOKENS).sum())

    @property
    def n_conservative(self) -> int:
        return int(self.conservative.sum())


def _concat_results(parts: list) -> ServeResult:
    return ServeResult(*(np.concatenate([getattr(p, f) for p in parts])
                         for f in ("server", "workload_type", "p95_bucket",
                                   "p95_eff", "conservative")))


def _concat_batches(parts: list) -> ArrivalBatch:
    return ArrivalBatch(*(np.concatenate([getattr(p, f) for p in parts])
                          for f in ArrivalBatch.__dataclass_fields__))


@lru_cache(maxsize=None)
def _adaptive_step_fn(cfg: adaptive.AdaptiveConfig):
    """Compiled unsharded adaptive-controller scan: per-chassis
    criticality aggregates from the cluster state, then the masked
    stability-scoring + ratio step (`serve.adaptive.adaptive_step`)."""

    def fn(gamma_nuf, gamma_uf, chassis_servers, ast, pw, mask):
        rho_lv = emergency.chassis_rho_levels(gamma_nuf, gamma_uf,
                                              chassis_servers, jnp)
        return adaptive.adaptive_step(cfg, ast, rho_lv, pw, mask, jnp)

    return jax.jit(fn)


@lru_cache(maxsize=None)
def _cap_step_fn(cfg: emergency.EmergencyConfig):
    """Compiled unsharded emergency scan: per-chassis criticality
    aggregates from the cluster state, then the masked alarm +
    apportionment step (`serve.emergency.masked_step`)."""

    def fn(gamma_nuf, gamma_uf, chassis_servers, emer, pw, mask, ts):
        rho_lv = emergency.chassis_rho_levels(gamma_nuf, gamma_uf,
                                              chassis_servers, jnp)
        return emergency.masked_step(cfg, emer, rho_lv, pw, mask, ts,
                                     jnp)

    return jax.jit(fn)


@lru_cache(maxsize=None)
def _balloon_cap_step_fn(ecfg: emergency.EmergencyConfig,
                         bcfg: ballooning.BallooningConfig):
    """Compiled unsharded balloon-then-cap scan: the ballooning rung
    (`serve.ballooning.balloon_step` over the chassis NUF-memory
    ledger) absorbs what the NUF frequency floor cannot, and the
    masked emergency step consumes the DRAM-adjusted draws."""

    def fn(gamma_nuf, gamma_uf, chassis_servers, mem_nuf, emer, bst,
           pw, mask, ts):
        rho_lv = emergency.chassis_rho_levels(gamma_nuf, gamma_uf,
                                              chassis_servers, jnp)
        bst2, bout = ballooning.balloon_step(
            bcfg, ecfg, bst, rho_lv, pw, mem_nuf, mask, jnp)
        emer2, eout = emergency.masked_step(
            ecfg, emer, rho_lv, bout.power_adj_w, mask, ts, jnp)
        return emer2, bst2, eout, bout

    return jax.jit(fn)


#: Sentinel distinguishing "kwarg not passed" from an explicit None on
#: the deprecated constructor kwargs.
_UNSET = object()


def _legacy_planes(planes: PlaneBundle, what: str,
                   **kw) -> PlaneBundle:
    """Fold deprecated constructor kwargs into the `PlaneBundle`,
    warning once per call site. Tier-1 runs with
    ``-W error::DeprecationWarning``, so every in-repo caller uses the
    `ServeConfig.planes` front door — the shim exists for external
    callers and for the equivalence tests that pin old == new."""
    given = {k: v for k, v in kw.items() if v is not _UNSET}
    if not given:
        return planes
    warnings.warn(
        f"{', '.join(sorted(given))} as {what} constructor kwargs are "
        "deprecated; pass ServeConfig(planes=PlaneBundle(...)) "
        "(docs/resources.md has the migration table)",
        DeprecationWarning, stacklevel=3)
    fields = {}
    if "chassis_budget_w" in given:
        w = given.pop("chassis_budget_w")
        fields["chassis_budget"] = \
            None if w is None else ResourceVector(watts=float(w))
    if "cluster_budget_w" in given:
        w = given.pop("cluster_budget_w")
        fields["cluster_budget"] = \
            None if w is None else ResourceVector(watts=float(w))
    for old, new in (("emergency_cfg", "emergency"),
                     ("adaptive_cfg", "adaptive"), ("obs", "obs")):
        if old in given:
            fields[new] = given.pop(old)
    return replace(planes, **fields)


def _unique_chassis_windows(chassis: np.ndarray):
    """Split one merged CAPPING run into maximal prefixes with unique
    chassis ids, preserving order: the dense masked kernel applies one
    sample per chassis per call, so a window that samples a chassis
    twice becomes two sequential windows (hysteresis clocks see both,
    in merged order)."""
    lo, seen = 0, set()
    for i, c in enumerate(chassis):
        c = int(c)
        if c in seen:
            yield lo, i
            lo, seen = i, set()
        seen.add(c)
    if lo < len(chassis):
        yield lo, len(chassis)


class ServePipeline:
    """Stateful serving endpoint. Not thread-safe; one instance serves
    one cluster from one host — `ShardedServePipeline` is the
    multi-host/device path (DESIGN.md §10, docs/sharding.md)."""

    def __init__(self, service: PredictionService,
                 table: SubscriptionTable,
                 state: placement.DeviceClusterState,
                 cores_per_server: int,
                 config: ServeConfig | None = None,
                 chassis_budget_w=_UNSET,
                 power_model: ServerPowerModel | None = None,
                 blades_per_chassis: int | None = None,
                 emergency_cfg=_UNSET,
                 obs=_UNSET,
                 adaptive_cfg=_UNSET):
        config = config or ServeConfig()
        planes = _legacy_planes(config.planes, type(self).__name__,
                                chassis_budget_w=chassis_budget_w,
                                emergency_cfg=emergency_cfg, obs=obs,
                                adaptive_cfg=adaptive_cfg)
        if planes.ballooning is not None and planes.emergency is None:
            raise ValueError(
                "PlaneBundle.ballooning requires PlaneBundle.emergency "
                "— the ballooning rung probes the emergency plane's "
                "alarm arithmetic to size its reclaim")
        self.config = replace(config, planes=planes)
        self.table = table
        self.state = state
        # observability plane (repro.obs, DESIGN.md §14) — purely
        # host-side consumers of outputs the kernels already produce,
        # so obs on/off never changes a decision
        self.obs = planes.obs
        # ingest watermark (stamp of the newest drained merged run) —
        # the clock the windows/SLO/recorder pillars (DESIGN.md §17)
        # aggregate on; stays 0.0 until the first streamed event
        self._watermark = 0.0
        # direct serve() calls bypass the ingest merge, so their
        # decisions are not replayable — the flight recorder skips
        # them while this flag is up
        self._recorder_suspended = False
        self._batches = 0
        self._has_pool = False      # sharded subclass may flip this
        self._chassis_of_host = np.asarray(state.chassis_of)
        self._rule_idx = self._policy_rule_index(self.config.policy)
        self.cores_per_server = int(cores_per_server)
        self._kernel = resolve_kernel(self.config.kernel)
        # double-buffered model: index _active serves, 1-_active packs
        self._buffers = [pack_service(service), None]
        self._active = 0
        n_chassis = state.rho_max.shape[0]
        self.n_chassis = n_chassis
        if blades_per_chassis is None:
            blades_per_chassis = state.n_servers // n_chassis
        self.blades_per_chassis = blades_per_chassis
        self.power_model = power_model or ServerPowerModel()
        # (C, R) per-chassis admission ceilings over the joint
        # (watts, cores, GB) ledger (DESIGN.md §16); a power-only (or
        # absent) budget leaves the cores/GB columns +inf — vacuous,
        # decision-identical to the scalar watt ceiling
        self.res_cap = jnp.asarray(admission.resource_caps_from_budget(
            planes.chassis_budget or ResourceVector(),
            blades_per_chassis, n_chassis, self.power_model))
        if self.config.n_ingest_hosts < 1:
            raise ValueError(
                f"n_ingest_hosts must be >= 1, "
                f"got {self.config.n_ingest_hosts}")
        self.ingest = IngestMux(self.config.n_ingest_hosts)
        self._pending: list[ArrivalBatch] = []   # merged, awaiting batch
        self._queued = 0
        self.swaps = 0
        self.served = 0
        # power-emergency plane (serve.emergency, DESIGN.md §12)
        self.emergency_cfg = planes.emergency
        self._pending_caps: list[tuple] = []    # queued (chassis, pw, t)
        self.emergency = None
        self._alarms = 0
        self._cap_epoch = None      # first cap stamp; rebases clocks
        if self.emergency_cfg is not None:
            ecfg = self.emergency_cfg
            if ecfg.blades_per_chassis != self.blades_per_chassis:
                raise ValueError(
                    f"emergency_cfg.blades_per_chassis="
                    f"{ecfg.blades_per_chassis} does not match "
                    f"the pipeline's {self.blades_per_chassis} — the "
                    "static chassis floor (and every alarm and cut) "
                    "would be miscalibrated")
            self.emergency = self._init_emergency()
        # ballooning rung (serve.ballooning, DESIGN.md §16): fires on
        # the same CAPPING samples, between the NUF frequency floor and
        # migration
        self._balloon = None
        if planes.ballooning is not None:
            self._balloon = self._init_ballooning()
        # adaptive oversubscription controller (serve.adaptive,
        # DESIGN.md §15): CAPPING samples feed per-chassis stability
        # windows; the stepped ratio rescales the admission ceiling
        # (and, sharded, the free token pools) between micro-batches
        self.adaptive_cfg = planes.adaptive
        self._adaptive = None
        self._res_cap_base = self.res_cap
        # (R,) time-of-day conditioning multipliers
        # (`core.resources.trough_ratios`; watts axis pinned at 1.0 —
        # the breaker limit never ratchets); `set_resource_ratios`
        # installs a fresh sample
        self._res_ratios = np.ones(N_RESOURCES)
        self._ratio_dev = None      # adaptive ratio, device scalar
        self._ratio_prev = 1.0
        if self.adaptive_cfg is not None:
            acfg = self.adaptive_cfg
            if acfg.blades_per_chassis != self.blades_per_chassis:
                raise ValueError(
                    f"adaptive_cfg.blades_per_chassis="
                    f"{acfg.blades_per_chassis} does not match "
                    f"the pipeline's {self.blades_per_chassis} — power "
                    "samples would read back as the wrong utilization")
            self._adaptive = self._init_adaptive()

    @property
    def rho_cap(self):
        """(C,) watt-axis admission ceiling (rho units) — the legacy
        scalar view of the (C, R) `res_cap` ledger ceiling."""
        return self.res_cap[..., 0]

    def _init_ballooning(self):
        """Fresh all-deflated balloon state (unsharded layout)."""
        return ballooning.init_ballooning(
            self.n_chassis, xp=jnp, dtype=self.state.free_cores.dtype)

    def _init_emergency(self):
        """Fresh per-chassis emergency state (unsharded layout)."""
        return emergency.init_emergency(
            self.n_chassis, xp=jnp,
            dtype=self.state.free_cores.dtype)

    @property
    def emergency(self):
        """Current emergency-plane state. Reading it flushes any cap
        sub-windows still queued for fusion, so observers always see
        the state as of the last event pushed — queueing is a pure
        dispatch-count optimization, never a semantic lag."""
        self._flush_caps()
        return self._emergency

    @emergency.setter
    def emergency(self, value):
        self._emergency = value

    @property
    def alarms(self) -> int:
        """Cumulative alarm count across all applied sample windows
        (flushes queued windows first, like `emergency`)."""
        self._flush_caps()
        return self._alarms

    # -- adaptive oversubscription (serve.adaptive, DESIGN.md §15) ---------
    def _init_adaptive(self):
        """Fresh controller state (unsharded layout, ratio 1.0)."""
        return adaptive.init_adaptive(
            self.adaptive_cfg, self.n_chassis, xp=jnp,
            dtype=self.state.free_cores.dtype)

    @property
    def adaptive_state(self):
        """Current adaptive-controller state (None with the controller
        off). Unlike `emergency` there is nothing to flush — the
        controller steps eagerly when CAPPING events are consumed, so
        its ratio is already in force for the next micro-batch."""
        return self._adaptive

    @property
    def adaptive_ratio(self):
        """Current oversubscription ratio (1.0 with the controller
        off); the sharded pipeline returns the (N,) per-shard ratios."""
        if self._adaptive is None:
            return 1.0
        return float(np.asarray(self._adaptive.ratio))

    def _adaptive_scan(self, chassis, power_w) -> None:
        """Run one controller scan over a unique-chassis sample window
        and put the stepped ratio in force (unsharded path)."""
        dtype = self.state.free_cores.dtype
        pw, mask, _ = emergency.scatter_samples(
            self.n_chassis, chassis, power_w,
            np.zeros(len(np.asarray(chassis))), jnp, dtype)
        if self.obs is not None:
            self.obs.registry.counter(
                "serve_dispatch_total",
                help="compiled kernel dispatches, by call site",
                kind="adaptive_step").inc()
        fn = _adaptive_step_fn(self.adaptive_cfg)
        self._adaptive, out = fn(self.state.gamma_nuf,
                                 self.state.gamma_uf,
                                 self.state.chassis_servers,
                                 self._adaptive, pw, mask)
        self._apply_ratio(out)

    def _apply_ratio(self, out) -> None:
        """Rescale the effective watt budget to the stepped ratio —
        unsharded, that is the watts axis of the per-chassis admission
        ceiling (the device-side product keeps the scan sync-free when
        obs is off). With ``adaptive_cfg.hold_on_stale`` the *applied*
        ratio is additionally clamped to ``ratio_min`` while the
        prediction scorecard reports `model_stale`
        (`serve.adaptive.gate_ratio_on_stale`) — the controller state
        is untouched, so the ratio resumes when the model scores
        fresh; off by default, preserving obs on/off bit-identity."""
        self._ratio_dev = out.ratio
        if (self.adaptive_cfg is not None
                and self.adaptive_cfg.hold_on_stale
                and self.obs is not None
                and self.obs.quality is not None):
            self._ratio_dev = adaptive.gate_ratio_on_stale(
                self.adaptive_cfg, np.asarray(out.ratio),
                self.obs.quality.model_stale)
        self._refresh_caps()
        self._record_adaptive(out)

    def _axis_mult(self, dtype) -> jnp.ndarray:
        """(R,) effective per-axis ceiling multiplier: the adaptive
        controller's ratio on the watts axis times the diurnal
        conditioning on the cores/GB axes. Both default to exact 1.0,
        so with neither plane active the base ceiling passes through
        bit-for-bit (IEEE multiply by 1.0 is the identity)."""
        one = jnp.ones((), dtype)
        r = one if self._ratio_dev is None \
            else jnp.asarray(self._ratio_dev, dtype)
        return jnp.stack([r, one, one]) \
            * jnp.asarray(self._res_ratios, dtype)

    def _refresh_caps(self) -> None:
        """Recompute the effective admission ceiling from the base
        ceiling and the current per-axis multipliers (unsharded; the
        sharded override also retargets the token pools)."""
        self.res_cap = self._res_cap_base \
            * self._axis_mult(self._res_cap_base.dtype)

    def set_resource_ratios(self, ratios) -> None:
        """Install a fresh (R,) time-of-day conditioning sample
        (`core.resources.trough_ratios` of the current diurnal
        utilization): the cores/GB axes of every admission ceiling
        (and, sharded, token pool) rescale immediately — Coach-style
        ratcheting on the trough. The watts axis must be exactly 1.0
        (a breaker budget is a physical limit, never conditioned)."""
        ratios = np.asarray(ratios, np.float64)
        if ratios.shape != (N_RESOURCES,):
            raise ValueError(
                f"ratios must be ({N_RESOURCES},) over {RESOURCES}, "
                f"got shape {ratios.shape}")
        if ratios[0] != 1.0:
            raise ValueError(
                f"ratios[0] (watts) must be 1.0, got {ratios[0]} — "
                "the watt budget is a breaker limit and never "
                "ratchets (core.resources.trough_ratios pins it)")
        self._res_ratios = ratios
        self._refresh_caps()

    def _record_adaptive(self, out) -> None:
        """Export one controller decision: ratio gauge, step counters,
        and an `obs.audit.AdaptiveTrail` reason row — host-side
        consumers of outputs the kernel already returned."""
        if self.obs is None:
            return
        reg = self.obs.registry
        r = float(np.asarray(out.ratio))
        reg.gauge("adaptive_ratio",
                  help="oversubscription ratio of the adaptive "
                  "controller").set(r)
        reg.counter("adaptive_ratchet_total",
                    help="adaptive-controller up-steps taken").inc(
                        int(np.asarray(out.ratchet)))
        reg.counter("adaptive_backoff_total",
                    help="adaptive-controller down-steps taken").inc(
                        int(np.asarray(out.backoff)))
        if self.obs.adaptive is not None:
            ratchet = bool(np.asarray(out.ratchet))
            backoff = bool(np.asarray(out.backoff))
            self.obs.adaptive.record(
                t=time.time(), shard=-1, ratio=r,
                stable_frac=float(np.asarray(out.stable_frac)),
                n_known=int(np.asarray(out.n_known)),
                n_stable=int(np.asarray(out.n_stable)),
                action=1 if ratchet else (-1 if backoff else 0),
                reason=adaptive.decision_reason(
                    self._ratio_prev, r, int(np.asarray(out.n_known)),
                    ratchet, backoff, bool(np.asarray(out.hot))))
        self._ratio_prev = r

    # -- observability (repro.obs, DESIGN.md §14) --------------------------
    @staticmethod
    def _policy_rule_index(policy: SchedulerPolicy) -> int:
        """Admission-rule index recorded into the audit trail: 0 =
        packing rule only (NoRule baseline), 1 = power rule only, 2 =
        combined weighted aggregation (the paper's default)."""
        if not policy.use_power_rule or policy.power_weight == 0:
            return 0
        if policy.packing_weight == 0:
            return 1
        return 2

    def _span(self, name: str):
        """Span context for one pipeline stage (no-op without obs)."""
        if self.obs is not None:
            return self.obs.span(name)
        return contextlib.nullcontext()

    def _pool_tokens_left(self) -> float:
        """Remaining power tokens recorded into audit rows (+inf when
        no cluster watt budget bounds admission — the unsharded
        pipeline and unbudgeted sharded pipelines)."""
        return float("inf")

    def _record_batch(self, batch: ArrivalBatch, res: ServeResult,
                      raw=None) -> None:
        """Fold one served batch's decisions into the metrics registry,
        audit trail, and the §17 pillars (windows / scorecard / flight
        recorder) — a pure host-side reduction of outputs the
        placement kernel already returned (`placement.
        outcome_counters`, plus the raw head outputs fetched alongside
        when the quality pillar is on), so recording can never perturb
        a decision."""
        if self.obs is None:
            return
        reg = self.obs.registry
        self._batches += 1
        b = len(res.server)
        valid = np.ones(b, bool)
        cnt = placement.outcome_counters(
            res.server, valid, np.asarray(batch.cores), res.p95_eff,
            mem_gb=np.asarray(batch.memory_gb))
        reg.counter("serve_batches_total",
                    help="micro-batches served").inc()
        reg.counter("serve_arrivals_total",
                    help="arrivals decided").inc(b)
        reg.counter("serve_admits_total",
                    help="arrivals admitted").inc(cnt["admits"])
        for reason, key in (("capacity", "fail_capacity"),
                            ("power", "fail_power"),
                            ("tokens", "fail_tokens")):
            reg.counter("serve_rejects_total",
                        help="arrivals rejected, by reason",
                        reason=reason).inc(cnt[key])
        reg.counter("serve_conservative_total",
                    help="decisions that hit a confidence gate").inc(
                        res.n_conservative)
        reg.counter("serve_rho_admitted_total",
                    help="admitted sum(p95*cores), rho units").inc(
                        cnt["rho_admitted"])
        reg.counter("serve_cores_admitted_total",
                    help="admitted virtual cores").inc(
                        cnt["cores_admitted"])
        reg.counter("serve_gb_admitted_total",
                    help="admitted memory, GB").inc(cnt["gb_admitted"])
        if self.obs.audit is not None:
            srv = np.asarray(res.server)
            chassis = np.where(
                srv >= 0, self._chassis_of_host[np.maximum(srv, 0)], -1)
            self.obs.audit.record_batch(
                t=time.time(), batch=self._batches, servers=srv,
                chassis=chassis, rule=self._rule_idx,
                cores=np.asarray(batch.cores),
                is_uf=res.workload_type == UF, p95_eff=res.p95_eff,
                valid=valid, conservative=res.conservative,
                pool_left=self._pool_tokens_left())
        if self.obs.windows is not None:
            w, t = self.obs.windows, self._watermark
            w.observe(t, "arrivals", n=b)
            if cnt["admits"]:
                w.observe(t, "admits", n=int(cnt["admits"]))
            if b - cnt["admits"]:
                w.observe(t, "rejects", n=int(b - cnt["admits"]))
            if res.n_conservative:
                w.observe(t, "conservative", n=int(res.n_conservative))
            w.observe(t, "rho_admitted", float(cnt["rho_admitted"]))
        if self.obs.quality is not None and raw is not None:
            self.obs.quality.record(
                true_crit=np.asarray(batch.user_facing, np.int64),
                true_bucket=np.asarray(
                    features.p95_bucket(np.asarray(batch.p95_util)),
                    np.int64),
                crit_used=res.workload_type,
                bucket_used=res.p95_bucket,
                crit_raw=raw[0], crit_conf=raw[1],
                bucket_raw=raw[2], bucket_conf=raw[3],
                conservative=res.conservative)
        if (self.obs.recorder is not None
                and not self._recorder_suspended):
            self.obs.recorder.record_decision(
                np.asarray(res.server), self._watermark)
        self._obs_tick()

    def _obs_tick(self) -> None:
        """Advance the watermark-clock pillars (DESIGN.md §17): close
        tumbling windows the watermark passed, re-sample the SLO
        monitor from the registry counters, and evaluate the
        burn-rate alerts. Host-side only; no-op for pillars that are
        off."""
        if self.obs is None:
            return
        if self.obs.windows is not None:
            self.obs.windows.advance(self._watermark)
        if self.obs.slo is not None:
            self.obs.slo.sample(self._watermark, self.obs.registry)
            self.obs.slo.evaluate(self._watermark)

    def _record_sweep(self, sweep: placement.SweepCounters,
                      windows: int) -> None:
        """Fold one emergency sweep's in-scan counters into the
        registry. `windows` is host-tracked (the device struct cannot
        carry it — summing per-shard copies would overcount)."""
        if self.obs is None:
            return
        reg = self.obs.registry
        reg.counter("emergency_cap_windows_total",
                    help="cap sample windows applied").inc(windows)
        reg.counter("emergency_samples_total",
                    help="chassis power samples consumed").inc(
                        int(np.asarray(sweep.samples)))
        reg.counter("emergency_alarms_total",
                    help="power-emergency alarms raised").inc(
                        int(np.asarray(sweep.alarms)))
        cut_w = float(np.asarray(sweep.cut_w))
        reg.counter("emergency_cut_watts_total",
                    help="watts of reduction demanded past the "
                    "target").inc(cut_w)
        reg.counter("emergency_leftover_watts_total",
                    help="demanded watts no frequency floor could "
                    "absorb (RAPL backstop)").inc(
                        float(np.asarray(sweep.leftover_w)))
        if cut_w > 0.0:
            reg.histogram("emergency_cut_watts",
                          help="watts of cut demanded per sweep"
                          ).observe(cut_w)
        for level, w in zip(LEVEL_NAMES,
                            np.asarray(sweep.cut_by_level_w, np.float64)):
            reg.counter("emergency_level_cut_watts_total",
                        help="watts actually removed, by criticality "
                        "level",
                        level=level).inc(float(w))
        alarms = int(np.asarray(sweep.alarms))
        if self.obs.windows is not None:
            wp, t = self.obs.windows, self._watermark
            if alarms:
                wp.observe(t, "alarms", n=alarms)
            if cut_w > 0.0:
                wp.observe(t, "cut_watts", cut_w)
                wp.observe_hist("cut_watts", cut_w, lo=0.0, hi=2.0e4)
        if self.obs.quality is not None:
            self.obs.quality.observe_alarms(
                alarms, cut_w=cut_w,
                samples=int(np.asarray(sweep.samples)))
        if self.obs.recorder is not None and alarms:
            self.obs.recorder.mark_incident(
                self._watermark, alarms,
                {k: reg.value(k) for k in (
                    "emergency_alarms_total",
                    "emergency_cut_watts_total",
                    "emergency_leftover_watts_total",
                    "serve_arrivals_total")})
        self._obs_tick()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_history(cls, service: PredictionService, history: Population,
                     uf_labels: np.ndarray, n_servers: int,
                     cores_per_server: int, blades_per_chassis: int,
                     table_capacity: int | None = None, **kw):
        """Bootstrap table + empty cluster from an offline labeled
        history (the state a daily retrain hands the serving job)."""
        if table_capacity is None:
            table_capacity = max(
                (v.subscription for v in history.vms), default=0) + 1024
        table = table_from_history(history, uf_labels, table_capacity)
        chassis_of = np.arange(n_servers) // blades_per_chassis
        state = placement.fresh_state(n_servers, cores_per_server,
                                      chassis_of)
        return cls(service, table, state, cores_per_server,
                   blades_per_chassis=blades_per_chassis, **kw)

    # -- model hot-swap (the paper's daily retrain) ------------------------
    def hot_swap(self, new_service: PredictionService) -> None:
        """Pack the retrained forest into the standby buffer, then flip
        atomically. Serving calls between pack and flip keep using the
        old model; the queue is untouched, so no arrival is dropped."""
        standby = 1 - self._active
        self._buffers[standby] = pack_service(new_service)
        self._active = standby
        self.swaps += 1
        if self.obs is not None and self.obs.quality is not None:
            # the old model's confusion/calibration/drift say nothing
            # about the one now serving
            self.obs.quality.on_hot_swap()

    # -- telemetry ingestion (label-bootstrap loop) ------------------------
    def observe(self, history: Population, uf_labels: np.ndarray) -> None:
        """Fold freshly labeled telemetry into the subscription
        aggregates (incremental twin of recomputing
        `features.subscription_aggregates` offline)."""
        self.table = ingest_population(self.table, history, uf_labels)

    # -- serving -----------------------------------------------------------
    def submit(self, batch: ArrivalBatch) -> list[ServeResult]:
        """Ingest arrivals through the single queue; serve every full
        micro-batch. Returns the results that became ready (possibly
        empty — call `flush` to drain a partial tail batch). This is
        the 1-host special case of `submit_to` — pipelines configured
        with ``n_ingest_hosts > 1`` must say which host queue an
        arrival belongs to."""
        if self.config.n_ingest_hosts != 1:
            raise ValueError(
                "submit() is the single-queue (1-host) path; with "
                f"n_ingest_hosts={self.config.n_ingest_hosts} use "
                "submit_to(host, batch, t=...)")
        return self.submit_to(0, batch)

    def submit_to(self, host: int, batch: ArrivalBatch,
                  t=None) -> list[ServeResult]:
        """Push a stamped arrival chunk into `host`'s ingest queue and
        serve whatever the fleet watermark releases. `t`: per-arrival
        strictly increasing stamps ((B,) array; None = the host-local
        unit clock). Micro-batches form over the *merged* stream, so
        with several hosts a batch is only served once every host's
        clock has passed it — push (or `flush`) regularly from all
        hosts to keep the watermark moving."""
        with self._span("ingest"):
            self.ingest.submit_to(host, batch, t)
        with self._span("merge"):
            events = self.ingest.poll()
        return self._drain_events(events)

    def depart_to(self, host: int, servers, cores, p95_eff, is_uf,
                  t=None, mem_gb=None) -> list[ServeResult]:
        """Push a stamped departure batch into `host`'s ingest queue.
        The departure takes effect at its merged-stream position, at
        micro-batch granularity: it is applied before any micro-batch
        served after it, so every arrival merged later sees the freed
        capacity (and, sharded, power tokens) — and so do arrivals
        merged earlier that are still pending in the current unfilled
        micro-batch window (batching trades exact stream position for
        batch efficiency; the order stays deterministic and the watt
        budget is never exceeded either way). Advancing this host's
        clock can release queued micro-batches — any results are
        returned."""
        with self._span("ingest"):
            self.ingest.depart_to(host, DepartureBatch(
                np.asarray(servers, np.int32),
                np.asarray(cores, np.float32),
                np.asarray(p95_eff, np.float32),
                np.asarray(is_uf, bool),
                None if mem_gb is None
                else np.asarray(mem_gb, np.float32)), t)
        with self._span("merge"):
            events = self.ingest.poll()
        return self._drain_events(events)

    def cap_to(self, host: int, chassis, power_w,
               t=None) -> list[ServeResult]:
        """Push a stamped chassis power-sample batch into `host`'s
        ingest queue — the cap/uncap events of the power-emergency
        plane (`serve.emergency`, third stream-event kind). Samples
        apply at their merged-stream position, so alarms, lifts, and
        the capacity/token effects of any mitigation traffic stay
        deterministic across host counts. Requires the pipeline to be
        built with `emergency_cfg` and/or `adaptive_cfg` (either plane
        consumes the samples). Advancing this host's clock can release
        queued micro-batches — any results are returned."""
        if self.emergency_cfg is None and self.adaptive_cfg is None:
            raise ValueError(
                "cap_to() needs a pipeline built with emergency_cfg "
                "or adaptive_cfg")
        with self._span("ingest"):
            self.ingest.cap_to(host, CapBatch(
                np.asarray(chassis, np.int32),
                np.asarray(power_w, np.float32)), t)
        with self._span("merge"):
            events = self.ingest.poll()
        return self._drain_events(events)

    def flush(self) -> ServeResult | None:
        """Serve everything still queued, watermark ignored (padded up
        to the batch size; chunked if the drain releases more than one
        micro-batch). Returns one concatenated result, or None."""
        with self._span("merge"):
            events = self.ingest.drain()
        out = self._drain_events(events)
        if self._queued:
            merged = _concat_batches(self._pending)
            self._pending, self._queued = [], 0
            out.append(self._serve_padded(merged))
        self._flush_caps()          # trailing caps with no batch to ride
        if not out:
            return None
        return out[0] if len(out) == 1 else _concat_results(out)

    def _drain_events(self, events: MergedEvents) -> list[ServeResult]:
        """Apply one released merged-event window in stream order:
        arrival runs accumulate toward (and serve) full micro-batches,
        departure runs apply at their merged position (before any
        micro-batch served after them — see `depart_to` for the
        batch-granularity caveat)."""
        bs = self.config.batch_size
        out: list[ServeResult] = []
        rec = None if self.obs is None else self.obs.recorder
        pos = 0
        for kind, lo, hi in events.runs():
            t_run = events.t[pos:pos + (hi - lo)]
            pos += hi - lo
            if len(t_run):
                # the merged stream is the watermark clock the §17
                # pillars aggregate on
                self._watermark = float(t_run[-1])
            if kind == CAPPING:
                caps = slice_soa(events.caps, lo, hi)
                if rec is not None:
                    rec.record_caps(t_run, caps)
                self._apply_caps(caps, t_run)
                continue
            if kind != ARRIVAL:
                d = slice_soa(events.departures, lo, hi)
                if rec is not None:
                    rec.record_departures(t_run, d)
                self._apply_departures(d.server, d.cores, d.p95_eff,
                                       d.is_uf, d.mem_gb)
                continue
            arr = slice_soa(events.arrivals, lo, hi)
            if rec is not None:
                rec.record_arrivals(t_run, arr)
            self._pending.append(arr)
            self._queued += hi - lo
            if self._queued < bs:
                continue
            merged = _concat_batches(self._pending)  # one copy, slice
            start = 0
            while self._queued - start >= bs:
                out.append(self._serve_padded(
                    slice_soa(merged, start, start + bs)))
                start += bs
            self._pending = [slice_soa(merged, start, len(merged))]
            self._queued = self._queued - start
        return out

    def serve(self, batch: ArrivalBatch) -> ServeResult:
        """Serve one batch synchronously, bypassing the queue (chunks
        internally if larger than the configured micro-batch). Bypassed
        batches are invisible to the flight recorder — only the
        streamed (queue) path is replayable (`obs.recorder`)."""
        self._recorder_suspended = True
        try:
            bs = self.config.batch_size
            if len(batch) <= bs:
                return self._serve_padded(batch)
            parts = [ArrivalBatch(*(getattr(batch, f)[i:i + bs]
                                    for f in
                                    ArrivalBatch.__dataclass_fields__))
                     for i in range(0, len(batch), bs)]
            return _concat_results([self._serve_padded(p)
                                    for p in parts])
        finally:
            self._recorder_suspended = False

    def _serve_padded(self, batch: ArrivalBatch) -> ServeResult:
        b = len(batch)
        pad_to = self.config.batch_size
        packed, meta = self._buffers[self._active]
        with self._span("featurize"):
            x = featurize_batch(self.table, batch, pad_to=pad_to)
        with self._span("infer"):
            q = served_query(packed, meta, x, kernel=self._kernel)
            is_uf = q["workload_type_used"] == UF
            policy = self.config.policy
            if policy.use_utilization_predictions:
                p95_eff = bucket_to_p95_jnp(q["p95_bucket_used"])
            else:
                p95_eff = jnp.ones(pad_to, jnp.float32)
        cores = jnp.zeros(pad_to, jnp.float32) \
            .at[:b].set(jnp.asarray(batch.cores))
        mem = jnp.zeros(pad_to, jnp.float32) \
            .at[:b].set(jnp.asarray(batch.memory_gb))
        valid = jnp.arange(pad_to) < b
        with self._span("place"):
            servers = self._place(cores, is_uf, p95_eff, valid, mem)
        self.served += b
        with self._span("commit"):
            # the quality pillar also wants the raw (ungated) head
            # outputs + confidences — fetched in the same device_get,
            # outputs only, so decisions are untouched either way
            fetch = (servers, q["workload_type_used"],
                     q["p95_bucket_used"], p95_eff, q["conservative"])
            score = self.obs is not None and self.obs.quality is not None
            if score:
                fetch += (q["workload_type"], q["workload_conf"],
                          q["p95_bucket"], q["p95_conf"])
            host = jax.device_get(fetch)
        res = ServeResult(*(a[:b] for a in host[:5]))
        raw = tuple(a[:b] for a in host[5:]) if score else None
        self._record_batch(batch, res, raw=raw)
        return res

    def _place(self, cores, is_uf, p95_eff, valid, mem):
        """Placement stage of one padded micro-batch: run the batched
        Algorithm-1 scan against the cluster state and return the (B,)
        server decisions (FAIL_* codes on reject). Cap sub-windows
        queued since the last batch ride along fused in front of the
        scan (`placement.place_batch_caps`) — the batch plus a full
        emergency sweep is still one compiled dispatch. The sharded
        pipeline overrides this single hook — every other serving
        stage is shard-agnostic."""
        if self._pending_caps:
            n_windows = len(self._pending_caps)
            pw, mask, ts = self._stacked_caps()
            self._pending_caps = []
            if self.obs is not None:
                self.obs.registry.counter(
                    "serve_dispatch_total",
                    help="compiled kernel dispatches, by call site",
                    kind="place_batch_caps").inc()
            (self.state, servers, self._emergency,
             sweep) = placement.place_batch_caps(
                self.state, self._emergency, pw, mask, ts, cores,
                is_uf, p95_eff, valid, self.res_cap,
                self.config.policy, self.cores_per_server,
                self.emergency_cfg, mem_gb=mem)
            self._alarms += int(np.asarray(sweep.alarms))
            self._record_sweep(sweep, windows=n_windows)
            return servers
        if self.obs is not None:
            self.obs.registry.counter(
                "serve_dispatch_total",
                help="compiled kernel dispatches, by call site",
                kind="place_batch").inc()
        self.state, servers = placement.place_batch(
            self.state, cores, is_uf, p95_eff, valid, self.res_cap,
            self.config.policy, self.cores_per_server, mem_gb=mem)
        return servers

    def _stacked_caps(self):
        """Densify the queued unique-chassis sub-windows into stacked
        (W, C) `masked_step` operands, merged order preserved."""
        dtype = self.state.free_cores.dtype
        rows = [emergency.scatter_samples(self.n_chassis, c, p, t, np,
                                          np.float64)
                for c, p, t in self._pending_caps]
        pw = jnp.asarray(np.stack([r[0] for r in rows]), dtype)
        mask = jnp.asarray(np.stack([r[1] for r in rows]))
        ts = jnp.asarray(np.stack([r[2] for r in rows]), dtype)
        return pw, mask, ts

    def depart(self, servers, cores, p95_eff, is_uf,
               mem_gb=None) -> None:
        """Release departed VMs' aggregates immediately (batched,
        order-free) — the 1-host special case. `depart_to` is the
        stream-ordered per-host path, and like `submit` this refuses
        multi-host pipelines: applying a departure out of merged-
        stream order would silently break the deterministic order the
        merge promises."""
        if self.config.n_ingest_hosts != 1:
            raise ValueError(
                "depart() is the single-queue (1-host) path; with "
                f"n_ingest_hosts={self.config.n_ingest_hosts} use "
                "depart_to(host, ..., t=...)")
        self._apply_departures(servers, cores, p95_eff, is_uf, mem_gb)

    def _apply_departures(self, servers, cores, p95_eff, is_uf,
                          mem_gb=None) -> None:
        """Apply a departure batch to the cluster state (the merged-
        stream consumer; `ShardedServePipeline` overrides with the
        per-shard route + in-scan pool credit). Queued cap windows
        flush first: they were merged earlier and must read the
        pre-departure aggregates."""
        self._flush_caps()
        self.state = placement.remove_batch(
            self.state, jnp.asarray(servers), jnp.asarray(cores),
            jnp.asarray(p95_eff), jnp.asarray(is_uf),
            mem_gb=None if mem_gb is None else jnp.asarray(mem_gb))

    # -- power-emergency plane (serve.emergency) ---------------------------
    def _apply_caps(self, batch: CapBatch, t: np.ndarray) -> None:
        """Consume one merged CAPPING run: split it into unique-chassis
        sub-windows and *queue* them in merged order for fusion into
        the next placement dispatch (`_place`). A cap touches only the
        emergency state, and every mutation of the aggregates it reads
        flushes the queue first (departures) or applies it ahead of
        the mutation in the same dispatch (arrival batches), so the
        deferred windows see exactly the aggregates they would have
        seen dispatched standalone at their merged position. Stamps
        are rebased to the first cap stamp this pipeline ever saw: the
        f32 serving path stores the emergency clocks in the state
        dtype, and epoch-second stamps (~1e9) would otherwise quantize
        the 30 s lift/dwell windows away — relative session time keeps
        sub-second resolution for years of stream.

        The adaptive controller (`adaptive_cfg`) consumes the same
        sub-windows *eagerly*: its scan reads only the placement
        aggregates (which every queued-cap consumer already sees
        consistently — mutations flush the queue first) and its
        stepped ratio must be in force for the very next micro-batch,
        so deferring it would lag the budget by one batch."""
        if self.emergency_cfg is None and self.adaptive_cfg is None:
            raise ValueError(
                "received CAPPING events but the pipeline was built "
                "without emergency_cfg or adaptive_cfg")
        if self._cap_epoch is None:
            self._cap_epoch = float(t[0])
        t = np.asarray(t, np.float64) - self._cap_epoch
        for lo, hi in _unique_chassis_windows(batch.chassis):
            if self.adaptive_cfg is not None:
                self._adaptive_scan(batch.chassis[lo:hi],
                                    batch.power_w[lo:hi])
            if self.emergency_cfg is not None:
                self._pending_caps.append(
                    (batch.chassis[lo:hi], batch.power_w[lo:hi],
                     t[lo:hi]))
        # the ballooning rung applies its windows eagerly: the fused
        # placement kernels step the emergency state alone, and a
        # deferred balloon would see a stale memory ledger once the
        # batch it rides with mutates `mem_nuf`
        if self._balloon is not None:
            self._flush_caps()

    def _flush_caps(self) -> None:
        """Apply queued cap sub-windows through the standalone kernel —
        the path for windows no placement batch will carry (reads of
        `emergency`/`alarms`, departures, end-of-stream `flush`)."""
        pending, self._pending_caps = self._pending_caps, []
        for chassis, power_w, t in pending:
            with self._span("emergency"):
                out = self._cap_window(chassis, power_w, t)
            alarms = int(np.asarray(out.alarm).sum())
            self._alarms += alarms
            if self.obs is not None:
                cbl = np.asarray(out.cut_by_level_w, np.float64)
                self._record_sweep(placement.SweepCounters(
                    samples=len(chassis), alarms=alarms,
                    cut_w=np.asarray(out.cut_w, np.float64).sum(),
                    leftover_w=np.asarray(out.leftover_w,
                                          np.float64).sum(),
                    cut_by_level_w=cbl.reshape(
                        -1, emergency.N_LEVELS).sum(0)), windows=1)

    def _cap_window(self, chassis, power_w, t):
        """Apply one unique-chassis sample window (unsharded path) —
        through the balloon-then-cap kernel when the ballooning rung is
        attached, the plain emergency kernel otherwise."""
        dtype = self.state.free_cores.dtype
        pw, mask, ts = emergency.scatter_samples(
            self.n_chassis, chassis, power_w, t, jnp, dtype)
        if self._balloon is not None:
            if self.obs is not None:
                self.obs.registry.counter(
                    "serve_dispatch_total",
                    help="compiled kernel dispatches, by call site",
                    kind="balloon_cap_step").inc()
            fn = _balloon_cap_step_fn(self.emergency_cfg,
                                      self.config.planes.ballooning)
            (self._emergency, self._balloon, out,
             bout) = fn(self.state.gamma_nuf, self.state.gamma_uf,
                        self.state.chassis_servers, self.state.mem_nuf,
                        self._emergency, self._balloon, pw, mask, ts)
            self._record_balloon(bout)
            return out
        if self.obs is not None:
            self.obs.registry.counter(
                "serve_dispatch_total",
                help="compiled kernel dispatches, by call site",
                kind="cap_step").inc()
        fn = _cap_step_fn(self.emergency_cfg)
        self._emergency, out = fn(self.state.gamma_nuf,
                                  self.state.gamma_uf,
                                  self.state.chassis_servers,
                                  self._emergency, pw, mask, ts)
        return out

    # -- ballooning rung (serve.ballooning) --------------------------------
    @property
    def balloon_state(self):
        """Current `serve.ballooning.BalloonState` (None with the rung
        off). Reading it flushes queued cap windows like `emergency`
        (with ballooning on they are applied eagerly anyway)."""
        self._flush_caps()
        return self._balloon

    def ballooned_gb(self) -> float:
        """Fleet-wide GB currently ballooned out (0.0 with the rung
        off)."""
        if self._balloon is None:
            return 0.0
        self._flush_caps()
        return ballooning.total_ballooned_gb(self._balloon)

    def _record_balloon(self, bout) -> None:
        """Export one balloon sweep's outputs: reclaim/release/absorb
        counters and the standing-balloon gauge — host-side reductions
        of outputs the kernel already returned."""
        if self.obs is None:
            return
        reg = self.obs.registry
        reg.counter("balloon_reclaimed_gb_total",
                    help="GB ballooned out of NUF VMs").inc(
                        float(np.asarray(bout.reclaimed_gb,
                                         np.float64).sum()))
        reg.counter("balloon_released_gb_total",
                    help="ballooned GB handed back on alarm clear").inc(
                        float(np.asarray(bout.released_gb,
                                         np.float64).sum()))
        reg.counter("balloon_absorbed_watts_total",
                    help="DRAM watts absorbed by standing + fresh "
                    "balloons").inc(
                        float(np.asarray(bout.absorbed_w,
                                         np.float64).sum()))
        reg.counter("balloon_inflations_total",
                    help="chassis sweeps where the rung fired").inc(
                        int(np.asarray(bout.inflated).sum()))
        reg.gauge("balloon_ballooned_gb",
                  help="fleet GB currently ballooned out").set(
                      ballooning.total_ballooned_gb(self._balloon))

    def throttled_by_level(self) -> np.ndarray:
        """(L,) cumulative throttled-seconds per criticality level
        (index `emergency.CRIT_UF` = critical) — the Table-4-style
        impact counter the emergency plane maintains."""
        if self.emergency is None:
            return np.zeros(emergency.N_LEVELS)
        return emergency.throttled_by_level(self.emergency)

    def mitigation_due_chassis(self) -> np.ndarray:
        """Global ids of chassis whose cap has dwelled past
        `emergency_cfg.dwell_s` with the critical level throttled —
        feed these (with a VM registry) to
        `serve.mitigation.plan_migrations` and push the plan's paired
        events through `depart_to`."""
        if self.emergency is None:
            return np.empty(0, np.int64)
        due = np.asarray(emergency.mitigation_due(self.emergency_cfg,
                                                  self.emergency))
        return np.flatnonzero(due.reshape(-1))

    def reset_dwell(self, chassis) -> None:
        """Zero the dwell clock of the given global chassis ids (call
        after emitting a migration plan for them)."""
        mask = np.zeros(self.n_chassis, bool)
        mask[np.asarray(chassis, np.int64)] = True
        self.emergency = emergency.reset_dwell(
            self.emergency, jnp.asarray(self._dwell_mask(mask)), jnp)

    def _dwell_mask(self, mask: np.ndarray) -> np.ndarray:
        """Reshape a (C,) global chassis mask to the emergency state's
        chassis layout (identity unsharded)."""
        return mask

    # -- diagnostics -------------------------------------------------------
    def chassis_headroom_w(self, budget_w) -> np.ndarray:
        """(C,) watts of remaining per-chassis admission headroom."""
        return admission.headroom_w(self.state, budget_w,
                                    self.blades_per_chassis,
                                    self.power_model)


@dataclass(frozen=True)
class ShardedServeConfig(ServeConfig):
    """`ServeConfig` plus the sharded-placement knobs (docs/sharding.md
    discusses picking them). `batch_size` must be divisible by
    `n_shards`; `use_shard_map='auto'` maps shards onto mesh devices
    when the runtime has enough and falls back to the single-device
    vmap twin otherwise."""
    n_shards: int = 1
    use_shard_map: bool | str = "auto"      # True | False | 'auto'
    spill_rounds: int | None = None         # default: n_shards - 1
    rebalance_tokens: bool = True
    shard_table: bool = True                # partition SubscriptionTable


class ShardedServePipeline(ServePipeline):
    """`ServePipeline` with the cluster state partitioned across a
    ``("shard",)`` device mesh (`serve.sharding`, DESIGN.md §10).

    Featurization and forest inference are shard-agnostic (one batched
    call; the subscription table is row-partitioned over the mesh when
    `shard_table` is set); only the placement stage fans out: arrivals
    are routed to their home shard, placed concurrently under the
    reserve/commit token protocol, and spilled cross-shard when the
    home shard rejects them. `cluster_budget_w` sets the global watt
    budget the token pools enforce — the sum of admitted `p95*cores`
    across all shards can never exceed its rho-unit conversion, no
    matter how the shards race."""

    def __init__(self, service, table, state, cores_per_server,
                 config: ShardedServeConfig | None = None,
                 cluster_budget_w=_UNSET, **kw):
        config = config or ShardedServeConfig()
        if config.batch_size % config.n_shards:
            raise ValueError(
                f"batch_size {config.batch_size} not divisible by "
                f"n_shards {config.n_shards}")
        config = replace(config, planes=_legacy_planes(
            config.planes, type(self).__name__,
            cluster_budget_w=cluster_budget_w))
        super().__init__(service, table, state, cores_per_server,
                         config=config, **kw)
        config = self.config        # planes merged by the superclass
        if config.use_shard_map == "auto":
            self.mesh = sharding.shard_mesh(config.n_shards) \
                if config.n_shards > 1 else None
        elif config.use_shard_map:
            self.mesh = sharding.shard_mesh(config.n_shards)
            if self.mesh is None:
                raise RuntimeError(
                    f"use_shard_map=True needs >= {config.n_shards} "
                    f"devices, have {len(jax.devices())}")
        else:
            self.mesh = None
        budget = config.planes.cluster_budget
        self.cluster_budget_w = None if budget is None else budget.watts
        # gross = the ratio-1.0 (R,) token allowance; the adaptive
        # controller retargets free pools against it (`retarget_pool`)
        gross = np.full(N_RESOURCES, np.inf) if budget is None else \
            sharding.resource_pool_from_budget(
                budget, state.n_servers, self.power_model)
        finite = np.isfinite(gross)
        self._has_pool = bool(finite.any())
        if self._has_pool:
            # a warm-started cluster has resources already committed;
            # the pool is the *remaining* allowance per axis, so the
            # budget invariant holds from the first batch (the sim
            # backend nets identically)
            committed = np.asarray(state.res_peak, np.float64).sum(0)
            pool_total = np.where(
                finite, np.maximum(gross - committed, 0.0), np.inf)
        else:
            pool_total = None
        self.sharded = sharding.shard_state(
            self.state, config.n_shards, rho_cap=self.res_cap,
            pool_total=pool_total)
        if self.mesh is not None:
            self.sharded = sharding.device_put_sharded_state(
                self.sharded, self.mesh)
            if config.shard_table:
                self.table = shard_table(self.table, self.mesh)
        self.state = None        # self.sharded is the source of truth
        self._sharded_cap_base = self.sharded.res_cap
        self._pool_base = None if not self._has_pool else \
            jnp.asarray(np.broadcast_to(
                gross / config.n_shards,
                (config.n_shards, N_RESOURCES)),
                self.sharded.pool.dtype)
        self._ratio_prev = np.ones(config.n_shards)
        self.spill_info = {"rounds": 0, "spilled": 0,
                           "spill_admitted": 0}

    # -- sharded placement stage -------------------------------------------
    def _place(self, cores, is_uf, p95_eff, valid, mem):
        cfg = self.config
        kw = {}
        fused = bool(self._pending_caps)
        if fused:
            n_windows = len(self._pending_caps)
            kw = dict(emer=self._emergency, caps=self._sharded_caps(),
                      ecfg=self.emergency_cfg)
            self._pending_caps = []
        if self.obs is not None:
            kw["registry"] = self.obs.registry
        out = sharding.place_group_sharded(
            self.sharded, np.asarray(cores), np.asarray(is_uf),
            np.asarray(p95_eff), np.asarray(valid), cfg.policy,
            self.cores_per_server, mem_gb=np.asarray(mem),
            mesh=self.mesh, spill_rounds=cfg.spill_rounds,
            rebalance=cfg.rebalance_tokens, **kw)
        if fused:
            (self.sharded, servers, info, self._emergency,
             sweep) = out
            self._alarms += int(np.asarray(sweep.alarms))
            self._record_sweep(sweep, windows=n_windows)
        else:
            self.sharded, servers, info = out
        self.spill_info = {k: self.spill_info[k] + info[k]
                           for k in self.spill_info}
        self._record_spill(info)
        return servers.astype(np.int32)

    def _record_spill(self, info: dict) -> None:
        """Fold one sharded placement call's spillover/token counters
        into the registry (host-side, from the already-returned
        ``info`` dict)."""
        if self.obs is None:
            return
        reg = self.obs.registry
        reg.counter("serve_spill_rounds_total",
                    help="spillover rounds run beyond the home round"
                    ).inc(max(info["rounds"] - 1, 0))
        reg.counter("serve_spilled_total",
                    help="arrivals that entered a spillover round").inc(
                        info["spilled"])
        reg.counter("serve_spill_admits_total",
                    help="arrivals admitted by a spillover round").inc(
                        info["spill_admitted"])
        if self._has_pool:
            reg.counter("serve_tokens_drawn_total",
                        help="power tokens drawn from the pools, "
                        "rho units").inc(
                            max(0.0, info.get("tokens_drawn", 0.0)))
            drawn = np.asarray(info.get(
                "tokens_drawn_vec", np.zeros(N_RESOURCES)), np.float64)
            pools = np.asarray(self.sharded.pool)
            for r, name in enumerate(RESOURCES):
                reg.counter("serve_tokens_drawn_res_total",
                            help="tokens drawn from the pools, by "
                            "resource axis",
                            resource=name).inc(max(0.0, float(drawn[r])))
            for i, row in enumerate(pools):
                reg.gauge("serve_pool_tokens",
                          help="remaining power tokens, by shard",
                          shard=str(i)).set(float(row[0]))
                for r, name in enumerate(RESOURCES):
                    if np.isfinite(row[r]):
                        reg.gauge("serve_pool_resources",
                                  help="remaining tokens, by shard "
                                  "and resource axis",
                                  shard=str(i),
                                  resource=name).set(float(row[r]))

    def _pool_tokens_left(self) -> float:
        if not self._has_pool:
            return float("inf")
        return float(np.asarray(self.sharded.pool)[:, 0].sum())

    def _sharded_caps(self):
        """Densify queued sub-windows into the stacked (N, W, C/N)
        per-shard operands of the fused home-round kernel."""
        dtype = self.sharded.shards.free_cores.dtype
        rows = [sharding.split_caps(self.sharded, c, p, t)
                for c, p, t in self._pending_caps]
        pw = jnp.asarray(np.stack([r[0] for r in rows], axis=1), dtype)
        mask = jnp.asarray(np.stack([r[1] for r in rows], axis=1))
        ts = jnp.asarray(np.stack([r[2] for r in rows], axis=1), dtype)
        return pw, mask, ts

    def _apply_departures(self, servers, cores, p95_eff, is_uf,
                          mem_gb=None) -> None:
        """Route each departure to its owner shard (per-shard
        batches, `sharding.split_departures`) and credit the freed
        (R,) demand vector back to that shard's pool in the consuming
        scan (`sharding.consume_departures`). Queued cap windows flush
        first — they read pre-departure aggregates."""
        self._flush_caps()
        if self.obs is not None and self._has_pool:
            srv = np.asarray(servers)
            live = srv >= 0
            credit = (np.asarray(p95_eff, np.float64)[live]
                      * np.asarray(cores, np.float64)[live]).sum()
            self.obs.registry.counter(
                "serve_tokens_credited_total",
                help="power tokens credited back by departures, "
                "rho units").inc(float(credit))
        self.sharded = sharding.remove_sharded(
            self.sharded, servers, cores, p95_eff, is_uf,
            mem_gb=mem_gb)

    # -- sharded adaptive oversubscription ---------------------------------
    def _init_adaptive(self):
        """Controller state partitioned like the cluster (leading
        shard axis over the same contiguous chassis blocks)."""
        return sharding.init_adaptive_sharded(
            self.adaptive_cfg, self.n_chassis, self.config.n_shards,
            dtype=self.state.free_cores.dtype)

    @property
    def adaptive_ratio(self):
        """(N,) per-shard oversubscription ratios (all 1.0 with the
        controller off) — each shard adapts the slice of the watt
        budget it owns."""
        if self._adaptive is None:
            return np.ones(self.config.n_shards)
        return np.asarray(self._adaptive.ratio)

    def _adaptive_scan(self, chassis, power_w) -> None:
        """Route one unique-chassis sample window to the owner shards
        and step every shard's controller concurrently."""
        if self.obs is not None:
            self.obs.registry.counter(
                "serve_dispatch_total",
                help="compiled kernel dispatches, by call site",
                kind="adaptive_sharded").inc()
        self._adaptive, out = sharding.apply_adaptive_sharded(
            self.adaptive_cfg, self.sharded, self._adaptive, chassis,
            power_w, mesh=self.mesh)
        self._apply_ratio(out)

    def _axis_mult(self, dtype) -> jnp.ndarray:
        """(N, R) per-shard effective ceiling/pool multipliers: each
        shard's adaptive ratio on the watts axis, the shared diurnal
        conditioning on cores/GB (see the unsharded `_axis_mult`)."""
        n = self.config.n_shards
        ones = jnp.ones((n,), dtype)
        r = ones if self._ratio_dev is None \
            else jnp.asarray(self._ratio_dev, dtype)
        return jnp.stack([r, ones, ones], axis=-1) \
            * jnp.asarray(self._res_ratios, dtype)[None]

    def _refresh_caps(self) -> None:
        """Put the current per-axis multipliers in force: rescale each
        shard's slice of the admission ceiling and retarget its free
        token pool against the committed (R,) ledger — never revoking
        tokens already committed to placed VMs
        (`adaptive.retarget_pool` floors the free pool at zero per
        axis), so the reserve/commit conservation invariant survives
        any mint/retire/ratchet sequence."""
        mult = self._axis_mult(self._sharded_cap_base.dtype)
        cap = self._sharded_cap_base * mult[:, None, :]
        pool = self.sharded.pool
        if self._pool_base is not None:
            sh = self.sharded.shards
            # per-axis chassis reduction, watts axis summed exactly as
            # the scalar-era code did (bit-stable against it)
            committed = jnp.stack(
                [jnp.sum(sh.res_peak[..., r], axis=-1)
                 for r in range(N_RESOURCES)], axis=-1)
            pool = adaptive.retarget_pool(
                self.adaptive_cfg, self._pool_base, mult, committed,
                jnp)
        self.sharded = self.sharded._replace(res_cap=cap, pool=pool)

    def _record_adaptive(self, out) -> None:
        """Per-shard export of one controller decision (shard-labelled
        gauge, summed step counters, one reason row per shard)."""
        if self.obs is None:
            return
        reg = self.obs.registry
        ratios = np.asarray(out.ratio)
        ratchets = np.asarray(out.ratchet)
        backoffs = np.asarray(out.backoff)
        for i, r in enumerate(ratios):
            reg.gauge("adaptive_ratio",
                      help="oversubscription ratio of the adaptive "
                      "controller", shard=str(i)).set(float(r))
        reg.counter("adaptive_ratchet_total",
                    help="adaptive-controller up-steps taken").inc(
                        int(ratchets.sum()))
        reg.counter("adaptive_backoff_total",
                    help="adaptive-controller down-steps taken").inc(
                        int(backoffs.sum()))
        if self.obs.adaptive is not None:
            now = time.time()
            n_known = np.asarray(out.n_known)
            n_stable = np.asarray(out.n_stable)
            frac = np.asarray(out.stable_frac)
            hot = np.asarray(out.hot)
            for i in range(len(ratios)):
                self.obs.adaptive.record(
                    t=now, shard=i, ratio=float(ratios[i]),
                    stable_frac=float(frac[i]),
                    n_known=int(n_known[i]),
                    n_stable=int(n_stable[i]),
                    action=1 if ratchets[i] else
                    (-1 if backoffs[i] else 0),
                    reason=adaptive.decision_reason(
                        float(self._ratio_prev[i]), float(ratios[i]),
                        int(n_known[i]), bool(ratchets[i]),
                        bool(backoffs[i]), bool(hot[i])))
        self._ratio_prev = ratios

    # -- sharded power-emergency plane -------------------------------------
    def _init_emergency(self):
        """Emergency state partitioned like the cluster (leading shard
        axis over the same contiguous chassis blocks)."""
        return sharding.init_emergency_sharded(
            self.n_chassis, self.config.n_shards,
            dtype=self.state.free_cores.dtype)

    def _init_ballooning(self):
        """Balloon state partitioned like the cluster (leading shard
        axis over the same contiguous chassis blocks)."""
        return sharding.init_ballooning_sharded(
            self.n_chassis, self.config.n_shards,
            dtype=self.state.free_cores.dtype)

    def _cap_window(self, chassis, power_w, t):
        """Apply one unique-chassis sample window: route samples to
        their owner shards and run every shard's alarm + apportionment
        kernel concurrently (vmap, or shard_map on the mesh) — with
        the ballooning rung in front when attached."""
        if self._balloon is not None:
            if self.obs is not None:
                self.obs.registry.counter(
                    "serve_dispatch_total",
                    help="compiled kernel dispatches, by call site",
                    kind="balloon_caps_sharded").inc()
            (self._emergency, self._balloon, out,
             bout) = sharding.apply_caps_ballooned_sharded(
                self.emergency_cfg, self.config.planes.ballooning,
                self.sharded, self._emergency, self._balloon, chassis,
                power_w, t, mesh=self.mesh)
            self._record_balloon(bout)
            return out
        if self.obs is not None:
            self.obs.registry.counter(
                "serve_dispatch_total",
                help="compiled kernel dispatches, by call site",
                kind="caps_sharded").inc()
        self._emergency, out = sharding.apply_caps_sharded(
            self.emergency_cfg, self.sharded, self._emergency, chassis,
            power_w, t, mesh=self.mesh)
        return out

    def _dwell_mask(self, mask: np.ndarray) -> np.ndarray:
        return mask.reshape(self.config.n_shards, -1)

    # -- diagnostics -------------------------------------------------------
    def global_state(self) -> placement.DeviceClusterState:
        """Reassembled single-cluster view of the sharded aggregates."""
        return sharding.unshard_state(self.sharded)

    def chassis_headroom_w(self, budget_w) -> np.ndarray:
        return admission.headroom_w(self.global_state(), budget_w,
                                    self.blades_per_chassis,
                                    self.power_model)

    def pool_left(self) -> np.ndarray:
        """(N,) remaining power tokens per shard (rho units) — the
        watts axis of `pool_left_vec`."""
        return np.asarray(self.sharded.pool)[:, 0]

    def pool_left_vec(self) -> np.ndarray:
        """(N, R) remaining tokens per shard and resource axis (+inf
        on unbudgeted axes)."""
        return np.asarray(self.sharded.pool)
