"""Vectorized Algorithm-1 placement (serve-pipeline stage 3).

`place_batch` is the jnp twin of `SchedulerPolicy.choose` +
`ClusterState.place`: one jitted `lax.scan` walks an arrival
micro-batch in order (placements must see earlier placements — the
same sequential semantics as the event-driven scheduler), and each
step scores *all* servers at once.

The rank-weight aggregation is reformulated sort-free AND
scatter-free, because a per-step argsort is the one operation XLA
cannot make fast inside a scan (~150 us per 720-element sort on CPU —
25x the whole step budget) and a per-step `.at[].set` scatter is the
next worst (~45 us each on the XLA CPU backend, vs ~1 us for the
gathers / cumsums / fused compares everything below is built from):

  * a placement only changes the scores of the placed chassis'
    K = S/C servers (its kappa, plus the chosen server's packing/eta
    term), so the order structures are *maintained incrementally* — no
    sort after the one batched argsort that seeds the scan. The
    packing rank row recounts the one moved key exactly; the two power
    orders are carried as *inverse* permutations (rank position ->
    server) plus the score-by-server table: the K moved servers'
    landing and vacated positions come from a fused O(K log S) binary
    search over the carried order (`_delta_positions`), and every
    surviving server keeps its relative order, so the recomposition is
    closed-form complement indexing via a histogram + shared prefix
    sum (`_compose_inverse`) — no S-sized scatter, no O(S*K) pass, no
    window search;
  * per-arrival feasibility and the objective are evaluated in power
    *rank-position* space, so forward power ranks never need to
    exist: gathering the feasibility mask through the inverse
    permutation and prefix-counting it yields the power subset rank
    at every position (gather + cumsum — branchless, no lax.cond,
    identical integers on every path), and the packing subset rank is
    exactly `full_rank - n_infeasible` because infeasible servers are
    strictly fuller and hold a contiguous prefix of the packing
    order;
  * the objective then mirrors `SchedulerPolicy.choose` operation for
    operation — `sum_r w_r * (1 - subset_rank_r/(n_feas-1))`, first
    argmax by server index (= min server id over float-maximal
    positions) — because even exactly-tied integer rank sums can
    resolve differently once divided and weighted in floats.

Rank rows are (packing, power-for-UF, power-for-NUF) — the power score
depends on the arriving VM's type, so both orders are maintained.
Single-rule policies (packing_weight or power_weight zero, or the
power rule off) skip the rank machinery entirely: one rule's rank
weight is a monotone transform of its raw score, so a stable score
argmax decides (`_place_batch_single_rule`).

Decision equivalence with the numpy path holds because subset ranks
are exact integers and the float aggregation replicates the host
arithmetic; the scheduler simulation's serve backend runs this same
scan in x64, where it is bit-equivalent to the f64 host rule
(DESIGN.md §9 bounds the residual f32-vs-f64 divergence of the score
inputs on the serving path).

The power-headroom admission check (serve-pipeline stage 4, see
`serve/admission.py`) is fused into the scan: a placement that would
push its chassis' projected peak draw over budget is rejected before
it mutates the state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.resources import N_RESOURCES, lift_caps, lift_pool
from repro.serve import emergency

#: `place_batch` outcome codes (in the returned server array).
FAIL_CAPACITY = -1      # no feasible server (deployment failure)
FAIL_POWER = -2         # a chassis resource ceiling rejected (any axis)
FAIL_TOKENS = -3        # shard's token pool exhausted (any axis)


class DeviceClusterState(NamedTuple):
    """Device mirror of `core.placement.ClusterState`'s aggregates,
    generalized to the (R,)-axis resource ledger (DESIGN.md §16):
    `res_peak` tracks committed (rho, cores, GB) per chassis — axis 0
    is the legacy ``rho_peak`` (exposed as a property so the scoring
    rules and diagnostics read it unchanged), and `mem_nuf` carries
    the NUF slice of the GB axis, the balloonable headroom the
    emergency ladder's middle rung reclaims (`serve.ballooning`)."""
    free_cores: jnp.ndarray      # (S,) f32
    gamma_uf: jnp.ndarray        # (S,) f32
    gamma_nuf: jnp.ndarray       # (S,) f32
    res_peak: jnp.ndarray        # (C, R) f32 — committed (rho, cores, GB)
    rho_max: jnp.ndarray         # (C,) f32
    chassis_of: jnp.ndarray      # (S,) i32
    chassis_servers: jnp.ndarray  # (C, S//C) i32 — servers per chassis
    mem_nuf: jnp.ndarray         # (C,) f32 — committed NUF GB

    @property
    def rho_peak(self) -> jnp.ndarray:
        """(C,) committed sum(p95*cores) — the watts axis of the
        ledger, the exact quantity the pre-vector state carried."""
        return self.res_peak[..., 0]

    @property
    def n_servers(self) -> int:
        return self.free_cores.shape[0]


def _chassis_servers(chassis_of: np.ndarray) -> np.ndarray:
    """(C, K) server-index table (rank maintenance gathers the placed
    chassis' servers through it). Chassis must be equal-sized."""
    chassis_of = np.asarray(chassis_of)
    n_chassis = int(chassis_of.max()) + 1
    sizes = np.bincount(chassis_of, minlength=n_chassis)
    assert (sizes == len(chassis_of) // n_chassis).all(), \
        "chassis must be equal-sized"
    order = np.argsort(chassis_of, kind="stable")
    return order.reshape(n_chassis, -1).astype(np.int32)


def device_state(state: ClusterState, dtype=jnp.float32,
                 mem_gb=None, mem_nuf=None) -> DeviceClusterState:
    """Mirror a host `ClusterState`'s aggregates onto the device.
    `dtype` selects the serving (f32) or equivalence-testing (f64,
    under `jax.experimental.enable_x64`) arithmetic.

    The host state is the watts/cores oracle; the cores axis of
    `res_peak` is derived from its per-server free cores, and the GB
    axis comes from `mem_gb`/`mem_nuf` ((C,) committed GB — total and
    NUF slice), zeros when the caller tracks no memory."""
    chassis_servers = _chassis_servers(state.chassis_of_server)
    free = np.asarray(state.free_cores, np.float64)
    cores_comm = (float(state.cores_per_server)
                  - free)[chassis_servers].sum(-1)
    n_chassis = chassis_servers.shape[0]
    mem = np.zeros(n_chassis) if mem_gb is None \
        else np.asarray(mem_gb, np.float64)
    res_peak = np.stack([np.asarray(state.rho_peak, np.float64),
                         cores_comm, mem], axis=-1)
    return DeviceClusterState(
        jnp.asarray(state.free_cores, dtype),
        jnp.asarray(state.gamma_uf, dtype),
        jnp.asarray(state.gamma_nuf, dtype),
        jnp.asarray(res_peak, dtype),
        jnp.asarray(state.rho_max, dtype),
        jnp.asarray(state.chassis_of_server, jnp.int32),
        jnp.asarray(chassis_servers),
        jnp.zeros(n_chassis, dtype) if mem_nuf is None
        else jnp.asarray(mem_nuf, dtype))


def fresh_state(n_servers: int, cores_per_server: int,
                chassis_of: np.ndarray) -> DeviceClusterState:
    """Device state of an empty cluster (every core free, nothing
    committed) with the given server→chassis layout."""
    return device_state(ClusterState(
        n_servers=n_servers, cores_per_server=cores_per_server,
        chassis_of_server=np.asarray(chassis_of),
        n_chassis=int(np.asarray(chassis_of).max()) + 1))


def score_chassis_batch(state: DeviceClusterState) -> jnp.ndarray:
    """jnp twin of `ClusterState.score_chassis` — (C,)."""
    return 1.0 - state.rho_peak / jnp.maximum(state.rho_max, 1e-9)


def score_server_batch(state: DeviceClusterState, vm_is_uf,
                       cores_per_server: int) -> jnp.ndarray:
    """jnp twin of `ClusterState.score_server`. `vm_is_uf` may be a
    scalar bool or a (B,) array (then the result is (B, S))."""
    uf = jnp.asarray(vm_is_uf, bool)
    diff = jnp.where(uf[..., None] if uf.ndim else uf,
                     state.gamma_nuf - state.gamma_uf,
                     state.gamma_uf - state.gamma_nuf)
    return 0.5 * (1.0 + diff / float(cores_per_server))


def _rule_scores(state: DeviceClusterState, policy: SchedulerPolicy,
                 cps: float) -> jnp.ndarray:
    """(R, S) score rows the preference rules order. Row 0: packing
    (`core.placement.packing_score`). Rows 1-2 (when the power rule is
    on): Algorithm-1 score for a UF / NUF arrival — both are kept
    because the arriving VM's type flips the eta term."""
    pack = 1.0 - state.free_cores / cps
    if not policy.use_power_rule:
        return pack[None]
    kappa = score_chassis_batch(state)[state.chassis_of]
    a = policy.alpha
    return jnp.stack(
        [pack] + [a * kappa + (1.0 - a)
                  * score_server_batch(state, uf, cps)
                  for uf in (True, False)])


def _before(s_j, j, s_i, i):
    """Stable descending order: does key (s_j, j) sort before key
    (s_i, i)? Ties break toward the smaller server index — the same
    order `np.argsort(kind='stable')` of negated scores produces."""
    return (s_j > s_i) | ((s_j == s_i) & (j < i))


def _init_ranks(scores: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stable descending ranks (R, S) plus the inverse permutations
    (R, S) (rank position -> server) the scan maintains — one batched
    argsort + scatter, once per micro-batch, outside the scan."""
    r, s = scores.shape
    perm = jnp.argsort(-scores, axis=-1, stable=True)
    rows = jnp.arange(r)[:, None]
    ranks = jnp.zeros((r, s), jnp.int32).at[rows, perm].set(
        jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (r, s)))
    return ranks, perm.astype(jnp.int32)


def _delta_positions(perm: jnp.ndarray, q_prev: jnp.ndarray,
                     new_d: jnp.ndarray, old_d: jnp.ndarray,
                     delta: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Landing + vacated positions of the K moved servers, by fused
    binary search over the carried power orders.

    `perm` (R', S) is rank position -> server, `q_prev` (R', S) the
    score-by-server table consistent with it; the search comparator is
    the stable-descending key ``(score, server-id)`` (`_before`), which
    is a strict total order, so (a) the lower bound of a *new* key
    `new_d` is exactly where it will land once every old delta key is
    deleted and the new ones inserted — after removing the old-key /
    intra-new corrections applied by the caller — and (b) the lower
    bound of an *old* key `old_d` is its exact current position. Both
    searches run fused (one (R', 2K) bracket, ceil(log2(S+1)) rounds
    of two tiny flat gathers) — O(K log S) work, no O(S) pass."""
    rp, s = perm.shape
    k = delta.shape[0]
    nb = max(int(np.ceil(np.log2(s + 1))), 1)
    roff = jnp.arange(rp, dtype=jnp.int32)[:, None] * s
    pflat = perm.reshape(-1)
    qflat = q_prev.reshape(-1)
    keys = jnp.concatenate([new_d, old_d], axis=1)          # (R', 2K)
    ids = jnp.concatenate([delta, delta])[None, :]
    lo = jnp.zeros((rp, 2 * k), jnp.int32)
    hi = jnp.full((rp, 2 * k), s, jnp.int32)
    for _ in range(nb):
        # mid == s only in the degenerate lo == hi == s bracket, where
        # both updates keep lo == hi; clamp so the gather stays legal
        mid = jnp.minimum((lo + hi) >> 1, s - 1)
        sm = pflat[(mid + roff).reshape(-1)].reshape(rp, 2 * k)
        km = qflat[(sm + roff).reshape(-1)].reshape(rp, 2 * k)
        b = _before(km, sm, keys, ids)
        lo = jnp.where(b, mid + 1, lo)
        hi = jnp.where(b, hi, mid)
    return lo[:, :k], lo[:, k:]


def _compose_inverse(perm: jnp.ndarray, fresh: jnp.ndarray,
                     d_old: jnp.ndarray,
                     delta: jnp.ndarray) -> jnp.ndarray:
    """Scatter-light inverse-permutation maintenance.

    `perm` (R', S) holds rank position -> server for R' rank rows;
    after one placement only the K servers of the placed chassis
    (`delta`, vacating old positions `d_old` and landing at new
    positions `fresh`, both (R', K)) move — every other server keeps
    its *relative* order (its pairwise keys are untouched). So the new
    order is: the surviving servers in old order, merged around the K
    landing positions. Position q that is not a landing spot holds the
    j-th survivor (``j = q - #landings <= q``), which sat at the j-th
    old position not vacated — the j-th element of the complement of
    the sorted vacated positions `sd`, in closed form
    ``g = j + #{k: sd[k] - k <= j}``.

    The count term is a table lookup: ``v = sd - arange(K)`` is
    nondecreasing, so a K-element histogram of v plus one prefix sum
    tabulates ``m(j) = #{v <= j}`` for every j at once. Everything is
    flat 1-D (rows concatenated, per-row corrections are the constant
    K each row contributes), so the whole compose is two K-sized
    scatters (XLA CPU folds these; only S-sized scatters hit the ~45us
    cliff), one fused cumsum, and two flat gathers — no sort (K
    elements order via pairwise counts), no O(S*K) pass."""
    rp, s = perm.shape
    k = delta.shape[0]
    qpos = jnp.arange(s, dtype=jnp.int32)
    kpos = jnp.arange(k, dtype=jnp.int32)
    roff = jnp.arange(rp, dtype=jnp.int32)[:, None] * s
    roffh = jnp.arange(rp, dtype=jnp.int32)[:, None] * (s + 1)
    rk_corr = jnp.arange(rp, dtype=jnp.int32)[:, None] * k
    # ascending vacated positions via pairwise-compare counting
    # (positions are distinct: counts are a permutation of 0..K-1)
    rkk = (d_old[:, None, :] < d_old[:, :, None]) \
        .sum(-1, dtype=jnp.int32)                           # (R', K)
    sd = ((rkk[:, None, :] == kpos[None, :, None])
          * d_old[:, None, :]).sum(-1).astype(jnp.int32)
    v = sd - kpos[None, :]                       # nondecreasing, >= 0
    # landing positions: K-sized scatter of server-id + 1 (0 == none)
    mark = jnp.zeros(rp * s, jnp.int32) \
        .at[(fresh + roff).reshape(-1)].set(
            jnp.broadcast_to(delta[None, :] + 1, (rp, k)).reshape(-1)) \
        .reshape(rp, s)
    is_new = mark > 0
    inew = is_new.astype(jnp.int32)
    hist = jnp.zeros(rp * (s + 1), jnp.int32) \
        .at[(v + roffh).reshape(-1)].add(1)
    # one fused prefix sum tabulates both the landing counts and m(j);
    # each row of each segment sums to exactly K, so the cross-row /
    # cross-segment carry is the deterministic correction below
    both = jnp.cumsum(jnp.concatenate([inew.reshape(-1), hist]))
    land_inc = both[:rp * s].reshape(rp, s) - rk_corr       # inclusive
    m_flat = both[rp * s:]
    j_q = qpos[None] - (land_inc - inew)
    m_at = m_flat[(j_q + roffh).reshape(-1)].reshape(rp, s) \
        - rp * k - rk_corr
    g = j_q + m_at
    moved = perm.reshape(-1)[
        (jnp.minimum(g, s - 1) + roff).reshape(-1)].reshape(rp, s)
    return jnp.where(is_new, mark - 1, moved)


def _commit(st: DeviceClusterState, pool, srv, found, cores_i, uf_i,
            p95_i, mem_i, valid_i, res_cap):
    """Admission check + masked state update + outcome code — the
    shared tail of both scan bodies. `srv` is the winning server with
    `found` indicating a feasible candidate existed.

    The admission draw is the (R,) demand vector ``(p95*cores, cores,
    GB)`` (`core.resources.demand_vector`): the chassis ledger check
    and the token-pool reserve both run per axis and every axis must
    clear (`res_cap` is (C, R), `pool` is the shard's (R,) balance —
    +inf axes are vacuous, so a power-only config reproduces the
    scalar watt protocol bit for bit). A reject on *any* axis maps to
    FAIL_POWER (ceiling) / FAIL_TOKENS (pool) before the state
    mutates."""
    dtype = st.free_cores.dtype
    srv = jnp.where(found, srv, 0).astype(jnp.int32)
    ch = st.chassis_of[srv]
    w = p95_i * cores_i
    d = jnp.stack([w, cores_i, mem_i])                         # (R,)
    admit_ch = jnp.all(st.res_peak[ch] + d <= res_cap[ch])
    admit_pool = jnp.all(d <= pool)
    scale = (found & admit_ch & admit_pool & valid_i).astype(dtype)
    uf_f = uf_i.astype(dtype)
    st2 = st._replace(
        free_cores=st.free_cores.at[srv].add(-cores_i * scale),
        gamma_uf=st.gamma_uf.at[srv].add(w * scale * uf_f),
        gamma_nuf=st.gamma_nuf.at[srv].add(w * scale * (1.0 - uf_f)),
        res_peak=st.res_peak.at[ch].add(d * scale),
        mem_nuf=st.mem_nuf.at[ch].add(mem_i * scale * (1.0 - uf_f)))
    pool2 = pool - d * scale
    out = jnp.where(~found, FAIL_CAPACITY,
                    jnp.where(~admit_ch, FAIL_POWER,
                              jnp.where(admit_pool, srv, FAIL_TOKENS)))
    return st2, pool2, out, srv


def _place_batch_single_rule(state, pool, cores, is_uf, p95_eff, mem,
                             valid, res_cap, policy: SchedulerPolicy,
                             cps):
    """Rank-free scan for single-rule policies: the winner is the
    stable argmax of the active rule's raw score over feasible servers
    (exactly `SchedulerPolicy.choose` with the other rule's weight 0,
    e.g. `packing_weight=0` == the paper's literal Algorithm-1 /
    §IV-E preference order)."""
    dtype = state.free_cores.dtype
    pack_only = (not policy.use_power_rule) or policy.power_weight == 0.0
    # no positive rule weight at all: the host objective is identically
    # zero and `choose` returns the first feasible server
    no_rule = pack_only and policy.packing_weight == 0.0
    neg_inf = jnp.asarray(-jnp.inf, dtype)

    def body(carry, inp):
        st, pl = carry
        cores_i, uf_i, p95_i, mem_i, valid_i = inp
        feasible = (st.free_cores >= cores_i) & valid_i
        n_feas = feasible.sum()
        if no_rule:
            score = jnp.zeros_like(st.free_cores)
        elif pack_only:
            score = 1.0 - st.free_cores / cps
        else:
            kappa = score_chassis_batch(st)[st.chassis_of]
            eta = score_server_batch(st, uf_i, cps)
            score = policy.alpha * kappa + (1.0 - policy.alpha) * eta
        srv = jnp.argmax(jnp.where(feasible, score, neg_inf))
        st2, pl2, out, _ = _commit(st, pl, srv, n_feas > 0, cores_i,
                                   uf_i, p95_i, mem_i, valid_i, res_cap)
        return (st2, pl2), out

    inputs = (jnp.asarray(cores, dtype), jnp.asarray(is_uf, bool),
              jnp.asarray(p95_eff, dtype), jnp.asarray(mem, dtype),
              jnp.asarray(valid, bool))
    (state, pool), servers = jax.lax.scan(body, (state, pool), inputs)
    return state, servers, pool


def _place_batch_impl(state: DeviceClusterState, pool, cores, is_uf,
                      p95_eff, mem, valid, rho_cap,
                      policy: SchedulerPolicy, cps: float):
    """Shared scan implementation behind `place_batch` (pool forced to
    +inf) and `place_batch_pooled`. Pure and transformation-friendly:
    the sharded serve protocol vmaps/shard_maps it across per-shard
    states (`serve.sharding`). `mem` is the (B,) GB demand; `rho_cap`
    may be the legacy (C,) watt-axis ceiling or a full (C, R) resource
    ceiling, and `pool` a scalar rho balance or an (R,) vector — both
    are lifted with vacuous +inf axes (`core.resources`). Returns
    (state, servers, pool_left) with pool_left (R,)."""
    dtype = state.free_cores.dtype
    pool = lift_pool(jnp.asarray(pool, dtype), xp=jnp)
    res_cap = lift_caps(jnp.asarray(rho_cap, dtype), xp=jnp)
    n_servers = state.n_servers
    idx = jnp.arange(n_servers, dtype=jnp.int32)
    use_power = policy.use_power_rule
    pw, qw = policy.packing_weight, policy.power_weight
    # With a single active rule, argmax of its rank weight IS argmax of
    # its raw score (rank is a monotone transform; stable argsort and
    # argmax both break ties toward the smaller server index), so the
    # whole rank machinery compiles away (~10x fewer step ops).
    single_rule = (not use_power) or pw == 0.0 or qw == 0.0
    if single_rule:
        return _place_batch_single_rule(
            state, pool, cores, is_uf, p95_eff, mem, valid, res_cap,
            policy, cps)

    # both rules active implies use_power: the carry holds the packing
    # rank row, the power score-by-server table, and the inverse
    # permutations (rank position -> server) of the two power rows; the
    # objective is evaluated in *position* space, so the forward power
    # ranks never need to exist
    assert n_servers < (1 << 15), \
        "rank/feasibility bit-packing assumes n_servers < 2**15"
    roff2 = jnp.arange(2, dtype=jnp.int32)[:, None] * n_servers
    a = policy.alpha

    def body(carry, inp):
        st, pl, q_prev, pranks, perm = carry
        cores_i, uf_i, p95_i, mem_i, valid_i = inp
        feasible = (st.free_cores >= cores_i) & valid_i
        n_feas = feasible.sum()
        n_out = n_servers - n_feas
        perm_pow = jnp.where(uf_i, perm[0], perm[1])

        # Everything is indexed by power-rank position p (server
        # perm_pow[p]). Subset rank of the power rule is the prefix
        # count of feasibility in rank order; subset rank of the
        # packing rule is exactly rank - n_out, because infeasible
        # servers are strictly *fuller* and hold a contiguous prefix
        # of the packing order. Branchless and exact on every path
        # (all-feasible reduces to prefix[p] counting every p' < p).
        # Packing rank and feasibility ride one gather (bit 15).
        comb = pranks | (feasible.astype(jnp.int32) << 15)
        cg = comb[perm_pow]
        by_rank = cg >= (1 << 15)
        br = by_rank.astype(jnp.int32)
        sr_pow = jnp.cumsum(br) - br
        sr_pack = (cg & 0x7FFF) - n_out.astype(jnp.int32)

        # numpy-bitwise objective: exact integer rank ties can still
        # resolve differently once divided by (n-1) and weighted (the
        # float sums round per operand set), so mirror
        # `core.placement._rank_weight` + `choose` operation for
        # operation. `choose` takes the first argmax by *server*
        # index; in position space that is the smallest server id
        # among the float-maximal feasible positions.
        denom = jnp.maximum(n_feas - 1, 1).astype(dtype)
        one = jnp.asarray(1.0, dtype)
        rw_guard = n_feas == 1

        def rw(sr):
            return jnp.where(rw_guard, one,
                             one - sr.astype(dtype) / denom)

        obj = pw * rw(sr_pack) + qw * rw(sr_pow)
        masked = jnp.where(by_rank, obj, jnp.asarray(-jnp.inf, dtype))
        srv = jnp.min(jnp.where(masked == jnp.max(masked), perm_pow,
                                n_servers))
        st2, pl2, out, srv = _commit(st, pl, srv, n_feas > 0, cores_i,
                                     uf_i, p95_i, mem_i, valid_i,
                                     res_cap)
        ch = st.chassis_of[srv]
        # Incremental maintenance. Packing ranks: only the placed
        # server's score moved — subtract its old key's wins over each
        # server, add the new ones, recount the placed row exactly.
        # Power orders: the placed chassis' K servers moved (kappa,
        # plus the placed server's eta) — their new keys are recomputed
        # on the K-subset with the exact `_rule_scores` float ops, the
        # landing/vacated positions come from `_delta_positions`, and
        # the inverse permutations recompose in closed form. A
        # rejected/failed arrival leaves scores unchanged, so every
        # correction cancels to zero.
        p_old = 1.0 - st.free_cores / cps
        p_new_s = 1.0 - st2.free_cores[srv] / cps
        dcnt0 = _before(p_new_s, srv, p_old, idx).astype(jnp.int32) \
            - _before(p_old[srv], srv, p_old, idx).astype(jnp.int32)
        fresh0 = _before(p_old, idx, p_new_s, srv) \
            .sum(dtype=jnp.int32) \
            - _before(p_old[srv], srv, p_new_s, srv).astype(jnp.int32)
        pranks2 = jnp.where(idx == srv, fresh0, pranks + dcnt0)
        delta = st.chassis_servers[ch]                   # (K,)
        # K-subset twin of `_rule_scores` rows 1-2 (same float ops on
        # the same operands, so the carried table stays bit-identical
        # to a full recompute)
        kappa2 = 1.0 - st2.rho_peak[ch] \
            / jnp.maximum(st2.rho_max[ch], 1e-9)
        diff = st2.gamma_nuf[delta] - st2.gamma_uf[delta]
        eta2 = 0.5 * (1.0 + jnp.stack([diff, -diff]) / cps)
        new_d = a * kappa2 + (1.0 - a) * eta2            # (2, K)
        old_d = q_prev[:, delta]
        q_prev2 = q_prev.reshape(-1) \
            .at[(delta[None, :] + roff2).reshape(-1)] \
            .set(new_d.reshape(-1)).reshape(2, n_servers)
        lb_new, d_old = _delta_positions(perm, q_prev, new_d, old_d,
                                         delta)
        # lower bound of a new key counts old delta keys and the other
        # new keys that sort before it; remove the former (they leave
        # the order), add this key's rank among the new keys
        before_old = _before(old_d[:, None, :], delta[None, None, :],
                             new_d[:, :, None], delta[None, :, None]) \
            .sum(-1, dtype=jnp.int32)
        intra_new = _before(new_d[:, None, :], delta[None, None, :],
                            new_d[:, :, None], delta[None, :, None]) \
            .sum(-1, dtype=jnp.int32)
        fresh = lb_new - before_old + intra_new
        perm2 = _compose_inverse(perm, fresh, d_old, delta)
        return (st2, pl2, q_prev2, pranks2, perm2), out

    inputs = (jnp.asarray(cores, dtype), jnp.asarray(is_uf, bool),
              jnp.asarray(p95_eff, dtype), jnp.asarray(mem, dtype),
              jnp.asarray(valid, bool))
    scores0 = _rule_scores(state, policy, cps)
    ranks0, perm0 = _init_ranks(scores0)
    (state, pool, _, _, _), servers = jax.lax.scan(
        body, (state, pool, scores0[1:], ranks0[0], perm0[1:]), inputs)
    return state, servers, pool


def _mem_or_zeros(mem_gb, cores):
    """(B,) GB demand; ``None`` (a memory-blind caller) places zero GB
    — every GB compare is then vacuous, preserving legacy decisions."""
    return jnp.zeros(jnp.shape(cores)) if mem_gb is None \
        else jnp.asarray(mem_gb)


@partial(jax.jit, static_argnames=("policy", "cores_per_server"))
def place_batch(state: DeviceClusterState, cores: jnp.ndarray,
                is_uf: jnp.ndarray, p95_eff: jnp.ndarray,
                valid: jnp.ndarray, rho_cap: jnp.ndarray,
                policy: SchedulerPolicy, cores_per_server: int,
                mem_gb=None):
    """Place one arrival micro-batch. cores/is_uf/p95_eff/valid: (B,)
    arrays (`valid=False` rows are padding and never touch state);
    `rho_cap`: per-chassis admission ceiling — (C,) on chassis
    sum(p95*cores) only (the legacy watt form), or (C, R) over the
    full (watts, cores, GB) resource ledger (+inf disables any axis —
    see `serve.admission`); `mem_gb`: optional (B,) GB demand (None
    places zero GB). Returns (new_state, servers (B,) i32) with
    FAIL_* codes for rejects.

    Arithmetic follows the state dtype: f32 on the serving path, f64
    (bit-equivalent to the numpy rule) when traced under
    `jax.experimental.enable_x64` with an f64 state — that is how the
    scheduler simulation's serve backend verifies decision
    equivalence."""
    state, servers, _ = _place_batch_impl(
        state, jnp.inf, cores, is_uf, p95_eff,
        _mem_or_zeros(mem_gb, cores), valid, rho_cap, policy,
        float(cores_per_server))
    return state, servers


class SweepCounters(NamedTuple):
    """In-scan observables of one fused emergency sweep, accumulated in
    the cap-window scan carry (`_apply_cap_windows`) and flushed into
    the host `repro.obs.MetricsRegistry` by the pipeline. All leaves
    are scalars except `cut_by_level_w` (L,) — per-criticality-level
    watts removed, level order = apportionment priority (NUF first)."""
    samples: Any        # i32 — chassis power samples applied
    alarms: Any         # i32 — protective-capping alarms raised
    cut_w: Any          # f — required reduction past the target (W)
    leftover_w: Any     # f — cut no floor absorbed (RAPL trigger, W)
    cut_by_level_w: Any  # (L,) f — realized watts cut per crit level


def _zero_sweep(dtype) -> SweepCounters:
    """All-zero `SweepCounters` (the scan-carry initial value)."""
    return SweepCounters(
        jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
        jnp.zeros((), dtype), jnp.zeros((), dtype),
        jnp.zeros(emergency.N_LEVELS, dtype))


def _apply_cap_windows(ecfg, state: DeviceClusterState, emer, pw, mask,
                       ts):
    """Apply W queued power-emergency sample sub-windows against the
    *current* cluster aggregates, inside whatever jit this is traced
    into. pw/mask/ts: (W, C) dense `masked_step` operands in merged
    order. The windows were all merged *before* the arrival batch this
    rides with, and a cap touches only the emergency state (never the
    placement aggregates), so applying them back-to-back ahead of the
    placement scan is exactly the semantics of dispatching each window
    on its own — minus W extra dispatches. Returns
    ``(emergency_state, SweepCounters)``."""
    rho_lv = emergency.chassis_rho_levels(
        state.gamma_nuf, state.gamma_uf, state.chassis_servers, jnp)
    dtype = state.free_cores.dtype

    def body(carry, xs):
        em, acc = carry
        p, m, t = xs
        em2, out = emergency.masked_step(ecfg, em, rho_lv, p, m, t, jnp)
        acc2 = SweepCounters(
            acc.samples + m.sum(dtype=jnp.int32),
            acc.alarms + out.alarm.sum(dtype=jnp.int32),
            acc.cut_w + out.cut_w.sum(dtype=dtype),
            acc.leftover_w + out.leftover_w.sum(dtype=dtype),
            acc.cut_by_level_w + out.cut_by_level_w.sum(0, dtype=dtype))
        return (em2, acc2), None

    (emer, sweep), _ = jax.lax.scan(body, (emer, _zero_sweep(dtype)),
                                    (pw, mask, ts))
    return emer, sweep


@partial(jax.jit,
         static_argnames=("policy", "cores_per_server", "ecfg"))
def place_batch_caps(state: DeviceClusterState, emer, pw, mask, ts,
                     cores, is_uf, p95_eff, valid, rho_cap,
                     policy: SchedulerPolicy, cores_per_server: int,
                     ecfg, mem_gb=None):
    """`place_batch` with the pending power-emergency cap sub-windows
    fused in front of the placement scan: one compiled dispatch steps
    the emergency state through every queued (W, C) sample window
    (`_apply_cap_windows`) and then places the arrival batch — an
    emergency sweep costs zero extra dispatches on the serving path.
    `ecfg` is the static `emergency.EmergencyConfig`. Returns
    ``(new_state, servers, emergency_state, SweepCounters)`` — the
    sweep counters replace PR 6's scalar alarm count (alarms is now
    ``sweep.alarms``) and feed the observability plane at zero extra
    dispatch cost."""
    emer, sweep = _apply_cap_windows(ecfg, state, emer, pw, mask, ts)
    state, servers, _ = _place_batch_impl(
        state, jnp.inf, cores, is_uf, p95_eff,
        _mem_or_zeros(mem_gb, cores), valid, rho_cap, policy,
        float(cores_per_server))
    return state, servers, emer, sweep


@partial(jax.jit, static_argnames=("policy", "cores_per_server"))
def place_batch_pooled(state: DeviceClusterState, pool, cores, is_uf,
                       p95_eff, valid, rho_cap,
                       policy: SchedulerPolicy, cores_per_server: int,
                       mem_gb=None):
    """`place_batch` with an explicit token pool: each admission
    additionally requires its (R,) demand vector to clear the pool on
    every axis and draws the pool down, else returns FAIL_TOKENS.
    `pool` is a scalar rho-unit balance (the legacy watt protocol) or
    an (R,) (watts, cores, GB) balance. This is the per-shard reserve
    primitive of the sharded serve protocol (`serve.sharding`,
    docs/sharding.md). Returns (new_state, servers, pool_left) with
    pool_left (R,)."""
    return _place_batch_impl(state, pool, cores, is_uf, p95_eff,
                             _mem_or_zeros(mem_gb, cores), valid,
                             rho_cap, policy, float(cores_per_server))


@jax.jit
def remove_batch(state: DeviceClusterState, servers: jnp.ndarray,
                 cores: jnp.ndarray, p95_eff: jnp.ndarray,
                 is_uf: jnp.ndarray, mem_gb=None) -> DeviceClusterState:
    """Batch departure: order-independent scatter-subtract (twin of
    `ClusterState.remove`), crediting the full (R,) demand vector back
    to the ledger. `servers < 0` rows are ignored; negated-cores rows
    are the pinned-placement encoding (`serve.mitigation`) and *debit*
    instead. Follows the state dtype like `place_batch`, so an f64
    place/remove roundtrip is bit-exact."""
    dtype = state.free_cores.dtype
    live = servers >= 0
    srv = jnp.where(live, servers, 0).astype(jnp.int32)
    scale = live.astype(dtype)
    cores = cores.astype(dtype) * scale
    mem = _mem_or_zeros(mem_gb, cores).astype(dtype) * scale
    w = p95_eff.astype(dtype) * cores
    uf_f = is_uf.astype(dtype)
    ch = state.chassis_of[srv]
    d = jnp.stack([w, cores, mem], axis=-1)                 # (B, R)
    return state._replace(
        free_cores=state.free_cores.at[srv].add(cores),
        gamma_uf=state.gamma_uf.at[srv].add(-w * uf_f),
        gamma_nuf=state.gamma_nuf.at[srv].add(-w * (1.0 - uf_f)),
        res_peak=state.res_peak.at[ch].add(-d),
        mem_nuf=state.mem_nuf.at[ch].add(-mem * (1.0 - uf_f)))


def outcome_counters(servers, valid, cores, p95_eff,
                     mem_gb=None) -> dict:
    """Per-batch decision counts from a placement's outputs — the
    host-side (numpy) reduction the observability plane accumulates.

    servers: (B,) outcome codes as returned by the `place_batch`
    family; valid/cores/p95_eff: the matching batch operands. Padding
    rows (``valid=False``) can carry arbitrary codes without ever
    touching state, so every count masks with `valid`. Returns integer
    counts per outcome plus ``rho_admitted`` / ``cores_admitted`` /
    ``gb_admitted`` (the admitted (R,) demand per axis — the exact
    quantities drawn from the chassis `res_peak` ledger and, sharded,
    the token pools; ``mem_gb=None`` reports 0 GB). Keys: admits /
    fail_capacity / fail_power / fail_tokens / rho_admitted /
    cores_admitted / gb_admitted; the first four always sum to
    ``valid.sum()``."""
    servers = np.asarray(servers)
    valid = np.asarray(valid, bool)
    admitted = (servers >= 0) & valid
    cores = np.asarray(cores, np.float64)
    w = np.asarray(p95_eff, np.float64) * cores
    mem = np.zeros_like(cores) if mem_gb is None \
        else np.asarray(mem_gb, np.float64)
    return {
        "admits": int(admitted.sum()),
        "fail_capacity": int(((servers == FAIL_CAPACITY) & valid).sum()),
        "fail_power": int(((servers == FAIL_POWER) & valid).sum()),
        "fail_tokens": int(((servers == FAIL_TOKENS) & valid).sum()),
        "rho_admitted": float(w[admitted].sum()),
        "cores_admitted": float(cores[admitted].sum()),
        "gb_admitted": float(mem[admitted].sum()),
    }
