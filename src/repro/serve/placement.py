"""Vectorized Algorithm-1 placement (serve-pipeline stage 3).

`place_batch` is the jnp twin of `SchedulerPolicy.choose` +
`ClusterState.place`: one jitted `lax.scan` walks an arrival
micro-batch in order (placements must see earlier placements — the
same sequential semantics as the event-driven scheduler), and each
step scores *all* servers at once.

The rank-weight aggregation is reformulated sort-free, because a
per-step argsort is the one operation XLA cannot make fast inside a
scan (~150 us per 720-element sort on CPU — 25x the whole step
budget):

  * a placement only changes the scores of the placed chassis'
    K = S/C servers (its kappa, plus the chosen server's packing/eta
    term), so full-fleet stable ranks are *maintained incrementally*:
    O(S*K) fused comparisons subtract the old Delta-keys and add the
    new ones, and the Delta rows are recounted exactly — no sort after
    the one batched argsort that seeds the scan;
  * per-arrival feasibility: infeasible servers are strictly fuller,
    so the packing subset rank is exactly `full_rank - n_infeasible`;
    the power rule falls back to a prefix count of the feasibility
    mask in rank order (scatter + cumsum + gather) only when some
    server is infeasible — a lax.cond keeps that off the common path;
  * the objective then mirrors `SchedulerPolicy.choose` operation for
    operation — `sum_r w_r * (1 - subset_rank_r/(n_feas-1))`, first
    argmax — because even exactly-tied integer rank sums can resolve
    differently once divided and weighted in floats.

Rank rows are (packing, power-for-UF, power-for-NUF) — the power score
depends on the arriving VM's type, so both orders are maintained.
Single-rule policies (packing_weight or power_weight zero, or the
power rule off) skip the rank machinery entirely: one rule's rank
weight is a monotone transform of its raw score, so a stable score
argmax decides (`_place_batch_single_rule`).

Decision equivalence with the numpy path holds because subset ranks
are exact integers and the float aggregation replicates the host
arithmetic; the scheduler simulation's serve backend runs this same
scan in x64, where it is bit-equivalent to the f64 host rule
(DESIGN.md §9 bounds the residual f32-vs-f64 divergence of the score
inputs on the serving path).

The power-headroom admission check (serve-pipeline stage 4, see
`serve/admission.py`) is fused into the scan: a placement that would
push its chassis' projected peak draw over budget is rejected before
it mutates the state.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import ClusterState, SchedulerPolicy

#: `place_batch` outcome codes (in the returned server array).
FAIL_CAPACITY = -1      # no feasible server (deployment failure)
FAIL_POWER = -2         # placed server's chassis lacks power headroom
FAIL_TOKENS = -3        # shard's power-token pool exhausted (sharded serve)


class DeviceClusterState(NamedTuple):
    """Device mirror of `core.placement.ClusterState`'s aggregates."""
    free_cores: jnp.ndarray      # (S,) f32
    gamma_uf: jnp.ndarray        # (S,) f32
    gamma_nuf: jnp.ndarray       # (S,) f32
    rho_peak: jnp.ndarray        # (C,) f32
    rho_max: jnp.ndarray         # (C,) f32
    chassis_of: jnp.ndarray      # (S,) i32
    chassis_servers: jnp.ndarray  # (C, S//C) i32 — servers per chassis

    @property
    def n_servers(self) -> int:
        return self.free_cores.shape[0]


def _chassis_servers(chassis_of: np.ndarray) -> np.ndarray:
    """(C, K) server-index table (rank maintenance gathers the placed
    chassis' servers through it). Chassis must be equal-sized."""
    chassis_of = np.asarray(chassis_of)
    n_chassis = int(chassis_of.max()) + 1
    sizes = np.bincount(chassis_of, minlength=n_chassis)
    assert (sizes == len(chassis_of) // n_chassis).all(), \
        "chassis must be equal-sized"
    order = np.argsort(chassis_of, kind="stable")
    return order.reshape(n_chassis, -1).astype(np.int32)


def device_state(state: ClusterState,
                 dtype=jnp.float32) -> DeviceClusterState:
    """Mirror a host `ClusterState`'s aggregates onto the device.
    `dtype` selects the serving (f32) or equivalence-testing (f64,
    under `jax.experimental.enable_x64`) arithmetic."""
    return DeviceClusterState(
        jnp.asarray(state.free_cores, dtype),
        jnp.asarray(state.gamma_uf, dtype),
        jnp.asarray(state.gamma_nuf, dtype),
        jnp.asarray(state.rho_peak, dtype),
        jnp.asarray(state.rho_max, dtype),
        jnp.asarray(state.chassis_of_server, jnp.int32),
        jnp.asarray(_chassis_servers(state.chassis_of_server)))


def fresh_state(n_servers: int, cores_per_server: int,
                chassis_of: np.ndarray) -> DeviceClusterState:
    """Device state of an empty cluster (every core free, nothing
    committed) with the given server→chassis layout."""
    return device_state(ClusterState(
        n_servers=n_servers, cores_per_server=cores_per_server,
        chassis_of_server=np.asarray(chassis_of),
        n_chassis=int(np.asarray(chassis_of).max()) + 1))


def score_chassis_batch(state: DeviceClusterState) -> jnp.ndarray:
    """jnp twin of `ClusterState.score_chassis` — (C,)."""
    return 1.0 - state.rho_peak / jnp.maximum(state.rho_max, 1e-9)


def score_server_batch(state: DeviceClusterState, vm_is_uf,
                       cores_per_server: int) -> jnp.ndarray:
    """jnp twin of `ClusterState.score_server`. `vm_is_uf` may be a
    scalar bool or a (B,) array (then the result is (B, S))."""
    uf = jnp.asarray(vm_is_uf, bool)
    diff = jnp.where(uf[..., None] if uf.ndim else uf,
                     state.gamma_nuf - state.gamma_uf,
                     state.gamma_uf - state.gamma_nuf)
    return 0.5 * (1.0 + diff / float(cores_per_server))


def _rule_scores(state: DeviceClusterState, policy: SchedulerPolicy,
                 cps: float) -> jnp.ndarray:
    """(R, S) score rows the preference rules order. Row 0: packing
    (`core.placement.packing_score`). Rows 1-2 (when the power rule is
    on): Algorithm-1 score for a UF / NUF arrival — both are kept
    because the arriving VM's type flips the eta term."""
    pack = 1.0 - state.free_cores / cps
    if not policy.use_power_rule:
        return pack[None]
    kappa = score_chassis_batch(state)[state.chassis_of]
    a = policy.alpha
    return jnp.stack(
        [pack] + [a * kappa + (1.0 - a)
                  * score_server_batch(state, uf, cps)
                  for uf in (True, False)])


def _before(s_j, j, s_i, i):
    """Stable descending order: does key (s_j, j) sort before key
    (s_i, i)? Ties break toward the smaller server index — the same
    order `np.argsort(kind='stable')` of negated scores produces."""
    return (s_j > s_i) | ((s_j == s_i) & (j < i))


def _init_ranks(scores: jnp.ndarray) -> jnp.ndarray:
    """(R, S) stable descending ranks (one batched argsort + scatter —
    runs once per micro-batch, outside the scan)."""
    r, s = scores.shape
    perm = jnp.argsort(-scores, axis=-1, stable=True)
    rows = jnp.arange(r)[:, None]
    return jnp.zeros((r, s), jnp.int32).at[rows, perm].set(
        jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (r, s)))


def _commit(st: DeviceClusterState, pool, srv, found, cores_i, uf_i,
            p95_i, valid_i, rho_cap):
    """Admission check + masked state update + outcome code — the
    shared tail of both scan bodies. `srv` is the winning server with
    `found` indicating a feasible candidate existed. `pool` is the
    scalar power-token balance (rho units) the placement draws from:
    +inf outside the sharded protocol, where the compare is vacuous and
    the arithmetic reduces to the unpooled rule."""
    dtype = st.free_cores.dtype
    srv = jnp.where(found, srv, 0).astype(jnp.int32)
    ch = st.chassis_of[srv]
    w = p95_i * cores_i
    admit_ch = st.rho_peak[ch] + w <= rho_cap[ch]
    admit_pool = w <= pool
    scale = (found & admit_ch & admit_pool & valid_i).astype(dtype)
    uf_f = uf_i.astype(dtype)
    st2 = st._replace(
        free_cores=st.free_cores.at[srv].add(-cores_i * scale),
        gamma_uf=st.gamma_uf.at[srv].add(w * scale * uf_f),
        gamma_nuf=st.gamma_nuf.at[srv].add(w * scale * (1.0 - uf_f)),
        rho_peak=st.rho_peak.at[ch].add(w * scale))
    pool2 = pool - w * scale
    out = jnp.where(~found, FAIL_CAPACITY,
                    jnp.where(~admit_ch, FAIL_POWER,
                              jnp.where(admit_pool, srv, FAIL_TOKENS)))
    return st2, pool2, out, srv


def _place_batch_single_rule(state, pool, cores, is_uf, p95_eff, valid,
                             rho_cap, policy: SchedulerPolicy, cps):
    """Rank-free scan for single-rule policies: the winner is the
    stable argmax of the active rule's raw score over feasible servers
    (exactly `SchedulerPolicy.choose` with the other rule's weight 0,
    e.g. `packing_weight=0` == the paper's literal Algorithm-1 /
    §IV-E preference order)."""
    dtype = state.free_cores.dtype
    pack_only = (not policy.use_power_rule) or policy.power_weight == 0.0
    # no positive rule weight at all: the host objective is identically
    # zero and `choose` returns the first feasible server
    no_rule = pack_only and policy.packing_weight == 0.0
    neg_inf = jnp.asarray(-jnp.inf, dtype)

    def body(carry, inp):
        st, pl = carry
        cores_i, uf_i, p95_i, valid_i = inp
        feasible = (st.free_cores >= cores_i) & valid_i
        n_feas = feasible.sum()
        if no_rule:
            score = jnp.zeros_like(st.free_cores)
        elif pack_only:
            score = 1.0 - st.free_cores / cps
        else:
            kappa = score_chassis_batch(st)[st.chassis_of]
            eta = score_server_batch(st, uf_i, cps)
            score = policy.alpha * kappa + (1.0 - policy.alpha) * eta
        srv = jnp.argmax(jnp.where(feasible, score, neg_inf))
        st2, pl2, out, _ = _commit(st, pl, srv, n_feas > 0, cores_i,
                                   uf_i, p95_i, valid_i, rho_cap)
        return (st2, pl2), out

    inputs = (jnp.asarray(cores, dtype), jnp.asarray(is_uf, bool),
              jnp.asarray(p95_eff, dtype), jnp.asarray(valid, bool))
    (state, pool), servers = jax.lax.scan(body, (state, pool), inputs)
    return state, servers, pool


def _place_batch_impl(state: DeviceClusterState, pool, cores, is_uf,
                      p95_eff, valid, rho_cap, policy: SchedulerPolicy,
                      cps: float):
    """Shared scan implementation behind `place_batch` (pool forced to
    +inf) and `place_batch_pooled`. Pure and transformation-friendly:
    the sharded serve protocol vmaps/shard_maps it across per-shard
    states (`serve.sharding`). Returns (state, servers, pool_left)."""
    dtype = state.free_cores.dtype
    pool = jnp.asarray(pool, dtype)
    n_servers = state.n_servers
    idx = jnp.arange(n_servers, dtype=jnp.int32)
    use_power = policy.use_power_rule
    pw, qw = policy.packing_weight, policy.power_weight
    rows_q = jnp.arange(2)[:, None]
    # With a single active rule, argmax of its rank weight IS argmax of
    # its raw score (rank is a monotone transform; stable argsort and
    # argmax both break ties toward the smaller server index), so the
    # whole rank machinery compiles away (~10x fewer step ops).
    single_rule = (not use_power) or pw == 0.0 or qw == 0.0
    if single_rule:
        return _place_batch_single_rule(
            state, pool, cores, is_uf, p95_eff, valid, rho_cap, policy,
            cps)

    def subset_rank(r, feasible):
        """Rank of each server among the feasible subset: prefix count
        of the feasibility mask in full-rank order. Costs two XLA CPU
        scatters (~45 us each) — slow-path only."""
        by_rank = jnp.zeros(n_servers, jnp.int32) \
            .at[r].set(feasible.astype(jnp.int32))
        return (jnp.cumsum(by_rank) - by_rank)[r]

    def body(carry, inp):
        st, pl, scores, ranks = carry
        cores_i, uf_i, p95_i, valid_i = inp
        raw_feas = st.free_cores >= cores_i
        feasible = raw_feas & valid_i
        n_feas = feasible.sum()
        n_out = n_servers - n_feas
        r_pow = jnp.where(uf_i, ranks[1], ranks[2]) if use_power \
            else ranks[0]

        # Subset rank of the packing rule is exactly r_p - n_out:
        # infeasible servers are strictly *fuller*, so they hold a
        # contiguous prefix of the packing order. The power rule needs
        # the real prefix count only when some server is infeasible
        # (cond keeps the two scatters off the common serving path).
        sr_pack = ranks[0] - n_out.astype(jnp.int32)
        sr_pow = jax.lax.cond(
            (n_out == 0) | (n_feas == 0),
            lambda _: r_pow,
            lambda _: subset_rank(r_pow, feasible), None) if use_power \
            else r_pow

        # numpy-bitwise objective: exact integer rank ties can still
        # resolve differently once divided by (n-1) and weighted (the
        # float sums round per operand set), so mirror
        # `core.placement._rank_weight` + `choose` operation for
        # operation and take the first argmax.
        denom = jnp.maximum(n_feas - 1, 1).astype(dtype)
        one = jnp.asarray(1.0, dtype)
        rw_guard = n_feas == 1

        def rw(sr):
            return jnp.where(rw_guard, one,
                             one - sr.astype(dtype) / denom)

        obj = pw * rw(sr_pack)
        if use_power:
            obj = obj + qw * rw(sr_pow)
        srv = jnp.argmax(jnp.where(feasible, obj,
                                   jnp.asarray(-jnp.inf, dtype)))
        st2, pl2, out, srv = _commit(st, pl, srv, n_feas > 0, cores_i,
                                     uf_i, p95_i, valid_i, rho_cap)
        ch = st.chassis_of[srv]
        # Incremental rank maintenance. Packing: only the placed
        # server's score moved. Power: the placed chassis' K servers
        # moved (kappa, plus the placed server's eta). Subtract the
        # old moved keys' wins over each server, add the new ones, and
        # recount the moved rows exactly under the new keys. A
        # rejected/failed arrival leaves scores unchanged, so every
        # correction cancels to zero.
        new_scores = _rule_scores(st2, policy, cps)
        p_old, p_new = scores[0], new_scores[0]
        dcnt0 = _before(p_new[srv], srv, p_old, idx).astype(jnp.int32) \
            - _before(p_old[srv], srv, p_old, idx).astype(jnp.int32)
        fresh0 = _before(p_new, idx, p_new[srv], srv) \
            .sum(dtype=jnp.int32)
        ranks0 = (ranks[0] + dcnt0).at[srv].set(fresh0)
        if use_power:
            delta = st.chassis_servers[ch]                   # (K,)
            q_old, q_new = scores[1:], new_scores[1:]        # (2, S)
            old_d = q_old[:, delta]                          # (2, K)
            new_d = q_new[:, delta]
            dcnt = (_before(new_d[:, None, :], delta[None, None, :],
                            q_old[:, :, None], idx[None, :, None])
                    .astype(jnp.int32)
                    - _before(old_d[:, None, :], delta[None, None, :],
                              q_old[:, :, None], idx[None, :, None])
                    .astype(jnp.int32)).sum(-1, dtype=jnp.int32)
            fresh = _before(q_new[:, None, :], idx[None, None, :],
                            new_d[:, :, None], delta[None, :, None]) \
                .sum(-1, dtype=jnp.int32)
            ranks_q = (ranks[1:] + dcnt) \
                .at[rows_q, delta[None, :]].set(fresh)
            ranks2 = jnp.concatenate([ranks0[None], ranks_q], 0)
        else:
            ranks2 = ranks0[None]
        return (st2, pl2, new_scores, ranks2), out

    inputs = (jnp.asarray(cores, dtype), jnp.asarray(is_uf, bool),
              jnp.asarray(p95_eff, dtype), jnp.asarray(valid, bool))
    scores0 = _rule_scores(state, policy, cps)
    (state, pool, _, _), servers = jax.lax.scan(
        body, (state, pool, scores0, _init_ranks(scores0)), inputs)
    return state, servers, pool


@partial(jax.jit, static_argnames=("policy", "cores_per_server"))
def place_batch(state: DeviceClusterState, cores: jnp.ndarray,
                is_uf: jnp.ndarray, p95_eff: jnp.ndarray,
                valid: jnp.ndarray, rho_cap: jnp.ndarray,
                policy: SchedulerPolicy, cores_per_server: int):
    """Place one arrival micro-batch. cores/is_uf/p95_eff/valid: (B,)
    arrays (`valid=False` rows are padding and never touch state);
    `rho_cap`: (C,) admission ceiling on chassis sum(p95*cores)
    (+inf disables the check — see `serve.admission`). Returns
    (new_state, servers (B,) i32) with FAIL_* codes for rejects.

    Arithmetic follows the state dtype: f32 on the serving path, f64
    (bit-equivalent to the numpy rule) when traced under
    `jax.experimental.enable_x64` with an f64 state — that is how the
    scheduler simulation's serve backend verifies decision
    equivalence."""
    state, servers, _ = _place_batch_impl(
        state, jnp.inf, cores, is_uf, p95_eff, valid, rho_cap, policy,
        float(cores_per_server))
    return state, servers


@partial(jax.jit, static_argnames=("policy", "cores_per_server"))
def place_batch_pooled(state: DeviceClusterState, pool, cores, is_uf,
                       p95_eff, valid, rho_cap,
                       policy: SchedulerPolicy, cores_per_server: int):
    """`place_batch` with an explicit scalar power-token pool (rho
    units — same currency as `rho_peak`): each admission additionally
    requires `p95*cores <= pool_left` and draws the pool down, else
    returns FAIL_TOKENS. This is the per-shard reserve primitive of the
    sharded serve protocol (`serve.sharding`, docs/sharding.md).
    Returns (new_state, servers, pool_left)."""
    return _place_batch_impl(state, pool, cores, is_uf, p95_eff, valid,
                             rho_cap, policy, float(cores_per_server))


@jax.jit
def remove_batch(state: DeviceClusterState, servers: jnp.ndarray,
                 cores: jnp.ndarray, p95_eff: jnp.ndarray,
                 is_uf: jnp.ndarray) -> DeviceClusterState:
    """Batch departure: order-independent scatter-subtract (twin of
    `ClusterState.remove`). `servers < 0` rows are ignored. Follows
    the state dtype like `place_batch`, so an f64 place/remove
    roundtrip is bit-exact."""
    dtype = state.free_cores.dtype
    live = servers >= 0
    srv = jnp.where(live, servers, 0).astype(jnp.int32)
    scale = live.astype(dtype)
    cores = cores.astype(dtype) * scale
    w = p95_eff.astype(dtype) * cores
    uf_f = is_uf.astype(dtype)
    ch = state.chassis_of[srv]
    return state._replace(
        free_cores=state.free_cores.at[srv].add(cores),
        gamma_uf=state.gamma_uf.at[srv].add(-w * uf_f),
        gamma_nuf=state.gamma_nuf.at[srv].add(-w * (1.0 - uf_f)),
        rho_peak=state.rho_peak.at[ch].add(-w))
