"""Sharded multi-host serve placement (DESIGN.md §10, docs/sharding.md).

One `ServePipeline` serves one cluster from one host; this module is
the fleet-scale path: the chassis state is partitioned over a device
mesh and arrival micro-batches are placed by all shards concurrently
under a consistent-placement protocol.

Layout
    Chassis are assigned to shards in contiguous equal blocks
    (`chassis_to_shard`); servers follow their chassis. Each shard owns
    a disjoint `DeviceClusterState` slice (local server/chassis ids,
    stacked with a leading shard axis — `ShardedState`), so no two
    shards can ever double-book a chassis: only the owner mutates it.

Routing
    Arrivals are dealt round-robin by arrival index (`route_shard` —
    arrival i's home shard is ``i % n_shards``), which keeps per-shard
    batches equal-sized and makes the whole protocol a deterministic
    function of the batch. With one shard the routing is the identity
    and the protocol degenerates to exactly `place_batch` — the
    decision-identity the equivalence tests assert.

Reserve/commit with power-headroom tokens
    A global watt budget converts to a pool of rho-unit tokens
    (`rho_pool_from_budget`) split across shards. Phase 1 (reserve):
    every shard runs the placement scan against its local state,
    drawing tokens from its own pool (`place_batch_pooled`); because
    chassis ownership is exclusive and pools are disjoint, local
    reservations commit immediately and the global budget cannot be
    exceeded, whatever the shards do concurrently. Phase 2 (spillover
    commit): arrivals their home shard rejected are re-offered to the
    other shards in deterministic rounds — round r sends arrival i to
    shard ``(i + r) % n_shards`` — after an all-gather of the shards'
    leftover tokens (the only cross-shard communication; optionally
    rebalanced equally). Token totals are conserved by rebalancing and
    by departures crediting their shard's pool, so the invariant
    ``sum(rho_peak) <= pool_total`` holds for the life of the cluster.

Execution
    Per-shard scans run under `jax.vmap` (single device — the
    semantics oracle) or `jax.shard_map` over a 1-D ``("shard",)``
    mesh (one scan per device — the scaling path benchmarked by
    `benchmarks/serve_sharded.py` with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``). Both
    execute identical per-shard arithmetic and are asserted equal in
    `tests/test_serve_sharded.py`.

Observability (DESIGN.md §14, §17)
    Every sharded scan carries its counters (admits, fails, spills,
    token draws, sweep totals) as extra *outputs* — never inputs — so
    the sharded pipeline feeds the registry, windowed aggregates,
    prediction scorecard, SLO monitor, and flight recorder entirely
    host-side; instrumented and uninstrumented runs stay
    decision-bit-identical (asserted in `tests/test_obs.py` and
    `tests/test_obs_quality.py`).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.placement import SchedulerPolicy
from repro.core.power_model import F_MAX, ServerPowerModel, idle_power
from repro.core.resources import (N_RESOURCES, ResourceVector,
                                  lift_caps, lift_pool)
from repro.serve import ballooning, emergency
from repro.serve.placement import (DeviceClusterState, FAIL_CAPACITY,
                                   SweepCounters, _apply_cap_windows,
                                   _place_batch_impl, remove_batch)

#: Mesh axis name the serve shards map over.
SHARD_AXIS = "shard"


class ShardedState(NamedTuple):
    """Cluster state partitioned into N disjoint shard slices.

    Every `shards` leaf carries a leading (N,) shard axis over *local*
    server/chassis ids; the `global_*` tables translate local winners
    back to cluster ids and `shard_of_server`/`local_of_server` invert
    them for departures. `res_cap` / `pool` are per-resource (R =
    (watts, cores, GB), `core.resources`): `pool` is each shard's
    remaining token balance per axis (+inf on unbudgeted axes — a
    power-only budget reproduces the scalar watt protocol exactly;
    axis 0 is rho units)."""
    shards: DeviceClusterState      # leaves (N, S/N) / (N, C/N) / ...
    global_server: jnp.ndarray      # (N, S/N) i32 — local -> global id
    global_chassis: jnp.ndarray     # (N, C/N) i32
    shard_of_server: jnp.ndarray    # (S,) i32 — global server -> shard
    local_of_server: jnp.ndarray    # (S,) i32 — global server -> local id
    res_cap: jnp.ndarray            # (N, C/N, R) — chassis admission caps
    pool: jnp.ndarray               # (N, R) — tokens left per resource

    @property
    def n_shards(self) -> int:
        return self.global_server.shape[0]

    @property
    def n_servers(self) -> int:
        return self.shard_of_server.shape[0]


def chassis_to_shard(n_chassis: int, n_shards: int) -> np.ndarray:
    """(C,) shard owner of each chassis: contiguous equal blocks.

    Shard counts must divide the chassis count (docs/sharding.md
    discusses picking them); contiguity keeps a rack's chassis on one
    shard under the standard ``chassis = server // blades`` layout."""
    if n_chassis % n_shards:
        raise ValueError(
            f"n_shards={n_shards} must divide n_chassis={n_chassis}")
    return np.repeat(np.arange(n_shards, dtype=np.int32),
                     n_chassis // n_shards)


def rho_pool_from_budget(cluster_budget_w, n_servers: int,
                         model: ServerPowerModel | None = None) -> float:
    """Cluster watt budget -> global power-token pool in rho units.

    The cluster-level twin of `serve.admission.rho_cap_from_budget`:
    tokens are the dynamic-power allowance
    ``(budget - S * P_idle(f_max)) / p_dyn_per_core`` — the ceiling on
    fleet-wide ``sum(p95 * cores)``. None/inf disables (+inf pool)."""
    if cluster_budget_w is None or np.isinf(cluster_budget_w):
        return float("inf")
    model = model or ServerPowerModel()
    static = n_servers * float(idle_power(F_MAX))
    return max((float(cluster_budget_w) - static) / model.p_dyn_per_core,
               0.0)


def resource_pool_from_budget(budget: ResourceVector, n_servers: int,
                              model: ServerPowerModel | None = None
                              ) -> np.ndarray:
    """Cluster `ResourceVector` budget -> (R,) global token pool.

    The watts axis converts through the power model exactly like
    `rho_pool_from_budget` (rho units); the cores/GB axes are already
    in pool currency (allocatable virtual cores / GB fleet-wide).
    ``None`` axes disable (+inf) — `ResourceVector(watts=B)` is the
    legacy scalar pool, which the per-axis compares reproduce bit for
    bit."""
    vec = budget.as_array()
    vec[0] = rho_pool_from_budget(
        budget.watts, n_servers, model)
    return vec


def shard_state(state: DeviceClusterState, n_shards: int,
                rho_cap=None, pool_total=None) -> ShardedState:
    """Partition a `DeviceClusterState` into N shard slices.

    Servers are regrouped chassis-major (the order of
    `DeviceClusterState.chassis_servers`, which for the standard
    ``chassis = server // blades`` layout is the server-id order, so
    1-shard tie-breaking matches the unsharded scan exactly).
    `rho_cap`: global per-chassis admission ceiling — (C,) watt-axis
    or (C, R) per-resource, lifted with +inf axes (None = all +inf);
    `pool_total`: global token pool — scalar rho units or (R,) per
    resource (None = +inf), each axis split equally across shards."""
    dtype = state.free_cores.dtype
    n_chassis, k = state.chassis_servers.shape
    n_servers = state.n_servers
    chassis_to_shard(n_chassis, n_shards)       # validates divisibility
    c_loc = n_chassis // n_shards
    s_loc = c_loc * k
    global_chassis = jnp.arange(n_chassis, dtype=jnp.int32) \
        .reshape(n_shards, c_loc)
    global_server = state.chassis_servers.reshape(n_shards, s_loc)
    local_chassis_of = jnp.broadcast_to(
        (jnp.arange(s_loc, dtype=jnp.int32) // k)[None],
        (n_shards, s_loc))
    local_chassis_servers = jnp.broadcast_to(
        jnp.arange(s_loc, dtype=jnp.int32).reshape(c_loc, k)[None],
        (n_shards, c_loc, k))
    shards = DeviceClusterState(
        free_cores=state.free_cores[global_server],
        gamma_uf=state.gamma_uf[global_server],
        gamma_nuf=state.gamma_nuf[global_server],
        res_peak=state.res_peak[global_chassis],
        rho_max=state.rho_max[global_chassis],
        chassis_of=local_chassis_of,
        chassis_servers=local_chassis_servers,
        mem_nuf=state.mem_nuf[global_chassis])
    flat = global_server.reshape(-1)
    shard_of = jnp.zeros(n_servers, jnp.int32).at[flat].set(
        jnp.repeat(jnp.arange(n_shards, dtype=jnp.int32), s_loc))
    local_of = jnp.zeros(n_servers, jnp.int32).at[flat].set(
        jnp.tile(jnp.arange(s_loc, dtype=jnp.int32), n_shards))
    if rho_cap is None:
        cap = jnp.full((n_shards, c_loc, N_RESOURCES), jnp.inf, dtype)
    else:
        cap = lift_caps(jnp.asarray(rho_cap, dtype),
                        xp=jnp)[global_chassis]
    if pool_total is None:
        pool = jnp.full((n_shards, N_RESOURCES), jnp.inf, dtype)
    else:
        total = lift_pool(jnp.asarray(pool_total, dtype), xp=jnp)
        pool = jnp.broadcast_to(total[None, :] / n_shards,
                                (n_shards, N_RESOURCES))
    return ShardedState(shards, global_server, global_chassis, shard_of,
                        local_of, cap, pool)


def unshard_state(sharded: ShardedState) -> DeviceClusterState:
    """Reassemble the global `DeviceClusterState` view (diagnostics,
    headroom reporting — the serving path never needs it)."""
    sh = sharded.shards
    dtype = sh.free_cores.dtype
    n, s_loc = sharded.global_server.shape
    c_loc, k = sh.chassis_servers.shape[1:]
    n_servers, n_chassis = n * s_loc, n * c_loc
    srv = sharded.global_server.reshape(-1)
    cha = sharded.global_chassis.reshape(-1)
    chassis_of = jnp.zeros(n_servers, jnp.int32).at[srv].set(
        jnp.take_along_axis(sharded.global_chassis, sh.chassis_of,
                            axis=1).reshape(-1))
    chassis_servers = jnp.zeros((n_chassis, k), jnp.int32).at[cha].set(
        sharded.global_server.reshape(n * c_loc, k))
    return DeviceClusterState(
        free_cores=jnp.zeros(n_servers, dtype).at[srv].set(
            sh.free_cores.reshape(-1)),
        gamma_uf=jnp.zeros(n_servers, dtype).at[srv].set(
            sh.gamma_uf.reshape(-1)),
        gamma_nuf=jnp.zeros(n_servers, dtype).at[srv].set(
            sh.gamma_nuf.reshape(-1)),
        res_peak=jnp.zeros((n_chassis, N_RESOURCES), dtype).at[cha].set(
            sh.res_peak.reshape(-1, N_RESOURCES)),
        rho_max=jnp.zeros(n_chassis, dtype).at[cha].set(
            sh.rho_max.reshape(-1)),
        chassis_of=chassis_of, chassis_servers=chassis_servers,
        mem_nuf=jnp.zeros(n_chassis, dtype).at[cha].set(
            sh.mem_nuf.reshape(-1)))


def shard_mesh(n_shards: int):
    """1-D ``("shard",)`` mesh over the first N devices, or None when
    the runtime has fewer devices than shards (the vmap path then runs
    all shards on one device with identical semantics)."""
    devices = jax.devices()
    if len(devices) < n_shards:
        return None
    return Mesh(np.asarray(devices[:n_shards]), (SHARD_AXIS,))


def device_put_sharded_state(sharded: ShardedState,
                             mesh: Mesh) -> ShardedState:
    """Pin each shard's slice of the stacked state to its mesh device
    (leading axis over SHARD_AXIS; the inverse-lookup tables are
    replicated), so the per-round jit starts from resident operands
    instead of resharding on entry."""
    row = NamedSharding(mesh, P(SHARD_AXIS))
    rep = NamedSharding(mesh, P())
    stacked = jax.tree.map(lambda x: jax.device_put(x, row),
                           (sharded.shards, sharded.global_server,
                            sharded.global_chassis, sharded.res_cap,
                            sharded.pool))
    inv = jax.tree.map(lambda x: jax.device_put(x, rep),
                       (sharded.shard_of_server,
                        sharded.local_of_server))
    return ShardedState(stacked[0], stacked[1], stacked[2], inv[0],
                        inv[1], stacked[3], stacked[4])


def route_shard(n_arrivals: int, n_shards: int, rnd: int = 0) \
        -> np.ndarray:
    """(B,) target shard of each arrival in spillover round `rnd`.

    Round 0 is the home assignment ``i % n_shards``; later rounds
    rotate (``+ rnd``), a bijection on shards, so every round keeps at
    most ``B / n_shards`` arrivals per shard — shapes never overflow
    the phase-1 slots."""
    return ((np.arange(n_arrivals) + rnd) % n_shards).astype(np.int32)


def _pack_round(pending: np.ndarray, targets: np.ndarray, n_shards: int,
                b_loc: int):
    """Per-shard slot assignment for one protocol round: (N, B/N)
    arrival-index and attempt-mask arrays, arrival order preserved
    within each shard."""
    idx = np.zeros((n_shards, b_loc), np.int32)
    attempt = np.zeros((n_shards, b_loc), bool)
    for s in range(n_shards):
        mine = pending[targets[pending] == s]
        idx[s, :len(mine)] = mine
        attempt[s, :len(mine)] = True
    return idx, attempt


@lru_cache(maxsize=None)
def _round_fn(policy: SchedulerPolicy, cps: float, mesh, ecfg=None):
    """Compiled one-round kernel: gather each shard's routed slice,
    place it on the local state (vmap or shard_map over SHARD_AXIS),
    translate winners to global server ids.

    With `ecfg` (a static `emergency.EmergencyConfig`) the kernel
    additionally takes the per-shard emergency state and queued
    (N, W, C/N) cap-sample windows and steps them *ahead of the
    placement scan in the same dispatch*
    (`placement._apply_cap_windows`) — the fused form the pipeline
    routes the home round through, so an emergency sweep costs zero
    extra vmap/shard_map dispatches. Spillover rounds use the plain
    (``ecfg=None``) kernel: the windows apply exactly once. The fused
    kernel's fifth output is the per-shard
    `placement.SweepCounters` (leading (N,) axis) — the in-scan
    observables of the sweep."""
    place = partial(_place_batch_impl, policy=policy, cps=cps)

    def one_shard(st, pool, cores, is_uf, p95, mem, attempt, cap,
                  *caps):
        if ecfg is None:
            return place(st, pool, cores, is_uf, p95, mem, attempt,
                         cap)
        emer, pw, mask, ts = caps
        emer2, sweep = _apply_cap_windows(ecfg, st, emer, pw, mask, ts)
        st2, srv, pool2 = place(st, pool, cores, is_uf, p95, mem,
                                attempt, cap)
        return st2, srv, pool2, emer2, sweep

    n_in = 8 if ecfg is None else 12
    n_out = 3 if ecfg is None else 5

    def fn(shards, pool, global_server, res_cap, idx, attempt, cores,
           is_uf, p95, mem, *caps):
        c, u, p, m = cores[idx], is_uf[idx], p95[idx], mem[idx]
        operands = (shards, pool, c, u, p, m, attempt, res_cap) + caps
        if mesh is None:
            out = jax.vmap(one_shard)(*operands)
        else:
            def per(*args):
                sq = partial(jax.tree.map, lambda x: x[0])
                res = one_shard(*(sq(a) for a in args))
                return jax.tree.map(lambda x: x[None], res)
            spec = P(SHARD_AXIS)
            out = shard_map(per, mesh=mesh, in_specs=(spec,) * n_in,
                            out_specs=(spec,) * n_out)(*operands)
        st2, srv, pool2 = out[:3]
        glob = jnp.take_along_axis(global_server, jnp.maximum(srv, 0),
                                   axis=1)
        glob = jnp.where(srv >= 0, glob, srv)
        if ecfg is None:
            return st2, pool2, glob
        return st2, pool2, glob, out[3], out[4]

    return jax.jit(fn)


def place_group_sharded(sharded: ShardedState, cores, is_uf, p95_eff,
                        valid, policy: SchedulerPolicy,
                        cores_per_server: int, *, mem_gb=None,
                        mesh=None, spill_rounds: int | None = None,
                        rebalance: bool = True, emer=None, caps=None,
                        ecfg=None, registry=None):
    """Place one arrival batch through the full sharded protocol.

    cores/is_uf/p95_eff/valid: (B,) host arrays with B divisible by
    the shard count (`valid=False` rows are padding). Runs the home
    round plus up to ``spill_rounds`` (default N-1) spillover rounds —
    an arrival therefore fails only if *every* shard rejected it, so
    sharding never invents capacity failures the single-shard oracle
    would not have (the regret is in objective quality, not
    feasibility; docs/sharding.md). `rebalance` equalizes leftover
    tokens across shards between rounds (conserves the total).

    `emer`/`caps`/`ecfg` fuse the power-emergency sweep into the home
    round's dispatch: `caps` is ``(pw, mask, ts)`` stacked (N, W, C/N)
    sample windows (the `split_caps` layout, one row per queued
    unique-chassis window in merged order) and `emer` the per-shard
    `EmergencyState`. The windows step *before* the placement scan in
    the same compiled call — bit-identical to W standalone
    `apply_caps_sharded` dispatches, because caps touch only the
    emergency state and the criticality aggregates are the pre-batch
    ones either way. Spillover rounds always run the plain kernel.

    Returns ``(sharded_state, servers, info)``: servers is (B,) global
    ids with FAIL_* codes (a still-failed arrival reports the
    most-severe code it saw across rounds), info counts
    ``{"rounds", "spilled", "spill_admitted", "tokens_drawn",
    "tokens_drawn_vec"}`` (tokens_drawn: watt-axis pool draw across
    rounds in rho units, 0.0 with no budget; tokens_drawn_vec: the
    full (R,) per-resource draw — only finite-pool axes report).
    `mem_gb` is the optional (B,) GB demand (None places zero GB —
    the GB ledger axis then never moves). With `emer` it returns ``(sharded_state, servers,
    info, emergency_state, sweep)`` where sweep is a host-side
    `placement.SweepCounters` summed over shards. `registry`, a
    `repro.obs.MetricsRegistry`, counts each compiled round dispatch
    into ``serve_dispatch_total{kind=...}`` at the true call site —
    the first-class replacement for monkeypatch dispatch counting."""
    n = sharded.n_shards
    cores = np.asarray(cores, np.float64)
    is_uf = np.asarray(is_uf, bool)
    p95_eff = np.asarray(p95_eff, np.float64)
    valid = np.asarray(valid, bool)
    b = len(cores)
    if b % n:
        raise ValueError(f"batch size {b} not divisible by {n} shards")
    b_loc = b // n
    if spill_rounds is None:
        spill_rounds = n - 1
    fn = _round_fn(policy, float(cores_per_server), mesh)
    dtype = sharded.shards.free_cores.dtype
    cores_d = jnp.asarray(cores, dtype)
    uf_d = jnp.asarray(is_uf)
    p95_d = jnp.asarray(p95_eff, dtype)
    mem_d = jnp.zeros_like(cores_d) if mem_gb is None \
        else jnp.asarray(np.asarray(mem_gb, np.float64), dtype)
    fused = emer is not None
    if fused:
        fn0 = _round_fn(policy, float(cores_per_server), mesh, ecfg)
        pw, mask, ts = (jnp.asarray(a) for a in caps)
        sweep = None

    result = np.full(b, FAIL_CAPACITY, np.int64)
    pending = np.arange(b)[valid]
    shards, pool = sharded.shards, sharded.pool
    pool_start = np.asarray(pool)
    info = {"rounds": 0, "spilled": 0, "spill_admitted": 0,
            "tokens_drawn": 0.0,
            "tokens_drawn_vec": np.zeros(pool_start.shape[-1])}
    for rnd in range(spill_rounds + 1):
        if not len(pending) and not (rnd == 0 and fused):
            break
        if rnd > 0:
            info["spilled"] += len(pending)
            if rebalance:
                # equalize per axis across shards (conserves each
                # axis total; +inf axes stay +inf)
                pool = jnp.broadcast_to(pool.mean(axis=0)[None, :],
                                        pool.shape)
        targets = route_shard(b, n, rnd)
        idx, attempt = _pack_round(pending, targets, n, b_loc)
        operands = (shards, pool, sharded.global_server,
                    sharded.res_cap, jnp.asarray(idx),
                    jnp.asarray(attempt), cores_d, uf_d, p95_d, mem_d)
        if rnd == 0 and fused:
            shards, pool, glob, emer, sw = fn0(*operands, emer, pw,
                                               mask, ts)
            sweep = SweepCounters(*(np.asarray(x).sum(axis=0)
                                    for x in sw))
            if registry is not None:
                registry.counter("serve_dispatch_total",
                                 kind="sharded_round_caps").inc()
        else:
            shards, pool, glob = fn(*operands)
            if registry is not None:
                registry.counter("serve_dispatch_total",
                                 kind="sharded_round").inc()
        out = np.asarray(glob)[attempt]
        arrivals = idx[attempt]
        admitted = out >= 0
        result[arrivals[admitted]] = out[admitted]
        if rnd > 0:
            info["spill_admitted"] += int(admitted.sum())
        failed = arrivals[~admitted]
        # keep the most severe failure reason seen across rounds
        result[failed] = np.minimum(result[failed], out[~admitted])
        pending = np.sort(failed)
        info["rounds"] = rnd + 1
    pool_end = np.asarray(pool)
    # rebalancing conserves each axis total, so the overall per-axis
    # delta is exactly the admitted draw of every round combined;
    # +inf (unbudgeted) axes report 0
    finite = np.isfinite(pool_start).all(axis=0)
    drawn = np.where(finite, pool_start.sum(axis=0)
                     - np.where(finite, pool_end, 0.0).sum(axis=0), 0.0)
    info["tokens_drawn_vec"] = drawn
    info["tokens_drawn"] = float(drawn[0])
    new = sharded._replace(shards=shards, pool=pool)
    if fused:
        # the home round always runs when fused (it must apply the
        # queued windows even with zero pending arrivals)
        return new, result, info, emer, sweep
    return new, result, info


def split_departures(sharded: ShardedState, servers, cores, p95_eff,
                     is_uf, mem_gb=None):
    """Host-side routing of a global departure batch into per-shard
    local batches — the pre-merge step the ingest subsystem
    (`serve.ingest`, DESIGN.md §11) hands each shard.

    servers: (B,) global ids (negative codes dropped). Returns
    ``(local_srv, cores, p95_eff, is_uf, mem_gb)`` stacked (N, B)
    arrays, padded with ``local_srv = -1`` rows; each shard's rows
    keep the input (merged-stream) order. Shapes stay (N, B) so the
    consuming jit never re-specializes on per-shard counts."""
    servers = np.asarray(servers)
    b = len(servers)
    n = sharded.n_shards
    live = servers >= 0
    safe = np.where(live, servers, 0).astype(np.int64)
    owner = np.where(live, np.asarray(sharded.shard_of_server)[safe], -1)
    local = np.asarray(sharded.local_of_server)[safe]
    srv_out = np.full((n, b), -1, np.int32)
    cores_out = np.zeros((n, b), np.float64)
    p95_out = np.zeros((n, b), np.float64)
    uf_out = np.zeros((n, b), bool)
    mem_out = np.zeros((n, b), np.float64)
    cores = np.asarray(cores, np.float64)
    p95_eff = np.asarray(p95_eff, np.float64)
    is_uf = np.asarray(is_uf, bool)
    mem = np.zeros(b) if mem_gb is None else np.asarray(mem_gb,
                                                        np.float64)
    for s in range(n):
        mine = owner == s
        k = int(mine.sum())
        srv_out[s, :k] = local[mine]
        cores_out[s, :k] = cores[mine]
        p95_out[s, :k] = p95_eff[mine]
        uf_out[s, :k] = is_uf[mine]
        mem_out[s, :k] = mem[mine]
    return srv_out, cores_out, p95_out, uf_out, mem_out


@jax.jit
def _consume_departures(shards, pool, srv, cores, p95_eff, is_uf, mem):
    def per_shard(st, pl, s, c, p, u, m):
        dtype = st.free_cores.dtype
        live = (s >= 0).astype(dtype)
        c_live = c.astype(dtype) * live
        w = p.astype(dtype) * c_live
        credit = jnp.stack([w.sum(), c_live.sum(),
                            (m.astype(dtype) * live).sum()])
        return remove_batch(st, s, c, p, u, m), pl + credit
    return jax.vmap(per_shard)(shards, pool, srv, cores, p95_eff,
                               is_uf, mem)


def consume_departures(sharded: ShardedState, local_srv, cores,
                       p95_eff, is_uf, mem_gb=None) -> ShardedState:
    """Consume per-shard departure batches (the `split_departures` /
    ingest-merge format): one vmapped kernel per shard applies
    `remove_batch` to its own rows and credits the freed (R,) demand
    vector — ``(p95*cores, cores, GB)`` — back to its own pool *in
    the same scan*, one axis at a time, so per-resource token totals
    are conserved. No shard ever sees another shard's departures, and
    no (N, B) broadcast of the full global batch is materialized on
    device."""
    dtype = sharded.shards.free_cores.dtype
    cores_d = jnp.asarray(cores, dtype)
    shards, pool = _consume_departures(
        sharded.shards, sharded.pool, jnp.asarray(local_srv, jnp.int32),
        cores_d, jnp.asarray(p95_eff, dtype),
        jnp.asarray(is_uf),
        jnp.zeros_like(cores_d) if mem_gb is None
        else jnp.asarray(mem_gb, dtype))
    return sharded._replace(shards=shards, pool=pool)


def remove_sharded(sharded: ShardedState, servers, cores, p95_eff,
                   is_uf, mem_gb=None) -> ShardedState:
    """Sharded twin of `serve.placement.remove_batch`: route each
    departure to its owner shard (negative server codes are ignored)
    and credit the freed (R,) demand vector back to that shard's
    pool per axis. Composition of `split_departures` +
    `consume_departures` — the per-shard batches the cross-host
    ingest merge produces directly skip the split."""
    return consume_departures(
        sharded, *split_departures(sharded, servers, cores, p95_eff,
                                   is_uf, mem_gb))


# --- sharded power-emergency plane (DESIGN.md §12) ------------------------

def init_emergency_sharded(n_chassis: int, n_shards: int,
                           dtype=jnp.float32):
    """Emergency state partitioned like the cluster: one
    `serve.emergency.EmergencyState` slice per shard, leading (N,)
    axis over the same contiguous chassis blocks as `shard_state`."""
    chassis_to_shard(n_chassis, n_shards)       # validates divisibility
    return emergency.init_emergency(
        n_chassis // n_shards, batch_shape=(n_shards,), xp=jnp,
        dtype=dtype)


def split_caps(sharded: ShardedState, chassis, power_w, t):
    """Host-side routing of a global power-sample batch into the dense
    per-shard `masked_step` operands: ``(power (N, C/N), mask
    (N, C/N), t (N, C/N))``. Chassis within the batch must be unique
    (the pipeline splits duplicate-bearing windows into sub-windows
    first); ownership is the contiguous-block layout of
    `chassis_to_shard`."""
    n = sharded.n_shards
    c_loc = sharded.global_chassis.shape[1]
    chassis = np.asarray(chassis, np.int64)
    pw = np.zeros((n, c_loc), np.float64)
    mask = np.zeros((n, c_loc), bool)
    ts = np.zeros((n, c_loc), np.float64)
    owner, local = chassis // c_loc, chassis % c_loc
    pw[owner, local] = np.asarray(power_w, np.float64)
    mask[owner, local] = True
    ts[owner, local] = np.asarray(t, np.float64)
    return pw, mask, ts


@lru_cache(maxsize=None)
def _caps_fn(cfg: emergency.EmergencyConfig, mesh):
    """Compiled sharded emergency scan: derive each shard's per-chassis
    per-criticality commitments from its own aggregates and run the
    masked emergency step — vmap on one device (the semantics oracle),
    shard_map over the mesh (identical per-shard arithmetic)."""

    def one_shard(st, emer, pw, mask, ts):
        rho_lv = emergency.chassis_rho_levels(
            st.gamma_nuf, st.gamma_uf, st.chassis_servers, jnp)
        return emergency.masked_step(cfg, emer, rho_lv, pw, mask, ts,
                                     jnp)

    def fn(shards, emer, pw, mask, ts):
        if mesh is None:
            return jax.vmap(one_shard)(shards, emer, pw, mask, ts)

        def per(st, em, p1, m1, t1):
            sq = partial(jax.tree.map, lambda x: x[0])
            e2, o2 = one_shard(sq(st), sq(em), p1[0], m1[0], t1[0])
            return jax.tree.map(lambda x: x[None], (e2, o2))
        spec = P(SHARD_AXIS)
        return shard_map(per, mesh=mesh, in_specs=(spec,) * 5,
                         out_specs=(spec, spec))(shards, emer, pw, mask,
                                                 ts)

    return jax.jit(fn)


def init_adaptive_sharded(cfg, n_chassis: int, n_shards: int,
                          dtype=jnp.float32):
    """Adaptive-controller state partitioned like the cluster: one
    `serve.adaptive.AdaptiveState` slice per shard, leading (N,) axis
    over the same contiguous chassis blocks as `shard_state` — each
    shard carries its *own* ratio over the budget slice it owns."""
    from repro.serve import adaptive
    chassis_to_shard(n_chassis, n_shards)       # validates divisibility
    return adaptive.init_adaptive(
        cfg, n_chassis // n_shards, batch_shape=(n_shards,), xp=jnp,
        dtype=dtype)


@lru_cache(maxsize=None)
def _adaptive_fn(cfg, mesh):
    """Compiled sharded adaptive-controller scan
    (`serve.adaptive.adaptive_step` per shard): each shard scores its
    own chassis windows and steps its own ratio — vmap on one device,
    shard_map over the mesh, identical per-shard arithmetic (the
    `_caps_fn` pattern)."""
    from repro.serve import adaptive

    def one_shard(st, ast, pw, mask):
        rho_lv = emergency.chassis_rho_levels(
            st.gamma_nuf, st.gamma_uf, st.chassis_servers, jnp)
        return adaptive.adaptive_step(cfg, ast, rho_lv, pw, mask, jnp)

    def fn(shards, ast, pw, mask):
        if mesh is None:
            return jax.vmap(one_shard)(shards, ast, pw, mask)

        def per(st, a1, p1, m1):
            sq = partial(jax.tree.map, lambda x: x[0])
            a2, o2 = one_shard(sq(st), sq(a1), p1[0], m1[0])
            return jax.tree.map(lambda x: x[None], (a2, o2))
        spec = P(SHARD_AXIS)
        return shard_map(per, mesh=mesh, in_specs=(spec,) * 4,
                         out_specs=(spec, spec))(shards, ast, pw, mask)

    return jax.jit(fn)


def apply_adaptive_sharded(cfg, sharded: ShardedState, ast, chassis,
                           power_w, *, mesh=None):
    """Apply one unique-chassis power-sample window to the sharded
    adaptive-controller state (`serve.adaptive`, DESIGN.md §15): route
    samples to their owner shards (`split_caps`) and run every shard's
    stability-scoring + ratio step concurrently — no cross-shard
    communication; each shard adapts the slice of the watt budget it
    owns. Returns ``(new_adaptive_state, AdaptiveOutputs)`` with the
    per-shard leading axis."""
    dtype = sharded.shards.free_cores.dtype
    pw, mask, _ = split_caps(sharded, chassis, power_w,
                             np.zeros(len(np.asarray(chassis))))
    fn = _adaptive_fn(cfg, mesh)
    return fn(sharded.shards, ast, jnp.asarray(pw, dtype),
              jnp.asarray(mask))


def apply_caps_sharded(cfg: emergency.EmergencyConfig,
                       sharded: ShardedState, emer, chassis, power_w,
                       t, *, mesh=None):
    """Apply one unique-chassis power-sample window to the sharded
    emergency state: route samples to their owner shards
    (`split_caps`) and run every shard's alarm + apportionment kernel
    concurrently — no cross-shard communication, because chassis
    ownership is exclusive and each shard's criticality aggregates are
    local. Returns ``(new_emergency_state, EmergencyOutputs)`` with
    the per-shard leading axis."""
    dtype = sharded.shards.free_cores.dtype
    pw, mask, ts = split_caps(sharded, chassis, power_w, t)
    fn = _caps_fn(cfg, mesh)
    return fn(sharded.shards, emer, jnp.asarray(pw, dtype),
              jnp.asarray(mask), jnp.asarray(ts, dtype))


def init_ballooning_sharded(n_chassis: int, n_shards: int,
                            dtype=jnp.float32):
    """Ballooning state partitioned like the cluster (leading (N,)
    axis over the same contiguous chassis blocks as `shard_state` —
    the `init_emergency_sharded` layout)."""
    chassis_to_shard(n_chassis, n_shards)       # validates divisibility
    return ballooning.init_ballooning(
        n_chassis // n_shards, batch_shape=(n_shards,), xp=jnp,
        dtype=dtype)


@lru_cache(maxsize=None)
def _caps_balloon_fn(ecfg: emergency.EmergencyConfig,
                     bcfg: ballooning.BallooningConfig, mesh):
    """Compiled sharded balloon-then-cap scan: each shard balloons its
    alarmed chassis against its own NUF-memory ledger
    (`serve.ballooning.balloon_step` over ``shards.mem_nuf``), then
    runs the masked emergency step on the DRAM-adjusted draws — vmap
    on one device, shard_map over the mesh (the `_caps_fn` pattern)."""

    def one_shard(st, emer, bst, pw, mask, ts):
        rho_lv = emergency.chassis_rho_levels(
            st.gamma_nuf, st.gamma_uf, st.chassis_servers, jnp)
        bst2, bout = ballooning.balloon_step(
            bcfg, ecfg, bst, rho_lv, pw, st.mem_nuf, mask, jnp)
        emer2, eout = emergency.masked_step(
            ecfg, emer, rho_lv, bout.power_adj_w, mask, ts, jnp)
        return emer2, bst2, eout, bout

    def fn(shards, emer, bst, pw, mask, ts):
        if mesh is None:
            return jax.vmap(one_shard)(shards, emer, bst, pw, mask, ts)

        def per(st, em, b1, p1, m1, t1):
            sq = partial(jax.tree.map, lambda x: x[0])
            out = one_shard(sq(st), sq(em), sq(b1), p1[0], m1[0], t1[0])
            return jax.tree.map(lambda x: x[None], out)
        spec = P(SHARD_AXIS)
        return shard_map(per, mesh=mesh, in_specs=(spec,) * 6,
                         out_specs=(spec,) * 4)(shards, emer, bst, pw,
                                                mask, ts)

    return jax.jit(fn)


def apply_caps_ballooned_sharded(ecfg: emergency.EmergencyConfig,
                                 bcfg: ballooning.BallooningConfig,
                                 sharded: ShardedState, emer, bst,
                                 chassis, power_w, t, *, mesh=None):
    """`apply_caps_sharded` with the ballooning rung in front
    (DESIGN.md §16): the window's samples are first offered to
    `serve.ballooning.balloon_step` — alarmed chassis reclaim NUF
    memory to absorb the cut the NUF frequency floor cannot — and the
    masked emergency step consumes the DRAM-adjusted draws. Returns
    ``(emergency_state, balloon_state, EmergencyOutputs,
    BalloonOutputs)``, all with the per-shard leading axis."""
    dtype = sharded.shards.free_cores.dtype
    pw, mask, ts = split_caps(sharded, chassis, power_w, t)
    fn = _caps_balloon_fn(ecfg, bcfg, mesh)
    return fn(sharded.shards, emer, bst, jnp.asarray(pw, dtype),
              jnp.asarray(mask), jnp.asarray(ts, dtype))
