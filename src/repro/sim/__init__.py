"""Datacenter simulation substrate: workload/telemetry generation, cluster
scheduler simulation, and chassis power dynamics."""
