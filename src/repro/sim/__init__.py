"""Datacenter simulation substrate: workload/telemetry generation, cluster
scheduler simulation, and chassis power dynamics.

Front door: build a :class:`SimSpec` (grouping the serve backend,
power-dynamics evaluation, and mitigation-plane configs into typed
sub-specs) and hand it to :func:`simulate`.  The flat keyword-argument
surface of earlier revisions still works behind a
``DeprecationWarning`` adapter (see docs/resources.md for the
migration table).
"""
from repro.sim.scheduler_sim import (GB_PER_CORE, PowerEvalSpec,
                                     PredictionChannel,
                                     ServeBackendSpec, SimMetrics,
                                     SimSpec, evaluate_power_dynamics,
                                     fig7_sweep, simulate)

__all__ = [
    "GB_PER_CORE", "PowerEvalSpec", "PredictionChannel",
    "ServeBackendSpec", "SimMetrics", "SimSpec",
    "evaluate_power_dynamics", "fig7_sweep", "simulate",
]
