"""Chassis/server power-capping dynamics (paper §IV-C, §IV-D; Figs 4-6).

A 200 ms-step simulator of the paper's testbed: 12-blade chassis, 40-core
blades (2x20), the per-VM controller + chassis manager + RAPL backup from
`repro.core.capping`, and two instrumented applications:

  * UF app — latency-critical transaction processing: open-loop arrivals
    into a fluid queue whose service capacity is the sum of its cores'
    frequencies; reports p95 latency (normalized to no-cap).
  * NUF app — batch (Terasort-like): saturates its cores; total work is
    fixed, so its metric is the completion slowdown: (time-integral of
    core frequency at no-cap) / (same integral capped).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.capping import (ALERT_MARGIN_W, POLL_INTERVAL_S,
                                ChassisManager, PerVMController,
                                RaplController, ServerCapState)
from repro.core.power_model import F_MAX, ServerPowerModel


@dataclass
class VMSpec:
    n_cores: int
    is_uf: bool
    #: offered load as a fraction of the VM's full-frequency capacity
    load: float = 0.75


@dataclass
class ServerSpec:
    vms: list                       # list[VMSpec]; sum cores <= n_cores
    n_cores: int = 40


@dataclass
class AppMetrics:
    latencies: list = field(default_factory=list)     # UF: per-step latency
    speed_integral: float = 0.0                       # NUF: sum f dt

    def p95_latency(self) -> float:
        return float(np.percentile(np.array(self.latencies), 95))


def _uf_load_trace(rng, n_steps: int, base: float) -> np.ndarray:
    """Fluctuating interactive load (paper Fig. 4 power wiggles)."""
    wave = 0.12 * np.sin(np.linspace(0, 6 * np.pi, n_steps))
    slow = 0.06 * np.sin(np.linspace(0, 1.5 * np.pi, n_steps))
    noise = rng.normal(0, 0.03, n_steps)
    return np.clip(base + wave + slow + noise, 0.05, 1.2)


@dataclass
class SimResult:
    power_w: np.ndarray                 # (n_steps,) per server or chassis
    min_nuf_freq: np.ndarray            # (n_steps,)
    uf_p95_latency: float               # mean across UF VMs
    nuf_slowdown: float                 # mean across NUF VMs (>= 1.0)
    rapl_engaged_frac: float


def simulate_server(spec: ServerSpec, budget_w: float | None,
                    mode: str, duration_s: float = 600.0,
                    seed: int = 0,
                    model: ServerPowerModel | None = None) -> SimResult:
    """One server under a power cap. mode: 'none' | 'rapl' | 'per_vm'."""
    chassis = simulate_chassis([spec], None if budget_w is None
                               else budget_w, mode, duration_s, seed, model)
    return chassis


def simulate_chassis(specs: list, budget_w: float | None, mode: str,
                     duration_s: float = 600.0, seed: int = 0,
                     model: ServerPowerModel | None = None) -> SimResult:
    """Simulate a chassis of servers under a shared chassis budget.

    mode 'per_vm' runs the full paper stack: chassis-manager alerts ->
    per-VM controllers -> RAPL only as backup. mode 'rapl' is the
    existing full-server mechanism (PSU -> BMC -> RAPL, all cores
    equally). mode 'none' = uncapped.
    """
    model = model or ServerPowerModel()
    rng = np.random.default_rng(seed)
    n_steps = int(duration_s / POLL_INTERVAL_S)
    n_srv = len(specs)

    states, per_vm_ctrls, rapl_ctrls, core_vm, vm_specs = [], [], [], [], []
    uf_loads = []        # list of (server idx, vm idx, cores, load trace)
    server_budget = None if budget_w is None else budget_w / n_srv
    for si, spec in enumerate(specs):
        uf_mask = np.zeros(spec.n_cores, bool)
        owner = np.full(spec.n_cores, -1)
        c0 = 0
        for vi, vm in enumerate(spec.vms):
            owner[c0:c0 + vm.n_cores] = vi
            if vm.is_uf:
                uf_mask[c0:c0 + vm.n_cores] = True
                uf_loads.append((si, vi, (c0, c0 + vm.n_cores),
                                 _uf_load_trace(rng, n_steps, vm.load)))
            c0 += vm.n_cores
        states.append(ServerCapState(spec.n_cores, uf_mask))
        core_vm.append(owner)
        vm_specs.append(spec.vms)
        sb = server_budget if server_budget is not None else np.inf
        per_vm_ctrls.append(PerVMController(model, sb))
        rapl_ctrls.append(RaplController(model, sb))

    manager = ChassisManager(budget_w if budget_w is not None else np.inf)
    backlogs = {(si, vi): 0.0 for si, vi, _, _ in uf_loads}
    uf_metrics = {(si, vi): AppMetrics() for si, vi, _, _ in uf_loads}
    nuf_speed = {}
    for si, spec in enumerate(specs):
        for vi, vm in enumerate(spec.vms):
            if not vm.is_uf:
                nuf_speed[(si, vi)] = 0.0

    power_trace = np.zeros(n_steps)
    min_freq_trace = np.zeros(n_steps)
    rapl_steps = 0

    utils = [np.zeros(s.n_cores) for s in specs]
    for t in range(n_steps):
        # --- offered utilization per core ---
        for si, spec in enumerate(specs):
            u = utils[si]
            for vi, vm in enumerate(spec.vms):
                sel = core_vm[si] == vi
                if vm.is_uf:
                    continue            # set from load trace below
                u[sel] = 1.0            # batch saturates its cores
        for si, vi, (a, b), trace in uf_loads:
            # interactive util rises when cores are slowed (same work,
            # less capacity): util = min(1, load / f)
            f = states[si].freq[a:b]
            utils[si][a:b] = np.minimum(trace[t] / np.maximum(f, 1e-3), 1.0)

        # --- power + control ---
        chassis_power = sum(
            per_vm_ctrls[si].model.power(utils[si], states[si].freq)
            for si in range(n_srv))
        alert = manager.poll(chassis_power)
        total = 0.0
        for si in range(n_srv):
            st = states[si]
            if mode == "per_vm":
                p = per_vm_ctrls[si].step(st, utils[si], alert)
                # out-of-band backup if still above the blade budget
                # (PSU trip threshold sits just above it), or while a
                # previous engagement is still restoring
                from repro.core.capping import PSU_TRIP_MARGIN_W
                if (p > per_vm_ctrls[si].budget + PSU_TRIP_MARGIN_W
                        or st.rapl_active):
                    p = rapl_ctrls[si].step(st, utils[si])
            elif mode == "rapl":
                p = rapl_ctrls[si].step(st, utils[si])
            else:
                p = per_vm_ctrls[si].model.power(utils[si], st.freq)
            total += p
            if st.rapl_active:
                rapl_steps += 1
        power_trace[t] = total

        nuf_f = [states[si].freq[core_vm[si] == vi]
                 for si in range(n_srv)
                 for vi, vm in enumerate(specs[si].vms) if not vm.is_uf]
        min_freq_trace[t] = min(f.min() for f in nuf_f) if nuf_f else F_MAX

        # --- application models ---
        for si, vi, (a, b), trace in uf_loads:
            cap = float(states[si].freq[a:b].sum())          # capacity
            lam = trace[t] * (b - a)                         # offered work
            backlog = backlogs[(si, vi)]
            backlog = max(0.0, backlog + (lam - cap) * POLL_INTERVAL_S)
            # closed-loop client pool (the paper's TPC-E-like app has a
            # finite concurrency): in-flight work is bounded, so sustained
            # overload degrades throughput with bounded latency
            backlog = min(backlog, 1.0 * cap)
            backlogs[(si, vi)] = backlog
            service = 1.0 / (states[si].freq[a:b].mean())
            # cap the stationary-queue term at rho=0.9: sustained overload
            # is carried by the backlog term instead of the M/M/c pole
            rho = min(lam / max(cap, 1e-6), 0.9)
            latency = service * (1.0 + rho / (1.0 - rho) * 0.15) \
                + backlog / max(cap, 1e-6)
            uf_metrics[(si, vi)].latencies.append(latency)
        for (si, vi) in nuf_speed:
            sel = core_vm[si] == vi
            nuf_speed[(si, vi)] += float(
                states[si].freq[sel].sum()) * POLL_INTERVAL_S

    uf_p95 = float(np.mean([m.p95_latency()
                            for m in uf_metrics.values()])) \
        if uf_metrics else 0.0
    # slowdown = nominal speed integral / achieved speed integral
    slowdowns = []
    for (si, vi), integ in nuf_speed.items():
        sel = core_vm[si] == vi
        nominal = float(sel.sum()) * F_MAX * duration_s
        slowdowns.append(nominal / max(integ, 1e-9))
    nuf_slow = float(np.mean(slowdowns)) if slowdowns else 1.0
    return SimResult(power_trace, min_freq_trace, uf_p95, nuf_slow,
                     rapl_steps / max(n_steps * n_srv, 1))


# --- canonical experiment setups -----------------------------------------

def paper_single_server_spec() -> ServerSpec:
    """§IV-C: one UF VM (20 vcores) + one NUF VM (20 vcores).

    UF offered load 0.58 of capacity: hot enough that a 210 W cap cannot
    be met by NUF throttling alone (RAPL backup engages, as in the
    paper), while 230 W can (UF protected)."""
    return ServerSpec(vms=[VMSpec(20, True, load=0.58),
                           VMSpec(20, False)])


def paper_chassis_specs(balanced: bool) -> list:
    """§IV-D: 12 servers, 36 UF VMs (4 cores) + 36 NUF VMs (6 cores).

    balanced: 3 UF + 3 NUF VMs round-robin per server.
    imbalanced: 6 servers with 6 UF VMs each, 6 servers with 6 NUF each.
    """
    if balanced:
        return [ServerSpec(vms=[VMSpec(4, True, load=0.85)] * 3
                           + [VMSpec(6, False)] * 3) for _ in range(12)]
    uf_servers = [ServerSpec(vms=[VMSpec(4, True, load=0.85)] * 6)
                  for _ in range(6)]
    nuf_servers = [ServerSpec(vms=[VMSpec(6, False)] * 6)
                   for _ in range(6)]
    return uf_servers + nuf_servers
