"""Chassis/server power-capping dynamics (paper §IV-C, §IV-D; Figs 4-6).

A 200 ms-step simulator of the paper's testbed: 12-blade chassis, 40-core
blades (2x20), the per-VM controller + chassis manager + RAPL backup from
`repro.core.fleet_dynamics`, and two instrumented applications:

  * UF app — latency-critical transaction processing: open-loop arrivals
    into a fluid queue whose service capacity is the sum of its cores'
    frequencies; reports p95 latency (normalized to no-cap).
  * NUF app — batch (Terasort-like): saturates its cores; total work is
    fixed, so its metric is the completion slowdown: (time-integral of
    core frequency at no-cap) / (same integral capped).

This module is now a thin, API-stable adapter over the batched fleet
engine (`repro.sim.fleet`): `backend='numpy'` steps the oracle in a
Python loop (the seed's execution model); `backend='jax'` runs the
scan/vmap-compiled engine, where Figs 4-6 are slices of one fleet run.
"""
from __future__ import annotations

from repro.core.power_model import ServerPowerModel
from repro.sim.fleet import (FleetResult, ServerSpec, SimResult, VMSpec,
                             _uf_load_trace, run_fleet)

__all__ = ["VMSpec", "ServerSpec", "SimResult", "simulate_server",
           "simulate_chassis", "paper_single_server_spec",
           "paper_chassis_specs", "_uf_load_trace"]


def simulate_server(spec: ServerSpec, budget_w: float | None,
                    mode: str, duration_s: float = 600.0,
                    seed: int = 0,
                    model: ServerPowerModel | None = None,
                    backend: str = "numpy") -> SimResult:
    """One server under a power cap. mode: 'none' | 'rapl' | 'per_vm'."""
    return simulate_chassis([spec], budget_w, mode, duration_s, seed,
                            model, backend)


def simulate_chassis(specs: list, budget_w: float | None, mode: str,
                     duration_s: float = 600.0, seed: int = 0,
                     model: ServerPowerModel | None = None,
                     backend: str = "numpy") -> SimResult:
    """Simulate a chassis of servers under a shared chassis budget.

    mode 'per_vm' runs the full paper stack: chassis-manager alerts ->
    per-VM controllers -> RAPL only as backup. mode 'rapl' is the
    existing full-server mechanism (PSU -> BMC -> RAPL, all cores
    equally). mode 'none' = uncapped.
    """
    res: FleetResult = run_fleet(specs, budget_w, mode, duration_s,
                                 seed, model, backend=backend)
    return res.chassis(0)


# --- canonical experiment setups -----------------------------------------

def paper_single_server_spec() -> ServerSpec:
    """§IV-C: one UF VM (20 vcores) + one NUF VM (20 vcores).

    UF offered load 0.58 of capacity: hot enough that a 210 W cap cannot
    be met by NUF throttling alone (RAPL backup engages, as in the
    paper), while 230 W can (UF protected)."""
    return ServerSpec(vms=[VMSpec(20, True, load=0.58),
                           VMSpec(20, False)])


def paper_chassis_specs(balanced: bool) -> list:
    """§IV-D: 12 servers, 36 UF VMs (4 cores) + 36 NUF VMs (6 cores).

    balanced: 3 UF + 3 NUF VMs round-robin per server.
    imbalanced: 6 servers with 6 UF VMs each, 6 servers with 6 NUF each.
    """
    if balanced:
        return [ServerSpec(vms=[VMSpec(4, True, load=0.85)] * 3
                           + [VMSpec(6, False)] * 3) for _ in range(12)]
    uf_servers = [ServerSpec(vms=[VMSpec(4, True, load=0.85)] * 6)
                  for _ in range(6)]
    nuf_servers = [ServerSpec(vms=[VMSpec(6, False)] * 6)
                   for _ in range(6)]
    return uf_servers + nuf_servers
