"""Fleet-scale simulation engine: scan/vmap-compiled capping dynamics.

The seed simulated one chassis at a time in a 200 ms-step Python loop;
the paper's headline results (Figs 4-7, Table IV) need capping dynamics
and policy sweeps over a whole fleet. This module makes the substrate
dense, fixed-shape, and compiled — the same transformation applied to
forest inference in `kernels/forest`:

  * `run_fleet(..., backend='jax')` — `jax.lax.scan` over time steps,
    `jax.vmap` over chassis, one `jax.jit`-compiled call simulating a
    (n_chassis, n_steps) grid. Figs 4-6 are slices of a fleet run.
  * `run_fleet(..., backend='numpy')` — the validation oracle: the SAME
    `repro.core.fleet_dynamics.fleet_step` arithmetic, stepped in a
    plain Python loop one chassis at a time (the seed's execution
    model, kept as ground truth and as the benchmark baseline).
  * `sweep_scenarios` — vmaps the engine across grids of chassis
    budgets, offered-load scales, and NUF frequency floors
    (`OversubConfig.fmin_nuf`), producing Table IV-style frontiers in
    one compiled call. Different chassis *layouts* (the beta/UF-fraction
    axis, heterogeneous VM placements) batch the layout arrays instead
    — see `run_fleet_layouts`.

State layout and padding rules are documented in DESIGN.md §8.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import numpy as np

from repro.core.fleet_dynamics import (ALERT_FRACTION, ALERT_MARGIN_W,
                                       FREQ_TABLE, POLL_INTERVAL_S,
                                       ControlParams, RunParams,
                                       fleet_step, init_state)
from repro.core.power_model import (F_MAX, F_MIN, N_PSTATES,
                                    ServerPowerModel)

_F32 = np.float32


# --- workload specification (the seed's vocabulary, unchanged) -----------

@dataclass
class VMSpec:
    n_cores: int
    is_uf: bool
    #: offered load as a fraction of the VM's full-frequency capacity
    load: float = 0.75


@dataclass
class ServerSpec:
    vms: list                       # list[VMSpec]; sum cores <= n_cores
    n_cores: int = 40


def _uf_load_trace(rng, n_steps: int, base: float) -> np.ndarray:
    """Fluctuating interactive load (paper Fig. 4 power wiggles)."""
    wave = 0.12 * np.sin(np.linspace(0, 6 * np.pi, n_steps))
    slow = 0.06 * np.sin(np.linspace(0, 1.5 * np.pi, n_steps))
    noise = rng.normal(0, 0.03, n_steps)
    return np.clip(base + wave + slow + noise, 0.05, 1.2)


# --- padded fleet layout --------------------------------------------------

class LayoutArrays(NamedTuple):
    """The per-chassis array pytree the engine consumes. Shared across a
    homogeneous fleet (vmap in_axes=None) or batched with a leading B
    axis for heterogeneous placements (in_axes=0; see stack_layouts).

    Per-step UF capacity uses a compact gather of only the UF cores
    (uf_core_idx/uf_compact) instead of a full (S*C)-wide one-hot
    matmul, and the NUF work integral is accumulated as a raw frequency
    sum and reduced by nuf_onehot ONCE after the scan — both measured
    wins for the compiled fleet step."""
    uf_mask: Any        # (S, C) bool
    nuf_core: Any       # (S, C) bool
    active: Any         # (S, C) bool or None (= all cores real)
    uf_id: Any          # (S*C,) i32, owning UF VM (Vu = unowned)
    uf_core_idx: Any    # (Ku,) i32, flat indices of UF cores (0-padded)
    uf_compact: Any     # (Ku, Vu) f32, UF-core -> VM membership
    uf_cores: Any       # (Vu,) f32
    nuf_onehot: Any     # (Vn, S*C) f32
    nuf_cores: Any      # (Vn,) f32


@dataclass(frozen=True)
class FleetLayout:
    """Dense, fixed-shape view of one chassis' VM placement.

    Core-level masks are (S, C); VM-level reductions are one-hot
    matrices over the flattened (S*C,) core axis so per-VM capacity is
    a single matmul. VM slots beyond the real count are padding
    (`*_valid` False, zero one-hot rows)."""
    n_servers: int
    n_cores: int
    uf_mask: np.ndarray        # (S, C) bool — cores of user-facing VMs
    nuf_core: np.ndarray       # (S, C) bool — cores of batch VMs
    active: np.ndarray         # (S, C) bool — core exists (not padding)
    uf_onehot: np.ndarray      # (Vu, S*C) f32 — membership of UF VM v
    uf_cores: np.ndarray       # (Vu,) f32
    uf_loads: np.ndarray       # (Vu,) f32 — base offered load
    uf_valid: np.ndarray       # (Vu,) bool
    nuf_onehot: np.ndarray     # (Vn, S*C) f32
    nuf_cores: np.ndarray      # (Vn,) f32
    nuf_valid: np.ndarray      # (Vn,) bool
    uf_id: np.ndarray          # (S*C,) i32 — owning UF VM, Vu = none
    nuf_id: np.ndarray         # (S*C,) i32 — owning NUF VM, Vn = none

    def arrays(self, pad_uf_cores_to: int = 0) -> LayoutArrays:
        """The pytree the engine closes over / vmaps. `active` is None
        when every core is real — the transition then skips all padding
        masks (see fleet_dynamics.server_power)."""
        n_uf = len(self.uf_valid)
        idx = np.nonzero(self.uf_id < n_uf)[0]
        ku = max(len(idx), pad_uf_cores_to, 1)
        core_idx = np.zeros(ku, np.int32)
        core_idx[:len(idx)] = idx
        compact = np.zeros((ku, n_uf), _F32)
        compact[np.arange(len(idx)), self.uf_id[idx]] = 1.0
        return LayoutArrays(self.uf_mask, self.nuf_core,
                            None if self.active.all() else self.active,
                            self.uf_id, core_idx, compact, self.uf_cores,
                            self.nuf_onehot, self.nuf_cores)


def build_layout(specs: list, pad_uf_to: int = 0, pad_nuf_to: int = 0,
                 pad_cores_to: int = 0) -> FleetLayout:
    """Pack a list[ServerSpec] into padded fleet arrays. VM walk order
    (server-major, then VM) matches the seed simulator, so load traces
    drawn per-VM consume the rng stream identically."""
    n_servers = len(specs)
    n_cores = max(pad_cores_to, max(s.n_cores for s in specs))
    uf_mask = np.zeros((n_servers, n_cores), bool)
    nuf_core = np.zeros((n_servers, n_cores), bool)
    active = np.zeros((n_servers, n_cores), bool)
    uf_members, uf_loads, nuf_members = [], [], []
    for si, spec in enumerate(specs):
        active[si, :spec.n_cores] = True
        c0 = 0
        for vm in spec.vms:
            cores = np.zeros((n_servers, n_cores), bool)
            cores[si, c0:c0 + vm.n_cores] = True
            if vm.is_uf:
                uf_mask |= cores
                uf_members.append(cores.ravel())
                uf_loads.append(vm.load)
            else:
                nuf_core |= cores
                nuf_members.append(cores.ravel())
            c0 += vm.n_cores

    def _pack(members, pad_to):
        n = max(len(members), pad_to, 1)
        onehot = np.zeros((n, n_servers * n_cores), _F32)
        valid = np.zeros(n, bool)
        for i, m in enumerate(members):
            onehot[i] = m
            valid[i] = True
        return onehot, onehot.sum(-1).astype(_F32), valid

    uf_onehot, uf_cores, uf_valid = _pack(uf_members, pad_uf_to)
    nuf_onehot, nuf_cores, nuf_valid = _pack(nuf_members, pad_nuf_to)
    loads = np.zeros(len(uf_valid), _F32)
    loads[:len(uf_loads)] = uf_loads

    def _ids(members, n_slots):
        ids = np.full(n_servers * n_cores, n_slots, np.int32)
        for i, m in enumerate(members):
            ids[m] = i
        return ids

    return FleetLayout(n_servers, n_cores, uf_mask, nuf_core, active,
                       uf_onehot, uf_cores, loads, uf_valid,
                       nuf_onehot, nuf_cores, nuf_valid,
                       _ids(uf_members, len(uf_valid)),
                       _ids(nuf_members, len(nuf_valid)))


def build_uf_traces(layout: FleetLayout, n_steps: int, seed: int,
                    load_scale: float = 1.0) -> np.ndarray:
    """(n_steps, Vu) offered-load traces, drawn in the seed's VM order."""
    rng = np.random.default_rng(seed)
    out = np.zeros((n_steps, len(layout.uf_valid)), _F32)
    for v in range(len(layout.uf_valid)):
        if layout.uf_valid[v]:
            out[:, v] = _uf_load_trace(rng, n_steps, layout.uf_loads[v])
    return out * _F32(load_scale)


def stack_layouts(layouts: list) -> LayoutArrays:
    """Batch heterogeneous chassis layouts (leading axis B). Pads the
    compact UF-core axis to the largest chassis; VM axes must already
    share shapes (build with pad_uf_to/pad_nuf_to)."""
    ku = max(int((lo.uf_id < len(lo.uf_valid)).sum()) for lo in layouts)
    # `active` collapses to None only if EVERY chassis is fully active
    # (a mix must keep the real masks, not inherit layouts[0]'s)
    active = None if all(lo.active.all() for lo in layouts) \
        else np.stack([lo.active for lo in layouts])
    arrs = [lo.arrays(pad_uf_cores_to=ku)._replace(active=None)
            for lo in layouts]
    return LayoutArrays(*(np.stack(x) if x[0] is not None else None
                          for x in zip(*arrs)))._replace(active=active)


# --- the shared per-step workload/application model -----------------------

def _offered_util(la: LayoutArrays, trace_t, freq, xp):
    """Per-core offered utilization: batch saturates its cores; the
    interactive load rises when cores are slowed (same work, less
    capacity): util = min(1, load / f). Unbatched (one chassis) — jax
    batches via vmap, numpy via the per-chassis loop."""
    pad = xp.concatenate([trace_t, xp.zeros(1, trace_t.dtype)])
    load_core = pad[la.uf_id].reshape(freq.shape)
    util = xp.where(la.uf_mask,
                    xp.minimum(load_core
                               / xp.maximum(freq, _F32(1e-3)), _F32(1.0)),
                    _F32(0.0))
    return xp.where(la.nuf_core, _F32(1.0), util)


def _app_update(la: LayoutArrays, trace_t, freq, backlog, freq_sum,
                dt, xp):
    """Fluid-queue UF app + fixed-work NUF app (paper §IV-C). Returns
    updated carries + per-step latency and the minimum NUF core
    frequency. Unbatched (one chassis). The NUF integral carry is the
    raw per-core frequency sum; callers reduce it per-VM after the run."""
    freq_flat = freq.reshape(-1)
    cap = freq_flat[la.uf_core_idx] @ la.uf_compact     # (Vu,)
    lam = trace_t * la.uf_cores
    backlog = xp.clip(backlog + (lam - cap) * _F32(dt), _F32(0.0), cap)
    # closed-loop client pool: bounded in-flight work (backlog <= cap);
    # stationary-queue term capped at rho = 0.9 — sustained overload is
    # carried by the backlog term instead of the M/M/c pole
    meanf = cap / xp.maximum(la.uf_cores, _F32(1.0))
    service = _F32(1.0) / xp.maximum(meanf, _F32(1e-6))
    rho = xp.minimum(lam / xp.maximum(cap, _F32(1e-6)), _F32(0.9))
    latency = service * (_F32(1.0) + rho / (_F32(1.0) - rho) * _F32(0.15)) \
        + backlog / xp.maximum(cap, _F32(1e-6))
    freq_sum = freq_sum + freq_flat
    min_nuf = xp.min(xp.where(la.nuf_core, freq, _F32(F_MAX)),
                     axis=(-2, -1))
    return backlog, freq_sum, latency, min_nuf


def _scalars(budget_w, n_servers: int, min_pstate) -> dict:
    """Per-run control scalars from a chassis budget (inf = uncapped)."""
    budget = np.asarray(budget_w, _F32)
    server_b = budget / _F32(n_servers)
    return {"server_budget": server_b,
            "target": server_b - _F32(ALERT_MARGIN_W),
            "alert": budget * _F32(ALERT_FRACTION),
            "min_pstate": np.asarray(min_pstate, np.int32)}


# --- results --------------------------------------------------------------

@dataclass
class SimResult:
    power_w: np.ndarray                 # (n_steps,) chassis draw
    min_nuf_freq: np.ndarray            # (n_steps,)
    uf_p95_latency: float               # mean across UF VMs
    nuf_slowdown: float                 # mean across NUF VMs (>= 1.0)
    rapl_engaged_frac: float


@dataclass
class FleetResult:
    """Batched over the run axis B (chassis / scenario grid points)."""
    power_w: np.ndarray                 # (B, T)
    min_nuf_freq: np.ndarray            # (B, T)
    uf_latency: np.ndarray              # (B, T, Vu) per-step, padded VMs 0
    alert_frac: np.ndarray              # (B,)
    rapl_engaged_frac: np.ndarray       # (B,)
    uf_p95_latency: np.ndarray          # (B,)
    nuf_slowdown: np.ndarray            # (B,)

    def chassis(self, b: int) -> SimResult:
        return SimResult(self.power_w[b], self.min_nuf_freq[b],
                         float(self.uf_p95_latency[b]),
                         float(self.nuf_slowdown[b]),
                         float(self.rapl_engaged_frac[b]))


def _aggregate(layout_valid, nuf_cores, duration_s, power, min_nuf, lat,
               rapl_cnt, alert, nuf_integ, n_servers) -> FleetResult:
    uf_valid, nuf_valid = layout_valid
    lat = lat * uf_valid.astype(lat.dtype)      # zero padded VM columns
    n_steps = power.shape[-1]
    if uf_valid.any():
        p95 = np.percentile(lat[..., uf_valid], 95, axis=1)   # (B, Vu')
        uf_p95 = p95.mean(-1)
    else:
        uf_p95 = np.zeros(power.shape[0])
    if nuf_valid.any():
        nominal = nuf_cores[nuf_valid] * F_MAX * duration_s
        slow = nominal / np.maximum(nuf_integ[..., nuf_valid], 1e-9)
        nuf_slow = slow.mean(-1)
    else:
        nuf_slow = np.ones(power.shape[0])
    return FleetResult(
        power_w=power, min_nuf_freq=min_nuf, uf_latency=lat,
        alert_frac=alert.mean(-1),
        rapl_engaged_frac=rapl_cnt.sum(-1) / (n_steps * n_servers),
        uf_p95_latency=uf_p95, nuf_slowdown=nuf_slow)


# --- numpy oracle: same arithmetic, Python loop ---------------------------

def _run_numpy_one(cp, la, sc, traces):
    """One chassis, looped over time — the seed's execution model."""
    S, C = la.uf_mask.shape
    st = init_state((), S, C, np)
    rp = RunParams(sc["server_budget"], sc["target"], sc["alert"],
                   sc["min_pstate"], la.uf_mask, la.active)
    n_steps = traces.shape[0]
    backlog = np.zeros(la.uf_cores.shape[0], _F32)
    freq_sum = np.zeros(S * C, _F32)
    power = np.zeros(n_steps, _F32)
    min_nuf = np.zeros(n_steps, _F32)
    lat = np.zeros((n_steps, la.uf_cores.shape[0]), _F32)
    rapl_cnt = np.zeros(n_steps, np.int32)
    alert = np.zeros(n_steps, bool)
    for t in range(n_steps):
        util = _offered_util(la, traces[t], st.freq, np)
        st, outs = fleet_step(cp, rp, st, util, np)
        backlog, freq_sum, lat_t, mn = _app_update(
            la, traces[t], st.freq, backlog, freq_sum, cp.dt, np)
        power[t] = outs.chassis_power_w
        min_nuf[t] = mn
        lat[t] = lat_t
        rapl_cnt[t] = outs.rapl.sum()
        alert[t] = outs.alert
    integ = (freq_sum @ la.nuf_onehot.T) * _F32(cp.dt)
    return power, min_nuf, lat, rapl_cnt, alert, integ


# --- jax engine: scan over time, vmap over chassis ------------------------

def _scan_one(cp, la, sc, traces):
    import jax
    import jax.numpy as jnp
    S, C = la.uf_mask.shape
    rp = RunParams(sc["server_budget"], sc["target"], sc["alert"],
                   sc["min_pstate"], la.uf_mask, la.active)
    st0 = init_state((), S, C, jnp)
    backlog0 = jnp.zeros(la.uf_cores.shape[0], jnp.float32)
    fsum0 = jnp.zeros(S * C, jnp.float32)

    def body(carry, trace_t):
        st, backlog, freq_sum = carry
        util = _offered_util(la, trace_t, st.freq, jnp)
        st2, outs = fleet_step(cp, rp, st, util, jnp)
        backlog, freq_sum, lat_t, mn = _app_update(
            la, trace_t, st2.freq, backlog, freq_sum, cp.dt, jnp)
        ys = (outs.chassis_power_w, mn, lat_t,
              jnp.sum(outs.rapl).astype(jnp.int32), outs.alert)
        return (st2, backlog, freq_sum), ys

    (_, _, freq_sum), ys = jax.lax.scan(body, (st0, backlog0, fsum0),
                                        traces, unroll=8)
    integ = (freq_sum @ la.nuf_onehot.T) * jnp.float32(cp.dt)
    return ys + (integ,)


_ENGINE_CACHE: dict = {}


def _jax_engine(cp: ControlParams, shared_layout: bool):
    """jit(vmap(scan)) with a stable cache key so recompilation only
    happens per (ControlParams, layout-sharing, shape) signature."""
    key = (cp, shared_layout)
    if key not in _ENGINE_CACHE:
        import jax
        ax = None if shared_layout else 0

        @jax.jit
        def engine(la, sc, traces):
            return jax.vmap(partial(_scan_one, cp),
                            in_axes=(ax, 0, 0))(la, sc, traces)
        _ENGINE_CACHE[key] = engine
    return _ENGINE_CACHE[key]


# --- public API -----------------------------------------------------------

def run_fleet(specs: list, budgets_w, mode: str,
              duration_s: float = 600.0, seed=0,
              model: ServerPowerModel | None = None,
              backend: str = "jax", load_scale=1.0, min_pstate=None,
              layout: FleetLayout | None = None,
              traces: np.ndarray | None = None) -> FleetResult:
    """Simulate a fleet of identical-layout chassis under per-chassis
    budgets. `budgets_w`: None (uncapped), scalar, or (B,) array —
    the run axis. `seed`: int (all chassis share one trace draw) or
    (B,) array (independent chassis). Returns batched FleetResult.
    """
    model = model or ServerPowerModel()
    cp = ControlParams.from_model(model, mode=mode)
    layout = layout or build_layout(specs)
    n_steps = int(duration_s / POLL_INTERVAL_S)

    budgets = np.asarray(
        [np.inf] if budgets_w is None else budgets_w, _F32).reshape(-1)
    budgets = np.where(np.isfinite(budgets), budgets, np.inf)
    n_runs = len(budgets)

    if traces is None:
        seeds = np.broadcast_to(np.asarray(seed), (n_runs,))
        scales = np.broadcast_to(np.asarray(load_scale, _F32), (n_runs,))
        if np.all(seeds == seeds[0]):
            base = build_uf_traces(layout, n_steps, int(seeds[0]))
            traces = base[None] * scales[:, None, None]
        else:
            traces = np.stack([
                build_uf_traces(layout, n_steps, int(s), float(sc))
                for s, sc in zip(seeds, scales)])
    traces = np.asarray(traces, _F32)
    if traces.ndim == 2:
        traces = np.broadcast_to(traces[None], (n_runs,) + traces.shape)

    minp = N_PSTATES - 1 if min_pstate is None else min_pstate
    sc = _scalars(budgets, layout.n_servers,
                  np.broadcast_to(np.asarray(minp, np.int32), (n_runs,)))
    la = layout.arrays()

    if backend == "numpy":
        outs = [_run_numpy_one(cp, la,
                               {k: v[b] for k, v in sc.items()},
                               traces[b])
                for b in range(n_runs)]
        power, min_nuf, lat, rapl_cnt, alert, integ = \
            (np.stack(x) for x in zip(*outs))
    else:
        engine = _jax_engine(cp, shared_layout=True)
        power, min_nuf, lat, rapl_cnt, alert, integ = \
            (np.asarray(x) for x in engine(la, sc, traces))
    return _aggregate((layout.uf_valid, layout.nuf_valid),
                      layout.nuf_cores, duration_s, power, min_nuf, lat,
                      rapl_cnt, alert, integ, layout.n_servers)


def run_fleet_layouts(layouts_arrays, uf_valid, nuf_valid, nuf_cores,
                      budgets_w, mode: str, traces,
                      model: ServerPowerModel | None = None,
                      duration_s: float | None = None,
                      backend: str = "jax") -> FleetResult:
    """Heterogeneous fleet: every chassis brings its own (padded,
    shape-identical) layout arrays — batched with leading axis B. Used
    by the scheduler simulation to evaluate the capping dynamics of the
    placements it actually produced."""
    model = model or ServerPowerModel()
    cp = ControlParams.from_model(model, mode=mode)
    n_runs, n_steps = traces.shape[0], traces.shape[1]
    layouts_arrays = LayoutArrays(*layouts_arrays)
    n_servers = layouts_arrays.uf_mask.shape[1]
    if duration_s is None:
        duration_s = n_steps * POLL_INTERVAL_S
    budgets = np.asarray(budgets_w, _F32).reshape(-1)
    minp = np.full(n_runs, N_PSTATES - 1, np.int32)
    sc = _scalars(np.broadcast_to(budgets, (n_runs,)), n_servers, minp)
    traces = np.asarray(traces, _F32)
    if backend == "numpy":
        outs = [_run_numpy_one(
                    cp, LayoutArrays(*(None if a is None else a[b]
                                       for a in layouts_arrays)),
                    {k: v[b] for k, v in sc.items()}, traces[b])
                for b in range(n_runs)]
        power, min_nuf, lat, rapl_cnt, alert, integ = \
            (np.stack(x) for x in zip(*outs))
    else:
        engine = _jax_engine(cp, shared_layout=False)
        power, min_nuf, lat, rapl_cnt, alert, integ = \
            (np.asarray(x) for x in engine(layouts_arrays, sc, traces))
    # per-chassis VM validity differs: aggregate row-wise
    lat = lat * uf_valid[:, None, :].astype(lat.dtype)
    uf_p95 = np.zeros(n_runs)
    nuf_slow = np.ones(n_runs)
    for b in range(n_runs):
        if uf_valid[b].any():
            uf_p95[b] = np.percentile(lat[b][:, uf_valid[b]], 95,
                                      axis=0).mean()
        if nuf_valid[b].any():
            nominal = nuf_cores[b][nuf_valid[b]] * F_MAX * duration_s
            nuf_slow[b] = (nominal / np.maximum(
                integ[b][nuf_valid[b]], 1e-9)).mean()
    return FleetResult(power, min_nuf, lat, alert.mean(-1),
                       rapl_cnt.sum(-1) / (n_steps * n_servers),
                       uf_p95, nuf_slow)


# --- scenario sweeps (Table IV-style frontiers) ---------------------------

def fmin_to_pstate(fmin: float) -> int:
    """Nearest p-state index for a frequency floor (FREQ_TABLE is
    descending f_max..f_min)."""
    return int(np.argmin(np.abs(FREQ_TABLE - np.float32(fmin))))


def sweep_scenarios(specs: list, budgets_w, load_scales=(1.0,),
                    fmin_nuf=(F_MIN,), mode: str = "per_vm",
                    duration_s: float = 120.0, seed: int = 0,
                    model: ServerPowerModel | None = None,
                    backend: str = "jax",
                    include_uncapped: bool = True) -> dict:
    """One compiled call over the (budget x load-scale x NUF-floor)
    grid. Returns metric arrays of shape (n_budgets[+1], n_loads,
    n_floors); index 0 of the budget axis is the uncapped baseline when
    `include_uncapped` (for latency-impact ratios)."""
    budgets = list(np.asarray(budgets_w, np.float64).reshape(-1))
    if include_uncapped:
        budgets = [np.inf] + budgets
    shape = (len(budgets), len(load_scales), len(fmin_nuf))
    bb, ll, ff = np.meshgrid(
        np.asarray(budgets, _F32), np.asarray(load_scales, _F32),
        np.asarray([fmin_to_pstate(f) for f in fmin_nuf], np.int32),
        indexing="ij")
    res = run_fleet(specs, bb.ravel(), mode, duration_s, seed, model,
                    backend, load_scale=ll.ravel(),
                    min_pstate=ff.ravel())
    out = {"budgets_w": np.asarray(budgets),
           "load_scales": np.asarray(load_scales),
           "fmin_nuf": np.asarray(fmin_nuf)}
    for name in ("uf_p95_latency", "nuf_slowdown", "rapl_engaged_frac",
                 "alert_frac"):
        out[name] = getattr(res, name).reshape(shape)
    out["power_max_w"] = res.power_w.max(-1).reshape(shape)
    if include_uncapped:
        base = out["uf_p95_latency"][:1]
        out["uf_latency_ratio"] = out["uf_p95_latency"] \
            / np.maximum(base, 1e-9)
    return out


def frontier(sweep: dict, provisioned_w: float,
             max_uf_latency_ratio: float = 1.05,
             max_rapl_frac: float = 0.001) -> dict:
    """Table IV-style frontier: for each (load-scale, NUF-floor) cell,
    the lowest budget whose measured UF impact and RAPL engagement stay
    within tolerance, and the recovered provisioned-power fraction."""
    if "uf_latency_ratio" not in sweep:
        raise ValueError("sweep must include the uncapped baseline")
    ok = (sweep["uf_latency_ratio"] <= max_uf_latency_ratio) \
        & (sweep["rapl_engaged_frac"] <= max_rapl_frac)
    budgets = sweep["budgets_w"]                       # descending walk
    best = np.full(ok.shape[1:], np.inf)
    for i in range(ok.shape[0]):
        best = np.where(ok[i] & np.isfinite(budgets[i]),
                        np.minimum(best, budgets[i]), best)
    feasible = np.isfinite(best)
    oversub = np.where(feasible, 1.0 - best / provisioned_w, 0.0)
    return {"budget_w": np.where(feasible, best, provisioned_w),
            "oversubscription": oversub, "feasible": feasible}
