"""Cluster VM-scheduler simulation (paper §IV-E, Fig. 7).

Event-driven 30-day simulation of a 20-rack x 3-chassis x 12-blade
cluster. Like Azure's simulator, it runs the *actual* placement code
(`repro.core.placement`) for every arrival; our only extension is the
simulated prediction channel (the paper's only extension was simulating
calls to the ML system).

Reported metrics (paper's four):
  * deployment failure rate,
  * average empty-server ratio,
  * std-dev across chassis of the chassis score 1 - rho_peak/rho_max,
  * std-dev across servers of the server score .5(1+(gNUF-gUF)/N).

New: the placements the scheduler actually produced can be fed to the
batched fleet engine (`repro.sim.fleet`) to measure the *capping
dynamics* they induce — `evaluate_power_dynamics` vmaps the compiled
chassis simulator across the live chassis layouts, closing the loop
between Fig 7 (placement balance) and Figs 4-6 (per-VM capping).

`simulate(backend='serve')` routes every deployment group through the
online serving pipeline's batched placement scan
(`repro.serve.placement`) instead of the per-arrival numpy rule, so
Fig 7 metrics can be reproduced through the served path and checked
against the event-driven oracle (DESIGN.md §9).
"""
from __future__ import annotations

import contextlib
import heapq
import warnings
from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from repro.core.placement import ClusterState, SchedulerPolicy
from repro.core.resources import (N_RESOURCES, ResourceVector,
                                  trough_ratios)
from repro.sim import telemetry as tel
from repro.sim.fleet import (ServerSpec, VMSpec, build_layout,
                             build_uf_traces, run_fleet_layouts,
                             stack_layouts)

CORES_PER_BLADE = 40            # Table I: 2 x 20 cores
BLADES_PER_CHASSIS = 12
CHASSIS_PER_RACK = 3
RACKS = 20

#: Deterministic GB-per-vcore of every simulated VM. Memory demand is
#: a pure function of the core draw, so threading the GB ledger
#: through the sim consumes NO extra randomness — every rng stream,
#: and therefore every placement decision of the watt-only era, is
#: preserved bit for bit.
GB_PER_CORE = 4.0


@dataclass(frozen=True)
class PredictionChannel:
    """Simulated ML-system responses (Table III operating point).

    mode:
      'oracle'    — perfect workload type and P95 bucket;
      'ml'        — criticality flipped w.p. its measured error, P95
                    bucket resampled w.p. its measured error; low-
                    confidence queries fall back to conservative values
                    (UF, bucket 4), as the real scheduler does;
      'crit_only' — criticality as 'ml', utilization assumed 100 %
                    (Fig 7 orange bars);
      'none'      — no predictions (NoRule baseline ignores them).
    """
    mode: str = "ml"
    crit_recall_uf: float = 0.99     # P(pred UF | true UF)   — Table III
    crit_recall_nuf: float = 0.69    # P(pred NUF | true NUF)
    p95_accuracy: float = 0.84
    p95_high_conf: float = 0.73

    def predict(self, rng, true_uf: bool, true_p95: float):
        if self.mode == "oracle":
            return true_uf, true_p95
        if true_uf:
            uf = rng.random() < self.crit_recall_uf
        else:
            uf = not (rng.random() < self.crit_recall_nuf)
        if self.mode == "crit_only":
            return uf, 1.0
        if rng.random() > self.p95_high_conf:
            return uf, 1.0                       # low confidence -> 100 %
        if rng.random() < self.p95_accuracy:
            p95 = true_p95
        else:
            p95 = float(np.clip(true_p95 + rng.choice([-0.25, 0.25]),
                                0.125, 0.875))
        return uf, p95


@dataclass(frozen=True)
class PowerEvalSpec:
    """Post-run capping-dynamics evaluation (`evaluate_power_dynamics`
    over the placements the scheduler produced). ``budget_w`` is the
    per-chassis watt budget the fleet engine enforces."""
    budget_w: float
    chassis: int = 8
    duration_s: float = 60.0
    backend: str = "jax"

    def __post_init__(self):
        if not self.budget_w > 0:
            raise ValueError(
                f"PowerEvalSpec.budget_w must be > 0, got {self.budget_w}")


@dataclass(frozen=True)
class ServeBackendSpec:
    """Which placement path runs, and the resource budgets it admits
    against (DESIGN.md §16).

    backend:          'event' | 'serve' | 'serve-sharded' (see
                      `simulate`).
    admission_budget: per-chassis `ResourceVector` ceiling for the
                      serve path (None = unbounded; the legacy
                      ``admission_budget_w`` float is
                      ``ResourceVector(watts=w)``, decision-identical).
    cluster_budget:   global `ResourceVector` the sharded token pools
                      enforce (legacy ``cluster_budget_w`` likewise).
    shards:           state partitions of the sharded protocol.
    ingest_hosts:     per-host queues the arrival stream is dealt
                      over (sharded backend only).
    diurnal_ratchet:  condition the cores/GB admission ceilings (and
                      sharded pool axes) on the diurnal trough via
                      `core.resources.trough_ratios` — Coach-style
                      time-of-day oversubscription; the watts axis is
                      a breaker limit and never ratchets.
    """
    backend: str = "event"
    admission_budget: ResourceVector | None = None
    cluster_budget: ResourceVector | None = None
    shards: int = 1
    ingest_hosts: int = 1
    diurnal_ratchet: bool = False

    def __post_init__(self):
        if self.backend not in ("event", "serve", "serve-sharded"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.ingest_hosts < 1:
            raise ValueError(f"ingest_hosts must be >= 1, "
                             f"got {self.ingest_hosts}")


@dataclass(frozen=True)
class SimSpec:
    """Everything `simulate` needs beyond the policy and the
    prediction channel — the one front door (DESIGN.md §16). Plane
    configs nest as typed sub-specs instead of a flat kwarg sprawl:
    ``serve`` picks the placement path and budgets, ``power`` the
    post-run capping evaluation, and ``emergency``/``adaptive``/
    ``ballooning`` the online planes (a `serve.emergency.
    EmergencyConfig`, `serve.adaptive.AdaptiveConfig` and
    `serve.ballooning.BallooningConfig` respectively — untyped here so
    the sim package never imports the serve package at module scope).
    """
    days: float = 30.0
    seed: int = 0
    deployments_per_hour: float = 8.0
    target_uf_core_ratio: float = 0.40
    sample_every_h: float = 2.0
    prefill_core_ratio: float = 0.0
    serve: ServeBackendSpec = field(default_factory=ServeBackendSpec)
    power: PowerEvalSpec | None = None
    emergency: object | None = None
    adaptive: object | None = None
    ballooning: object | None = None

    def __post_init__(self):
        if not self.days > 0:
            raise ValueError(f"days must be > 0, got {self.days}")
        if self.ballooning is not None and self.emergency is None:
            raise ValueError(
                "SimSpec.ballooning requires SimSpec.emergency — the "
                "balloon rung fires on the emergency plane's alarms")


_UNSET = object()

#: legacy `simulate` kwarg -> where it lives on `SimSpec` (doc string
#: for the DeprecationWarning; the adapter below does the mapping)
_LEGACY_SIM_KW = {
    "days": "days", "seed": "seed",
    "deployments_per_hour": "deployments_per_hour",
    "target_uf_core_ratio": "target_uf_core_ratio",
    "sample_every_h": "sample_every_h",
    "prefill_core_ratio": "prefill_core_ratio",
    "backend": "serve.backend",
    "admission_budget_w": "serve.admission_budget",
    "cluster_budget_w": "serve.cluster_budget",
    "serve_shards": "serve.shards",
    "n_ingest_hosts": "serve.ingest_hosts",
    "power_eval_budget_w": "power.budget_w",
    "power_eval_chassis": "power.chassis",
    "power_eval_duration_s": "power.duration_s",
    "power_eval_backend": "power.backend",
    "emergency_cfg": "emergency", "adaptive_cfg": "adaptive",
}


def _spec_from_legacy(spec: SimSpec | None, kw: dict) -> SimSpec:
    """Adapter: fold legacy `simulate` kwargs into a `SimSpec`,
    warning `DeprecationWarning` (tier-1 runs warnings-as-errors, so
    no in-repo caller may reach this path). Decision-identical by
    construction — every legacy value lands on the spec field the new
    body reads."""
    given = {k: v for k, v in kw.items() if v is not _UNSET}
    if not given:
        return spec if spec is not None else SimSpec()
    if spec is not None:
        raise TypeError("pass either spec=SimSpec(...) or legacy "
                        f"kwargs, not both: {sorted(given)}")
    warnings.warn(
        f"{', '.join(sorted(given))} as simulate() kwargs are "
        "deprecated; pass spec=SimSpec(...) (docs/resources.md has "
        "the migration table)", DeprecationWarning, stacklevel=3)
    top = {k: given.pop(k) for k in list(given)
           if "." not in _LEGACY_SIM_KW[k]
           and _LEGACY_SIM_KW[k] in ("days", "seed",
                                     "deployments_per_hour",
                                     "target_uf_core_ratio",
                                     "sample_every_h",
                                     "prefill_core_ratio")}
    serve_kw = {}
    for src, dst in (("backend", "backend"), ("serve_shards", "shards"),
                     ("n_ingest_hosts", "ingest_hosts")):
        if src in given:
            serve_kw[dst] = given.pop(src)
    for src, dst in (("admission_budget_w", "admission_budget"),
                     ("cluster_budget_w", "cluster_budget")):
        if src in given:
            w = given.pop(src)
            serve_kw[dst] = None if w is None \
                else ResourceVector(watts=float(w))
    power = None
    if given.get("power_eval_budget_w") is not None:
        power = PowerEvalSpec(
            budget_w=given.pop("power_eval_budget_w"),
            chassis=given.pop("power_eval_chassis", 8),
            duration_s=given.pop("power_eval_duration_s", 60.0),
            backend=given.pop("power_eval_backend", "jax"))
    else:
        for k in ("power_eval_budget_w", "power_eval_chassis",
                  "power_eval_duration_s", "power_eval_backend"):
            given.pop(k, None)
    top["emergency"] = given.pop("emergency_cfg", None)
    top["adaptive"] = given.pop("adaptive_cfg", None)
    assert not given, f"unmapped legacy kwargs: {sorted(given)}"
    return SimSpec(serve=ServeBackendSpec(**serve_kw), power=power,
                   **top)


@dataclass
class PowerEval:
    """Capping dynamics of scheduler-produced placements (fleet engine)."""
    chassis_ids: np.ndarray             # (B,) evaluated chassis
    uf_p95_latency: np.ndarray          # (B,)
    nuf_slowdown: np.ndarray            # (B,)
    rapl_engaged_frac: np.ndarray       # (B,)
    alert_frac: np.ndarray              # (B,)
    power_max_w: np.ndarray             # (B,)


@dataclass
class SimMetrics:
    failure_rate: float
    empty_server_ratio: float
    chassis_score_std: float
    server_score_std: float
    placements: int
    failures: int
    power: PowerEval | None = None
    #: power-emergency plane counters (`emergency_cfg` runs only):
    #: per-criticality throttled-seconds — the paper's Table-4-style
    #: impact axis (critical should stay near zero under
    #: criticality-aware apportionment) — plus alarm and migration
    #: counts. `throttled_s` is (L,) in the emergency plane's level
    #: order (index `serve.emergency.CRIT_NUF` = 0, `CRIT_UF` = 1 —
    #: the `obs.LEVEL_NAMES` order), matching `EmergencyState.
    #: throttled_s` instead of the historical pair of drifting scalar
    #: names; those survive as read-only properties.
    throttled_s: np.ndarray = field(default_factory=lambda: np.zeros(2))
    alarms: int = 0
    migrations: int = 0
    #: ballooning rung (`SimSpec.ballooning` runs only): inflation
    #: events, total GB reclaimed across the run, and the GB still
    #: ballooned out at the end — all 0 when the rung is off
    balloon_events: int = 0
    balloon_reclaimed_gb: float = 0.0
    ballooned_gb: float = 0.0
    #: adaptive-ratio controller (`adaptive_cfg` runs only): the final
    #: oversubscription ratio and the up/down step counts — 1.0/0/0
    #: when the controller is off
    adaptive_ratio: float = 1.0
    adaptive_ratchets: int = 0
    adaptive_backoffs: int = 0
    #: measured predicted-vs-realized labels (DESIGN.md §17): every
    #: `PredictionChannel.predict` call is scored against the ground
    #: truth it was sampled from — ``crit_confusion[true, pred]``
    #: (2, 2) over criticality, ``p95_confusion[true, pred]`` (4, 4)
    #: over P95 buckets. Accuracy is an *output* of the run, not the
    #: channel's generative constant (`measured_p95_accuracy` vs the
    #: assumed ``p95_accuracy`` knob).
    crit_confusion: np.ndarray = field(
        default_factory=lambda: np.zeros((2, 2), np.int64))
    p95_confusion: np.ndarray = field(
        default_factory=lambda: np.zeros((4, 4), np.int64))

    @property
    def measured_crit_accuracy(self) -> float:
        """Realized criticality-prediction accuracy over the run
        (NaN when nothing was scored)."""
        n = self.crit_confusion.sum()
        return float(np.trace(self.crit_confusion) / n) if n \
            else float("nan")

    @property
    def measured_p95_accuracy(self) -> float:
        """Realized P95-bucket-prediction accuracy over the run
        (NaN when nothing was scored)."""
        n = self.p95_confusion.sum()
        return float(np.trace(self.p95_confusion) / n) if n \
            else float("nan")

    @property
    def nuf_throttled_s(self) -> float:
        """Non-critical throttled-seconds (``throttled_s[CRIT_NUF]``)."""
        return float(self.throttled_s[0])

    @property
    def uf_throttled_s(self) -> float:
        """Critical throttled-seconds (``throttled_s[CRIT_UF]``)."""
        return float(self.throttled_s[1])


class _EmergencySim:
    """Power-emergency plane driven inside `simulate` (DESIGN.md §12).

    Holds one fleet-wide `serve.emergency.EmergencyState` (f64) and
    steps it at every deployment event: the committed per-criticality
    aggregates are scaled by the deterministic diurnal utilization
    sample (`sim.telemetry.diurnal_util`) into per-chassis power
    samples, the alarm + apportionment kernel consumes them, and
    chassis whose critical level dwells capped past the threshold get
    a migration plan (`serve.mitigation`) applied to the cluster
    state as paired depart/arrive moves.

    The numpy execution is the oracle; with `use_jax` (the serve
    backends) every scan ALSO runs the compiled jnp kernel in x64 and
    asserts it bit-identical — the acceptance invariant, checked on
    every scan rather than trusted to a test fixture. The sample set
    is a pure function of simulation time, so the emergency trace is
    identical for every backend and ingest-host count."""

    def __init__(self, cfg, n_chassis: int, chassis_of: np.ndarray,
                 use_jax: bool, bcfg=None):
        from repro.serve import ballooning, emergency, mitigation
        self.emg, self.mit = emergency, mitigation
        self.bal = ballooning
        self.cfg = cfg
        self.bcfg = bcfg
        self.n_chassis = n_chassis
        self.chassis_of = chassis_of
        self.use_jax = use_jax
        self.st = emergency.init_emergency(n_chassis, xp=np,
                                           dtype=np.float64)
        self.bst = None if bcfg is None else \
            ballooning.init_ballooning(n_chassis, xp=np,
                                       dtype=np.float64)
        self.alarms = 0
        self.migrations = 0
        self.balloon_events = 0
        self.balloon_reclaimed_gb = 0.0
        # span factory for the observability plane; `simulate` rebinds
        # it to `Observability.span` when tracing is on
        self.span = lambda name: contextlib.nullcontext()

    def _rho_lv(self, state) -> np.ndarray:
        c = self.n_chassis
        return np.stack(
            [np.bincount(self.chassis_of, weights=state.gamma_nuf,
                         minlength=c),
             np.bincount(self.chassis_of, weights=state.gamma_uf,
                         minlength=c)], axis=-1)

    def scan(self, t_h: float, state, vm_live: dict,
             mem_nuf: np.ndarray = None, mem_chassis: np.ndarray = None,
             gb_cap: np.ndarray = None) -> None:
        """One emergency scan at simulation time `t_h` (hours).

        `mem_nuf`/`mem_chassis`: (C,) committed GB (NUF slice and
        total) — the ballooning rung's headroom and the migration
        planner's GB-fit ledger; `gb_cap`: (C,) chassis GB capacity
        (None disables the destination GB-fit check)."""
        emg = self.emg
        u = float(tel.diurnal_util(t_h))
        rho_lv = self._rho_lv(state)
        idx = np.arange(self.n_chassis)
        stamps = t_h * 3600.0 + (idx + 1) * 1e-7
        power = np.asarray(emg.sampled_power(
            self.cfg, rho_lv, u, np.zeros((self.n_chassis, 2), np.int32),
            np.zeros(self.n_chassis, bool), np))
        pw, mask, ts = emg.scatter_samples(self.n_chassis, idx, power,
                                           stamps, np, np.float64)
        # ballooning rung: absorb the watt deficit the NUF frequency
        # floor cannot, by powering NUF DRAM down — BEFORE the capping
        # step consumes the sample, so a fully served demand never
        # touches the critical level at all
        bst2 = bout = None
        pw_step = pw
        if self.bst is not None:
            nuf = np.zeros(self.n_chassis) if mem_nuf is None else mem_nuf
            bst2, bout = self.bal.balloon_step(
                self.bcfg, self.cfg, self.bst, rho_lv, pw, nuf, mask, np)
            pw_step = bout.power_adj_w
        st2, out = emg.masked_step(self.cfg, self.st, rho_lv, pw_step,
                                   mask, ts, np)
        if self.use_jax:
            import jax
            import jax.numpy as jnp
            with jax.experimental.enable_x64():
                pwj = jnp.asarray(pw)
                if self.bst is not None:
                    bstj, boutj = self.bal.balloon_step(
                        self.bcfg, self.cfg,
                        jax.tree.map(jnp.asarray, self.bst),
                        jnp.asarray(rho_lv), pwj, jnp.asarray(nuf),
                        jnp.asarray(mask), jnp)
                    assert np.array_equal(np.asarray(bstj.ballooned_gb),
                                          bst2.ballooned_gb), \
                        "ballooning kernel diverged from numpy oracle"
                    pwj = boutj.power_adj_w
                stj, outj = emg.masked_step(
                    self.cfg, jax.tree.map(jnp.asarray, self.st),
                    jnp.asarray(rho_lv), pwj,
                    jnp.asarray(mask), jnp.asarray(ts), jnp)
            for a, b in zip(st2, stj):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    "serve emergency kernel diverged from numpy oracle"
        self.st = st2
        if bst2 is not None:
            self.bst = bst2
            self.balloon_events += int(np.asarray(bout.inflated).sum())
            self.balloon_reclaimed_gb += float(
                np.asarray(bout.reclaimed_gb).sum())
        self.alarms += int(out.alarm.sum())
        # no chassis past the alarm window may exceed its budget when
        # the cut was achievable within the floors (the RAPL-leftover
        # rows are physically pinned at the all-core frequency floor)
        achievable = out.alarm & (out.leftover_w <= 1e-6)
        assert (np.asarray(out.power_after_w)[achievable]
                <= self.cfg.chassis_budget_w + 1e-6).all(), \
            "chassis exceeded its budget past the alarm window"
        self._mitigate(u, state, vm_live, mem_chassis, gb_cap)

    def _mitigate(self, u: float, state, vm_live: dict,
                  mem_chassis: np.ndarray = None,
                  gb_cap: np.ndarray = None) -> None:
        emg, mit = self.emg, self.mit
        due = np.asarray(emg.mitigation_due(self.cfg, self.st, np))
        if not due.any() or not vm_live:
            return
        tokens = np.fromiter(vm_live.keys(), np.int64, len(vm_live))
        tokens.sort()                       # deterministic registry order
        rows = [vm_live[int(k)] for k in tokens]
        live = mit.LiveVMs(
            server=np.array([r[0] for r in rows], np.int32),
            cores=np.array([r[1] for r in rows], np.float64),
            p95_eff=np.array([r[2] for r in rows], np.float64),
            is_uf=np.array([r[3] for r in rows], bool),
            token=tokens,
            mem_gb=np.array([r[4] for r in rows], np.float64))
        with self.span("migrate"):
            plan = mit.plan_migrations(
                self.cfg, live, self.chassis_of, state.free_cores,
                self._rho_lv(state), u, due,
                mem_chassis=mem_chassis, gb_cap=gb_cap)
            # paired depart/arrive application; pairs touch disjoint
            # VMs, so plan order == any merged event order (the
            # pipeline path routes the same pairs through the ingest
            # merge)
            for m in range(len(plan)):
                cores = float(plan.cores[m])
                p95, uf = float(plan.p95_eff[m]), bool(plan.is_uf[m])
                mem = float(plan.mem_gb[m])
                src, dst = int(plan.src_server[m]), int(plan.dst_server[m])
                state.remove(src, cores, p95, uf)
                state.place(dst, cores, p95, uf)
                if mem_chassis is not None:
                    mem_chassis[self.chassis_of[src]] -= mem
                    mem_chassis[self.chassis_of[dst]] += mem
                vm_live[int(plan.token[m])] = (dst, cores, p95, uf, mem)
        self.migrations += len(plan)
        self.st = emg.reset_dwell(self.st, due, np)


class _AdaptiveSim:
    """Adaptive-ratio controller driven inside `simulate`
    (DESIGN.md §15, docs/adaptive.md).

    Holds one fleet-wide `serve.adaptive.AdaptiveState` (f64) and
    steps it at every deployment event from the same synthetic power
    samples the emergency plane reads: the committed per-criticality
    aggregates scaled by the deterministic diurnal utilization sample
    (`sim.telemetry.diurnal_util`) through `serve.adaptive.
    offered_power`. The resulting ratio scales the serve path's
    admission ceiling (and, sharded, the global token allowance)
    before the *next* placement scan — closed loop, one scan behind,
    exactly like the pipeline's eager cap-window stepping.

    The numpy execution is the oracle; with `use_jax` every scan ALSO
    runs the compiled jnp twin in x64 and asserts it bit-identical —
    the same acceptance invariant `_EmergencySim` enforces."""

    def __init__(self, cfg, n_chassis: int, chassis_of: np.ndarray,
                 use_jax: bool):
        from repro.serve import adaptive
        self.adp = adaptive
        self.cfg = cfg
        self.n_chassis = n_chassis
        self.chassis_of = chassis_of
        self.use_jax = use_jax
        self.st = adaptive.init_adaptive(cfg, n_chassis, xp=np,
                                         dtype=np.float64)
        self.span = lambda name: contextlib.nullcontext()

    def _rho_lv(self, state) -> np.ndarray:
        c = self.n_chassis
        return np.stack(
            [np.bincount(self.chassis_of, weights=state.gamma_nuf,
                         minlength=c),
             np.bincount(self.chassis_of, weights=state.gamma_uf,
                         minlength=c)], axis=-1)

    @property
    def ratio(self) -> float:
        """Current fleet oversubscription ratio (starts at 1.0)."""
        return float(self.st.ratio)

    @property
    def ratchets(self) -> int:
        """Up-steps taken so far."""
        return int(self.st.ratchets)

    @property
    def backoffs(self) -> int:
        """Down-steps taken so far."""
        return int(self.st.backoffs)

    def scan(self, t_h: float, state) -> None:
        """One controller scan at simulation time `t_h` (hours)."""
        adp = self.adp
        u = float(tel.diurnal_util(t_h))
        rho_lv = self._rho_lv(state)
        power = np.asarray(adp.offered_power(self.cfg, rho_lv, u, np))
        mask = np.ones(self.n_chassis, bool)
        st2, out = adp.adaptive_step(self.cfg, self.st, rho_lv, power,
                                     mask, np)
        if self.use_jax:
            import jax
            import jax.numpy as jnp
            with jax.experimental.enable_x64():
                stj, _ = adp.adaptive_step(
                    self.cfg, jax.tree.map(jnp.asarray, self.st),
                    jnp.asarray(rho_lv), jnp.asarray(power),
                    jnp.asarray(mask), jnp)
            for a, b in zip(st2, stj):
                assert np.array_equal(np.asarray(a), np.asarray(b)), \
                    "adaptive controller kernel diverged from numpy " \
                    "oracle"
        self.st = st2


def evaluate_power_dynamics(vm_live: dict, chassis_of: np.ndarray,
                            n_chassis: int, budget_w: float,
                            blades_per_chassis: int = BLADES_PER_CHASSIS,
                            cores_per_blade: int = CORES_PER_BLADE,
                            sample_chassis: int = 8,
                            duration_s: float = 60.0, seed: int = 0,
                            backend: str = "jax") -> PowerEval:
    """Run the fleet engine on the placements the scheduler produced.

    Picks the `sample_chassis` most-allocated chassis, packs each one's
    live VMs into padded fleet layouts (UF VMs' offered load = their
    effective P95), and simulates the per-VM capping stack on all of
    them in one vmapped call. Different chassis have different VM
    placements — the layout arrays are the batch axis.
    """
    per_server = defaultdict(list)
    alloc = np.zeros(n_chassis)
    for (srv, cores, p95e, ufp, *_mem) in vm_live.values():
        per_server[srv].append(VMSpec(int(cores), bool(ufp),
                                      load=float(p95e)))
        alloc[chassis_of[srv]] += cores
    picked = np.argsort(-alloc)[:sample_chassis]
    picked = picked[alloc[picked] > 0]

    def chassis_specs(c):
        servers = np.nonzero(chassis_of == c)[0]
        return [ServerSpec(vms=per_server.get(int(s), []),
                           n_cores=cores_per_blade) for s in servers]

    all_specs = [chassis_specs(c) for c in picked]
    pad_uf = max(1, max(sum(v.is_uf for s in sp for v in s.vms)
                        for sp in all_specs))
    pad_nuf = max(1, max(sum(not v.is_uf for s in sp for v in s.vms)
                         for sp in all_specs))
    layouts = [build_layout(sp, pad_uf_to=pad_uf, pad_nuf_to=pad_nuf,
                            pad_cores_to=cores_per_blade)
               for sp in all_specs]
    n_steps = int(duration_s / 0.2)
    traces = np.stack([build_uf_traces(lo, n_steps, seed + i)
                       for i, lo in enumerate(layouts)])
    la = stack_layouts(layouts)
    res = run_fleet_layouts(
        la, np.stack([lo.uf_valid for lo in layouts]),
        np.stack([lo.nuf_valid for lo in layouts]),
        np.stack([lo.nuf_cores for lo in layouts]),
        np.full(len(layouts), budget_w), "per_vm", traces,
        backend=backend)
    return PowerEval(chassis_ids=picked,
                     uf_p95_latency=res.uf_p95_latency,
                     nuf_slowdown=res.nuf_slowdown,
                     rapl_engaged_frac=res.rapl_engaged_frac,
                     alert_frac=res.alert_frac,
                     power_max_w=res.power_w.max(-1))


def _sample_vm(rng):
    cores = int(rng.choice(tel.CORE_SIZES, p=tel.CORE_PROBS))
    life_h = tel._sample_bucket(rng, tel.LIFETIME_BUCKETS,
                                tel.LIFETIME_PROBS)
    return cores, life_h


def _sample_deployment_size(rng):
    return int(tel._sample_bucket(rng, tel.DEPLOY_SIZE_BUCKETS,
                                  tel.DEPLOY_SIZE_PROBS))


#: Serve-backend micro-batch pad (max deployment size is 60 — Table I).
SERVE_GROUP_PAD = 64


def simulate(policy: SchedulerPolicy, channel: PredictionChannel,
             spec: SimSpec | None = None, *,
             trace: list | None = None,
             obs=None,
             days=_UNSET, seed=_UNSET,
             deployments_per_hour=_UNSET,
             target_uf_core_ratio=_UNSET,
             sample_every_h=_UNSET,
             power_eval_budget_w=_UNSET,
             power_eval_chassis=_UNSET,
             power_eval_duration_s=_UNSET,
             power_eval_backend=_UNSET,
             backend=_UNSET,
             admission_budget_w=_UNSET,
             serve_shards=_UNSET,
             n_ingest_hosts=_UNSET,
             cluster_budget_w=_UNSET,
             emergency_cfg=_UNSET,
             adaptive_cfg=_UNSET,
             prefill_core_ratio=_UNSET) -> SimMetrics:
    """Run the 30-day simulation. Table I parameters throughout:
    UF:NUF core ratio 4:6, UF P95 ~ 65 % (bucket 3), NUF ~ 44 %
    (bucket 2).

    ``spec``, a `SimSpec`, is the front door: every run parameter
    lives on it (``SimSpec(serve=ServeBackendSpec(...),
    power=PowerEvalSpec(...), emergency=..., adaptive=...,
    ballooning=...)``). The flat keyword arguments of the scalar-watt
    era are still accepted — mapped onto the same spec fields by a
    thin adapter, decision-identically — but warn
    `DeprecationWarning`; ``trace`` and ``obs`` are live attachments,
    not run parameters, and stay real keywords. Every VM carries
    ``GB_PER_CORE`` GB per vcore (deterministic, so the rng streams —
    and every scalar-era decision — are untouched); the committed GB
    ledger feeds the serve path's per-resource admission, the
    ballooning rung's headroom, and the migration planner's
    destination fit.

    serve.backend:
      'event' — the per-arrival numpy path (`SchedulerPolicy.choose`),
                the decision oracle;
      'serve' — each deployment group is placed by one call to the
                serving pipeline's batched scorer
                (`repro.serve.placement.place_batch`, padded to
                SERVE_GROUP_PAD), exercising the online path against
                the same arrival stream. `serve.admission_budget`
                adds the serve path's per-chassis (watts, cores, GB)
                admission ceilings (rejections count as failures; a
                watt-only vector reproduces the scalar-era decisions
                bit for bit). Every serve-sharded scan additionally
                asserts per-resource token conservation: the pool
                delta each finite axis reports must equal the summed
                demand of the VMs it admitted;
      'serve-sharded' —
                each group runs the sharded consistent-placement
                protocol (`repro.serve.sharding`, docs/sharding.md)
                over `serve.shards` state partitions. With 1 shard it
                is decision-identical to 'serve' (asserted in tests);
                with N it bounds the objective regret of concurrent
                placement while never exceeding `serve.cluster_budget`
                (the global per-resource budget the per-shard token
                pools enforce — tracked net of departures across the
                run). Arrivals reach the protocol through the
                cross-host ingest merge (`repro.serve.ingest`,
                docs/ingest.md): the group is dealt round-robin over
                `serve.ingest_hosts` per-host queues with strictly
                increasing stamps and timestamp-merged back, so the
                merged order — and every placement decision — is
                identical for any host count (1 host == today's
                single-queue path, asserted in tests).
    `prefill_core_ratio` warm-starts the cluster before the event loop:
    VMs are sampled and placed by the event-path rule (identically for
    every backend — the stream draws from the same rng prefix) until
    that fraction of the fleet's cores is committed, with normal
    lifetimes feeding the departure heap. Short runs can then exercise
    occupancy regimes — like a 2x-oversubscribed fleet near its alarm
    threshold — that an empty 720-server cluster would need weeks of
    simulated arrivals to reach.

    `spec.emergency`, a `serve.emergency.EmergencyConfig`, turns on
    the online power-emergency plane (DESIGN.md §12,
    docs/emergency.md): every deployment event also scans all chassis
    — committed aggregates scaled by the deterministic diurnal
    utilization sample (`sim.telemetry.diurnal_util`) become power
    samples, alarms apportion cuts lowest-criticality-first,
    per-criticality throttled-seconds accrue into the metrics, and
    chassis whose critical level stays capped past the dwell
    threshold get their cheapest critical VMs migrated to headroom
    chassis (`serve.mitigation` — GB-fit-checked when the admission
    budget carries a GB axis). The scan asserts that no alarmed
    chassis with an achievable cut exceeds its budget, and under the
    serve backends additionally asserts the compiled jnp kernel
    bit-identical to the numpy oracle on every scan.

    `spec.ballooning`, a `serve.ballooning.BallooningConfig`, arms
    the middle mitigation rung (cap -> balloon -> migrate; DESIGN.md
    §16, docs/resources.md): on every alarmed scan the watt deficit
    the NUF frequency floor cannot absorb is served by ballooning NUF
    memory out (`serve.ballooning.balloon_step`, the committed-GB
    ledger bounding the reclaim) *before* the capping step consumes
    the sample — fewer critical throttled-seconds and fewer
    migrations at the same watt budget, counted into the metrics.
    Requires `spec.emergency`; the jnp twin is asserted bit-identical
    on every scan like the other planes.

    `spec.adaptive`, a `serve.adaptive.AdaptiveConfig`, turns on the
    closed-loop adaptive oversubscription controller (DESIGN.md §15,
    docs/adaptive.md) and requires a serve backend — it modulates the
    serve path's admission ceiling, which the event oracle does not
    read. Every deployment event also steps the controller from the
    same diurnal power samples; the resulting ratio scales the
    admission budget's per-chassis watt ceiling (and, sharded, the
    cluster watt allowance, never revoking committed tokens) for the
    *next* placement scan. Under the serve backends every controller
    scan asserts the compiled jnp twin bit-identical to the numpy
    oracle, like the emergency plane.

    `trace`, if given, collects the chosen server (or failure code)
    per placement attempt — the decision-equivalence probe.

    `obs`, a `repro.obs.Observability`, turns on the fleet
    observability plane (DESIGN.md §14): placement and emergency
    stages run under spans, the sharded backend counts its compiled
    round dispatches into the registry, and the final `SimMetrics`
    is exported through `repro.obs.record_sim_metrics` so sim runs
    snapshot under the same schema as live serve runs. Decisions are
    bit-identical with `obs` on or off (asserted in tests)."""
    spec = _spec_from_legacy(spec, dict(
        days=days, seed=seed,
        deployments_per_hour=deployments_per_hour,
        target_uf_core_ratio=target_uf_core_ratio,
        sample_every_h=sample_every_h,
        prefill_core_ratio=prefill_core_ratio,
        power_eval_budget_w=power_eval_budget_w,
        power_eval_chassis=power_eval_chassis,
        power_eval_duration_s=power_eval_duration_s,
        power_eval_backend=power_eval_backend,
        backend=backend, admission_budget_w=admission_budget_w,
        serve_shards=serve_shards, n_ingest_hosts=n_ingest_hosts,
        cluster_budget_w=cluster_budget_w,
        emergency_cfg=emergency_cfg, adaptive_cfg=adaptive_cfg))
    sv = spec.serve
    backend_name = sv.backend
    if spec.adaptive is not None and backend_name == "event":
        # the controller modulates the serve admission ceiling; the
        # event oracle has no such ceiling, so silently accepting the
        # knob would report a ratio that never bound anything
        raise ValueError("SimSpec.adaptive requires a serve backend")
    if sv.ingest_hosts != 1 and backend_name != "serve-sharded":
        # only the sharded backend routes groups through the ingest
        # merge; silently ignoring the knob would make an invariance
        # assertion on another backend a vacuous pass
        raise ValueError(
            f"ingest_hosts={sv.ingest_hosts} requires "
            f"backend='serve-sharded', got {backend_name!r}")
    if sv.diurnal_ratchet and backend_name == "event":
        raise ValueError(
            "diurnal_ratchet conditions the serve admission ceilings; "
            "it requires a serve backend")
    if backend_name in ("serve", "serve-sharded"):
        import jax
        import jax.numpy as jnp
        from repro.serve.admission import resource_caps_from_budget
        from repro.serve.ingest import kway_merge
        from repro.serve.placement import device_state, place_batch
        from repro.serve.sharding import (place_group_sharded,
                                          resource_pool_from_budget,
                                          shard_state)
    span = obs.span if obs is not None else \
        (lambda name: contextlib.nullcontext())
    rng = np.random.default_rng(spec.seed)
    n_servers = RACKS * CHASSIS_PER_RACK * BLADES_PER_CHASSIS
    chassis_of = np.arange(n_servers) // BLADES_PER_CHASSIS
    state = ClusterState(
        n_servers=n_servers, cores_per_server=CORES_PER_BLADE,
        chassis_of_server=chassis_of,
        n_chassis=n_servers // BLADES_PER_CHASSIS)
    # committed-GB ledgers (total and NUF slice per chassis) — the
    # joint admission / ballooning / migration planes' memory view
    mem_chassis = np.zeros(state.n_chassis)
    mem_nuf_chassis = np.zeros(state.n_chassis)

    if backend_name in ("serve", "serve-sharded"):
        serve_res_cap = resource_caps_from_budget(
            sv.admission_budget or ResourceVector(),
            BLADES_PER_CHASSIS, state.n_chassis)
        serve_pool_total = resource_pool_from_budget(
            sv.cluster_budget or ResourceVector(), n_servers)
        pool_finite = np.isfinite(serve_pool_total)
        gb_cap_col = serve_res_cap[:, 2].astype(np.float64)
        gb_cap = gb_cap_col if np.isfinite(gb_cap_col).any() else None
    else:
        gb_cap = None
    emer = None
    if spec.emergency is not None:
        emer = _EmergencySim(spec.emergency, state.n_chassis,
                             chassis_of,
                             use_jax=backend_name != "event",
                             bcfg=spec.ballooning)
        if obs is not None:
            emer.span = obs.span
    adp = None
    if spec.adaptive is not None:
        adp = _AdaptiveSim(spec.adaptive, state.n_chassis, chassis_of,
                           use_jax=True)
        if obs is not None:
            adp.span = obs.span
    departures: list = []        # heap of (time, vm_token)
    # token -> (server, cores, p95eff, uf_pred, mem_gb)
    vm_live: dict = {}
    token = 0
    placements = failures = 0
    # measured predicted-vs-realized scoring (DESIGN.md §17): every
    # channel.predict is scored against the ground truth it was
    # sampled from — consumes no randomness and feeds nothing back
    # into placement, so the decision stream is untouched
    from repro.core.features import p95_bucket as _p95_bucket
    crit_cm = np.zeros((2, 2), np.int64)
    p95_cm = np.zeros((4, 4), np.int64)
    quality = None if obs is None else obs.quality

    def _score(true_uf, true_p95, uf_pred, p95_pred):
        tb = int(_p95_bucket(true_p95 * 100.0))
        pb = int(_p95_bucket(p95_pred * 100.0))
        crit_cm[int(true_uf), int(uf_pred)] += 1
        p95_cm[tb, pb] += 1
        if quality is not None:
            quality.record(int(true_uf), tb, int(uf_pred), pb)
    # warm start (identical for every backend: one rng prefix, the
    # event-path placement rule). A snapshot of a running fleet is
    # length-biased — long-lived VMs dominate the standing population —
    # so prefill lifetimes sample the duration-weighted buckets with a
    # uniform residual, keeping the occupancy roughly stationary
    # instead of draining at the short-life rate.
    target_cores = spec.prefill_core_ratio * n_servers * CORES_PER_BLADE
    mids = np.array([(lo + hi) / 2 for lo, hi in tel.LIFETIME_BUCKETS])
    standing_probs = tel.LIFETIME_PROBS * mids
    standing_probs = standing_probs / standing_probs.sum()
    filled = 0.0
    while filled < target_cores:
        cores = int(rng.choice(tel.CORE_SIZES, p=tel.CORE_PROBS))
        life_h = rng.random() * tel._sample_bucket(
            rng, tel.LIFETIME_BUCKETS, standing_probs)
        true_uf = rng.random() < spec.target_uf_core_ratio
        true_p95 = float(np.clip(
            rng.normal(0.65 if true_uf else 0.44, 0.12), 0.05, 1.0))
        uf_pred, p95_pred = channel.predict(rng, true_uf, true_p95)
        _score(true_uf, true_p95, uf_pred, p95_pred)
        p95_eff = policy.effective_p95(p95_pred)
        srv = policy.choose(state, cores, uf_pred)
        if srv is None:
            break
        mem = cores * GB_PER_CORE
        state.place(srv, cores, p95_eff, uf_pred)
        mem_chassis[chassis_of[srv]] += mem
        if not uf_pred:
            mem_nuf_chassis[chassis_of[srv]] += mem
        vm_live[token] = (srv, cores, p95_eff, uf_pred, mem)
        heapq.heappush(departures, (life_h, token))
        token += 1
        filled += cores
    t = 0.0
    next_sample = 0.0
    empty_samples, chassis_stds, server_stds = [], [], []
    horizon = spec.days * 24.0

    while t < horizon:
        t += rng.exponential(1.0 / spec.deployments_per_hour)
        # departures first
        while departures and departures[0][0] <= t:
            _, tok = heapq.heappop(departures)
            srv, cores, p95e, ufp, mem = vm_live.pop(tok)
            state.remove(srv, cores, p95e, ufp)
            mem_chassis[chassis_of[srv]] -= mem
            if not ufp:
                mem_nuf_chassis[chassis_of[srv]] -= mem
        while next_sample <= t and next_sample < horizon:
            busy = state.free_cores < CORES_PER_BLADE
            empty_samples.append(1.0 - busy.mean())
            chassis_stds.append(float(np.std(state.score_chassis())))
            server_stds.append(float(np.std(state.score_server(True))))
            next_sample += spec.sample_every_h
        if t >= horizon:
            break
        if emer is not None:
            # windowed/SLO feeds (DESIGN.md §17) read plane state
            # before/after the scan and hand the *deltas* to the
            # watermark-clock pillars — never the emergency_* registry
            # counters, which the end-of-run `record_sim_metrics`
            # export owns
            feeds = obs is not None and (obs.windows is not None
                                         or obs.slo is not None)
            if feeds:
                pre_alarms = emer.alarms
                pre_thr = np.asarray(
                    emer.emg.throttled_by_level(emer.st), np.float64)
            with span("emergency"):
                emer.scan(t, state, vm_live, mem_nuf=mem_nuf_chassis,
                          mem_chassis=mem_chassis, gb_cap=gb_cap)
            if feeds:
                t_s = t * 3600.0
                d_alarms = emer.alarms - pre_alarms
                d_thr = np.asarray(
                    emer.emg.throttled_by_level(emer.st),
                    np.float64) - pre_thr
                if obs.windows is not None:
                    if d_alarms:
                        obs.windows.observe(t_s, "alarms",
                                            n=int(d_alarms))
                    if d_thr[1] > 0:
                        obs.windows.observe(t_s, "uf_throttled_s",
                                            float(d_thr[1]))
                    obs.windows.advance(t_s)
                if obs.slo is not None:
                    obs.slo.ingest(t_s, "emergency_alarms_total",
                                   float(d_alarms))
                    for lvl, d in zip(("nuf", "uf"), d_thr):
                        obs.slo.ingest(
                            t_s, "emergency_throttled_seconds_total",
                            float(d), level=lvl)
                    obs.slo.evaluate(t_s)
        if adp is not None:
            with span("adaptive"):
                adp.scan(t, state)
        # sample the whole deployment group first (placement consumes
        # no randomness, so both backends see the same stream), then
        # place per-VM (event) or via one batched scan (serve)
        group = []
        for _ in range(_sample_deployment_size(rng)):
            cores, life_h = _sample_vm(rng)
            true_uf = rng.random() < spec.target_uf_core_ratio
            true_p95 = float(np.clip(
                rng.normal(0.65 if true_uf else 0.44, 0.12), 0.05, 1.0))
            uf_pred, p95_pred = channel.predict(rng, true_uf, true_p95)
            _score(true_uf, true_p95, uf_pred, p95_pred)
            group.append((cores, life_h, uf_pred,
                          policy.effective_p95(p95_pred)))
        if backend_name in ("serve", "serve-sharded"):
            n = len(group)
            assert n <= SERVE_GROUP_PAD, \
                "deployment group exceeds SERVE_GROUP_PAD"
            if backend_name == "serve-sharded":
                # cross-host ingest: deal the group round-robin over
                # per-host queues with strictly increasing stamps and
                # timestamp-merge it back (the serve.ingest merge).
                # Unique stamps make the merged order the arrival
                # order for ANY host count — 1 host is exactly the
                # single-queue path, asserted in tests.
                host_of = np.arange(n) % sv.ingest_hosts
                stamps = t + np.arange(1, n + 1) * 1e-7
                rows = [np.flatnonzero(host_of == h)
                        for h in range(sv.ingest_hosts)]
                mh, mi = kway_merge([stamps[r] for r in rows])
                order = np.array([rows[h][i]
                                  for h, i in zip(mh, mi)], np.int64)
            else:
                order = np.arange(n, dtype=np.int64)
            pad = np.zeros(SERVE_GROUP_PAD, np.float64)
            cores_a, uf_a, p95_a = pad.copy(), pad.copy(), pad.copy()
            for k, j in enumerate(order):
                cores, _, ufp, p95e = group[j]
                cores_a[k], uf_a[k], p95_a[k] = cores, ufp, p95e
            mem_a = cores_a * GB_PER_CORE
            valid = np.arange(SERVE_GROUP_PAD) < n
            # trace/run the scan in x64: bit-equivalent to the f64 host
            # rule, so 'serve' reproduces 'event' placements exactly
            # (the f32 serving path's divergence is bounded in
            # DESIGN.md §9)
            # the controller's ratio (stepped just above, one scan
            # behind by construction) widens or shrinks the watt
            # ceilings for THIS group's scan; the diurnal ratchet does
            # the same to the cores/GB axes from the trough sample
            # (watts never ratchet — the breaker limit is physical).
            # The watt multiply stays in f32 like the scalar era, so a
            # watt-only budget reproduces those decisions bit for bit.
            ratio = 1.0 if adp is None else adp.ratio
            rrat = trough_ratios(float(tel.diurnal_util(t))) \
                if sv.diurnal_ratchet else np.ones(N_RESOURCES)
            cap_mult = np.asarray([ratio, rrat[1], rrat[2]], np.float32)
            with jax.experimental.enable_x64(), span("place"):
                if backend_name == "serve":
                    if obs is not None:
                        obs.registry.counter(
                            "serve_dispatch_total",
                            help="compiled kernel dispatches, "
                            "by call site", kind="place_batch").inc()
                    _, srvs = place_batch(
                        device_state(state, jnp.float64,
                                     mem_gb=mem_chassis,
                                     mem_nuf=mem_nuf_chassis), cores_a,
                        uf_a.astype(bool), p95_a, valid,
                        serve_res_cap * cap_mult,
                        policy, state.cores_per_server, mem_gb=mem_a)
                    chosen = [int(s) for s in np.asarray(srvs)[:n]]
                else:
                    # the token pool is the global allowance net of
                    # everything currently committed — per resource
                    # axis — so the budget invariant holds across the
                    # whole run, not just within one group; the
                    # adaptive ratio retargets the watt allowance but
                    # never the committed side (`serve.adaptive.
                    # retarget_pool` semantics)
                    committed_vec = np.array([
                        float(state.rho_peak.sum()),
                        n_servers * float(CORES_PER_BLADE)
                        - float(state.free_cores.sum()),
                        float(mem_chassis.sum())])
                    pool_mult = np.array([ratio, rrat[1], rrat[2]])
                    pool = None if not pool_finite.any() else np.where(
                        pool_finite,
                        np.maximum(serve_pool_total * pool_mult
                                   - committed_vec, 0.0), np.inf)
                    sharded = shard_state(
                        device_state(state, jnp.float64,
                                     mem_gb=mem_chassis,
                                     mem_nuf=mem_nuf_chassis),
                        sv.shards, rho_cap=serve_res_cap * cap_mult,
                        pool_total=pool)
                    _, srvs, info = place_group_sharded(
                        sharded, cores_a, uf_a.astype(bool), p95_a,
                        valid, policy, state.cores_per_server,
                        mem_gb=mem_a,
                        registry=None if obs is None else obs.registry)
                    # per-resource token conservation, asserted on
                    # every scan: the pool delta each finite axis
                    # reports must equal the summed demand of the VMs
                    # it admitted (nothing minted, nothing leaked)
                    if pool is not None:
                        adm = (np.asarray(srvs) >= 0) & valid
                        admitted_vec = np.array([
                            float((p95_a * cores_a)[adm].sum()),
                            float(cores_a[adm].sum()),
                            float(mem_a[adm].sum())])
                        drawn = np.asarray(info["tokens_drawn_vec"])
                        assert np.allclose(
                            drawn[pool_finite],
                            admitted_vec[pool_finite],
                            rtol=1e-9, atol=1e-6), \
                            "per-resource token conservation violated: " \
                            f"drawn={drawn} admitted={admitted_vec}"
                    chosen = [None] * n        # un-permute the merge
                    for k, j in enumerate(order):
                        chosen[j] = int(srvs[k])
        else:
            chosen = None
        for i, (cores, life_h, uf_pred, p95_eff) in enumerate(group):
            srv = chosen[i] if chosen is not None else \
                policy.choose(state, cores, uf_pred)
            placements += 1
            if trace is not None:
                trace.append(-1 if srv is None else int(srv))
            if srv is None or srv < 0:
                failures += 1
                continue
            mem = cores * GB_PER_CORE
            state.place(srv, cores, p95_eff, uf_pred)
            mem_chassis[chassis_of[srv]] += mem
            if not uf_pred:
                mem_nuf_chassis[chassis_of[srv]] += mem
            vm_live[token] = (srv, cores, p95_eff, uf_pred, mem)
            heapq.heappush(departures, (t + life_h, token))
            token += 1

    power = None
    if spec.power is not None and vm_live:
        power = evaluate_power_dynamics(
            vm_live, chassis_of, state.n_chassis, spec.power.budget_w,
            sample_chassis=spec.power.chassis,
            duration_s=spec.power.duration_s, seed=spec.seed,
            backend=spec.power.backend)
    throttled = np.zeros(2)
    if emer is not None:
        from repro.serve.emergency import throttled_by_level
        throttled = throttled_by_level(emer.st)
    metrics = SimMetrics(
        failure_rate=failures / max(placements, 1),
        empty_server_ratio=float(np.mean(empty_samples)),
        chassis_score_std=float(np.mean(chassis_stds)),
        server_score_std=float(np.mean(server_stds)),
        placements=placements, failures=failures, power=power,
        throttled_s=np.asarray(throttled, np.float64),
        alarms=0 if emer is None else emer.alarms,
        migrations=0 if emer is None else emer.migrations,
        balloon_events=0 if emer is None else emer.balloon_events,
        balloon_reclaimed_gb=0.0 if emer is None
        else emer.balloon_reclaimed_gb,
        ballooned_gb=0.0 if emer is None or emer.bst is None
        else float(np.asarray(emer.bst.ballooned_gb).sum()),
        adaptive_ratio=1.0 if adp is None else adp.ratio,
        adaptive_ratchets=0 if adp is None else adp.ratchets,
        adaptive_backoffs=0 if adp is None else adp.backoffs,
        crit_confusion=crit_cm, p95_confusion=p95_cm)
    if obs is not None:
        from repro.obs import record_sim_metrics
        record_sim_metrics(obs.registry, metrics)
    return metrics


def fig7_sweep(alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0), days: float = 30.0,
               seed: int = 0, deployments_per_hour: float = 8.0) -> dict:
    """Fig 7: NoRule baseline + {ml, oracle, crit_only} x alpha sweep."""
    def run(pol, mode):
        return simulate(pol, PredictionChannel(mode), SimSpec(
            days=days, seed=seed,
            deployments_per_hour=deployments_per_hour))
    out = {"NoRule": run(SchedulerPolicy(use_power_rule=False), "none")}
    for mode in ("ml", "oracle", "crit_only"):
        for a in alphas:
            pol = SchedulerPolicy(
                alpha=a,
                use_utilization_predictions=(mode != "crit_only"))
            out[f"{mode}:alpha={a}"] = run(pol, mode)
    return out
