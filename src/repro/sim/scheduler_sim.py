"""Cluster VM-scheduler simulation (paper §IV-E, Fig. 7).

Event-driven 30-day simulation of a 20-rack x 3-chassis x 12-blade
cluster. Like Azure's simulator, it runs the *actual* placement code
(`repro.core.placement`) for every arrival; our only extension is the
simulated prediction channel (the paper's only extension was simulating
calls to the ML system).

Reported metrics (paper's four):
  * deployment failure rate,
  * average empty-server ratio,
  * std-dev across chassis of the chassis score 1 - rho_peak/rho_max,
  * std-dev across servers of the server score .5(1+(gNUF-gUF)/N).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core.placement import ClusterState, SchedulerPolicy
from repro.sim import telemetry as tel

CORES_PER_BLADE = 40            # Table I: 2 x 20 cores
BLADES_PER_CHASSIS = 12
CHASSIS_PER_RACK = 3
RACKS = 20


@dataclass(frozen=True)
class PredictionChannel:
    """Simulated ML-system responses (Table III operating point).

    mode:
      'oracle'    — perfect workload type and P95 bucket;
      'ml'        — criticality flipped w.p. its measured error, P95
                    bucket resampled w.p. its measured error; low-
                    confidence queries fall back to conservative values
                    (UF, bucket 4), as the real scheduler does;
      'crit_only' — criticality as 'ml', utilization assumed 100 %
                    (Fig 7 orange bars);
      'none'      — no predictions (NoRule baseline ignores them).
    """
    mode: str = "ml"
    crit_recall_uf: float = 0.99     # P(pred UF | true UF)   — Table III
    crit_recall_nuf: float = 0.69    # P(pred NUF | true NUF)
    p95_accuracy: float = 0.84
    p95_high_conf: float = 0.73

    def predict(self, rng, true_uf: bool, true_p95: float):
        if self.mode == "oracle":
            return true_uf, true_p95
        if true_uf:
            uf = rng.random() < self.crit_recall_uf
        else:
            uf = not (rng.random() < self.crit_recall_nuf)
        if self.mode == "crit_only":
            return uf, 1.0
        if rng.random() > self.p95_high_conf:
            return uf, 1.0                       # low confidence -> 100 %
        if rng.random() < self.p95_accuracy:
            p95 = true_p95
        else:
            p95 = float(np.clip(true_p95 + rng.choice([-0.25, 0.25]),
                                0.125, 0.875))
        return uf, p95


@dataclass
class SimMetrics:
    failure_rate: float
    empty_server_ratio: float
    chassis_score_std: float
    server_score_std: float
    placements: int
    failures: int


def _sample_vm(rng):
    cores = int(rng.choice(tel.CORE_SIZES, p=tel.CORE_PROBS))
    life_h = tel._sample_bucket(rng, tel.LIFETIME_BUCKETS,
                                tel.LIFETIME_PROBS)
    return cores, life_h


def _sample_deployment_size(rng):
    return int(tel._sample_bucket(rng, tel.DEPLOY_SIZE_BUCKETS,
                                  tel.DEPLOY_SIZE_PROBS))


def simulate(policy: SchedulerPolicy, channel: PredictionChannel,
             days: float = 30.0, seed: int = 0,
             deployments_per_hour: float = 8.0,
             target_uf_core_ratio: float = 0.40,
             sample_every_h: float = 2.0) -> SimMetrics:
    """Run the 30-day simulation. Table I parameters throughout:
    UF:NUF core ratio 4:6, UF P95 ~ 65 % (bucket 3), NUF ~ 44 %
    (bucket 2)."""
    rng = np.random.default_rng(seed)
    n_servers = RACKS * CHASSIS_PER_RACK * BLADES_PER_CHASSIS
    chassis_of = np.arange(n_servers) // BLADES_PER_CHASSIS
    state = ClusterState(
        n_servers=n_servers, cores_per_server=CORES_PER_BLADE,
        chassis_of_server=chassis_of,
        n_chassis=n_servers // BLADES_PER_CHASSIS)

    departures: list = []        # heap of (time, vm_token)
    vm_live: dict = {}           # token -> (server, cores, p95eff, uf_pred)
    token = 0
    placements = failures = 0
    t = 0.0
    next_sample = 0.0
    empty_samples, chassis_stds, server_stds = [], [], []
    horizon = days * 24.0

    while t < horizon:
        t += rng.exponential(1.0 / deployments_per_hour)
        # departures first
        while departures and departures[0][0] <= t:
            _, tok = heapq.heappop(departures)
            srv, cores, p95e, ufp = vm_live.pop(tok)
            state.remove(srv, cores, p95e, ufp)
        while next_sample <= t and next_sample < horizon:
            busy = state.free_cores < CORES_PER_BLADE
            empty_samples.append(1.0 - busy.mean())
            chassis_stds.append(float(np.std(state.score_chassis())))
            server_stds.append(float(np.std(state.score_server(True))))
            next_sample += sample_every_h
        if t >= horizon:
            break
        for _ in range(_sample_deployment_size(rng)):
            cores, life_h = _sample_vm(rng)
            true_uf = rng.random() < target_uf_core_ratio
            true_p95 = float(np.clip(
                rng.normal(0.65 if true_uf else 0.44, 0.12), 0.05, 1.0))
            uf_pred, p95_pred = channel.predict(rng, true_uf, true_p95)
            p95_eff = policy.effective_p95(p95_pred)
            srv = policy.choose(state, cores, uf_pred)
            placements += 1
            if srv is None:
                failures += 1
                continue
            state.place(srv, cores, p95_eff, uf_pred)
            vm_live[token] = (srv, cores, p95_eff, uf_pred)
            heapq.heappush(departures, (t + life_h, token))
            token += 1

    return SimMetrics(
        failure_rate=failures / max(placements, 1),
        empty_server_ratio=float(np.mean(empty_samples)),
        chassis_score_std=float(np.mean(chassis_stds)),
        server_score_std=float(np.mean(server_stds)),
        placements=placements, failures=failures)


def fig7_sweep(alphas=(0.0, 0.2, 0.4, 0.6, 0.8, 1.0), days: float = 30.0,
               seed: int = 0, deployments_per_hour: float = 8.0) -> dict:
    """Fig 7: NoRule baseline + {ml, oracle, crit_only} x alpha sweep."""
    out = {"NoRule": simulate(
        SchedulerPolicy(use_power_rule=False), PredictionChannel("none"),
        days=days, seed=seed, deployments_per_hour=deployments_per_hour)}
    for mode in ("ml", "oracle", "crit_only"):
        for a in alphas:
            pol = SchedulerPolicy(
                alpha=a,
                use_utilization_predictions=(mode != "crit_only"))
            out[f"{mode}:alpha={a}"] = simulate(
                pol, PredictionChannel(mode), days=days, seed=seed,
                deployments_per_hour=deployments_per_hour)
    return out
