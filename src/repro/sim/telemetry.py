"""Synthetic VM workload population with ground truth.

Azure's April-2019 VM workload and its 840 manually-labeled series are
private; this generator is the documented substitution (DESIGN.md §7).
It reproduces the *structure* the paper describes:

  * user-facing diurnal workloads with (paper §III-B issues 1-2) noise,
    interruptions, growth/decay trends, and day-to-day peak variation;
  * machine-generated workloads with 1h/4h/6h/8h/12h periods (issue 3 —
    all divide 24h, which fools FFT/ACF);
  * non-user-facing batch/dev-test workloads (constant, random-walk,
    bursty);
  * subscription-level correlation: VMs arrive from subscriptions whose
    historical mix is predictive (this is what the paper's ML models
    exploit: their top features are subscription aggregates).

Everything is numpy (host-side data plane); the algorithms under test are
jnp/Pallas.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

SLOTS_PER_DAY = 48
DAYS = 5
T = SLOTS_PER_DAY * DAYS

VM_TYPES = ("web", "db", "api", "batch", "dev", "ci", "agent")
UF_CLASS_NAMES = ("uf_diurnal", "uf_noisy", "machine_periodic", "batch_flat",
                  "batch_random", "dev_burst")
UF_TRUTH = {"uf_diurnal": True, "uf_noisy": True, "machine_periodic": False,
            "batch_flat": False, "batch_random": False, "dev_burst": False}


@dataclass
class VMRecord:
    """One VM with its ground truth and arrival-time metadata."""
    vm_id: int
    subscription: int
    klass: str                 # generator class name (ground truth source)
    user_facing: bool          # ground-truth label
    cores: int
    memory_gb: int
    vm_type: str
    lifetime_hours: float
    avg_util: float            # realized average CPU utilization (0-100)
    p95_util: float            # realized P95 CPU utilization (0-100)
    series: np.ndarray         # (T,) 30-min average utilization


def _diurnal(rng, noisy: bool) -> np.ndarray:
    slots = np.arange(T)
    tod = (slots % SLOTS_PER_DAY) / SLOTS_PER_DAY
    phase = rng.uniform(0, 0.3)
    # business-hours hump + secondary evening bump
    base = (np.clip(np.sin((tod - 0.25 - phase) * 2 * np.pi), 0, None) ** 1.5
            + 0.35 * np.clip(np.sin((tod - 0.7 - phase) * 2 * np.pi), 0, None))
    peak = rng.uniform(35, 90)
    floor = rng.uniform(2, 15)
    # per-day peak magnitude variation (paper issue #2)
    day_scale = 1.0 + rng.uniform(-0.35, 0.35, DAYS).repeat(SLOTS_PER_DAY)
    # multiplicative growth/decay trend (paper issue #2)
    trend = np.exp(rng.uniform(-0.12, 0.18) * slots / SLOTS_PER_DAY)
    x = floor + peak * base * day_scale * trend
    noise_sd = rng.uniform(1.0, 3.0) if not noisy else rng.uniform(5.0, 10.0)
    x = x + rng.normal(0, noise_sd, T)
    if noisy:
        # day-to-day phase jitter (+-30 min): users shift their day;
        # lag-based autocorrelation decorrelates, 30-min median
        # templates barely move (paper issues #1/#2 combined)
        for d in range(DAYS):
            shift = int(rng.integers(-1, 2))
            seg = x[d * SLOTS_PER_DAY:(d + 1) * SLOTS_PER_DAY]
            x[d * SLOTS_PER_DAY:(d + 1) * SLOTS_PER_DAY] = \
                np.roll(seg, shift)
        # interruption: up to a day of constant or random load (issue #1)
        w = int(rng.integers(SLOTS_PER_DAY // 2, SLOTS_PER_DAY))
        s = int(rng.integers(0, T - w))
        if rng.random() < 0.5:
            x[s:s + w] = rng.uniform(5, 60)
        else:
            x[s:s + w] = rng.uniform(5, 60, w)
    return x


def _machine_periodic(rng) -> np.ndarray:
    # Mostly divisors of 8h (hourly crons, 4h syncs, ...). 6h/12h periods
    # do NOT divide 8h, so Compare8 conservatively labels them user-facing
    # (the paper accepts this direction of error); keep them a small tail.
    period_hours = rng.choice([1, 2, 4, 8, 6, 12],
                              p=[0.3, 0.25, 0.25, 0.1, 0.05, 0.05])
    period = int(period_hours * 2)           # slots
    slots = np.arange(T)
    duty = rng.uniform(0.1, 0.5)
    spike = ((slots % period) < max(1, int(duty * period))).astype(float)
    lo = rng.uniform(2, 10)
    hi = rng.uniform(40, 95)
    x = lo + (hi - lo) * spike + rng.normal(0, 1.5, T)
    return x


def _batch_flat(rng) -> np.ndarray:
    level = rng.uniform(20, 95)
    return level + rng.normal(0, rng.uniform(0.5, 4.0), T)


def _batch_random(rng) -> np.ndarray:
    # random-walk load (data-dependent batch stages)
    steps = rng.normal(0, 6.0, T)
    x = 40 + np.cumsum(steps)
    x = 40 + (x - 40) * 0.9 ** (np.arange(T) / 24)  # mean-revert slowly
    return x + rng.normal(0, 2.0, T)


def _dev_burst(rng) -> np.ndarray:
    # idle with sporadic bursts (development / testing)
    x = rng.uniform(1, 6) + rng.normal(0, 1.0, T)
    n_bursts = rng.integers(3, 12)
    for _ in range(n_bursts):
        s = rng.integers(0, T - 4)
        w = rng.integers(2, 8)
        x[s:s + w] += rng.uniform(30, 90)
    return x


_GEN = {"uf_diurnal": lambda rng: _diurnal(rng, False),
        "uf_noisy": lambda rng: _diurnal(rng, True),
        "machine_periodic": _machine_periodic,
        "batch_flat": _batch_flat,
        "batch_random": _batch_random,
        "dev_burst": _dev_burst}

#: Paper Table I distributions.
CORE_SIZES = np.array([1, 2, 4, 8, 16, 24, 32])
CORE_PROBS = np.array([0.33, 0.27, 0.21, 0.10, 0.05, 0.03, 0.01])
LIFETIME_BUCKETS = [(1, 1), (2, 2), (3, 5), (6, 10), (10, 25), (26, 720),
                    (721, 2160)]
LIFETIME_PROBS = np.array([0.52, 0.05, 0.10, 0.09, 0.07, 0.08, 0.09])
DEPLOY_SIZE_BUCKETS = [(1, 1), (2, 2), (3, 5), (6, 10), (11, 15), (16, 25),
                       (26, 60)]
DEPLOY_SIZE_PROBS = np.array([0.39, 0.14, 0.16, 0.09, 0.08, 0.05, 0.09])

_UF_TYPES = ("web", "db", "api")
_NUF_TYPES = ("batch", "dev", "ci", "agent")


def _sample_bucket(rng, buckets, probs):
    i = rng.choice(len(buckets), p=probs)
    lo, hi = buckets[i]
    return float(rng.integers(lo, hi + 1))


@dataclass
class Population:
    vms: list = field(default_factory=list)

    @property
    def series(self) -> np.ndarray:
        return np.stack([v.series for v in self.vms])

    @property
    def labels(self) -> np.ndarray:
        return np.array([v.user_facing for v in self.vms])

    def classes(self) -> np.ndarray:
        return np.array([v.klass for v in self.vms])


def generate_population(n_vms: int, seed: int = 0,
                        uf_fraction: float = 0.45,
                        n_subscriptions: int | None = None) -> Population:
    """Generate a labeled VM population.

    Subscriptions are sampled with a per-subscription UF propensity so
    subscription aggregates carry signal (paper §IV-B: the top model
    features are subscription-level percentages).
    """
    rng = np.random.default_rng(seed)
    if n_subscriptions is None:
        n_subscriptions = max(8, n_vms // 24)
    # Strongly bimodal: most subscriptions are near-single-purpose (all
    # interactive services or all batch), which is why the paper's top
    # criticality feature — subscription %-user-facing — is so predictive.
    sub_propensity = rng.beta(0.35, 0.35, n_subscriptions)
    sub_propensity = uf_fraction * sub_propensity / sub_propensity.mean()
    sub_propensity = np.clip(sub_propensity, 0.02, 0.98)
    # Per-subscription utilization scale: subscriptions run consistently
    # hot or cold fleets. This is the signal behind the paper's top P95
    # features (subscription avg-of-P95 / avg-of-avg utilizations), and
    # makes bucket-1/bucket-4 the most popular buckets as in Table III.
    sub_util_scale = 0.10 + 1.15 * rng.beta(0.40, 0.40, n_subscriptions)

    pop = Population()
    for vm_id in range(n_vms):
        sub = int(rng.integers(0, n_subscriptions))
        is_uf = rng.random() < sub_propensity[sub]
        if is_uf:
            klass = rng.choice(["uf_diurnal", "uf_noisy"], p=[0.7, 0.3])
            vm_type = rng.choice(_UF_TYPES)
        else:
            klass = rng.choice(
                ["machine_periodic", "batch_flat", "batch_random",
                 "dev_burst"], p=[0.3, 0.25, 0.25, 0.2])
            vm_type = rng.choice(_NUF_TYPES)
        amp = sub_util_scale[sub] * rng.uniform(0.88, 1.12)
        series = np.clip(_GEN[klass](rng) * amp, 0.0, 100.0)
        cores = int(rng.choice(CORE_SIZES, p=CORE_PROBS))
        pop.vms.append(VMRecord(
            vm_id=vm_id, subscription=sub, klass=klass,
            user_facing=UF_TRUTH[klass], cores=cores,
            memory_gb=int(cores * rng.choice([2, 4, 8])),
            vm_type=vm_type,
            lifetime_hours=_sample_bucket(rng, LIFETIME_BUCKETS,
                                          LIFETIME_PROBS),
            avg_util=float(series.mean()),
            p95_util=float(np.percentile(series, 95)),
            series=series.astype(np.float32)))
    return pop


# --- streaming arrivals (serve-pipeline ingest format) --------------------

VM_TYPE_IDX = {t: i for i, t in enumerate(VM_TYPES)}


@dataclass
class ArrivalBatch:
    """Struct-of-arrays view of a slice of arriving VMs — the wire
    format of the online serving pipeline (`repro.serve`). Ground-truth
    columns ride along for evaluation; the pipeline never reads them."""
    subscription: np.ndarray        # (B,) int32
    cores: np.ndarray               # (B,) float32
    memory_gb: np.ndarray           # (B,) float32
    vm_type_idx: np.ndarray         # (B,) int32
    user_facing: np.ndarray         # (B,) bool — ground truth
    p95_util: np.ndarray            # (B,) float32 (0-100) — ground truth
    lifetime_hours: np.ndarray      # (B,) float32 — ground truth

    def __len__(self) -> int:
        return len(self.subscription)


def arrival_batch(pop: Population, idx=None) -> ArrivalBatch:
    """Pack (a slice of) a population into one ArrivalBatch."""
    vms = pop.vms if idx is None else [pop.vms[i] for i in np.atleast_1d(idx)]
    return ArrivalBatch(
        subscription=np.array([v.subscription for v in vms], np.int32),
        cores=np.array([v.cores for v in vms], np.float32),
        memory_gb=np.array([v.memory_gb for v in vms], np.float32),
        vm_type_idx=np.array([VM_TYPE_IDX[v.vm_type] for v in vms],
                             np.int32),
        user_facing=np.array([v.user_facing for v in vms], bool),
        p95_util=np.array([v.p95_util for v in vms], np.float32),
        lifetime_hours=np.array([v.lifetime_hours for v in vms],
                                np.float32))


def stream_arrivals(pop: Population, batch_size: int,
                    arrival_rate_per_s: float | None = None,
                    seed: int = 0):
    """Yield `(t_arrive_s, ArrivalBatch)` micro-batches in VM order —
    the arrival stream the serve pipeline ingests. When
    `arrival_rate_per_s` is set, batch timestamps follow a Poisson
    process (the last arrival's time stamps the batch); otherwise
    timestamps advance by one per batch."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for start in range(0, len(pop.vms), batch_size):
        idx = np.arange(start, min(start + batch_size, len(pop.vms)))
        if arrival_rate_per_s is not None:
            t += float(rng.exponential(1.0 / arrival_rate_per_s,
                                       len(idx)).sum())
        else:
            t += 1.0
        yield t, arrival_batch(pop, idx)


def arrival_stamps(n: int, arrival_rate_per_s: float | None = None,
                   seed: int = 0) -> np.ndarray:
    """(n,) strictly increasing per-arrival timestamps: a Poisson
    process at `arrival_rate_per_s`, or the unit clock (1, 2, ...)
    when None. Strict monotonicity (required by the per-host ingest
    queues, `repro.serve.ingest`) is enforced even if float cumsum
    ties a pair of Poisson gaps."""
    if n == 0:
        return np.empty(0, np.float64)
    if arrival_rate_per_s is None:
        return np.arange(1, n + 1, dtype=np.float64)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / arrival_rate_per_s, n)
    return np.cumsum(np.maximum(gaps, 1e-9))


def split_streams(pop: Population, n_hosts: int, batch_size: int,
                  arrival_rate_per_s: float | None = None,
                  seed: int = 0) -> list:
    """Deal a population into per-host stamped arrival streams — the
    trace format the cross-host ingest subsystem consumes
    (`repro.serve.ingest`, docs/ingest.md).

    One shared strictly-increasing clock stamps VM *i* with
    `arrival_stamps(...)[i]`; VM *i* lands on host ``i % n_hosts``;
    each host's stream is chunked into `batch_size` micro-batches.
    Returns a list over hosts of ``[(stamps, ArrivalBatch), ...]``
    chunk lists. Because the stamps are globally unique, the merged
    order is invariant to which host a VM was dealt to."""
    stamps = arrival_stamps(len(pop.vms), arrival_rate_per_s, seed)
    streams = []
    for h in range(n_hosts):
        rows = np.arange(h, len(pop.vms), n_hosts)
        chunks = []
        for lo in range(0, len(rows), batch_size):
            idx = rows[lo:lo + batch_size]
            chunks.append((stamps[idx], arrival_batch(pop, idx)))
        streams.append(chunks)
    return streams


def merge_streams(streams: list) -> tuple:
    """Reference merge oracle for per-host stamped streams (the
    `split_streams` format): returns ``(stamps, host_of, batch)`` in
    global ``(t, host, seq)`` order. Implemented as one lexsort of the
    concatenated keys — the streaming k-way merge the serve ingest
    runs (`repro.serve.ingest.kway_merge`) must agree with it exactly
    (asserted in tests), while never materializing this global
    sort."""
    ts, hosts, seqs, parts = [], [], [], []
    for h, chunks in enumerate(streams):
        seq = 0
        for stamps, batch in chunks:
            ts.append(np.asarray(stamps, np.float64))
            hosts.append(np.full(len(batch), h, np.int32))
            seqs.append(seq + np.arange(len(batch)))
            parts.append(batch)
            seq += len(batch)
    if not ts:
        return (np.empty(0, np.float64), np.empty(0, np.int32),
                arrival_batch(Population()))
    t = np.concatenate(ts)
    host = np.concatenate(hosts)
    seq = np.concatenate(seqs)
    order = np.lexsort((seq, host, t))
    merged = ArrivalBatch(
        *(np.concatenate([getattr(p, f) for p in parts])[order]
          for f in ArrivalBatch.__dataclass_fields__))
    return t[order], host[order], merged


def diurnal_util(t_hours) -> np.ndarray:
    """Deterministic fleet-utilization sample at simulation time
    `t_hours` (hours; scalar or array) — the fraction of the committed
    P95 the fleet is actually drawing, driving the power-emergency
    scans of the scheduler simulation (`repro.sim.scheduler_sim`,
    ``emergency_cfg``).

    A business-hours diurnal hump with a harmonic ripple, clipped to
    [0.15, 0.95]: peaks push oversubscribed chassis past their alarm
    threshold once per simulated day, troughs let caps lift — and
    because it is a pure function of `t` (no rng), every backend and
    ingest-host count sees the identical emergency trace."""
    tod = (np.asarray(t_hours, np.float64) % 24.0) / 24.0
    x = 0.55 + 0.32 * np.sin((tod - 0.25) * 2 * np.pi) \
        + 0.08 * np.sin((tod - 0.10) * 4 * np.pi)
    return np.clip(x, 0.15, 0.95)


def generate_chassis_telemetry(n_chassis: int, n_days: int,
                               provisioned_w: float, seed: int = 0,
                               slots_per_day: int = 48) -> np.ndarray:
    """Historical chassis power draws for the oversubscription strategy
    (paper §IV-F used 1440 chassis over 3 months).

    Returns (n_chassis, n_days * slots_per_day) watts. Draws combine a
    diurnal fleet pattern, per-chassis offsets, noise, and rare correlated
    regional peaks — calibrated so the maximum draw sits ~6-7 % below the
    provisioned (nameplate) power, matching the headroom the paper's
    state-of-the-art row recovers.

    The tail is calibrated (see EXPERIMENTS.md §Table IV) to the shape the
    paper's results imply: P99 ~ 0.80, P99.9 ~ 0.853 and max ~ 0.91 of
    provisioned power — the quantiles at which the paper's scenario rows
    (6.2 % / 11.0 % / 12.1 % / 8.4 %) become self-consistent under the
    measured power/frequency curves.
    """
    rng = np.random.default_rng(seed)
    t = n_days * slots_per_day
    tod = (np.arange(t) % slots_per_day) / slots_per_day
    diurnal = 0.5 + 0.5 * np.clip(np.sin((tod - 0.25) * 2 * np.pi), 0, None)
    base = 0.56 + 0.155 * diurnal                               # of provisioned
    chassis_offset = rng.normal(0, 0.025, (n_chassis, 1))
    noise = rng.normal(0, 0.020, (n_chassis, t))
    draw = base[None, :] + chassis_offset + noise
    # per-chassis high-load episodes (~1.1 % of readings): tenant bursts
    # pushing the chassis into the 78-85 % band
    episode = rng.random((n_chassis, t)) < 0.0115
    draw = np.where(episode,
                    np.maximum(draw, rng.uniform(0.78, 0.853,
                                                 (n_chassis, t))),
                    draw)
    # rare correlated fleet events (~0.1 % of readings): most chassis
    # spike together into the 85-91 % band
    n_events = max(1, int(0.00175 * t))
    ev_slots = rng.choice(t, n_events, replace=False)
    for s in ev_slots:
        hit = rng.random(n_chassis) < 0.6
        draw[hit, s] = np.maximum(
            draw[hit, s], rng.uniform(0.848, 0.9105, int(hit.sum())))
    draw = np.clip(draw, 0.25, 0.9105)   # breakers never trip historically
    return (draw * provisioned_w).astype(np.float32)
