"""Minimal deterministic stand-in for `hypothesis`.

The tier-1 suite must run on a bare interpreter (jax + numpy + pytest
only). When the real hypothesis is unavailable, `conftest.py` installs
this module as `hypothesis` (and `hypothesis.strategies`,
`hypothesis.extra.numpy`) in `sys.modules`. Property tests then run a
fixed number of deterministic examples drawn from a seeded generator —
weaker than real hypothesis (no shrinking, no edge-case bias), but the
properties still get exercised instead of the whole collection crashing.
"""
from __future__ import annotations


import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value,
                                                  max_value + 1)))


def floats(min_value=0.0, max_value=1.0, width=64, **_):
    def sample(rng):
        v = rng.uniform(min_value, max_value)
        return float(np.float32(v)) if width == 32 else float(v)
    return _Strategy(sample)


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def lists(elements, min_size=0, max_size=None, unique=False, **_):
    cap = min_size + 10 if max_size is None else max_size

    def sample(rng):
        n = int(rng.integers(min_size, cap + 1))
        out, seen, tries = [], set(), 0
        while len(out) < n and tries < 200 * (cap + 1):
            v = elements.sample(rng)
            tries += 1
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out
    return _Strategy(sample)


class _DrawFn:
    """The `draw` callable handed to @composite functions."""

    def __init__(self, rng):
        self._rng = rng

    def __call__(self, strategy):
        return strategy.sample(self._rng)


def composite(fn):
    """`@composite def s(draw, ...)` -> calling ``s(...)`` returns a
    strategy, like the real decorator (draw pulls from the shared
    seeded generator)."""
    def make(*args, **kw):
        return _Strategy(lambda rng: fn(_DrawFn(rng), *args, **kw))
    make.__name__ = fn.__name__
    make.__doc__ = fn.__doc__
    return make


def arrays(dtype, shape, elements=None, **_):
    if isinstance(shape, int):
        shape = (shape,)

    def sample(rng):
        if elements is None:
            return rng.random(shape).astype(dtype)
        n = int(np.prod(shape))
        flat = [elements.sample(rng) for _ in range(n)]
        return np.asarray(flat, dtype=dtype).reshape(shape)
    return _Strategy(sample)


class settings:
    _max_examples = DEFAULT_MAX_EXAMPLES
    _profiles: dict = {}

    def __init__(self, **kw):          # @settings(...) decorator form
        self._kw = kw

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, max_examples=DEFAULT_MAX_EXAMPLES,
                         **_):
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name):
        cls._max_examples = cls._profiles.get(name, DEFAULT_MAX_EXAMPLES)


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def given(*strategies, **kw_strategies):
    def decorate(fn):
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(settings._max_examples):
                args = [s.sample(rng) for s in strategies]
                kwargs = {k: s.sample(rng)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except _Unsatisfied:
                    continue
        # keep the test's name/module for pytest, but NOT __wrapped__
        # (pytest would introspect the original signature and look for
        # fixtures named like the strategy arguments)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return decorate


def install() -> None:
    """Register this stub as `hypothesis` in sys.modules."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.booleans = booleans
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    st_mod.composite = composite
    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.arrays = arrays
    hyp.strategies = st_mod
    extra.numpy = hnp
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp
