import os
import re

# tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS out of the way — except an explicit
# host-device-count request, which the sharded-serve smoke tests use to
# exercise the shard_map execution path on a multi-device CPU runtime.
_flags = os.environ.pop("XLA_FLAGS", "")
_keep = re.findall(r"--xla_force_host_platform_device_count=\d+",
                   _flags)
if _keep:
    os.environ["XLA_FLAGS"] = " ".join(_keep)

# The suite must collect and run on a bare interpreter (jax + numpy +
# pytest). If hypothesis is missing, install the deterministic stub so
# property tests still exercise a fixed sample instead of crashing
# collection. `pip install -e .[test]` brings in the real thing.
try:
    from hypothesis import settings
except ImportError:
    import _hypothesis_stub
    _hypothesis_stub.install()
    from hypothesis import settings

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")
