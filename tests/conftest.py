import os
import sys

# tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS out of the way.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")
