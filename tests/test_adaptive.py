"""Closed-loop adaptive oversubscription (`repro.serve.adaptive`) —
oracle parity, controller semantics, and pipeline/sim wiring.

The contract under test (docs/adaptive.md, DESIGN.md §15):

  * the branchless numpy scan is the oracle and the compiled jnp twin
    is bit-identical to it, scan for scan (f32 and x64-f64);
  * the controller ratchets up slowly on stable quorum, backs off
    fast on any hot chassis or a broken quorum, clamps to
    ``[ratio_min, ratio_max]``, and holds 1.0 with no history;
  * `retarget_pool` mints/retires only the free allowance — tokens
    committed to placed VMs are never revoked;
  * a `ServePipeline` with `PlaneBundle(adaptive=...)` scans eagerly
    per cap window, and the 1-shard `ShardedServePipeline` reproduces
    it ratio for ratio (both equal to a hand-stepped numpy oracle);
  * `SimSpec(adaptive=...)` requires a serve backend, and
    'serve' == 'serve-sharded' @ 1 shard trace-for-trace with the
    controller live.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import SchedulerPolicy
from repro.obs import AdaptiveRecord, Observability
from repro.serve import (REASON_NAMES, AdaptiveConfig, PlaneBundle,
                         ResourceVector, ServeConfig,
                         ServePipeline, ShardedServeConfig,
                         ShardedServePipeline, adaptive_step,
                         decision_reason, init_adaptive, offered_power,
                         retarget_pool)
from repro.sim.scheduler_sim import (PredictionChannel, ServeBackendSpec,
                                     SimSpec, simulate)

C = 6              # chassis in the kernel-level tests


def _cfg(**kw) -> AdaptiveConfig:
    kw.setdefault("window", 8)
    kw.setdefault("min_history", 3)
    return AdaptiveConfig(**kw)


def _scan_stream(cfg, utils, xp=np, dtype=np.float64):
    """Step a C-chassis controller through a (T, C) utilization
    stream (powers synthesized through `offered_power`, the sim's
    feed), returning the state and per-scan outputs."""
    rho_lv = xp.asarray(np.full((C, 2), 40.0, dtype))
    st = init_adaptive(cfg, C, xp=xp, dtype=dtype)
    outs = []
    for u in utils:
        pw = offered_power(cfg, rho_lv, xp.asarray(u, dtype), xp)
        st, out = adaptive_step(cfg, st, rho_lv, pw,
                                xp.ones(C, bool), xp)
        outs.append(out)
    return st, outs


# --- controller semantics -------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        AdaptiveConfig(window=1)
    with pytest.raises(ValueError):
        AdaptiveConfig(window=8, min_history=9)
    with pytest.raises(ValueError):
        AdaptiveConfig(spread_q_lo=0.9, spread_q_hi=0.1)
    with pytest.raises(ValueError):
        AdaptiveConfig(backoff_quorum=0.95, ratchet_quorum=0.9)


def test_zero_history_holds_ratio_one():
    """No samples, no oversubscription: an all-masked-out scan leaves
    the ratio at 1.0 and classifies as hold_no_history."""
    cfg = _cfg()
    st = init_adaptive(cfg, C)
    rho = np.full((C, 2), 40.0)
    st, out = adaptive_step(cfg, st, rho, np.full(C, 500.0),
                            np.zeros(C, bool), np)
    assert float(out.ratio) == 1.0
    assert int(out.n_known) == 0 and not bool(out.backoff)
    r = decision_reason(1.0, float(out.ratio), int(out.n_known),
                        bool(out.ratchet), bool(out.backoff),
                        bool(out.hot))
    assert REASON_NAMES[r] == "hold_no_history"


def test_min_history_gates_the_first_decision():
    """The ratio must not move before any window reaches min_history
    samples, however stable the early stream looks."""
    cfg = _cfg(min_history=4)
    st, outs = _scan_stream(cfg, [np.full(C, 0.4)] * 3)
    assert all(float(o.ratio) == 1.0 for o in outs)
    assert int(outs[-1].n_known) == 0


def test_steady_windows_ratchet_to_ceiling():
    """A flat, cool stream ratchets by step_up per scan once known,
    then clamps at ratio_max (ratchet_ceiling)."""
    cfg = _cfg(step_up=0.25, ratio_max=1.6)
    st, outs = _scan_stream(cfg, [np.full(C, 0.4)] * 8)
    ratios = [float(o.ratio) for o in outs]
    assert ratios[1] == 1.0                       # still gathering
    assert ratios[-1] == pytest.approx(1.6)       # pinned at max
    assert int(st.ratchets) >= 3
    last = outs[-1]
    r = decision_reason(1.6, float(last.ratio), int(last.n_known),
                        bool(last.ratchet), bool(last.backoff),
                        bool(last.hot))
    assert REASON_NAMES[r] == "ratchet_ceiling"


def test_hot_sample_backs_off_fast():
    """One hot chassis collapses the ratio by step_down (several
    up-steps at once) regardless of the stable quorum."""
    cfg = _cfg(step_up=0.05, step_down=0.25, ratio_max=3.0)
    utils = [np.full(C, 0.4)] * 6
    hot = np.full(C, 0.4)
    hot[2] = 0.95
    st, outs = _scan_stream(cfg, utils + [hot])
    before, after = float(outs[-2].ratio), float(outs[-1].ratio)
    assert bool(outs[-1].hot) and bool(outs[-1].backoff)
    assert after == pytest.approx(max(before - 0.25, 1.0))
    r = decision_reason(before, after, int(outs[-1].n_known),
                        bool(outs[-1].ratchet), bool(outs[-1].backoff),
                        bool(outs[-1].hot))
    assert REASON_NAMES[r] == "backoff_hot"


def test_oscillating_windows_pin_the_floor():
    """A thrashing stream (sign flip every delta) never ratchets: the
    flip-rate assesser keeps every window unstable and the ratio
    stays at ratio_min (backoff_floor once known)."""
    cfg = _cfg(flip_thresh=0.5)
    utils = [np.full(C, 0.3 + 0.2 * (k % 2)) for k in range(10)]
    st, outs = _scan_stream(cfg, utils)
    assert all(float(o.ratio) == 1.0 for o in outs)
    last = outs[-1]
    assert int(last.n_known) == C and bool(last.backoff)
    r = decision_reason(1.0, 1.0, int(last.n_known), bool(last.ratchet),
                        bool(last.backoff), bool(last.hot))
    assert REASON_NAMES[r] == "backoff_floor"
    assert int(st.backoffs) > 0


def test_masked_chassis_keep_their_windows():
    """A scan whose mask excludes a chassis must leave that chassis'
    window (count, samples) untouched while the rest advance."""
    cfg = _cfg()
    rho = np.full((C, 2), 40.0)
    st = init_adaptive(cfg, C)
    mask = np.ones(C, bool)
    mask[0] = False
    pw = np.asarray(offered_power(cfg, rho, 0.4, np))
    st, _ = adaptive_step(cfg, st, rho, pw, mask, np)
    assert int(st.count[0]) == 0
    assert (np.asarray(st.count)[1:] == 1).all()


def test_spread_assesser_rejects_wide_band():
    """Same mean, wide percentile spread -> unstable even with a low
    flip rate (a monotone ramp has zero flips)."""
    cfg = _cfg(spread_thresh=0.1, flip_thresh=1.0)
    ramp = [np.full(C, 0.12 * k) for k in range(8)]
    _, outs = _scan_stream(cfg, ramp)
    assert int(outs[-1].n_stable) == 0
    assert all(float(o.ratio) == 1.0 for o in outs)


# --- numpy <-> jnp bit-equality -------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_jnp_twin_bit_equal_to_numpy_oracle(dtype):
    """The compiled twin reproduces the numpy oracle bit for bit over
    a randomized stream — masked writes, percentile gathers, flip
    counts, and the fleet reduction included."""
    rng = np.random.default_rng(0)
    cfg = _cfg(ratio_max=3.0)
    rho = rng.uniform(5.0, 80.0, (C, 2)).astype(dtype)
    stn = init_adaptive(cfg, C, xp=np, dtype=dtype)
    ctx = jax.experimental.enable_x64() if dtype == np.float64 \
        else contextlib_null()
    with ctx:
        stj = jax.tree.map(jnp.asarray, stn)
        for _ in range(12):
            u = rng.uniform(0.0, 1.1, C).astype(dtype)
            mask = rng.random(C) < 0.7
            pw = np.asarray(offered_power(cfg, rho, u, np), dtype)
            stn, outn = adaptive_step(cfg, stn, rho, pw, mask, np)
            stj, outj = adaptive_step(cfg, stj, jnp.asarray(rho),
                                      jnp.asarray(pw),
                                      jnp.asarray(mask), jnp)
            for a, b in zip(stn, stj):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
            for a, b in zip(outn, outj):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))


def contextlib_null():
    import contextlib
    return contextlib.nullcontext()


# --- pool retargeting -----------------------------------------------------

def test_retarget_pool_mints_and_retires_only_free_tokens():
    cfg = _cfg()
    base, committed = 100.0, 40.0
    # ratchet: allowance grows -> free pool widens
    assert float(retarget_pool(cfg, base, 1.5, committed, np)) \
        == pytest.approx(110.0)
    # back-off below commitment: free pool drains to zero, committed
    # tokens stay out (never negative, never revoked)
    assert float(retarget_pool(cfg, base, 1.0, 120.0, np)) == 0.0


def test_retarget_pool_conserves_through_mint_retire_sequences():
    """Through any ratio walk, committed + free ==
    max(base * ratio, committed) — the §10 conservation invariant
    with the controller in the loop."""
    rng = np.random.default_rng(1)
    cfg = _cfg()
    base = np.array([80.0, 120.0, 60.0, 140.0])
    committed = np.zeros(4)
    for _ in range(50):
        ratio = float(rng.uniform(1.0, 3.0))
        free = np.asarray(retarget_pool(cfg, base, ratio, committed, np))
        np.testing.assert_allclose(
            committed + free, np.maximum(base * ratio, committed))
        # commit some of the free pool (placements), release some
        committed = committed + rng.uniform(0, 1, 4) * free
        committed = np.maximum(
            committed - rng.uniform(0, 10, 4), 0.0)


def test_decision_reason_covers_every_branch():
    cases = {
        "hold_no_history": (1.0, 1.0, 0, False, False, False),
        "hold_band": (1.2, 1.2, 5, False, False, False),
        "ratchet_quorum": (1.2, 1.25, 5, True, False, False),
        "ratchet_ceiling": (2.0, 2.0, 5, True, False, False),
        "backoff_hot": (1.5, 1.25, 5, False, True, True),
        "backoff_quorum": (1.5, 1.25, 5, False, True, False),
        "backoff_floor": (1.0, 1.0, 5, False, True, True),
    }
    for name, args in cases.items():
        assert REASON_NAMES[decision_reason(*args)] == name


# --- pipeline wiring ------------------------------------------------------

@pytest.fixture(scope="module")
def serve_world():
    from repro.core import features as F
    from repro.core.predictor import train_service
    from repro.sim.telemetry import generate_population
    pop = generate_population(400, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    return svc, hist, labels, arrivals


PIPE_KW = dict(n_servers=48, cores_per_server=40, blades_per_chassis=12)


def _cap_stream(pipe, n_scans=6, power=500.0):
    """Push `n_scans` full-fleet constant power sweeps (an empty
    cluster reads util 0 -> every window stabilizes -> ratchet)."""
    idx = np.arange(4)
    for k in range(n_scans):
        t0 = float(k + 1)
        pipe.cap_to(0, idx, np.full(4, power), t=t0 + (idx + 1) * 1e-7)
    pipe.flush()


def test_pipeline_ratio_ratchets_and_scales_rho_cap(serve_world):
    svc, hist, labels, _ = serve_world
    acfg = _cfg(ratio_max=2.0)
    obs = Observability.full()
    pipe = ServePipeline.from_history(
        svc, hist, labels,
        config=ServeConfig(batch_size=32,
                           planes=PlaneBundle(adaptive=acfg, obs=obs)),
        **PIPE_KW)
    base_cap = np.asarray(pipe.rho_cap).copy()
    _cap_stream(pipe)
    r = pipe.adaptive_ratio
    assert r > 1.0
    np.testing.assert_allclose(np.asarray(pipe.rho_cap), base_cap * r)
    # the decision trail and metrics recorded every scan
    assert obs.adaptive.total_recorded == 6
    snap = obs.registry.snapshot()
    assert snap["adaptive_ratio"][0]["value"] == pytest.approx(r)
    assert snap["adaptive_ratchet_total"][0]["value"] > 0
    rows = obs.adaptive.tail(6)
    assert any(AdaptiveRecord(row).reason_name.startswith("ratchet")
               for row in rows)


def test_cap_to_accepted_with_adaptive_only(serve_world):
    """cap_to must work with adaptive_cfg alone (no emergency plane) —
    and still raise with neither plane configured."""
    svc, hist, labels, _ = serve_world
    pipe = ServePipeline.from_history(
        svc, hist, labels,
        config=ServeConfig(batch_size=32,
                           planes=PlaneBundle(adaptive=_cfg())),
        **PIPE_KW)
    pipe.cap_to(0, [0], [500.0])
    pipe.flush()
    assert pipe.adaptive_state is not None
    bare = ServePipeline.from_history(
        svc, hist, labels, config=ServeConfig(batch_size=32), **PIPE_KW)
    with pytest.raises(ValueError):
        bare.cap_to(0, [0], [500.0])


def test_one_shard_sharded_matches_unsharded_and_numpy_oracle(
        serve_world):
    """1-shard sharded pipeline == unsharded pipeline == hand-stepped
    numpy oracle, ratio for ratio and window for window, on the same
    cap stream."""
    svc, hist, labels, _ = serve_world
    acfg = _cfg(ratio_max=2.0)
    base = ServePipeline.from_history(
        svc, hist, labels,
        config=ServeConfig(batch_size=32,
                           planes=PlaneBundle(adaptive=acfg)),
        **PIPE_KW)
    shp = ShardedServePipeline.from_history(
        svc, hist, labels,
        config=ShardedServeConfig(batch_size=32, n_shards=1,
                                  planes=PlaneBundle(adaptive=acfg)),
        **PIPE_KW)
    for pipe in (base, shp):
        _cap_stream(pipe)
    # numpy oracle on the same stream: empty cluster -> rho_lv = 0
    st = init_adaptive(acfg, 4, xp=np, dtype=np.float32)
    for _ in range(6):
        st, _ = adaptive_step(acfg, st, np.zeros((4, 2), np.float32),
                              np.full(4, 500.0, np.float32),
                              np.ones(4, bool), np)
    want = float(st.ratio)
    assert base.adaptive_ratio == pytest.approx(want)
    assert float(shp.adaptive_ratio[0]) == pytest.approx(want)
    a, b = base.adaptive_state, shp.adaptive_state
    for xa, xb, xn in zip(a, b, st):
        np.testing.assert_array_equal(np.asarray(xa),
                                      np.asarray(xb)[0])
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xn))


def test_sharded_backoff_drains_only_free_pool(serve_world):
    """With a finite cluster budget, a controller back-off retargets
    the free pool but never below zero and never touches committed
    rho (mint/retire is free-side only)."""
    from repro.sim.telemetry import arrival_batch, arrival_stamps
    svc, hist, labels, arrivals = serve_world
    acfg = _cfg(ratio_max=3.0)
    shp = ShardedServePipeline.from_history(
        svc, hist, labels,
        config=ShardedServeConfig(
            batch_size=32, n_shards=1,
            planes=PlaneBundle(
                adaptive=acfg,
                cluster_budget=ResourceVector(watts=40000.0))),
        **PIPE_KW)
    _cap_stream(shp)                          # ratchets: pool widens
    pool_up = float(np.asarray(shp.sharded.pool)[:, 0].sum())
    # commit real VMs so power samples read back as utilization...
    idx64 = np.arange(64)
    shp.submit_to(0, arrival_batch(arrivals, idx64),
                  t=50.0 + arrival_stamps(64))
    shp.flush()
    committed = np.asarray(shp.sharded.shards.rho_peak).sum()
    assert committed > 0
    # ...then run the fleet hot: back-off drains the free pool but
    # never below zero and never touches committed rho
    idx = np.arange(4)
    for k in range(8):
        shp.cap_to(0, idx, np.full(4, 6000.0),
                   t=200.0 + k + (idx + 1) * 1e-7)
    shp.flush()
    pool_down = float(np.asarray(shp.sharded.pool)[:, 0].sum())
    assert pool_down < pool_up
    assert pool_down >= 0.0
    np.testing.assert_array_equal(
        np.asarray(shp.sharded.shards.rho_peak).sum(), committed)


# --- sim wiring -----------------------------------------------------------

SIM_KW = dict(days=0.08, seed=3, deployments_per_hour=16.0,
              prefill_core_ratio=0.5)


def _sim_spec(acfg, backend="serve", shards=1):
    return SimSpec(serve=ServeBackendSpec(
        backend=backend, shards=shards,
        admission_budget=ResourceVector(watts=12 * 310.0 / 2)),
        adaptive=acfg, **SIM_KW)


def test_sim_adaptive_requires_serve_backend():
    with pytest.raises(ValueError, match="serve"):
        simulate(SchedulerPolicy(), PredictionChannel("ml"),
                 SimSpec(adaptive=_cfg(), **SIM_KW))


def test_sim_adaptive_ratchets_and_asserts_twin():
    """A short serve-backend run with the controller live: the ratio
    moves off 1.0, steps are counted, and every scan asserted the
    compiled twin bit-equal in-sim (the assert is inside the scan)."""
    m = simulate(SchedulerPolicy(), PredictionChannel("ml"),
                 _sim_spec(_cfg(ratio_max=3.0)))
    assert m.adaptive_ratio > 1.0
    assert m.adaptive_ratchets > 0
    assert m.placements > 0


def test_sim_one_shard_sharded_identical_with_adaptive():
    """'serve' == 'serve-sharded' @ 1 shard, trace for trace, with
    the adaptive controller scaling admission on both paths."""
    acfg = _cfg(ratio_max=3.0)
    tr_s, tr_sh = [], []
    ms = simulate(SchedulerPolicy(), PredictionChannel("ml"),
                  _sim_spec(acfg), trace=tr_s)
    msh = simulate(SchedulerPolicy(), PredictionChannel("ml"),
                   _sim_spec(acfg, backend="serve-sharded"),
                   trace=tr_sh)
    assert tr_s == tr_sh
    assert ms.adaptive_ratio == msh.adaptive_ratio
    assert ms.adaptive_ratchets == msh.adaptive_ratchets
    assert ms.failure_rate == msh.failure_rate


def test_sim_metrics_export_through_obs_registry():
    obs = Observability.full()
    m = simulate(SchedulerPolicy(), PredictionChannel("ml"),
                 _sim_spec(_cfg(ratio_max=2.0)), obs=obs)
    snap = obs.registry.snapshot()
    assert snap["adaptive_ratio"][0]["value"] \
        == pytest.approx(m.adaptive_ratio)
    assert snap["adaptive_ratchet_total"][0]["value"] \
        == m.adaptive_ratchets
