"""The consolidated SimSpec/PlaneBundle front door (DESIGN.md §16).

Three families:

  * spec construction: defaults, validation errors, and frozen-ness of
    `SimSpec` / `ServeBackendSpec` / `PowerEvalSpec` and the
    `ResourceVector` budget currency;
  * legacy `simulate` kwargs: the adapter warns `DeprecationWarning`
    and is *decision-identical* — same trace, same metrics, field for
    field — on both the event and serve-sharded backends;
  * legacy pipeline constructor kwargs: folding
    ``chassis_budget_w``/``cluster_budget_w``/``emergency_cfg``/
    ``adaptive_cfg``/``obs`` into `PlaneBundle` warns and reproduces
    every placement decision bit for bit.

Tier-1 runs ``-W error::DeprecationWarning`` (pyproject), so these
``pytest.warns`` blocks are the only sanctioned road to the adapters.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.placement import SchedulerPolicy
from repro.core.resources import RESOURCES, ResourceVector
from repro.serve import (EmergencyConfig, PlaneBundle, ServeConfig,
                         ServePipeline, ShardedServeConfig,
                         ShardedServePipeline)
from repro.sim.scheduler_sim import (PowerEvalSpec, PredictionChannel,
                                     ServeBackendSpec, SimSpec,
                                     simulate)
from repro.sim.telemetry import arrival_batch

BUDGET_TIGHT = 1480.0


# --- spec construction and validation -------------------------------------


def test_simspec_defaults():
    spec = SimSpec()
    assert spec.days == 30.0
    assert spec.serve == ServeBackendSpec()
    assert spec.serve.backend == "event"
    assert spec.power is None
    assert spec.emergency is None and spec.ballooning is None


def test_simspec_is_frozen():
    spec = SimSpec()
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.days = 1.0
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.serve.shards = 2


def test_simspec_validation():
    with pytest.raises(ValueError, match="days"):
        SimSpec(days=0.0)
    with pytest.raises(ValueError, match="backend"):
        ServeBackendSpec(backend="gpu")
    with pytest.raises(ValueError):
        ServeBackendSpec(shards=0)
    with pytest.raises(ValueError):
        ServeBackendSpec(ingest_hosts=0)
    with pytest.raises(ValueError, match="budget_w"):
        PowerEvalSpec(budget_w=0.0)
    # the balloon rung sizes its reclaim off the emergency plane
    with pytest.raises(ValueError, match="emergency"):
        from repro.serve import BallooningConfig
        SimSpec(ballooning=BallooningConfig())


def test_resource_vector_roundtrip():
    rv = ResourceVector(watts=100.0, cores=8.0, gb=32.0)
    arr = rv.as_array()
    assert arr.shape == (len(RESOURCES),)
    np.testing.assert_array_equal(arr, [100.0, 8.0, 32.0])
    # None axes lift to +inf (vacuous ceilings)
    part = ResourceVector(watts=50.0).as_array()
    assert part[0] == 50.0 and np.isinf(part[1]) and np.isinf(part[2])
    assert ResourceVector(watts=50.0).power_only
    assert not rv.power_only


def test_spec_plus_legacy_kwargs_is_an_error():
    with pytest.raises(TypeError):
        simulate(SchedulerPolicy(), PredictionChannel(),
                 SimSpec(days=0.05), days=0.05)


def test_planebundle_ballooning_requires_emergency():
    from repro.serve import BallooningConfig
    with pytest.raises(ValueError, match="emergency"):
        ServePipeline.from_history(
            *_world()[:3], n_servers=24, cores_per_server=40,
            blades_per_chassis=12,
            config=ServeConfig(planes=PlaneBundle(
                ballooning=BallooningConfig())))


# --- simulate legacy-kwarg adapter parity ---------------------------------


def _metrics_equal(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
        else:
            assert va == vb, f.name


def test_legacy_kwargs_match_spec_event_backend():
    pol, ch = SchedulerPolicy(alpha=0.8), PredictionChannel("ml")
    cfg = EmergencyConfig.from_model(BUDGET_TIGHT, dwell_s=120.0)
    tr_new, tr_old = [], []
    m_new = simulate(pol, ch, SimSpec(days=0.08, seed=0,
                                      deployments_per_hour=16.0,
                                      prefill_core_ratio=0.6,
                                      emergency=cfg), trace=tr_new)
    with pytest.warns(DeprecationWarning, match="spec=SimSpec"):
        m_old = simulate(pol, ch, days=0.08, seed=0,
                         deployments_per_hour=16.0,
                         prefill_core_ratio=0.6, emergency_cfg=cfg,
                         trace=tr_old)
    assert tr_new == tr_old
    _metrics_equal(m_new, m_old)


def test_legacy_kwargs_match_spec_serve_sharded_backend():
    pol, ch = SchedulerPolicy(alpha=0.8), PredictionChannel("ml")
    budget = 2.0e6
    spec = SimSpec(days=0.08, seed=1, deployments_per_hour=16.0,
                   prefill_core_ratio=0.5,
                   serve=ServeBackendSpec(
                       backend="serve-sharded", shards=2,
                       cluster_budget=ResourceVector(watts=budget)))
    tr_new, tr_old = [], []
    m_new = simulate(pol, ch, spec, trace=tr_new)
    with pytest.warns(DeprecationWarning, match="spec=SimSpec"):
        m_old = simulate(pol, ch, days=0.08, seed=1,
                         deployments_per_hour=16.0,
                         prefill_core_ratio=0.5,
                         backend="serve-sharded", serve_shards=2,
                         cluster_budget_w=budget, trace=tr_old)
    assert tr_new == tr_old
    _metrics_equal(m_new, m_old)


# --- pipeline constructor adapter parity ----------------------------------


@pytest.fixture(scope="module", name="pipe_world")
def _pipe_world():
    return _world()


def _world():
    from repro.core import features as F
    from repro.core.predictor import train_service
    from repro.sim.telemetry import generate_population
    pop = generate_population(300, seed=0)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=8)
    return svc, hist, labels, arrivals


_KW = dict(n_servers=24, cores_per_server=40, blades_per_chassis=12)


def _drive(pipe, arrivals):
    out = pipe.cap_to(0, [0, 1], [2200.0, 2100.0],
                      t=np.array([1.0, 2.0]))
    out += pipe.submit_to(0, arrival_batch(arrivals, np.arange(64)),
                          t=np.arange(64, dtype=np.float64) + 10.0)
    tail = pipe.flush()
    if tail is not None:
        out.append(tail)
    return out


def test_pipeline_legacy_kwargs_decision_identical(pipe_world):
    svc, hist, labels, arrivals = pipe_world
    budget_w = 12 * 112.0 + 500.0
    ecfg = EmergencyConfig.from_model(BUDGET_TIGHT)
    new = ServePipeline.from_history(
        svc, hist, labels,
        config=ServeConfig(batch_size=32, planes=PlaneBundle(
            chassis_budget=ResourceVector(watts=budget_w),
            emergency=ecfg)), **_KW)
    with pytest.warns(DeprecationWarning, match="PlaneBundle"):
        old = ServePipeline.from_history(
            svc, hist, labels, config=ServeConfig(batch_size=32),
            chassis_budget_w=budget_w, emergency_cfg=ecfg, **_KW)
    np.testing.assert_array_equal(np.asarray(new.res_cap),
                                  np.asarray(old.res_cap))
    for a, b in zip(_drive(new, arrivals), _drive(old, arrivals)):
        np.testing.assert_array_equal(a.server, b.server)
        np.testing.assert_array_equal(a.workload_type, b.workload_type)
        np.testing.assert_array_equal(a.p95_eff, b.p95_eff)
    assert new.alarms == old.alarms


def test_sharded_pipeline_legacy_kwargs_decision_identical(pipe_world):
    svc, hist, labels, arrivals = pipe_world
    budget_w = 24 * 112.0 + 700.0
    new = ShardedServePipeline.from_history(
        svc, hist, labels,
        config=ShardedServeConfig(batch_size=32, n_shards=2,
                                  planes=PlaneBundle(
                                      cluster_budget=ResourceVector(
                                          watts=budget_w))), **_KW)
    with pytest.warns(DeprecationWarning, match="PlaneBundle"):
        old = ShardedServePipeline.from_history(
            svc, hist, labels,
            config=ShardedServeConfig(batch_size=32, n_shards=2),
            cluster_budget_w=budget_w, **_KW)
    b = arrival_batch(arrivals, np.arange(64))
    r_new, r_old = new.serve(b), old.serve(b)
    np.testing.assert_array_equal(r_new.server, r_old.server)
    np.testing.assert_array_equal(np.asarray(new.sharded.pool),
                                  np.asarray(old.sharded.pool))
