"""Pin the public API surface of `repro.serve` and `repro.sim`.

The spec front door (SimSpec / PlaneBundle / ResourceVector) is a
compatibility contract: downstream callers import these names, so a
rename or a dropped export is a breaking change that must show up in
review as an edit to *this file*, not as a silent diff in an
`__init__`.  Accidental additions are caught too — a new export is a
deliberate API decision, so it lands here alongside the code.
"""
import pytest

import repro.serve
import repro.sim

SERVE_API = [
    "ARRIVAL",
    "AdaptiveConfig",
    "AdaptiveOutputs",
    "AdaptiveState",
    "BalloonOutputs",
    "BalloonState",
    "BallooningConfig",
    "CAPPING",
    "CRIT_NUF",
    "CRIT_UF",
    "CapBatch",
    "DEPARTURE",
    "DepartureBatch",
    "DeviceClusterState",
    "EmergencyConfig",
    "EmergencyOutputs",
    "EmergencyState",
    "FAIL_CAPACITY",
    "FAIL_POWER",
    "FAIL_TOKENS",
    "HostQueue",
    "IngestMux",
    "LiveVMs",
    "MergedEvents",
    "MigrationPlan",
    "N_LEVELS",
    "PackedService",
    "PlaneBundle",
    "REASON_NAMES",
    "RESOURCES",
    "ResourceVector",
    "SHARD_AXIS",
    "ServeConfig",
    "ServePipeline",
    "ServeResult",
    "ServiceMeta",
    "ShardedServeConfig",
    "ShardedServePipeline",
    "ShardedState",
    "SubscriptionTable",
    "SweepCounters",
    "adaptive_step",
    "apply_adaptive_sharded",
    "apply_caps_ballooned_sharded",
    "apply_caps_sharded",
    "balloon_demand_w",
    "balloon_step",
    "bucket_to_p95_jnp",
    "chassis_rho_levels",
    "chassis_to_shard",
    "consume_departures",
    "decision_reason",
    "demand_vector",
    "device_put_sharded_state",
    "device_state",
    "emergency_step",
    "empty_arrivals",
    "empty_caps",
    "empty_departures",
    "empty_table",
    "featurize",
    "featurize_batch",
    "fresh_state",
    "headroom_w",
    "ingest_population",
    "init_adaptive",
    "init_adaptive_sharded",
    "init_ballooning",
    "init_ballooning_sharded",
    "init_emergency",
    "init_emergency_sharded",
    "kway_merge",
    "masked_step",
    "mitigation_due",
    "offered_power",
    "outcome_counters",
    "pack_service",
    "place_batch",
    "place_batch_caps",
    "place_batch_pooled",
    "place_group_sharded",
    "plan_migrations",
    "projected_chassis_power",
    "remove_batch",
    "remove_sharded",
    "reset_dwell",
    "resolve_kernel",
    "resource_caps_from_budget",
    "resource_pool_from_budget",
    "retarget_pool",
    "rho_cap_from_budget",
    "rho_pool_from_budget",
    "route_shard",
    "sampled_power",
    "scatter_samples",
    "score_chassis_batch",
    "score_server_batch",
    "served_query",
    "shard_mesh",
    "shard_state",
    "shard_table",
    "slice_soa",
    "split_caps",
    "split_departures",
    "table_from_history",
    "throttled_by_level",
    "total_ballooned_gb",
    "trough_ratios",
    "unshard_state",
    "update_table",
    "util_from_power",
]

SIM_API = [
    "GB_PER_CORE",
    "PowerEvalSpec",
    "PredictionChannel",
    "ServeBackendSpec",
    "SimMetrics",
    "SimSpec",
    "evaluate_power_dynamics",
    "fig7_sweep",
    "simulate",
]


@pytest.mark.parametrize(
    "mod, pinned",
    [(repro.serve, SERVE_API), (repro.sim, SIM_API)],
    ids=["repro.serve", "repro.sim"])
def test_all_matches_pin(mod, pinned):
    assert sorted(mod.__all__) == pinned
    assert len(mod.__all__) == len(set(mod.__all__)), "duplicate export"


@pytest.mark.parametrize(
    "mod", [repro.serve, repro.sim], ids=["repro.serve", "repro.sim"])
def test_every_export_resolves(mod):
    for name in mod.__all__:
        assert getattr(mod, name) is not None, name


def test_spec_front_door_is_exported():
    # the names every migration-table row in docs/resources.md points at
    for name in ("PlaneBundle", "ResourceVector"):
        assert name in repro.serve.__all__
    for name in ("SimSpec", "ServeBackendSpec", "PowerEvalSpec"):
        assert name in repro.sim.__all__
