"""The ballooning rung of the mitigation ladder (DESIGN.md §16).

  * `balloon_step` is xp-generic and branchless: the jitted jnp twin
    is bit-equal to the numpy oracle over randomized scenarios (x64);
  * the closed-form demand really is the fixed point it claims: a
    fully served demand drops the subsequent `emergency.masked_step`
    to a zero UF p-state and no RAPL engagement, while the same
    sample un-ballooned throttles the critical level;
  * state discipline: headroom caps the grab, cleared alarms deflate
    fully, unmasked chassis pass through bit-for-bit;
  * in-sim ladder effect: cap -> balloon -> migrate reports fewer
    critical throttled-seconds (and no more migrations) than
    cap -> migrate at identical watt budgets and alarm counts — with
    the sim asserting the jnp kernel against the numpy oracle on
    every scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import SchedulerPolicy
from repro.serve import (CRIT_NUF, CRIT_UF, BallooningConfig,
                         EmergencyConfig, balloon_demand_w,
                         balloon_step, init_ballooning, masked_step,
                         init_emergency, total_ballooned_gb)
from repro.sim.scheduler_sim import (PredictionChannel, SimSpec,
                                     simulate)

BUDGET_TIGHT = 1480.0
C = 4


def _cfg(**kw):
    return EmergencyConfig.from_model(BUDGET_TIGHT, **kw)


def _scenario(seed, n=C):
    """Randomized chassis loads: mixed NUF/UF commitments, standing
    balloons, hot and cool samples, partial masks."""
    rng = np.random.default_rng(seed)
    rho_lv = rng.uniform(10.0, 80.0, (n, 2))
    power = rng.uniform(900.0, 2600.0, n)
    mem_nuf = rng.uniform(0.0, 600.0, n)
    mask = rng.random(n) < 0.75
    standing = rng.uniform(0.0, 40.0, n) * (rng.random(n) < 0.5)
    return rho_lv, power, mem_nuf, mask, standing


@pytest.mark.parametrize("seed", range(6))
def test_jnp_twin_bit_equal_to_numpy_oracle(seed):
    """Eager jnp in x64 is bit-equal to numpy — this is the exact
    assertion the serve-backend sim re-runs on every scan.  The
    *jitted* twin is additionally held to one-ulp agreement (XLA's
    CPU backend FMA-contracts the closed form, so strict bit equality
    is not a property jit can promise)."""
    cfg, bcfg = _cfg(), BallooningConfig()
    rho_lv, power, mem_nuf, mask, standing = _scenario(seed)
    st_np = init_ballooning(C, xp=np, dtype=np.float64) \
        ._replace(ballooned_gb=standing.copy())
    st2_np, out_np = balloon_step(bcfg, cfg, st_np, rho_lv, power,
                                  mem_nuf, mask, np)
    with jax.experimental.enable_x64():
        st_j = init_ballooning(C, xp=jnp, dtype=jnp.float64) \
            ._replace(ballooned_gb=jnp.asarray(standing))
        args = (st_j, jnp.asarray(rho_lv), jnp.asarray(power),
                jnp.asarray(mem_nuf), jnp.asarray(mask))
        fn = lambda s, r, p, m, k: balloon_step(bcfg, cfg, s, r, p,
                                                m, k, jnp)
        st2_j, out_j = fn(*args)          # eager: the sim's oracle check
        st2_jit, out_jit = jax.jit(fn)(*args)
    np.testing.assert_array_equal(np.asarray(st2_j.ballooned_gb),
                                  st2_np.ballooned_gb)
    for name in out_np._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(out_j, name)), getattr(out_np, name),
            err_msg=name)
    np.testing.assert_allclose(np.asarray(st2_jit.ballooned_gb),
                               st2_np.ballooned_gb, rtol=1e-15)
    for name in out_np._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(out_jit, name)),
            np.asarray(getattr(out_np, name), dtype=np.float64),
            rtol=1e-15, err_msg=name)


def test_served_demand_zeroes_critical_throttle():
    """With unbounded memory headroom the closed form is exact: the
    DRAM-adjusted sample leaves the critical level at full frequency
    and RAPL disengaged, where the raw sample throttles it."""
    cfg, bcfg = _cfg(), BallooningConfig()
    rho_lv = np.tile([60.0, 40.0], (C, 1))
    power = np.full(C, 2400.0)
    mask = np.ones(C, bool)
    alarm, demand = balloon_demand_w(cfg, rho_lv, power)
    assert alarm.all() and (demand > 0).all()     # the rung is needed
    # un-ballooned: the cut overflows the NUF floor onto UF
    st_e, _ = masked_step(cfg, init_emergency(C, dtype=np.float64),
                          rho_lv, power, mask, 1.0, np)
    assert (st_e.pstate[:, CRIT_UF] > 0).all() or st_e.rapl.any()
    # ballooned with ample headroom: demand fully served
    _, bout = balloon_step(bcfg, cfg, init_ballooning(C),
                           rho_lv, power, np.full(C, 1e6), mask, np)
    assert bout.inflated.all()
    st_e2, _ = masked_step(cfg, init_emergency(C, dtype=np.float64),
                           rho_lv, bout.power_adj_w, mask, 1.0, np)
    np.testing.assert_array_equal(st_e2.pstate[:, CRIT_UF], 0)
    assert not st_e2.rapl.any()
    # NUF still does its share first — ballooning is the second rung,
    # not a bypass of the first
    assert (st_e2.pstate[:, CRIT_NUF] > 0).all()


def test_headroom_caps_grab_and_clear_deflates():
    cfg, bcfg = _cfg(), BallooningConfig(reclaim_frac=0.5)
    rho_lv = np.tile([60.0, 40.0], (C, 1))
    mem_nuf = np.full(C, 10.0)            # tiny: headroom binds
    hot = np.full(C, 2400.0)
    st = init_ballooning(C)
    st, _ = balloon_step(bcfg, cfg, st, rho_lv, hot, mem_nuf,
                         np.ones(C, bool), np)
    np.testing.assert_allclose(st.ballooned_gb, 0.5 * mem_nuf)
    assert total_ballooned_gb(st) == pytest.approx(0.5 * mem_nuf.sum())
    # a cool sample deflates the standing balloon completely
    cool = np.full(C, 500.0)
    st2, out2 = balloon_step(bcfg, cfg, st, rho_lv, cool, mem_nuf,
                             np.ones(C, bool), np)
    np.testing.assert_allclose(out2.released_gb, st.ballooned_gb)
    np.testing.assert_array_equal(st2.ballooned_gb, 0.0)
    assert not out2.inflated.any()


def test_unmasked_chassis_pass_through():
    cfg, bcfg = _cfg(), BallooningConfig()
    rho_lv, power, mem_nuf, _, standing = _scenario(11)
    mask = np.array([True, False, True, False])
    st = init_ballooning(C)._replace(ballooned_gb=standing.copy())
    st2, out = balloon_step(bcfg, cfg, st, rho_lv, power, mem_nuf,
                            mask, np)
    np.testing.assert_array_equal(st2.ballooned_gb[~mask],
                                  standing[~mask])
    np.testing.assert_array_equal(out.power_adj_w[~mask], power[~mask])
    np.testing.assert_array_equal(out.absorbed_w[~mask], 0.0)


def test_sim_ladder_beats_cap_migrate():
    """cap -> balloon -> migrate vs cap -> migrate on the same trace:
    identical alarms, strictly fewer critical throttled-seconds, no
    more migrations — and the serve scan asserts the jnp ballooning
    kernel bit-equal to the numpy oracle in-sim."""
    pol, ch = SchedulerPolicy(alpha=0.8), PredictionChannel("ml")
    kw = dict(days=0.1, seed=0, deployments_per_hour=16.0,
              prefill_core_ratio=0.6)
    ecfg = _cfg(dwell_s=120.0)
    base = simulate(pol, ch, SimSpec(emergency=ecfg, **kw))
    rung = simulate(pol, ch, SimSpec(emergency=ecfg,
                                     ballooning=BallooningConfig(),
                                     **kw))
    assert base.alarms == rung.alarms > 0
    assert rung.balloon_events > 0
    assert rung.balloon_reclaimed_gb > 0
    assert rung.uf_throttled_s < base.uf_throttled_s
    assert rung.migrations <= base.migrations
    assert base.balloon_events == 0
    # decisions (placements) are untouched — ballooning acts after
    # admission, on the power plane only
    for f in ("placements", "failures", "failure_rate"):
        assert getattr(base, f) == getattr(rung, f)
