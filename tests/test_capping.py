import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.capping import (LIFT_AFTER_S,
                                POLL_INTERVAL_S, ChassisManager,
                                PerVMController, RaplController,
                                ServerCapState)
from repro.core.power_model import (F_MAX, F_MIN, ServerPowerModel,
                                    dyn_scale, freq_power_curve)


def make(n_uf=20, n_nuf=20, budget=230.0):
    model = ServerPowerModel()
    state = ServerCapState(
        n_uf + n_nuf,
        np.concatenate([np.ones(n_uf, bool), np.zeros(n_nuf, bool)]))
    return model, state, PerVMController(model, budget)


def test_power_model_calibration():
    m = ServerPowerModel()
    assert m.power_uniform(0.0, 1.0) == pytest.approx(112.0)
    assert m.power_uniform(1.0, 1.0) == pytest.approx(310.0)
    assert m.power_uniform(0.0, 0.5) == pytest.approx(111.0)
    assert m.power_uniform(1.0, 0.5) == pytest.approx(169.0)


def test_freq_power_curve_monotone():
    freqs, watts = freq_power_curve(ServerPowerModel(), util=0.6)
    assert (np.diff(watts) < 0).all()          # descending freq table


def test_alert_drops_nuf_to_min_pstate():
    model, state, ctrl = make()
    util = np.concatenate([np.full(20, 0.6), np.ones(20)])
    ctrl.step(state, util, alert=True)
    assert state.capping
    assert (state.freq[20:] == F_MIN).all()
    assert (state.freq[:20] == F_MAX).all()    # UF untouched


def test_in_band_never_throttles_uf_cores():
    model, state, ctrl = make(budget=215.0)
    rng = np.random.default_rng(0)
    for _ in range(300):
        util = np.concatenate([rng.uniform(0.4, 1.0, 20), np.ones(20)])
        ctrl.step(state, util, alert=True)
        assert (state.freq[:20] == F_MAX).all()


def test_feedback_converges_below_target():
    model, state, ctrl = make(budget=240.0)
    util = np.concatenate([np.full(20, 0.55), np.ones(20)])
    p = None
    for _ in range(600):
        p = ctrl.step(state, util, alert=True)
    assert p < ctrl.target
    # and the controller recovered some NUF frequency from the floor
    assert state.freq[20:].max() > F_MIN


def test_cap_lifts_after_quiet_period():
    # power at (0.6 UF, 1.0 NUF) utils ~= 270 W > target 255 => capping
    model, state, ctrl = make(budget=260.0)
    util = np.concatenate([np.full(20, 0.6), np.ones(20)])
    ctrl.step(state, util, alert=True)
    assert state.capping
    # load drops; alert clears; capped power stays under the target
    util_low = np.concatenate([np.full(20, 0.3), np.full(20, 0.4)])
    quiet_steps = int(LIFT_AFTER_S / POLL_INTERVAL_S) + 2
    for _ in range(quiet_steps):
        ctrl.step(state, util_low, alert=False)
    assert not state.capping
    assert (state.freq == F_MAX).all()


def test_rapl_throttles_everything_as_backstop():
    model = ServerPowerModel()
    state = ServerCapState(40, np.ones(40, bool))   # all user-facing
    rapl = RaplController(model, 200.0)
    util = np.ones(40)
    p = model.power(util, state.freq)
    for _ in range(100):
        p = rapl.step(state, util)
    assert p <= 200.0 + 1e-6
    assert (state.freq < F_MAX).all()              # UF throttled too


@given(st.integers(0, 10_000))
def test_power_never_exceeds_budget_at_convergence(seed):
    rng = np.random.default_rng(seed)
    model, state, ctrl = make(budget=float(rng.uniform(215, 300)))
    rapl = RaplController(model, ctrl.budget)
    util = np.concatenate([rng.uniform(0.2, 0.9, 20), np.ones(20)])
    p = None
    for _ in range(200):
        p = ctrl.step(state, util, alert=True)
        if p > ctrl.budget:
            p = rapl.step(state, util)
    assert p <= ctrl.budget + 1e-6


def test_chassis_manager_threshold():
    mgr = ChassisManager(1000.0)
    assert not mgr.poll(900.0)
    assert mgr.poll(mgr.alert_threshold_w)
    assert mgr.poll(1000.0)


def test_dyn_scale_calibration_point():
    # paper: dynamic power at f/2 is (169-111)/(310-112) of max
    assert float(dyn_scale(0.5)) == pytest.approx(
        (169.0 - 111.0) / (310.0 - 112.0), abs=1e-9)


def test_chassis_manager_batched_poll_and_params():
    """The serve emergency plane polls every chassis at once and reads
    the thresholds as plain floats (batched-friendly params)."""
    mgr = ChassisManager(1860.0)
    np.testing.assert_array_equal(
        mgr.poll(np.array([1700.0, 1804.2, 1900.0])),
        [False, True, True])
    assert mgr.alert_w == mgr.alert_threshold_w
    assert mgr.target_w == pytest.approx(1855.0)


def test_reducible_fracs_monotone_and_calibrated():
    from repro.core.capping import reducible_fracs
    fr = reducible_fracs()
    assert fr[0] == 0.0
    assert (np.diff(fr) > 0).all()
    assert fr[-1] == pytest.approx(1.0 - float(dyn_scale(0.5)))


def test_apportion_watts_priority_cascade():
    """Lowest-criticality-first: level 0 absorbs the whole cut up to
    its floor before level 1 loses anything; a zero-draw level is
    skipped NaN-free; an unabsorbable remainder reports as leftover
    (the RAPL trigger), never silently vanishes."""
    from repro.core.capping import apportion_watts, reducible_fracs
    fr = reducible_fracs()
    floors = np.array([10, 5], np.int32)
    dyn = np.array([[100.0, 200.0]])
    small = 0.5 * 100.0 * fr[10]
    ps, take, left = apportion_watts(np.array([small]), dyn, floors, np)
    assert take[0, 1] == 0.0 and ps[0, 1] == 0 and left[0] == 0.0
    assert 0 < ps[0, 0] <= 10
    huge = 100.0 * fr[10] + 200.0 * fr[5] + 50.0
    ps, take, left = apportion_watts(np.array([huge]), dyn, floors, np)
    assert ps[0, 0] == 10 and ps[0, 1] == 5
    assert left[0] == pytest.approx(50.0)
    ps, take, left = apportion_watts(
        np.array([30.0]), np.array([[0.0, 0.0]]), floors, np)
    assert np.isfinite(take).all() and (ps == 0).all()
    assert left[0] == pytest.approx(30.0)
