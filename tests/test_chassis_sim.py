import pytest

from repro.sim.chassis_sim import (paper_chassis_specs,
                                   paper_single_server_spec,
                                   simulate_chassis, simulate_server)

DUR = 120.0     # short runs for CI; benchmarks use the full durations


@pytest.fixture(scope="module")
def nocap():
    return simulate_server(paper_single_server_spec(), None, "none",
                           duration_s=DUR, seed=3)


def test_caps_respected(nocap):
    for mode in ("rapl", "per_vm"):
        r = simulate_server(paper_single_server_spec(), 230.0, mode,
                            duration_s=DUR, seed=3)
        # after control convergence (RAPL steps 5%/poll), power stays
        # within the PSU alert margin of the cap; transient load spikes
        # between polls are what that margin exists for
        assert r.power_w[50:].max() <= 230.0 + 5.0


def test_per_vm_protects_uf_at_moderate_cap(nocap):
    r = simulate_server(paper_single_server_spec(), 230.0, "per_vm",
                        duration_s=DUR, seed=3)
    assert r.uf_p95_latency <= nocap.uf_p95_latency * 1.05


def test_full_server_hurts_uf(nocap):
    r = simulate_server(paper_single_server_spec(), 230.0, "rapl",
                        duration_s=DUR, seed=3)
    assert r.uf_p95_latency > nocap.uf_p95_latency * 1.15


def test_per_vm_costs_nuf_more_than_rapl(nocap):
    rv = simulate_server(paper_single_server_spec(), 230.0, "per_vm",
                         duration_s=DUR, seed=3)
    rr = simulate_server(paper_single_server_spec(), 230.0, "rapl",
                         duration_s=DUR, seed=3)
    assert rv.nuf_slowdown > rr.nuf_slowdown


def test_very_low_cap_forces_rapl_backup(nocap):
    r = simulate_server(paper_single_server_spec(), 210.0, "per_vm",
                        duration_s=DUR, seed=3)
    assert r.rapl_engaged_frac > 0.01
    assert r.uf_p95_latency > nocap.uf_p95_latency * 1.1


def test_balanced_placement_protects_uf():
    specs = paper_chassis_specs(balanced=True)
    nc = simulate_chassis(specs, None, "none", duration_s=DUR, seed=4)
    rv = simulate_chassis(specs, 2450.0, "per_vm", duration_s=DUR,
                          seed=4)
    assert rv.uf_p95_latency <= nc.uf_p95_latency * 1.05
    assert rv.power_w[25:].max() <= 2450.0 + 12.0


def test_imbalanced_placement_defeats_per_vm_capping():
    specs = paper_chassis_specs(balanced=False)
    nc = simulate_chassis(specs, None, "none", duration_s=DUR, seed=4)
    rv = simulate_chassis(specs, 2450.0, "per_vm", duration_s=DUR,
                          seed=4)
    assert rv.uf_p95_latency > nc.uf_p95_latency * 1.15
