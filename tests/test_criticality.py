import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import acf_score, fft_score, precision_at_recall
from repro.core.criticality import (COMPARE8_THRESHOLD, MIN_SAMPLES,
                                    classify, classify_with_length, score)
from repro.sim.telemetry import generate_population


@pytest.fixture(scope="module")
def pop():
    return generate_population(500, seed=11)


def test_diurnal_classified_user_facing(pop):
    s = jnp.asarray(pop.series)
    pred = np.asarray(classify(s))
    klass = pop.classes()
    uf_clean = pred[klass == "uf_diurnal"]
    assert uf_clean.mean() > 0.97


def test_flat_and_bursty_not_user_facing(pop):
    s = jnp.asarray(pop.series)
    pred = np.asarray(classify(s))
    klass = pop.classes()
    assert pred[klass == "batch_flat"].mean() < 0.3
    assert pred[klass == "dev_burst"].mean() < 0.2


def test_conservative_direction(pop):
    """False positives (NUF classified UF) are tolerable; false negatives
    are not: recall on true-UF must dominate."""
    s = jnp.asarray(pop.series)
    pred = np.asarray(classify(s))
    labels = pop.labels
    recall = (pred & labels).sum() / labels.sum()
    assert recall > 0.95


def test_beats_fft_and_acf_at_high_recall():
    """Table II direction: averaged over seeds, pattern-matching yields
    the highest precision at the 0.99-recall target (individual seeds
    can favor ACF on synthetic data; the benchmark reports per-seed)."""
    ours, fft, acf = [], [], []
    for seed in (1, 11, 23):
        p = generate_population(400, seed=seed)
        s = jnp.asarray(p.series)
        sc = score(s)
        ours.append(precision_at_recall(-np.asarray(sc.compare8),
                                        p.labels, 0.99)[0])
        fft.append(precision_at_recall(np.asarray(fft_score(s)),
                                       p.labels, 0.99)[0])
        acf.append(precision_at_recall(np.asarray(acf_score(s)),
                                       p.labels, 0.99)[0])
    assert np.mean(ours) > np.mean(fft)
    assert np.mean(ours) > np.mean(acf) - 0.02


def test_short_series_conservatively_user_facing(pop):
    s = jnp.asarray(pop.series[:8])
    n_valid = jnp.asarray([10, MIN_SAMPLES] * 4)
    out = np.asarray(classify_with_length(s, n_valid))
    assert out[0] and out[2] and out[4] and out[6]


def test_threshold_semantics(pop):
    s = jnp.asarray(pop.series[:32])
    sc = score(s)
    pred = np.asarray(sc.classify())
    np.testing.assert_array_equal(
        pred, np.asarray(sc.compare8) < COMPARE8_THRESHOLD)


def test_scores_finite_and_nonnegative(pop):
    sc = score(jnp.asarray(pop.series))
    for arr in (sc.compare8, sc.compare12, sc.dev24, sc.dev12, sc.dev8):
        a = np.asarray(arr)
        assert np.isfinite(a).all()
        assert (a >= 0).all()
