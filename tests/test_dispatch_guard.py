"""Dispatch-count guard for the fused emergency sweep (DESIGN.md §13).

The perf contract: a serve batch that carries queued cap windows costs
exactly the placement dispatch — the emergency sweep rides inside it
(`placement.place_batch_caps` unsharded, the `ecfg` home-round kernel
sharded) and the standalone cap kernels never run on the streamed
path. These tests count the module-level entry points so the sweep can
never silently regrow an extra dispatch."""
import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import ClusterState
from repro.core.predictor import train_service
from repro.serve import (EmergencyConfig, ServeConfig, ServePipeline,
                         ShardedServeConfig, ShardedServePipeline,
                         device_state)
from repro.serve import pipeline as pipeline_mod
from repro.serve import placement, sharding
from repro.serve.featurizer import table_from_history
from repro.sim.telemetry import arrival_batch, generate_population

BUDGET_TIGHT = 1480.0


def _loaded_state(seed=3, n_servers=48, per_chassis=12, cores=40,
                  n=260):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers)
                      // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0.2, 1)),
                     bool(rng.random() < 0.5))
    return st


@pytest.fixture(scope="module")
def guard_world():
    pop = generate_population(300, seed=1)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    return svc, hist, labels, arrivals


def _first_n(batch, n):
    return type(batch)(*(getattr(batch, f)[:n]
                         for f in type(batch).__dataclass_fields__))


def _cfg():
    return EmergencyConfig.from_model(BUDGET_TIGHT)


def test_unsharded_sweep_rides_placement_dispatch(guard_world,
                                                  monkeypatch):
    svc, hist, labels, arrivals = guard_world
    cap = max(v.subscription for v in hist.vms) + 8
    pipe = ServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(_loaded_state()), cores_per_server=40,
        blades_per_chassis=12, config=ServeConfig(batch_size=32),
        emergency_cfg=_cfg())
    calls = {"fused": 0, "plain": 0, "standalone": 0}
    real_fused = placement.place_batch_caps
    real_plain = placement.place_batch
    real_standalone = pipeline_mod._cap_step_fn
    monkeypatch.setattr(
        placement, "place_batch_caps",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    monkeypatch.setattr(
        placement, "place_batch",
        lambda *a, **k: (calls.__setitem__("plain", calls["plain"] + 1),
                         real_plain(*a, **k))[1])
    monkeypatch.setattr(
        pipeline_mod, "_cap_step_fn",
        lambda cfg: (calls.__setitem__("standalone",
                                       calls["standalone"] + 1),
                     real_standalone(cfg))[1])
    # one full emergency sweep (4 unique chassis -> 1 window) ...
    pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    # ... then one full micro-batch of arrivals
    out = pipe.submit_to(0, _first_n(arrival_batch(arrivals), 32),
                         t=np.arange(32, dtype=np.float64) + 10.0)
    assert len(out) == 1
    # fused budget: the sweep + batch is ONE placement dispatch
    assert calls["fused"] == 1
    assert calls["plain"] == 0
    assert calls["standalone"] == 0
    assert pipe.alarms >= 1                  # the sweep really applied
    assert calls["standalone"] == 0          # ... without a flush


def test_sharded_sweep_rides_home_round(guard_world, monkeypatch):
    svc, hist, labels, arrivals = guard_world
    cap = max(v.subscription for v in hist.vms) + 8
    pipe = ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(_loaded_state()), cores_per_server=40,
        blades_per_chassis=12,
        config=ShardedServeConfig(batch_size=32, n_shards=4),
        emergency_cfg=_cfg())
    counts = {"rounds": 0, "fused_rounds": 0, "standalone": 0}
    real_round = sharding._round_fn
    real_caps = sharding.apply_caps_sharded

    def counting_round(policy, cps, mesh, ecfg=None):
        fn = real_round(policy, cps, mesh, ecfg)

        def wrapped(*a, **k):
            counts["rounds"] += 1
            counts["fused_rounds"] += ecfg is not None
            return fn(*a, **k)
        return wrapped

    monkeypatch.setattr(sharding, "_round_fn", counting_round)
    monkeypatch.setattr(
        sharding, "apply_caps_sharded",
        lambda *a, **k: (counts.__setitem__(
            "standalone", counts["standalone"] + 1),
            real_caps(*a, **k))[1])
    pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    out = pipe.submit_to(0, _first_n(arrival_batch(arrivals), 32),
                         t=np.arange(32, dtype=np.float64) + 10.0)
    assert len(out) == 1
    # fused budget: one home round carrying the sweep, zero standalone
    # cap dispatches; spill rounds only if the home round rejected
    assert counts["fused_rounds"] == 1
    assert counts["rounds"] <= 1 + pipe.spill_info["rounds"]
    assert counts["standalone"] == 0
    assert pipe.alarms >= 1
    assert counts["standalone"] == 0
