"""Dispatch-count guard for the fused emergency sweep (DESIGN.md §13).

The perf contract: a serve batch that carries queued cap windows costs
exactly the placement dispatch — the emergency sweep rides inside it
(`placement.place_batch_caps` unsharded, the `ecfg` home-round kernel
sharded) and the standalone cap kernels never run on the streamed
path. These tests assert it through the first-class dispatch counters
(`serve_dispatch_total{kind=...}` in `repro.obs.MetricsRegistry`,
incremented at the true call sites) instead of the old monkeypatch
wrappers, so the invariant is checked against the same instrumentation
operators scrape."""
import numpy as np
import pytest

from repro.core import features as F
from repro.core.placement import ClusterState
from repro.core.predictor import train_service
from repro.obs import Observability
from repro.serve import (EmergencyConfig, PlaneBundle, ServeConfig,
                         ServePipeline,
                         ShardedServeConfig, ShardedServePipeline,
                         device_state)
from repro.serve.featurizer import table_from_history
from repro.sim.telemetry import arrival_batch, generate_population

BUDGET_TIGHT = 1480.0


def _loaded_state(seed=3, n_servers=48, per_chassis=12, cores=40,
                  n=260):
    rng = np.random.default_rng(seed)
    st = ClusterState(n_servers=n_servers, cores_per_server=cores,
                      chassis_of_server=np.arange(n_servers)
                      // per_chassis,
                      n_chassis=n_servers // per_chassis)
    for _ in range(n):
        srv = int(rng.integers(0, n_servers))
        c = int(rng.integers(1, 8))
        if st.free_cores[srv] >= c:
            st.place(srv, c, float(rng.uniform(0.2, 1)),
                     bool(rng.random() < 0.5))
    return st


@pytest.fixture(scope="module")
def guard_world():
    pop = generate_population(300, seed=1)
    hist, arrivals = F.split_history_arrivals(pop)
    labels = hist.labels.astype(np.float64)
    aggs = F.subscription_aggregates(hist, labels)
    svc = train_service(F.build_features(hist, aggs),
                        labels.astype(np.int64),
                        F.p95_bucket([v.p95_util for v in hist.vms]),
                        n_trees=12)
    return svc, hist, labels, arrivals


def _first_n(batch, n):
    return type(batch)(*(getattr(batch, f)[:n]
                         for f in type(batch).__dataclass_fields__))


def _cfg():
    return EmergencyConfig.from_model(BUDGET_TIGHT)


def _dispatches(obs):
    v = obs.registry.value
    return {kind: v("serve_dispatch_total", kind=kind)
            for kind in ("place_batch_caps", "place_batch", "cap_step",
                         "sharded_round_caps", "sharded_round",
                         "caps_sharded")}


def test_unsharded_sweep_rides_placement_dispatch(guard_world):
    svc, hist, labels, arrivals = guard_world
    cap = max(v.subscription for v in hist.vms) + 8
    obs = Observability()
    pipe = ServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(_loaded_state()), cores_per_server=40,
        blades_per_chassis=12,
        config=ServeConfig(batch_size=32,
                           planes=PlaneBundle(emergency=_cfg(),
                                              obs=obs)))
    # one full emergency sweep (4 unique chassis -> 1 window) ...
    pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    # ... then one full micro-batch of arrivals
    out = pipe.submit_to(0, _first_n(arrival_batch(arrivals), 32),
                         t=np.arange(32, dtype=np.float64) + 10.0)
    assert len(out) == 1
    d = _dispatches(obs)
    # fused budget: the sweep + batch is ONE placement dispatch
    assert d["place_batch_caps"] == 1
    assert d["place_batch"] == 0
    assert d["cap_step"] == 0
    assert pipe.alarms >= 1                  # the sweep really applied
    # reading `alarms` flushes the (now empty) queue — still no
    # standalone cap dispatch
    assert _dispatches(obs)["cap_step"] == 0
    # and the sweep's in-scan counters surfaced through the registry
    assert obs.registry.value("emergency_alarms_total") == pipe.alarms
    assert obs.registry.value("emergency_cap_windows_total") == 1


def test_unsharded_standalone_flush_is_counted(guard_world):
    """A cap window with no batch to ride (an `emergency` read forces
    the flush) takes exactly one standalone cap-step dispatch."""
    svc, hist, labels, arrivals = guard_world
    cap = max(v.subscription for v in hist.vms) + 8
    obs = Observability()
    pipe = ServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(_loaded_state()), cores_per_server=40,
        blades_per_chassis=12,
        config=ServeConfig(batch_size=32,
                           planes=PlaneBundle(emergency=_cfg(),
                                              obs=obs)))
    pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    assert pipe.alarms >= 1                  # property read -> flush
    d = _dispatches(obs)
    assert d["cap_step"] == 1
    assert d["place_batch_caps"] == 0
    assert obs.registry.value("emergency_cap_windows_total") == 1
    assert obs.registry.value("emergency_samples_total") == 4


def test_sharded_sweep_rides_home_round(guard_world):
    svc, hist, labels, arrivals = guard_world
    cap = max(v.subscription for v in hist.vms) + 8
    obs = Observability()
    pipe = ShardedServePipeline(
        svc, table_from_history(hist, labels, cap),
        device_state(_loaded_state()), cores_per_server=40,
        blades_per_chassis=12,
        config=ShardedServeConfig(batch_size=32, n_shards=4,
                                  planes=PlaneBundle(emergency=_cfg(),
                                                     obs=obs)))
    pipe.cap_to(0, [0, 1, 2, 3], [2200.0] * 4,
                t=np.array([1.0, 2.0, 3.0, 4.0]))
    out = pipe.submit_to(0, _first_n(arrival_batch(arrivals), 32),
                         t=np.arange(32, dtype=np.float64) + 10.0)
    assert len(out) == 1
    d = _dispatches(obs)
    # fused budget: one home round carrying the sweep, zero standalone
    # cap dispatches; spill rounds only if the home round rejected
    assert d["sharded_round_caps"] == 1
    assert d["sharded_round"] == pipe.spill_info["rounds"] - 1
    assert d["caps_sharded"] == 0
    assert pipe.alarms >= 1
    assert _dispatches(obs)["caps_sharded"] == 0
    assert obs.registry.value("emergency_alarms_total") == pipe.alarms
