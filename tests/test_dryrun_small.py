"""Sharding/dry-run machinery on a small fake-device mesh, run in a
subprocess (device count must be set before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch import sharding as shd
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import step_for_shape

out = {}
for multi_pod in (False, True):
    mesh = make_debug_mesh(2, 2, multi_pod=multi_pod)
    for arch in ("llama3-8b", "mamba2-2.7b", "mixtral-8x22b"):
        cfg = get_config(arch).reduced()
        shape = ShapeConfig("t", 64, 8, "train")
        strat = shd.make_strategy("fsdp2d", mesh)
        step, args, names = step_for_shape(cfg, shape, impl="naive",
                                           n_data=2)
        shards = []
        for name, arg in zip(names, args):
            if name == "params":
                shards.append(shd.param_shardings(strat, mesh, arg))
            elif name == "opt_state":
                shards.append(shd.opt_shardings(strat, mesh, arg))
            else:
                shards.append(shd.batch_shardings(strat, mesh, arg))
        with shd.use_strategy(strat, mesh), mesh:
            compiled = jax.jit(step, in_shardings=tuple(shards)) \
                .lower(*args).compile()
            mem = compiled.memory_analysis()
        key = f"{arch}|pod{2 if multi_pod else 1}"
        out[key] = {"temp": mem.temp_size_in_bytes,
                    "args": mem.argument_size_in_bytes}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_debug_mesh_dryrun_compiles():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    assert len(out) == 6
    for key, rec in out.items():
        assert rec["args"] > 0


@pytest.mark.slow
def test_production_dryrun_artifacts_if_present():
    """If the full 512-device sweep has produced artifacts, validate
    their invariants (every cell ok or an allowed skip)."""
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
    if not os.path.isdir(art) or len(os.listdir(art)) < 10:
        pytest.skip("full dry-run artifacts not present")
    bad = []
    for name in os.listdir(art):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(art, name)) as f:
            rec = json.load(f)
        if rec["status"] == "error":
            bad.append((name, rec.get("error")))
        elif rec["status"] == "skipped":
            assert rec["shape"] == "long_500k"
    assert not bad, bad
