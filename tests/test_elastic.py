"""Direct unit coverage of `repro.runtime.elastic`.

Elastic events (scale-up/scale-down) are: same logical run, new mesh.
`reshard_plan` derives the before/after shardings from one Strategy so
an audit can show exactly which axes move, and `elastic_restore` loads
the newest gathered checkpoint with the new placement. Tests run on
ONE real device — meshes of shape (1, 1) keep every strategy spec
intact (all mesh axes divide), while a mesh missing an axis exercises
the drop-to-replicated fallback a real topology change can hit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import Checkpointer
from repro.runtime.elastic import elastic_restore, reshard_plan


def _mesh(*axes):
    dev = np.array(jax.devices()[:1]).reshape((1,) * len(axes))
    return Mesh(dev, axes)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


PARAMS_SHAPE = {
    "layers": {"attn": {"wq": {"w": _sds((64, 128))}},
               "mlp": {"up": {"w": _sds((64, 256)),
                              "b": _sds((256,))}}},
    "embed": {"w": _sds((512, 64))},
}


def test_reshard_plan_same_strategy_same_axes_is_stable():
    old, new = reshard_plan("fsdp2d", _mesh("data", "model"),
                            _mesh("data", "model"), PARAMS_SHAPE)
    for tree in (old, new):
        assert tree["layers"]["attn"]["wq"]["w"].spec == \
            P("data", "model")
        assert tree["embed"]["w"].spec == P("model", "data")
        assert tree["layers"]["mlp"]["up"]["b"].is_fully_replicated
    # every leaf is a placeable NamedSharding, matching the tree
    assert jax.tree.structure(old) == jax.tree.structure(PARAMS_SHAPE)
    assert all(isinstance(s, NamedSharding)
               for s in jax.tree.leaves(old))


def test_reshard_plan_across_strategies_and_serve_handoff():
    """The train->serve handoff: fsdp2d rows over 'data', tp_serve
    drops the row sharding so decode never re-gathers weights."""
    mesh = _mesh("data", "model")
    old, _ = reshard_plan("fsdp2d", mesh, mesh, PARAMS_SHAPE)
    new, _ = reshard_plan("tp_serve", mesh, mesh, PARAMS_SHAPE)
    assert old["layers"]["attn"]["wq"]["w"].spec == P("data", "model")
    assert new["layers"]["attn"]["wq"]["w"].spec == P(None, "model")
    assert new["embed"]["w"].spec == P("model", None)


def test_reshard_plan_axis_loss_falls_back_to_replication():
    """Scaling down to a mesh without the 'model' axis must not
    produce unplaceable specs: non-resolvable axes drop away."""
    old, new = reshard_plan("fsdp2d", _mesh("data", "model"),
                            _mesh("data"), PARAMS_SHAPE)
    assert old["layers"]["attn"]["wq"]["w"].spec == P("data", "model")
    assert all(s.is_fully_replicated for s in jax.tree.leaves(new))


def test_reshard_plan_unknown_strategy_raises():
    mesh = _mesh("data", "model")
    with pytest.raises(KeyError):
        reshard_plan("nope", mesh, mesh, PARAMS_SHAPE)


def _params():
    rng = np.random.default_rng(0)
    return {
        "enc": {"wq": {"w": jnp.asarray(
            rng.normal(size=(8, 4)).astype(np.float32))},
            "b": jnp.asarray(np.arange(4, dtype=np.float32))},
        "half": jnp.asarray(
            rng.normal(size=(4, 4)).astype(np.float32)
        ).astype(jnp.bfloat16),
    }


def test_elastic_restore_round_trips_onto_new_mesh(tmp_path):
    """Save gathered, restore elastically: exact values (bfloat16
    included) land with the new mesh's shardings attached."""
    ck = Checkpointer(str(tmp_path))
    params = _params()
    ck.save(3, params)
    restored, step = elastic_restore(ck, params, "fsdp2d",
                                     _mesh("data", "model"))
    assert step == 3
    assert restored["enc"]["wq"]["w"].sharding.spec == \
        P("data", "model")
    assert restored["enc"]["b"].sharding.is_fully_replicated
    for got, want in zip(jax.tree.leaves(restored),
                         jax.tree.leaves(params)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))


def test_elastic_restore_takes_newest_step_and_new_strategy(tmp_path):
    """A second elastic event may also switch strategy (train mesh ->
    serve mesh); the newest commit wins regardless."""
    ck = Checkpointer(str(tmp_path))
    stale, fresh = _params(), _params()
    fresh["enc"]["b"] = fresh["enc"]["b"] + 100.0
    ck.save(1, stale)
    ck.save(2, fresh)
    restored, step = elastic_restore(ck, fresh, "tp_serve",
                                     _mesh("data", "model"))
    assert step == 2
    assert restored["enc"]["wq"]["w"].sharding.spec == P(None, "model")
    np.testing.assert_array_equal(np.asarray(restored["enc"]["b"]),
                                  np.asarray(fresh["enc"]["b"]))
