"""Direct unit coverage of `repro.runtime.fault_tolerance`.

The module is dormant on the serve path today (ROADMAP gap); these
tests pin its observable behavior — the restore-retry loop, restart
exhaustion, the pre-commit rewind, the straggler rolling deadline
(the loop's heartbeat), and the fact that only the chaos channel
(`InjectedFailure`) is retried while real exceptions propagate —
so later PRs can wire it into ingest against a fixed contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime.fault_tolerance import (FaultToleranceConfig,
                                           FaultTolerantLoop,
                                           InjectedFailure, RunState)


def _loop(tmp_path, **kw):
    kw.setdefault("checkpoint_every", 5)
    return FaultTolerantLoop(FaultToleranceConfig(**kw),
                             Checkpointer(str(tmp_path)))


def _counting(state, batch):
    return {"x": state["x"] + batch}, float(state["x"])


# --- restore-retry --------------------------------------------------------

def test_injected_failure_from_step_fn_restores_and_retries(tmp_path):
    """A step_fn raising the chaos exception rewinds to the newest
    commit and the run still converges to the exact final state."""
    loop = _loop(tmp_path)
    fails = {7: True, 12: True}

    def step_fn(state, batch):
        if fails.pop(int(state["x"]), False):
            raise InjectedFailure("chaos")
        return _counting(state, batch)

    state, history = loop.run({"x": jnp.asarray(0.0)}, step_fn,
                              lambda s: 1.0, n_steps=20)
    assert float(state["x"]) == 20.0
    assert loop.state.restarts == 2
    # the replayed steps re-run: history is longer than n_steps
    assert len(history) > 20


def test_failure_before_first_commit_rewinds_to_snapshot(tmp_path):
    """With no committed checkpoint yet, restore falls back to the
    pre-loop snapshot and start_step — no stale state leaks in."""
    loop = _loop(tmp_path, checkpoint_every=100)
    seen = []

    def step_fn(state, batch):
        seen.append(float(state["x"]))
        if len(seen) == 3:
            raise InjectedFailure("early")
        return _counting(state, batch)

    state, _ = loop.run({"x": jnp.asarray(5.0)}, step_fn,
                        lambda s: 1.0, n_steps=4)
    assert loop.state.restarts == 1
    assert float(state["x"]) == 9.0          # 5 + 4, replayed from 5
    assert seen[3] == 5.0                    # rewound to the snapshot


def test_restart_exhaustion_reraises(tmp_path):
    """max_restarts bounds the retry loop: one more chaos failure
    than allowed escapes to the caller."""
    loop = _loop(tmp_path, max_restarts=3)

    def step_fn(state, batch):
        raise InjectedFailure("always")

    with pytest.raises(InjectedFailure):
        loop.run({"x": jnp.asarray(0.0)}, step_fn, lambda s: 1.0,
                 n_steps=5)
    assert loop.state.restarts == 4          # 3 retries + the fatal one


def test_real_exception_propagates_without_retry(tmp_path):
    """Only the chaos channel is retried: a genuine defect in step_fn
    must fail the job loudly, untouched by the restore loop."""
    loop = _loop(tmp_path)
    calls = []

    def step_fn(state, batch):
        calls.append(1)
        raise ValueError("real bug")

    with pytest.raises(ValueError, match="real bug"):
        loop.run({"x": jnp.asarray(0.0)}, step_fn, lambda s: 1.0,
                 n_steps=5)
    assert len(calls) == 1                   # no retry happened
    assert loop.state.restarts == 0


def test_injection_rate_draws_from_seeded_rng(tmp_path):
    """The loop's own chaos channel: a high injection rate produces
    restarts deterministically for a fixed seed, and the run still
    lands on the exact final state."""
    cfg = FaultToleranceConfig(checkpoint_every=4,
                               inject_failure_rate=0.3)
    loop = FaultTolerantLoop(cfg, Checkpointer(str(tmp_path)),
                             rng_seed=7)
    state, _ = loop.run({"x": jnp.asarray(0.0)}, _counting,
                        lambda s: 1.0, n_steps=16)
    assert loop.state.restarts > 0
    assert float(state["x"]) == 16.0


# --- checkpoint cadence / resume ------------------------------------------

def test_checkpoints_commit_on_cadence(tmp_path):
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(FaultToleranceConfig(checkpoint_every=4),
                             ck)
    loop.run({"x": jnp.asarray(0.0)}, _counting, lambda s: 1.0,
             n_steps=10)
    assert ck.latest_step() == 8             # 4 and 8 committed, not 10


def test_resume_or_init_cold_and_warm(tmp_path):
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(FaultToleranceConfig(), ck)
    state, start = loop.resume_or_init(lambda: {"x": jnp.asarray(1.0)})
    assert start == 0 and float(state["x"]) == 1.0
    ck.save(6, {"x": jnp.asarray(42.0)})
    state, start = loop.resume_or_init(lambda: {"x": jnp.asarray(1.0)})
    assert start == 6 and float(state["x"]) == 42.0


# --- straggler rolling deadline (the loop's heartbeat) --------------------

def test_straggler_deadline_fires_after_patience():
    """Steps slower than factor x rolling median for `patience`
    consecutive beats expire the deadline: mitigation fires and the
    counter rearms."""
    loop = FaultTolerantLoop(
        FaultToleranceConfig(straggler_factor=2.0,
                             straggler_patience=3),
        Checkpointer.__new__(Checkpointer))   # never touched here
    hits = []
    loop.on_straggler = lambda s: hits.append(s.mitigations)
    for dt in [0.1] * 10:
        loop._track_straggler(dt)
        loop.state.step_times.append(dt)
    for dt in [0.5] * 6:
        loop._track_straggler(dt)
        loop.state.step_times.append(dt)
    assert loop.state.mitigations >= 1
    assert hits                               # callback saw each expiry


def test_fast_step_rearms_the_straggler_counter():
    """A single on-deadline beat resets patience — intermittent slow
    steps (capping-induced) never accumulate into a mitigation."""
    loop = FaultTolerantLoop(
        FaultToleranceConfig(straggler_factor=2.0,
                             straggler_patience=2),
        Checkpointer.__new__(Checkpointer))
    for dt in [0.1] * 10:
        loop._track_straggler(dt)
        loop.state.step_times.append(dt)
    for dt in [0.5, 0.1] * 4:                 # never 2 slow in a row
        loop._track_straggler(dt)
        loop.state.step_times.append(dt)
    assert loop.state.mitigations == 0
    assert loop.state.straggler_steps == 0


def test_no_deadline_before_any_history():
    """median of an empty window is +inf: the first beats can never
    expire the deadline, however slow."""
    loop = FaultTolerantLoop(
        FaultToleranceConfig(straggler_factor=2.0,
                             straggler_patience=1),
        Checkpointer.__new__(Checkpointer))
    loop._track_straggler(999.0)
    assert loop.state.mitigations == 0
    assert RunState().median_step_time() == float("inf")


def test_median_uses_trailing_window():
    st = RunState(step_times=[0.1] * 50 + [1.0] * 50)
    assert st.median_step_time() == pytest.approx(1.0)
    assert np.isfinite(st.median_step_time())
