"""Fleet engine acceptance: the scan/vmap-compiled jnp engine matches
the numpy oracle on the seed's Fig 4-6 configurations (per-step chassis
power, min NUF frequency, RAPL-engaged fraction), sweeps vmap cleanly,
and padding rules hold."""
import numpy as np
import pytest

from repro.core.power_model import F_MAX, F_MIN
from repro.sim.chassis_sim import (paper_chassis_specs,
                                   paper_single_server_spec)
from repro.sim.fleet import (ServerSpec, VMSpec, build_layout,
                             fmin_to_pstate, frontier, run_fleet,
                             run_fleet_layouts, stack_layouts,
                             sweep_scenarios)

DUR = 60.0          # 300 steps: long enough to cover cap/lift episodes

POWER_TOL_W = 0.5           # per-step chassis power agreement
RAPL_FRAC_TOL = 0.01
FIG45 = [(230.0, "per_vm"), (230.0, "rapl"), (210.0, "per_vm")]


def _parity(specs, budget, mode, seed):
    a = run_fleet(specs, budget, mode, DUR, seed, backend="numpy")
    b = run_fleet(specs, budget, mode, DUR, seed, backend="jax")
    np.testing.assert_allclose(a.power_w, b.power_w, atol=POWER_TOL_W)
    np.testing.assert_allclose(a.min_nuf_freq, b.min_nuf_freq,
                               atol=1e-5)
    assert np.abs(a.rapl_engaged_frac
                  - b.rapl_engaged_frac).max() <= RAPL_FRAC_TOL
    np.testing.assert_allclose(a.uf_p95_latency, b.uf_p95_latency,
                               rtol=1e-3)
    np.testing.assert_allclose(a.nuf_slowdown, b.nuf_slowdown,
                               rtol=1e-3)


@pytest.mark.parametrize("budget,mode", FIG45)
def test_fig4_5_single_server_parity(budget, mode):
    _parity([paper_single_server_spec()], budget, mode, seed=3)


@pytest.mark.parametrize("balanced", [True, False])
def test_fig6_chassis_parity(balanced):
    _parity(paper_chassis_specs(balanced), 2450.0, "per_vm", seed=4)


def test_budget_batch_matches_individual_runs():
    """A vmapped cap grid produces exactly the per-budget runs."""
    specs = [paper_single_server_spec()]
    batch = run_fleet(specs, [250.0, 230.0, 210.0], "per_vm", DUR,
                      seed=3, backend="jax")
    for i, cap in enumerate((250.0, 230.0, 210.0)):
        single = run_fleet(specs, cap, "per_vm", DUR, seed=3,
                           backend="jax")
        np.testing.assert_allclose(batch.power_w[i], single.power_w[0],
                                   atol=1e-3)
        assert batch.rapl_engaged_frac[i] == pytest.approx(
            single.rapl_engaged_frac[0], abs=1e-9)


def test_heterogeneous_layouts_parity():
    """Chassis with different VM placements batch via stacked layout
    arrays; jnp matches the oracle per chassis."""
    chassis = [
        [ServerSpec(vms=[VMSpec(8, True, load=0.7),
                         VMSpec(24, False)]) for _ in range(3)],
        [ServerSpec(vms=[VMSpec(4, True, load=0.9)] * 2
                    + [VMSpec(10, False)]) for _ in range(3)],
    ]
    layouts = [build_layout(sp, pad_uf_to=6, pad_nuf_to=3)
               for sp in chassis]
    la = stack_layouts(layouts)
    n_steps = int(DUR / 0.2)
    from repro.sim.fleet import build_uf_traces
    traces = np.stack([build_uf_traces(lo, n_steps, seed=9 + i)
                       for i, lo in enumerate(layouts)])
    kw = dict(budgets_w=np.full(2, 620.0), mode="per_vm", traces=traces)
    uf_v = np.stack([lo.uf_valid for lo in layouts])
    nuf_v = np.stack([lo.nuf_valid for lo in layouts])
    nuf_c = np.stack([lo.nuf_cores for lo in layouts])
    a = run_fleet_layouts(la, uf_v, nuf_v, nuf_c, backend="numpy", **kw)
    b = run_fleet_layouts(la, uf_v, nuf_v, nuf_c, backend="jax", **kw)
    np.testing.assert_allclose(a.power_w, b.power_w, atol=POWER_TOL_W)
    np.testing.assert_allclose(a.uf_p95_latency, b.uf_p95_latency,
                               rtol=1e-3)
    assert np.abs(a.rapl_engaged_frac
                  - b.rapl_engaged_frac).max() <= RAPL_FRAC_TOL


def test_fleet_step_direct_batched_scalars():
    """fleet_step honors the documented contract without vmap: batch
    dims (B,) on the run scalars against (B, S, C) state."""
    from repro.core.fleet_dynamics import (ControlParams, RunParams,
                                           fleet_step, init_state)
    B, S, C = 3, 2, 8
    cp = ControlParams(mode="per_vm")
    uf = np.zeros((S, C), bool)
    uf[:, :4] = True
    budgets = np.array([200.0, 120.0, 90.0], np.float32)
    rp = RunParams(budgets, budgets - 5.0, budgets * 2 * 0.97,
                   np.full(B, 10, np.int32), uf, None)
    st = init_state((B,), S, C)
    util = np.ones((B, S, C), np.float32)
    for _ in range(30):
        st, outs = fleet_step(cp, rp, st, util, np)
    assert outs.server_power_w.shape == (B, S)
    # tighter per-server budgets throttle more
    assert st.freq[2].mean() < st.freq[0].mean()
    # generous chassis 0: in-band only, UF untouched; starved chassis
    # 2 trips the RAPL backstop, which throttles UF cores too
    assert (st.freq[0, :, :4] == 1.0).all()
    assert outs.rapl[2].all() and not outs.rapl[0].any()
    assert (st.freq[2, :, :4] < 1.0).all()


def test_stack_layouts_mixed_core_padding():
    """Batching a padded-core chassis with a fully-active one must keep
    the real active masks (not inherit the first layout's None)."""
    a = build_layout([ServerSpec(vms=[VMSpec(4, True), VMSpec(8, False)],
                                 n_cores=16)], pad_uf_to=1, pad_nuf_to=1,
                     pad_cores_to=24)
    b = build_layout([ServerSpec(vms=[VMSpec(4, True), VMSpec(8, False)],
                                 n_cores=24)], pad_uf_to=1, pad_nuf_to=1)
    for layouts in ([a, b], [b, a]):
        la = stack_layouts(layouts)
        assert la.active is not None
        assert la.active.shape == (2, 1, 24)
        assert {int(m.sum()) for m in la.active} == {16, 24}
    full = stack_layouts([b, b])
    assert full.active is None                   # all-active collapses


def test_core_padding_is_inert():
    """Padding the core axis must not change any metric: padded cores
    are excluded from power sums, frequency means, and app models."""
    specs = [paper_single_server_spec()]
    plain = build_layout(specs)
    padded = build_layout(specs, pad_cores_to=48)
    assert padded.active.sum() == plain.active.sum() == 40
    a = run_fleet(specs, 230.0, "per_vm", DUR, 3, backend="numpy",
                  layout=plain)
    b = run_fleet(specs, 230.0, "per_vm", DUR, 3, backend="numpy",
                  layout=padded)
    np.testing.assert_allclose(a.power_w, b.power_w, atol=1e-3)
    np.testing.assert_allclose(a.min_nuf_freq, b.min_nuf_freq, atol=0)
    assert a.uf_p95_latency[0] == pytest.approx(b.uf_p95_latency[0],
                                                rel=1e-6)


def test_sweep_scenarios_grid_and_frontier():
    """One compiled call over (budget x load x NUF-floor); uncapped
    baseline rides along; the frontier is sane."""
    specs = [paper_single_server_spec()]
    sw = sweep_scenarios(specs, [250.0, 230.0, 210.0],
                         load_scales=(1.0, 0.8), fmin_nuf=(0.5, 0.75),
                         duration_s=DUR, seed=3)
    assert sw["uf_p95_latency"].shape == (4, 2, 2)   # +1 uncapped row
    assert np.isinf(sw["budgets_w"][0])
    # uncapped row has unit latency ratio and never engages RAPL
    np.testing.assert_allclose(sw["uf_latency_ratio"][0], 1.0)
    assert sw["rapl_engaged_frac"][0].max() == 0.0
    # a shallower NUF floor (0.75) can shed less power, so RAPL engages
    # at least as often as with the deep floor at the tightest cap
    assert sw["rapl_engaged_frac"][3, 0, 1] >= \
        sw["rapl_engaged_frac"][3, 0, 0] - 1e-9
    fr = frontier(sw, provisioned_w=310.0, max_uf_latency_ratio=1.10,
                  max_rapl_frac=0.05)
    assert fr["budget_w"].shape == (2, 2)
    # lighter load can only improve (or keep) the recovered fraction
    assert (fr["oversubscription"][1] >=
            fr["oversubscription"][0] - 1e-9).all()
    assert fmin_to_pstate(F_MIN) == 10 and fmin_to_pstate(F_MAX) == 0
